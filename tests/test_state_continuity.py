"""Prefill -> decode state-cache continuity for recurrent/hybrid archs.

For attention archs, continuity is covered by
test_decode_matches_prefill_continuation; here the recurrent state handoff
(RWKV wkv + token-shift, Mamba conv buffer + ssm state) is validated:
prefilling N tokens and decoding token N+1 must match a full (N+1)-prefill's
final-position logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models.model_zoo import build

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "jamba-1.5-large-398b"])
def test_recurrent_prefill_decode_continuity(arch):
    cfg = smoke_variant(get_config(arch))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    N = 16
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (1, N + 1)), jnp.int32)

    full_logits, _ = model.prefill(params, {"tokens": toks}, chunked=False)

    l16, cache = model.prefill(params, {"tokens": toks[:, :N]}, chunked=False)
    if arch != "rwkv6-1.6b":
        # grow attention cache seq dim by one slot for the decoded token
        def grow(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            if name == "pos":
                return jnp.pad(leaf, [(0, 0)] * (leaf.ndim - 1) + [(0, 1)],
                               constant_values=-1)
            if name in ("k", "v") and leaf.ndim == 5 and leaf.shape[2] == N:
                return jnp.pad(leaf, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])
            return leaf
        cache = jax.tree_util.tree_map_with_path(grow, cache)
    dec_logits, _ = model.decode_step(params, cache, toks[:, N:N + 1],
                                      jnp.int32(N))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               atol=5e-3, rtol=5e-2)


def test_whisper_prefill_decode_continuity():
    """Enc-dec: self-attn KV + cross-attn KV carried through decode."""
    cfg = smoke_variant(get_config("whisper-base"))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(5))
    N = 12
    frames = jnp.asarray(RNG.normal(size=(1, cfg.encoder_seq, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (1, N + 1)), jnp.int32)

    full_logits, _ = model.prefill(params, {"frames": frames,
                                            "tokens": toks})
    _, cache = model.prefill(params, {"frames": frames,
                                      "tokens": toks[:, :N]})

    def grow(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "pos":
            return jnp.pad(leaf, [(0, 0)] * (leaf.ndim - 1) + [(0, 1)],
                           constant_values=-1)
        if name in ("k", "v") and leaf.shape[2] == N:
            return jnp.pad(leaf, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])
        return leaf
    cache = jax.tree_util.tree_map_with_path(grow, cache)
    dec_logits, _ = model.decode_step(params, cache, toks[:, N:N + 1],
                                      jnp.int32(N))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               atol=5e-3, rtol=5e-2)


def test_rwkv_chunked_prefill_state_matches_naive():
    cfg = smoke_variant(get_config("rwkv6-1.6b"))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(4))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 128)), jnp.int32)
    _, c1 = model.prefill(params, {"tokens": toks}, chunked=True)
    _, c2 = model.prefill(params, {"tokens": toks}, chunked=False)
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)
