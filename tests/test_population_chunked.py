"""Chunked population state (repro.hier.population).

The tier's bit-equality contract: the chunked solver and the chunked
trace generator return identical results for EVERY block size — the
single-block call IS the dense one-shot path — and the stacked-array
deployment is value-identical to the flat engine's node objects.
Chunk boundaries are probed one-below/at/one-above the solver's
DEFAULT_BLOCK-style widths and the trace's fixed stripe.
"""
import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import load_allocation
from repro.core.delay_model import NodeDelayParams
from repro.hier import population
from repro.net.channel import CHANNEL_PROFILES
from repro.net.trace import generate_trace

N = 300
CAP = 4.0
M = 900.0
U_MAX = 60.0


def _fl(n=N, seed=3):
    # bounded heterogeneity at population sizes: the §V-A per-client
    # geometric knobs are re-exponentiated to span the same range at any n
    return FLConfig(n_clients=n, delta=0.2, seed=seed,
                    rate_decay=0.95 ** (12.0 / n),
                    mac_decay=0.8 ** (12.0 / n))


@pytest.fixture(scope="module")
def prm():
    return population.population_delay_arrays(_fl(), 16)


def test_population_arrays_match_node_oracle(prm):
    """Stacked arrays == stack_node_params over the flat node objects."""
    oracle = load_allocation.stack_node_params(
        population._oracle_nodes(_fl(), 16))
    for key in oracle:
        np.testing.assert_array_equal(prm[key], oracle[key], err_msg=key)


def test_nodes_for_range_rebuilds_oracle_slice(prm):
    nodes = population.nodes_for_range(prm, 37, 61)
    oracle = population._oracle_nodes(_fl(), 16)[37:61]
    for got, want in zip(nodes, oracle):
        assert got == want
    # symmetric entries come back as reciprocal-link nodes (fast paths)
    assert all(nd.tau_up is None and nd.p_up is None for nd in nodes)


@pytest.mark.parametrize("block_size", [
    1, population.SUM_STRIPE - 1, population.SUM_STRIPE,
    population.SUM_STRIPE + 1, N - 1, N, N + 1, 4096])
def test_chunked_solver_bit_identical_across_blocks(prm, block_size):
    """Every partition == the dense one-shot (block_size >= n)."""
    ref = population.two_step_allocate_chunked(
        prm=prm, client_caps=CAP, server=None, u_max=U_MAX, m=M,
        block_size=N)
    alloc = population.two_step_allocate_chunked(
        prm=prm, client_caps=CAP, server=None, u_max=U_MAX, m=M,
        block_size=block_size)
    assert alloc.t_star == ref.t_star
    np.testing.assert_array_equal(alloc.loads, ref.loads)
    np.testing.assert_array_equal(alloc.returns, ref.returns)


def test_chunked_solver_matches_dense_reference(prm):
    """Tolerance-level agreement with two_step_allocate_vectorized (the
    dense jnp.sum association cannot be chunked bit-exactly)."""
    nodes = population.nodes_for_range(prm, 0, N)
    dense = load_allocation.two_step_allocate_vectorized(
        nodes, np.full(N, CAP), None, U_MAX, M)
    chunked = population.two_step_allocate_chunked(
        prm=prm, client_caps=CAP, server=None, u_max=U_MAX, m=M,
        block_size=128)
    assert chunked.t_star == pytest.approx(dense.t_star, rel=1e-6)
    np.testing.assert_allclose(chunked.loads, dense.loads,
                               rtol=1e-5, atol=1e-8)


def test_chunked_solver_feasibility_and_caps(prm):
    with pytest.raises(ValueError, match="infeasible"):
        population.two_step_allocate_chunked(
            prm=prm, client_caps=CAP, server=None,
            u_max=1.0, m=10.0 * N * CAP, block_size=128)
    alloc = population.two_step_allocate_chunked(
        prm=prm, client_caps=CAP, server=None, u_max=U_MAX, m=M,
        block_size=128)
    assert np.all(alloc.loads <= CAP + 1e-12)
    assert np.all(alloc.loads >= 0.0)
    # the deadline actually meets the coverage target in expectation
    assert float(np.sum(alloc.returns)) + U_MAX >= M - 1e-6


def test_return_prob_matches_scalar_cdf(prm):
    """Vectorized return_prob vs the per-node NodeDelayParams.cdf."""
    alloc = population.two_step_allocate_chunked(
        prm=prm, client_caps=CAP, server=None, u_max=U_MAX, m=M,
        block_size=N)
    loads = np.minimum(np.floor(alloc.loads), CAP)
    got = population.return_prob(prm, 0, N, alloc.t_star, loads)
    nodes = population.nodes_for_range(prm, 0, N)
    want = np.array([nd.cdf(alloc.t_star, float(ld))
                     for nd, ld in zip(nodes, loads)])
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


def test_return_prob_rejects_asymmetric(prm):
    bad = {k: v.copy() for k, v in prm.items()}
    bad["tau_up"] = bad["tau_up"] * 2.0
    with pytest.raises(NotImplementedError, match="reciprocal"):
        population.return_prob(bad, 0, 4, 1.0, np.ones(4))


@pytest.mark.parametrize("block_size", [
    1, population.TRACE_STRIPE - 1, population.TRACE_STRIPE,
    population.TRACE_STRIPE + 1, N, 10 ** 6])
def test_chunked_trace_bit_identical_across_blocks(prm, block_size):
    profile = CHANNEL_PROFILES["drift_churn"]
    ref = population.generate_trace_chunked(prm, profile, 4, seed=11)
    tr = population.generate_trace_chunked(prm, profile, 4, seed=11,
                                           block_size=block_size)
    for field in ("mu_mult", "tau_mult", "p_down", "p_up", "active"):
        np.testing.assert_array_equal(getattr(tr, field),
                                      getattr(ref, field), err_msg=field)


def test_single_stripe_trace_is_flat_generate_trace(prm):
    """n <= stripe: the chunked generator IS the flat generator on the
    (seed, 0)-keyed stream."""
    profile = CHANNEL_PROFILES["drift_churn"]
    tr = population.generate_trace_chunked(prm, profile, 3, seed=7)
    nodes = population.nodes_for_range(prm, 0, N)
    flat = generate_trace(nodes, profile, 3,
                          np.random.default_rng((7, 0)))
    for field in ("mu_mult", "tau_mult", "p_down", "p_up", "active"):
        np.testing.assert_array_equal(getattr(tr, field),
                                      getattr(flat, field), err_msg=field)


def test_trace_stripe_crossing_blocks(prm):
    """Blocks that straddle stripe boundaries reassemble exactly."""
    profile = CHANNEL_PROFILES["drift_churn"]
    small_stripe = 64            # force multiple stripes at N=300
    ref = population.generate_trace_chunked(prm, profile, 2, seed=5,
                                            stripe=small_stripe)
    for bs in (small_stripe - 1, small_stripe + 1, 100):
        chunks = list(population.iter_trace_chunks(
            prm, profile, 2, seed=5, block_size=bs, stripe=small_stripe))
        assert chunks[0][0] == 0 and chunks[-1][1] == N
        reassembled = np.concatenate([c.mu_mult for _, _, c in chunks],
                                     axis=1)
        np.testing.assert_array_equal(reassembled, ref.mu_mult)


def test_solver_rejects_bad_blocks(prm):
    with pytest.raises(ValueError, match="block_size"):
        population.two_step_allocate_chunked(
            prm=prm, client_caps=CAP, server=None, u_max=U_MAX, m=M,
            block_size=0)
    with pytest.raises(ValueError, match="block_size"):
        next(population.iter_trace_chunks(
            prm, CHANNEL_PROFILES["drift_churn"], 2, seed=0, block_size=0))


def test_chunked_solver_with_server_node(prm):
    """The coded-server variant (u_max rows behind a fallible link) stays
    partition bit-identical too."""
    server = NodeDelayParams(mu=50.0, alpha=2.0, tau=1e-4, p=0.05)
    ref = population.two_step_allocate_chunked(
        prm=prm, client_caps=CAP, server=server, u_max=U_MAX, m=M,
        block_size=N + 1)
    alloc = population.two_step_allocate_chunked(
        prm=prm, client_caps=CAP, server=server, u_max=U_MAX, m=M,
        block_size=97)
    assert alloc.t_star == ref.t_star
    assert alloc.u_star == ref.u_star
    np.testing.assert_array_equal(alloc.loads, ref.loads)
