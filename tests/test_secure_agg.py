"""Secure aggregation of parity sets (paper §VI future-work extension)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding, secure_agg


def _parities(n=4, u=8, q=16, c=3, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    key = jax.random.PRNGKey(seed)
    for j in range(n):
        x = jnp.asarray(rng.normal(size=(u, q)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(u, c)), jnp.float32)
        out.append(encoding.LocalParity(x=x, y=y))
    return out


def test_masks_cancel_exactly():
    parities = _parities()
    key = jax.random.PRNGKey(42)
    masked = [secure_agg.mask_parity(key, j, len(parities), p, scale=5.0)
              for j, p in enumerate(parities)]
    got = secure_agg.secure_aggregate(masked)
    want = encoding.aggregate_parity(parities)
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(want.x),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(got.y), np.asarray(want.y),
                               atol=1e-4)


def test_individual_upload_is_masked():
    parities = _parities()
    key = jax.random.PRNGKey(43)
    masked = secure_agg.mask_parity(key, 0, len(parities), parities[0],
                                    scale=10.0)
    # the upload must differ substantially from the raw parity set
    diff = float(jnp.mean(jnp.abs(masked.x - parities[0].x)))
    assert diff > 1.0


def test_masks_are_pairwise_consistent():
    key = jax.random.PRNGKey(44)
    k01 = secure_agg._pair_key(key, 0, 1)
    k10 = secure_agg._pair_key(key, 1, 0)
    assert jnp.array_equal(jax.random.key_data(k01),
                           jax.random.key_data(k10))
