"""Channel-traced engine + adaptive re-allocation (acceptance criteria).

Three pillars:
  (a) traces are deterministic per seed (engine-level: identical reruns);
  (b) a static (no-drift, no-churn) channel profile reproduces the
      stationary engine's trajectories BIT-exactly, on both kernel
      backends;
  (c) under a drifting profile the adaptive controller reaches the target
      loss in less simulated wall-clock than the static allocation.
"""
import json

import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.launch import scenarios as scenarios_mod


def _data(n=6, l=16, q=24, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _spec(scheme="coded", **over):
    base = dict(
        fl=FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme=scheme)
    base.update(over)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# (b) static-profile bit-exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel_backend", ["xla", "pallas"])
@pytest.mark.parametrize("scheme", ["coded", "naive", "greedy", "ideal"])
def test_static_channel_bit_identical_to_stationary(scheme, kernel_backend):
    xs, ys = _data()
    plain = api.build_experiment(
        _spec(scheme, kernel_backend=kernel_backend), xs, ys)
    traced = api.build_experiment(
        _spec(scheme, kernel_backend=kernel_backend,
              channel_profile="static"), xs, ys)
    trace = lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)
    res_p = plain.run(10, eval_fn=trace, eval_every=1)
    res_t = traced.run(10, eval_fn=trace, eval_every=1)
    np.testing.assert_array_equal(np.asarray(res_p.theta),
                                  np.asarray(res_t.theta))
    for hp, ht in zip(res_p.history, res_t.history):
        assert hp.returned == ht.returned
        assert hp.wall_clock == ht.wall_clock
        assert hp.loss == ht.loss


def test_channel_runs_deterministic_per_seed():
    xs, ys = _data()
    outs = []
    for _ in range(2):
        exp = api.build_experiment(
            _spec("coded", channel_profile="drift_churn"), xs, ys)
        outs.append(exp.run(8))
    np.testing.assert_array_equal(np.asarray(outs[0].theta),
                                  np.asarray(outs[1].theta))
    assert [h.wall_clock for h in outs[0].history] == \
        [h.wall_clock for h in outs[1].history]


def test_drifting_channel_changes_trajectory():
    xs, ys = _data()
    plain = api.build_experiment(_spec("coded"), xs, ys).run(8)
    drift = api.build_experiment(
        _spec("coded", channel_profile="degrade_drift"), xs, ys).run(8)
    assert not np.array_equal(np.asarray(plain.theta),
                              np.asarray(drift.theta))


def test_channel_params_override_profile():
    xs, ys = _data()
    exp = api.build_experiment(
        _spec("naive", channel_profile="churn",
              channel_params={"dropout_prob": 0.0}), xs, ys)
    assert exp.channel.dropout_prob == 0.0
    assert exp.channel.rejoin_prob == 0.25      # rest of profile kept


def test_churned_client_contributes_nothing():
    """A client that is churned out for a round neither counts as
    returned nor contributes gradient (naive under full churn == the
    same round with those clients' gradients masked)."""
    xs, ys = _data()
    exp = api.build_experiment(
        _spec("naive", channel_profile="churn",
              channel_params={"dropout_prob": 0.6, "rejoin_prob": 0.2}),
        xs, ys)
    res = exp.run(12)
    returned = [h.returned for h in res.history]
    assert returned[0] == exp.n                 # round 0: everyone present
    assert min(returned) < exp.n                # churn bites later
    assert np.isfinite(np.asarray(res.theta)).all()


def test_channel_run_multi_shapes_and_determinism():
    xs, ys = _data()
    outs = []
    for _ in range(2):
        # naive: the round clock is the sampled max delay, so realization
        # variance is visible (coded rounds cost exactly t* by design)
        exp = api.build_experiment(
            _spec("naive", channel_profile="slow_fade"), xs, ys)
        outs.append(exp.run_multi(6, 3, eval_fn=lambda th: (0.0, 1.0)))
    assert outs[0].theta.shape == (3, 24, 3)
    assert outs[0].wall_clock.shape == (3, 6)
    assert outs[0].accuracy.shape == (3,)
    np.testing.assert_array_equal(outs[0].wall_clock, outs[1].wall_clock)
    # realizations face independent traces/delays
    assert np.std(outs[0].wall_clock[:, -1]) > 0.0


# ---------------------------------------------------------------------------
# Adaptive schemes
# ---------------------------------------------------------------------------

def test_adaptive_requires_adapt_every_and_batched_engine():
    xs, ys = _data()
    with pytest.raises(ValueError, match="adapt_every"):
        api.build_experiment(_spec("adaptive_coded"), xs, ys)
    with pytest.raises(ValueError, match="batched"):
        api.build_experiment(
            _spec("adaptive_coded", adapt_every=4, engine="legacy"),
            xs, ys)
    with pytest.raises(NotImplementedError, match="mesh"):
        api.build_experiment(
            _spec("adaptive_coded", adapt_every=4, mesh=1), xs, ys)
    with pytest.raises(ValueError, match="fused_coded"):
        api.build_experiment(
            _spec("adaptive_coded", adapt_every=4, fused_coded=False),
            xs, ys)


def test_adaptive_coded_near_static_on_static_channel():
    """With no drift, the estimator converges to the nominal network, so
    re-allocation stays near the round-0 plan: same deadline to a few
    percent, similar trajectory."""
    xs, ys = _data()
    static = api.build_experiment(_spec("coded"), xs, ys)
    adaptive = api.build_experiment(
        _spec("adaptive_coded", adapt_every=5,
              channel_profile="static"), xs, ys)
    res_a = adaptive.run(20)
    sched = adaptive.last_schedule
    t_stars = np.asarray(sched.t_star, np.float64)
    np.testing.assert_allclose(t_stars, static.t_star, rtol=0.25)
    assert np.isfinite(np.asarray(res_a.theta)).all()
    assert sched.n_blocks == 4
    # block 0 is exactly the static allocation
    np.testing.assert_array_equal(sched.loads_blocks[0], static.loads)
    assert t_stars[0] == pytest.approx(static.t_star, rel=1e-6)


def test_adaptive_deadlines_track_drift_direction():
    xs, ys = _data()
    out = {}
    for prof in ("speedup_drift", "degrade_drift"):
        exp = api.build_experiment(
            _spec("adaptive_coded", adapt_every=4, channel_profile=prof),
            xs, ys)
        exp.run(24)
        out[prof] = np.asarray(exp.last_schedule.t_star, np.float64)
    assert out["speedup_drift"][-1] < 0.8 * out["speedup_drift"][0]
    assert out["degrade_drift"][-1] > 1.2 * out["degrade_drift"][0]


def test_adaptive_greedy_adapts_wait_count_under_churn():
    xs, ys = _data()
    exp = api.build_experiment(
        _spec("adaptive_greedy", adapt_every=4, channel_profile="churn",
              channel_params={"dropout_prob": 0.4, "rejoin_prob": 0.05}),
        xs, ys)
    res = exp.run(24)
    sched = exp.last_schedule
    assert sched.n_wait is not None
    # heavy churn: the controller must stop waiting for the full (1-psi)n
    assert sched.n_wait[-1] < sched.n_wait[0]
    assert np.isfinite(np.asarray(res.theta)).all()


def test_adaptive_estimator_knobs_via_scheme_params():
    xs, ys = _data()
    exp = api.build_experiment(
        _spec("adaptive_coded", adapt_every=4, channel_profile="static",
              scheme_params={"est_beta": 0.5, "est_window": 8}), xs, ys)
    assert exp.scheme_params_estimator_kwargs() == {"beta": 0.5,
                                                    "window": 8}
    exp.run(8)


# ---------------------------------------------------------------------------
# (c) adaptive beats static under drift
# ---------------------------------------------------------------------------

def test_adaptive_beats_static_time_to_target_under_drift():
    """The headline claim: under a drifting profile, adaptive
    re-allocation reaches the target loss in less simulated wall-clock
    than the static round-0 allocation."""
    section = scenarios_mod.run_scenarios(
        n_clients=6, l=16, q=16, c=3, iters=50, adapt_every=5)
    assert not scenarios_mod.validate_scenarios(section)
    for name, case in section["cases"].items():
        assert case["adaptive_speedup"] > 1.05, (name, case)
    # and under degradation the static scheme also converges WORSE
    deg = section["cases"]["degrade_drift"]
    assert deg["adaptive"]["final_loss"] < deg["static"]["final_loss"]


# ---------------------------------------------------------------------------
# Spec surface / guards
# ---------------------------------------------------------------------------

def test_spec_channel_round_trip_and_validation():
    spec = _spec("adaptive_coded", adapt_every=7,
                 channel_profile="drift_churn",
                 channel_params={"dropout_prob": 0.01})
    revived = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert revived == spec and hash(revived) == hash(spec)
    assert revived.resolved_channel().dropout_prob == 0.01
    with pytest.raises(ValueError, match="channel_profile"):
        _spec(channel_profile="hurricane")
    with pytest.raises(ValueError, match="channel_params"):
        _spec(channel_profile="static",
              channel_params={"not_a_knob": 1}).resolved_channel()
    with pytest.raises(ValueError, match="adapt_every"):
        _spec(adapt_every=-1)
    with pytest.raises(ValueError, match="legacy"):
        _spec(channel_profile="static", engine="legacy")
    assert _spec().resolved_channel() is None


def test_sweep_rejects_adaptive_and_channel_specs():
    from repro.launch import sweep as sweep_mod
    xs, ys = _data()
    profiles = {"uniform": dict(rate_decay=1.0, mac_decay=1.0)}
    tc = TrainConfig(learning_rate=0.5)
    with pytest.raises(ValueError, match="grid-sweepable"):
        sweep_mod.run_sweep(xs, ys, profiles=profiles, train_cfg=tc,
                            iterations=2, realizations=1,
                            schemes=("adaptive_coded",))
    with pytest.raises(ValueError, match="channel"):
        sweep_mod.run_sweep(xs, ys, profiles=profiles, train_cfg=tc,
                            iterations=2, realizations=1,
                            schemes=("coded",),
                            base_spec=_spec(channel_profile="slow_fade"))


def test_registry_grid_names_exclude_adaptive():
    from repro.core import schemes
    names = schemes.registered_names()
    assert {"adaptive_coded", "adaptive_greedy"} <= set(names)
    grid = schemes.grid_names()
    assert "adaptive_coded" not in grid and "adaptive_greedy" not in grid
    assert {"coded", "naive", "greedy", "ideal"} <= set(grid)
