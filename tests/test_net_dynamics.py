"""Network-dynamics subsystem: channel profiles, traces, estimation.

Pins the two contracts the engine integration rests on — per-seed trace
determinism and static-profile bit-exactness with the stationary sampler
— plus the channel models' own semantics (Gilbert–Elliott occupancy, MCS
monotonicity, churn transitions) and the online estimator's convergence
to the true network parameters.
"""
import numpy as np
import pytest

from repro.core.delay_model import NodeDelayParams, sample_round_times
from repro.net import channel as channel_mod
from repro.net.channel import CHANNEL_PROFILES, ChannelProfile
from repro.net.estimator import OnlineChannelEstimator
from repro.net.trace import (generate_trace, sample_round_observations,
                             sample_round_times_traced)


def _nodes(n=5, seed=0, asym=False):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        kw = {}
        if asym:
            kw = dict(tau_up=float(rng.uniform(0.05, 0.4)),
                      p_up=float(rng.uniform(0.0, 0.4)))
        out.append(NodeDelayParams(
            mu=float(rng.uniform(2, 10)), alpha=float(rng.uniform(1, 3)),
            tau=float(rng.uniform(0.02, 0.2)),
            p=float(rng.uniform(0.0, 0.4)), **kw))
    return out


# ---------------------------------------------------------------------------
# ChannelProfile / registry
# ---------------------------------------------------------------------------

def test_profile_registry_contains_static_and_drifts():
    assert "static" in CHANNEL_PROFILES
    assert CHANNEL_PROFILES["static"].is_static
    for name in ("markov_loss", "slow_fade", "speedup_drift",
                 "degrade_drift", "churn", "drift_churn"):
        assert name in CHANNEL_PROFILES
        assert not CHANNEL_PROFILES[name].is_static, name


def test_profile_validation_errors():
    with pytest.raises(ValueError, match="ge_p_gb"):
        ChannelProfile(ge_p_gb=1.5)
    with pytest.raises(ValueError, match="shadow_sigma_db"):
        ChannelProfile(shadow_sigma_db=-1.0)
    with pytest.raises(ValueError, match="mu_min"):
        ChannelProfile(mu_min=2.0)
    with pytest.raises(ValueError, match="mu_drift_rate"):
        ChannelProfile(mu_drift_rate=-1.0)
    with pytest.raises(ValueError, match="p_cap"):
        ChannelProfile(p_cap=1.0)
    with pytest.raises(ValueError, match="dropout_prob"):
        ChannelProfile(dropout_prob=-0.1)


def test_mcs_mapping_monotone_and_clamped():
    effs = channel_mod.mcs_efficiency(np.linspace(-20.0, 30.0, 200))
    assert np.all(np.diff(effs) >= 0.0)
    assert effs[0] == channel_mod.MCS_EFFICIENCY[0]     # below lowest CQI
    assert effs[-1] == channel_mod.MCS_EFFICIENCY[-1]   # above highest


# ---------------------------------------------------------------------------
# Trace generation
# ---------------------------------------------------------------------------

FIELDS = ("mu_mult", "tau_mult", "p_down", "p_up", "active")


@pytest.mark.parametrize("profile", ["static", "markov_loss", "slow_fade",
                                     "speedup_drift", "drift_churn"])
def test_trace_deterministic_per_seed(profile):
    nodes = _nodes()
    a = generate_trace(nodes, CHANNEL_PROFILES[profile], 100,
                       np.random.default_rng(42))
    b = generate_trace(nodes, CHANNEL_PROFILES[profile], 100,
                       np.random.default_rng(42))
    c = generate_trace(nodes, CHANNEL_PROFILES[profile], 100,
                       np.random.default_rng(43))
    for f in FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
    assert any(not np.array_equal(getattr(a, f), getattr(c, f))
               for f in FIELDS) or profile == "static"


def test_static_trace_exactly_neutral():
    nodes = _nodes(asym=True)
    tr = generate_trace(nodes, CHANNEL_PROFILES["static"], 50,
                        np.random.default_rng(0))
    assert np.all(tr.mu_mult == 1.0)
    assert np.all(tr.tau_mult == 1.0)
    assert np.all(tr.active)
    np.testing.assert_array_equal(
        tr.p_down, np.tile([nd.p for nd in nodes], (50, 1)))
    np.testing.assert_array_equal(
        tr.p_up, np.tile([nd._p_up for nd in nodes], (50, 1)))


def test_fixed_rng_layout_isolates_dynamics():
    """Toggling one dynamic must not change another's realization at
    equal seed (the fixed four-block draw layout)."""
    nodes = _nodes()
    just_churn = generate_trace(
        nodes, ChannelProfile(dropout_prob=0.1, rejoin_prob=0.3), 80,
        np.random.default_rng(7))
    churn_and_fade = generate_trace(
        nodes, ChannelProfile(dropout_prob=0.1, rejoin_prob=0.3,
                              shadow_sigma_db=3.0), 80,
        np.random.default_rng(7))
    np.testing.assert_array_equal(just_churn.active, churn_and_fade.active)


def test_gilbert_elliott_occupancy_and_clip():
    nodes = _nodes()
    prof = ChannelProfile(ge_p_gb=0.2, ge_p_bg=0.4, ge_bad_scale=50.0,
                          p_cap=0.9)
    tr = generate_trace(nodes, prof, 4000, np.random.default_rng(3))
    base = np.array([nd.p for nd in nodes])
    bad = tr.p_down > base[None, :] + 1e-12
    # stationary bad-state occupancy = p_gb / (p_gb + p_bg) = 1/3
    assert abs(bad.mean() - 0.2 / 0.6) < 0.05
    assert tr.p_down.max() <= 0.9 + 1e-12


def test_churn_transitions_and_round0_all_active():
    nodes = _nodes()
    prof = ChannelProfile(dropout_prob=0.1, rejoin_prob=0.2)
    tr = generate_trace(nodes, prof, 5000, np.random.default_rng(5))
    assert np.all(tr.active[0])
    # stationary availability = rejoin / (rejoin + dropout) = 2/3
    assert abs(tr.active.mean() - 2.0 / 3.0) < 0.05


def test_compute_drift_bounded():
    prof = ChannelProfile(mu_drift_sigma=0.5, mu_min=0.5, mu_max=2.0)
    tr = generate_trace(_nodes(), prof, 500, np.random.default_rng(1))
    assert np.all(tr.mu_mult >= 0.5 - 1e-12)
    assert np.all(tr.mu_mult <= 2.0 + 1e-12)
    assert np.all(tr.mu_mult[0] == 1.0)      # round 0 at nominal


def test_tau_trend_directionality():
    up = generate_trace(_nodes(), ChannelProfile(tau_trend_db=0.5), 40,
                        np.random.default_rng(0))
    down = generate_trace(_nodes(), ChannelProfile(tau_trend_db=-0.5), 40,
                         np.random.default_rng(0))
    assert np.all(up.tau_mult[-1] > 1.0)     # degrading: slower links
    assert np.all(down.tau_mult[-1] < 1.0)   # improving: faster links


# ---------------------------------------------------------------------------
# Traced sampling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("asym", [False, True])
def test_static_traced_sampling_bit_exact(asym):
    """The acceptance contract: under the static profile, the traced
    sampler is BIT-IDENTICAL to delay_model.sample_round_times for the
    same generator state — symmetric and asymmetric links alike."""
    nodes = _nodes(asym=asym)
    loads = np.array([10.0, 0.0, 25.0, 7.0, 13.0])
    tr = generate_trace(nodes, CHANNEL_PROFILES["static"], 300,
                        np.random.default_rng(9))
    a = sample_round_times(nodes, loads, np.random.default_rng(5),
                           rounds=300)
    b = sample_round_times_traced(nodes, loads, np.random.default_rng(5),
                                  tr)
    np.testing.assert_array_equal(a, b)


def test_observations_components_sum_to_total():
    nodes = _nodes()
    tr = generate_trace(nodes, CHANNEL_PROFILES["drift_churn"], 100,
                        np.random.default_rng(2))
    obs = sample_round_observations(nodes, np.full(5, 12.0),
                                    np.random.default_rng(3), tr)
    np.testing.assert_allclose(obs.total,
                               obs.t_down + obs.t_up + obs.t_comp,
                               rtol=1e-12)
    assert np.all(obs.n_down >= 1) and np.all(obs.n_up >= 1)


def test_traced_sampling_per_round_loads():
    nodes = _nodes()
    tr = generate_trace(nodes, CHANNEL_PROFILES["static"], 4,
                        np.random.default_rng(0))
    loads_rn = np.tile(np.array([5.0, 0.0, 8.0, 2.0, 1.0]), (4, 1))
    loads_rn[2] = 0.0                       # a zero-load round
    obs = sample_round_observations(nodes, loads_rn,
                                    np.random.default_rng(1), tr)
    assert np.all(obs.t_comp[2] == 0.0)
    assert np.all(obs.t_comp[0, [0, 2, 3, 4]] > 0.0)
    with pytest.raises(ValueError, match="loads shape"):
        sample_round_observations(nodes, np.ones((3, 5)),
                                  np.random.default_rng(1), tr)


def test_drift_trace_shifts_delay_distribution():
    """Degrading compute must lengthen sampled delays round over round."""
    nodes = _nodes()
    prof = ChannelProfile(mu_drift_rate=-0.05, mu_min=0.05)
    tr = generate_trace(nodes, prof, 200, np.random.default_rng(4))
    t = sample_round_times_traced(nodes, np.full(5, 20.0),
                                  np.random.default_rng(5), tr)
    assert t[150:].mean() > 2.0 * t[:50].mean()


# ---------------------------------------------------------------------------
# Online estimation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ewma", "window"])
def test_estimator_converges_from_wrong_priors(mode):
    true = [NodeDelayParams(mu=4.0, alpha=2.0, tau=0.08, p=0.15)
            for _ in range(4)]
    tr = generate_trace(true, CHANNEL_PROFILES["static"], 2500,
                        np.random.default_rng(1))
    obs = sample_round_observations(true, np.full(4, 20.0),
                                    np.random.default_rng(2), tr)
    prior = [NodeDelayParams(mu=1.0, alpha=2.0, tau=0.4, p=0.5)
             for _ in range(4)]
    kw = {"beta": 0.02} if mode == "ewma" else {"window": 2500}
    est = OnlineChannelEstimator(prior, **kw)
    est.update(obs)
    np.testing.assert_allclose(est.mu_hat, 4.0, rtol=0.15)
    np.testing.assert_allclose(est.tau_hat, 0.08, rtol=0.05)
    np.testing.assert_allclose(est.p_hat, 0.15, atol=0.04)
    np.testing.assert_allclose(est.avail_hat, 1.0, atol=1e-9)
    nodes = est.estimated_nodes()
    assert all(isinstance(nd, NodeDelayParams) for nd in nodes)


def test_estimator_warm_starts_at_nominal():
    nodes = _nodes(asym=True)
    est = OnlineChannelEstimator(nodes)
    for j, nd in enumerate(nodes):
        assert est.mu_hat[j] == pytest.approx(nd.mu)
        assert est.tau_hat[j] == pytest.approx((nd.tau + nd._tau_up) / 2)
        assert est.p_hat[j] == pytest.approx((nd.p + nd._p_up) / 2)


def test_estimator_churned_rounds_only_move_availability():
    true = [NodeDelayParams(mu=4.0, alpha=2.0, tau=0.08, p=0.1)
            for _ in range(3)]
    tr = generate_trace(true, CHANNEL_PROFILES["static"], 50,
                        np.random.default_rng(1))
    obs = sample_round_observations(true, np.full(3, 10.0),
                                    np.random.default_rng(2), tr)
    obs.active[:, 0] = False                 # node 0 never reports
    est = OnlineChannelEstimator(true, beta=0.2)
    mu0, tau0, p0 = est.mu_hat[0], est.tau_hat[0], est.p_hat[0]
    est.update(obs)
    assert est.mu_hat[0] == mu0 and est.tau_hat[0] == tau0
    assert est.p_hat[0] == p0
    assert est.avail_hat[0] < 0.01
    assert est.avail_hat[1] == pytest.approx(1.0)


def test_estimator_validation():
    nodes = _nodes()
    with pytest.raises(ValueError, match="beta"):
        OnlineChannelEstimator(nodes, beta=0.0)
    with pytest.raises(ValueError, match="window"):
        OnlineChannelEstimator(nodes, window=0)


def test_windowed_estimator_all_nan_column_is_warning_free():
    """A node unseen for the whole window keeps its previous estimates
    without np.nanmean's all-NaN RuntimeWarning (the windowed refresh
    uses an explicit mask; warnings-as-errors pins it)."""
    import warnings

    true = [NodeDelayParams(mu=4.0, alpha=2.0, tau=0.08, p=0.1)
            for _ in range(3)]
    tr = generate_trace(true, CHANNEL_PROFILES["static"], 30,
                        np.random.default_rng(1))
    obs = sample_round_observations(true, np.full(3, 10.0),
                                    np.random.default_rng(2), tr)
    obs.active[:, 0] = False                 # node 0: all-NaN window
    est = OnlineChannelEstimator(true, window=30)
    mu0, tau0, p0 = est.mu_hat[0], est.tau_hat[0], est.p_hat[0]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        est.update(obs)
    assert est.mu_hat[0] == mu0 and est.tau_hat[0] == tau0
    assert est.p_hat[0] == p0
    assert est.avail_hat[0] == 0.0
    # the observed nodes' windowed means did move off the warm start
    assert est.mu_hat[1] != pytest.approx(true[1].mu, abs=0.0)
