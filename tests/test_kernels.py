"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles in ref.py.

Every Pallas kernel (interpret mode on CPU) is asserted allclose against
the jnp oracle of the same name across shapes, dtypes (f32/bf16), padded
q_true, and ragged validity masks.  Marked `kernels` so CI can run the
kernel/property job separately from the system suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.kernels

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


SHAPES_RFF = [(128, 128, 128), (256, 384, 128), (200, 300, 100),
              (64, 512, 96), (130, 257, 70)]


@pytest.mark.parametrize("m,q,d", SHAPES_RFF)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_rff_embed(m, q, d, dtype):
    x = _arr((m, d), dtype)
    omega = _arr((d, q), dtype)
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), dtype)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    want = ref.rff_embed(x, omega, delta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


SHAPES_GRAD = [(128, 128, 8), (256, 256, 10), (200, 260, 3), (384, 128, 1),
               (130, 70, 5)]


@pytest.mark.parametrize("m,q,c", SHAPES_GRAD)
def test_linreg_grad(m, q, c):
    x = _arr((m, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((m, c))
    got = ops.linreg_grad(x, theta, y, use_pallas=True)
    want = ref.linreg_grad(x, theta, y)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)


SHAPES_PAR = [(128, 128, 128), (96, 200, 260), (256, 130, 64), (64, 64, 500)]


@pytest.mark.parametrize("u,l,q", SHAPES_PAR)
def test_parity_encode(u, l, q):
    g = _arr((u, l))
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(l,)), jnp.float32)
    x = _arr((l, q), scale=0.5)
    got = ops.parity_encode(g, w, x, use_pallas=True)
    want = ref.parity_encode(g, w, x)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)


SHAPES_PAR_BATCHED = [(1, 128, 128, 128), (4, 96, 200, 130), (7, 13, 20, 24),
                      (3, 64, 64, 500)]


@pytest.mark.parametrize("n,u,l,q", SHAPES_PAR_BATCHED)
def test_parity_encode_batched(n, u, l, q):
    """All-clients kernel (client axis = outer grid dim) vs the vmapped
    oracle AND the per-client single kernel (bit-equal: same dots, same
    accumulation order per client)."""
    g = _arr((n, u, l))
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(n, l)), jnp.float32)
    x = _arr((n, l, q), scale=0.5)
    got = ops.parity_encode_batched(g, w, x, use_pallas=True)
    want = jax.vmap(ref.parity_encode)(g, w, x)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)
    per_client = jnp.stack([
        ops.parity_encode(g[j], w[j], x[j], use_pallas=True)
        for j in range(n)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(per_client))


# n, l, q, c — deliberately non-divisible shapes to exercise the padding
SHAPES_MASKED = [(4, 128, 128, 8), (3, 100, 70, 3), (6, 257, 130, 1),
                 (1, 64, 300, 5)]


@pytest.mark.parametrize("n,l,q,c", SHAPES_MASKED)
def test_linreg_grad_masked(n, l, q, c):
    """Batched masked kernel == per-client masked jnp oracle, ragged masks."""
    x = _arr((n, l, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n, l, c))
    # ragged validity: client j keeps a random prefix-free subset of rows
    mask = jnp.asarray((RNG.uniform(size=(n, l)) < 0.6).astype(np.float32))
    got = ops.linreg_grad_masked(x, theta, y, mask, use_pallas=True)
    want = ops.linreg_grad_masked(x, theta, y, mask)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)
    # and the jnp fallback against the scalar oracle, client by client
    for j in range(n):
        single = ref.linreg_grad_masked(x[j], theta, y[j], mask[j])
        np.testing.assert_allclose(np.asarray(want[j]), np.asarray(single),
                                   rtol=1e-5, atol=1e-5)


def test_linreg_grad_masked_ignores_unzeroed_padding():
    """Rows with mask 0 contribute nothing even when x/y are NOT pre-zeroed."""
    n, l, q, c = 3, 40, 24, 2
    x = _arr((n, l, q), scale=0.5)
    theta = _arr((q, c), scale=0.5)
    y = _arr((n, l, c))
    keep = np.zeros((n, l), np.float32)
    keep[:, : l // 2] = 1.0
    mask = jnp.asarray(keep)
    for use_pallas in (False, True):
        got = ops.linreg_grad_masked(x, theta, y, mask,
                                     use_pallas=use_pallas)
        want = jnp.stack([ref.linreg_grad(x[j, : l // 2], theta,
                                          y[j, : l // 2])
                          for j in range(n)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_linreg_grad_masked_matches_batched_all_ones():
    """All-ones mask reduces the masked kernel to the plain batched path."""
    n, l, q, c = 4, 60, 40, 4
    x = _arr((n, l, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n, l, c))
    ones = jnp.ones((n, l), jnp.float32)
    a = ops.linreg_grad_masked(x, theta, y, ones, use_pallas=True)
    b = ops.linreg_grad_batched(x, theta, y, use_pallas=True)
    cpl = ops.linreg_grad_batched(x, theta, y)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(cpl),
                               rtol=1e-4, atol=1e-4)


def test_linreg_grad_masked_bf16():
    n, l, q, c = 2, 128, 128, 4
    x = _arr((n, l, q), jnp.bfloat16, scale=0.3)
    theta = _arr((q, c), jnp.bfloat16, scale=0.3)
    y = _arr((n, l, c), jnp.bfloat16)
    mask = jnp.asarray((RNG.uniform(size=(n, l)) < 0.5), jnp.bfloat16)
    got = ops.linreg_grad_masked(x, theta, y, mask, use_pallas=True)
    want = ops.linreg_grad_masked(x, theta, y, mask)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.5, rtol=0.1)


def test_linreg_grad_c_too_wide_raises_clear_error():
    """Satellite: c that cannot fit a VMEM tile must raise a clear error,
    not an opaque Pallas shape assert."""
    x = jnp.zeros((128, 128), jnp.float32)
    wide = 300_000
    theta = jnp.zeros((128, wide), jnp.float32)
    y = jnp.zeros((128, wide), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        ops.linreg_grad(x, theta, y, use_pallas=True)
    with pytest.raises(ValueError, match="VMEM"):
        ops.linreg_grad_masked(x[None], theta, y[None],
                               jnp.ones((1, 128), jnp.float32),
                               use_pallas=True)


def test_rff_embed_padded_q_true():
    """Zero-padding q must keep the sqrt(2/q_true) scale of the real q."""
    from repro.kernels.rff_embed import rff_embed as kernel
    m, d, q = 128, 128, 100
    x = _arr((m, d))
    omega = _arr((d, q))
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    want = ref.rff_embed(x, omega, delta)
    # ops-level padding path (pads q 100 -> 128 and passes q_true=100)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # direct kernel call on hand-padded operands
    op = jnp.pad(omega, ((0, 0), (0, 28)))
    dp = jnp.pad(delta, (0, 28))
    direct = kernel(x, op, dp, q_true=q)[:, :q]
    np.testing.assert_allclose(np.asarray(direct), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # omitting q_true silently rescales by sqrt(q_true/q_pad) — make sure
    # the guard actually matters
    wrong = kernel(x, op, dp)[:, :q]
    assert not np.allclose(np.asarray(wrong), np.asarray(want), atol=1e-3)


def test_rff_embed_batched_matches_vmapped_oracle():
    n, l, d, q = 3, 50, 33, 70
    x = _arr((n, l, d))
    omega = _arr((d, q))
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    got = ops.rff_embed_batched(x, omega, delta, use_pallas=True)
    want = jax.vmap(lambda xj: ref.rff_embed(xj, omega, delta))(x)
    assert got.shape == (n, l, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_parity_encode_bf16():
    u, l, q = 128, 128, 128
    g = _arr((u, l), jnp.bfloat16)
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(l,)), jnp.bfloat16)
    x = _arr((l, q), jnp.bfloat16, scale=0.5)
    got = ops.parity_encode(g, w, x, use_pallas=True)
    want = ref.parity_encode(g, w, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.6, rtol=0.1)


DECODE_SHAPES = [
    # B, H, K, hd, hd_v, T, window
    (2, 8, 2, 64, 64, 256, 0),      # GQA
    (2, 8, 8, 64, 64, 300, 0),      # MHA, non-divisible T (padded)
    (1, 16, 4, 32, 32, 128, 48),    # sliding window
    (2, 4, 4, 16, 8, 64, 0),        # MLA-style hd_v != hd
]


@pytest.mark.parametrize("B,H,K,hd,hdv,T,win", DECODE_SHAPES)
def test_gqa_decode(B, H, K, hd, hdv, T, win):
    q = _arr((B, H, hd))
    k = _arr((B, T, K, hd), scale=0.3)
    v = _arr((B, T, K, hdv))
    kp = jnp.asarray(np.where(RNG.uniform(size=T) < 0.9,
                              np.arange(T), -1), jnp.int32)
    qp = jnp.int32(T - 1)
    got = ops.gqa_decode(q, k, v, kp, qp, window=win, use_pallas=True, bt=64)
    want = ref.gqa_decode(q, k, v, kp, qp, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gqa_decode_matches_model_attention():
    """Kernel oracle agrees with the model's _attend_single decode path."""
    from repro.models.attention import _attend_single
    B, H, K, hd, T = 2, 8, 4, 32, 96
    q = _arr((B, 1, H, hd))
    k = _arr((B, T, K, hd), scale=0.3)
    v = _arr((B, T, K, hd))
    kp = jnp.arange(T, dtype=jnp.int32)
    qp = jnp.full((1,), T - 1, jnp.int32)
    want = _attend_single(q, k, v, qp, kp, 0)[:, 0]
    got = ref.gqa_decode(q[:, 0], k, v, kp, jnp.int32(T - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_bf16_support():
    x = _arr((128, 128), jnp.bfloat16)
    omega = _arr((128, 128), jnp.bfloat16)
    delta = jnp.zeros((128,), jnp.bfloat16)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    want = ref.rff_embed(x, omega, delta)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.15)


def test_block_shape_sweep():
    """Kernel must be numerically invariant to BlockSpec tiling choices."""
    x = _arr((256, 256), scale=0.3)
    theta = _arr((256, 4), scale=0.3)
    y = _arr((256, 4))
    base = np.asarray(ref.linreg_grad(x, theta, y))
    for bm, bq in [(64, 64), (128, 256), (256, 128)]:
        got = np.asarray(ops.linreg_grad(x, theta, y, use_pallas=True,
                                         bm=bm, bq=bq))
        np.testing.assert_allclose(got, base, atol=1e-3)


# --- fused RFF-embed -> masked gradient kernel (raw features in, grads out) ---

# n, l, d, q, c — mixed divisible and ragged shapes
SHAPES_FUSED = [(3, 128, 16, 128, 4), (2, 100, 33, 70, 3),
                (4, 257, 20, 130, 1), (1, 64, 128, 256, 5)]


@pytest.mark.parametrize("n,l,d,q,c", SHAPES_FUSED)
def test_rff_linreg_grad_fused(n, l, d, q, c):
    """Fused kernel == its jnp fallback == the explicit two-pass path."""
    x = _arr((n, l, d), scale=0.3)
    omega = _arr((d, q), scale=0.3)
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n, l, c))
    mask = jnp.asarray((RNG.uniform(size=(n, l)) < 0.7).astype(np.float32))
    got = ops.rff_linreg_grad_masked(x, omega, delta, theta, y, mask,
                                     use_pallas=True)
    want = ops.rff_linreg_grad_masked(x, omega, delta, theta, y, mask)
    assert got.shape == (n, q, c) and got.dtype == jnp.float32
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)
    # the fallback IS the two-pass composition, bit for bit — the fused
    # path replaces it without changing what is computed
    phi = ops.rff_embed_batched(x, omega, delta)
    two_pass = ops.linreg_grad_masked(phi, theta, y, mask)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(two_pass))


def test_rff_linreg_grad_fused_parity_row():
    """The coded parity pseudo-client rides the same grid: pre-embedded
    (l, q) parity features substitute for the in-kernel embed on the last
    row, and its mask carries the coded 1/u scale."""
    n, l, d, q, c, u = 3, 64, 16, 64, 3, 24
    x = _arr((n, l, d), scale=0.3)
    omega = _arr((d, q), scale=0.3)
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n + 1, l, c))
    parity_phi = jnp.zeros((l, q), jnp.float32).at[:u].set(
        _arr((u, q), scale=0.5))
    mask = np.zeros((n + 1, l), np.float32)
    mask[:n] = (RNG.uniform(size=(n, l)) < 0.7)
    mask[n, :u] = 1.0 / u
    mask = jnp.asarray(mask)
    got = ops.rff_linreg_grad_masked(x, omega, delta, theta, y, mask,
                                     parity_phi=parity_phi, use_pallas=True)
    want = ops.rff_linreg_grad_masked(x, omega, delta, theta, y, mask,
                                      parity_phi=parity_phi)
    assert got.shape == (n + 1, q, c)
    # single-block shapes: the padded contraction contributes exact zeros,
    # so pallas and jnp agree bit for bit
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the parity row must equal the plain masked gradient on parity_phi
    par = ref.linreg_grad_masked(parity_phi, theta, y[n], mask[n])
    np.testing.assert_allclose(np.asarray(got[n]), np.asarray(par),
                               rtol=1e-6, atol=1e-6)


def test_rff_linreg_grad_fused_bf16():
    """bf16 inputs accumulate in f32 and the output stays f32."""
    n, l, d, q, c = 2, 128, 16, 128, 4
    x = _arr((n, l, d), jnp.bfloat16, scale=0.3)
    omega = _arr((d, q), jnp.bfloat16, scale=0.3)
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.bfloat16)
    theta = _arr((q, c), jnp.bfloat16, scale=0.3)
    y = _arr((n, l, c), jnp.bfloat16)
    mask = jnp.asarray((RNG.uniform(size=(n, l)) < 0.7), jnp.bfloat16)
    got = ops.rff_linreg_grad_masked(x, omega, delta, theta, y, mask,
                                     use_pallas=True)
    want = ops.rff_linreg_grad_masked(x, omega, delta, theta, y, mask)
    assert got.dtype == jnp.float32 and want.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=0.1, rtol=0.1)


def test_rff_linreg_grad_fused_block_sweep():
    """Numerically stable across BlockSpec tiling choices."""
    n, l, d, q, c = 2, 256, 16, 256, 4
    x = _arr((n, l, d), scale=0.3)
    omega = _arr((d, q), scale=0.3)
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n, l, c))
    mask = jnp.ones((n, l), jnp.float32)
    base = np.asarray(ops.rff_linreg_grad_masked(x, omega, delta, theta, y,
                                                 mask))
    for bm, bq in [(64, 64), (128, 256), (256, 128)]:
        got = np.asarray(ops.rff_linreg_grad_masked(
            x, omega, delta, theta, y, mask, use_pallas=True, bm=bm, bq=bq))
        np.testing.assert_allclose(got, base, atol=1e-3)


def test_rff_linreg_grad_fused_rejects_bad_args():
    from repro.kernels.rff_linreg_grad import (
        rff_linreg_grad_masked as kernel)
    rows, l, d, q, c = 2, 128, 128, 128, 4
    x = jnp.zeros((rows, l, d), jnp.float32)
    omega = jnp.zeros((d, q), jnp.float32)
    delta = jnp.zeros((q,), jnp.float32)
    theta = jnp.zeros((q, c), jnp.float32)
    y = jnp.zeros((rows, l, c), jnp.float32)
    mask = jnp.ones((rows, l), jnp.float32)
    pphi = jnp.zeros((1, l, q), jnp.float32)
    with pytest.raises(ValueError, match="q_true"):
        kernel(x, omega, delta, theta, y, mask, pphi, n_real=rows, q_true=0)
    with pytest.raises(ValueError, match="n_real"):
        kernel(x, omega, delta, theta, y, mask, pphi, n_real=rows + 1)
    # resident Omega/theta past the VMEM budget must raise a clear error
    wide = 300_000
    with pytest.raises(ValueError, match="VMEM"):
        ops.rff_linreg_grad_masked(
            x, jnp.zeros((d, wide), jnp.float32),
            jnp.zeros((wide,), jnp.float32),
            jnp.zeros((wide, c), jnp.float32), y, mask, use_pallas=True)


# --- wrapper padding edges: one below / at / one above the block size ---
#
# Where the zero-padding stays inside a single contraction block, the
# padded terms are exact +0.0 contributions and the Pallas (interpret)
# result must be BIT-EQUAL to the jnp reference — any `_pad_to` /
# `_clamp_block` regression (wrong scale, garbage in the pad, off-by-one
# slicing) breaks exact equality loudly.  One past the block multiple the
# contraction legitimately splits into two accumulation steps, so those
# cases assert tight allclose instead.

_EDGE = (127, 128, 129)   # around the 128-lane block


def _assert_edge(got, want, exact):
    got, want = np.asarray(got), np.asarray(want)
    if exact:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("d", _EDGE)
@pytest.mark.parametrize("q", _EDGE)
def test_rff_embed_batched_padding_edges(d, q):
    n, l = 2, 9
    x = _arr((n, l, d))
    omega = _arr((d, q))
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    got = ops.rff_embed_batched(x, omega, delta, use_pallas=True)
    want = jax.vmap(lambda xj: ref.rff_embed(xj, omega, delta))(x)
    _assert_edge(got, want, exact=(d <= 128 and q <= 128))


@pytest.mark.parametrize("l", _EDGE)
@pytest.mark.parametrize("q", _EDGE)
def test_linreg_grad_masked_padding_edges(l, q):
    n, c = 2, 3
    x = _arr((n, l, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n, l, c))
    mask = jnp.asarray((RNG.uniform(size=(n, l)) < 0.7).astype(np.float32))
    got = ops.linreg_grad_masked(x, theta, y, mask, use_pallas=True)
    want = jnp.stack([ref.linreg_grad_masked(x[j], theta, y[j], mask[j])
                      for j in range(n)])
    _assert_edge(got, want, exact=(l <= 128))


@pytest.mark.parametrize("l", _EDGE)
@pytest.mark.parametrize("q", (63, 64, 65))
def test_parity_encode_batched_padding_edges(l, q):
    n, u = 2, 24
    g = _arr((n, u, l))
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(n, l)), jnp.float32)
    x = _arr((n, l, q), scale=0.5)
    got = ops.parity_encode_batched(g, w, x, use_pallas=True)
    want = jax.vmap(ref.parity_encode)(g, w, x)
    _assert_edge(got, want, exact=(l <= 128))


# --- satellite bugfix pins ---


def test_rff_embed_q_true_guard():
    """q_true=0 must raise, not silently fall back to the padded q."""
    from repro.kernels.rff_embed import rff_embed as kernel
    m, d, q = 128, 128, 128
    x = _arr((m, d))
    omega = _arr((d, q))
    delta = jnp.zeros((q,), jnp.float32)
    with pytest.raises(ValueError, match="q_true"):
        kernel(x, omega, delta, q_true=0)
    with pytest.raises(ValueError, match="q_true"):
        kernel(x, omega, delta, q_true=-3)
    # None still defaults to the (padded) q
    got = kernel(x, omega, delta, q_true=None)
    want = ref.rff_embed(x, omega, delta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_gqa_decode_block_clamp():
    """T=500 with the default bt=512 must clamp to an 8-aligned block (a
    bare min(bt, T) left bt=500, which only interpret mode tolerates)."""
    from repro.kernels.ops import _clamp_block
    assert _clamp_block(512, 500, True) == 504
    assert _clamp_block(512, 500, True) % 8 == 0
    assert _clamp_block(512, 500, False) == 512   # compiled path untouched
    B, H, K, hd, T = 2, 8, 4, 32, 500
    q = _arr((B, H, hd))
    k = _arr((B, T, K, hd), scale=0.3)
    v = _arr((B, T, K, hd))
    kp = jnp.asarray(np.where(RNG.uniform(size=T) < 0.9,
                              np.arange(T), -1), jnp.int32)
    qp = jnp.int32(T - 1)
    got = ops.gqa_decode(q, k, v, kp, qp, use_pallas=True)
    want = ref.gqa_decode(q, k, v, kp, qp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
