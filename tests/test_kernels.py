"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles in ref.py."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


SHAPES_RFF = [(128, 128, 128), (256, 384, 128), (200, 300, 100),
              (64, 512, 96), (130, 257, 70)]


@pytest.mark.parametrize("m,q,d", SHAPES_RFF)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_rff_embed(m, q, d, dtype):
    x = _arr((m, d), dtype)
    omega = _arr((d, q), dtype)
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), dtype)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    want = ref.rff_embed(x, omega, delta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


SHAPES_GRAD = [(128, 128, 8), (256, 256, 10), (200, 260, 3), (384, 128, 1),
               (130, 70, 5)]


@pytest.mark.parametrize("m,q,c", SHAPES_GRAD)
def test_linreg_grad(m, q, c):
    x = _arr((m, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((m, c))
    got = ops.linreg_grad(x, theta, y, use_pallas=True)
    want = ref.linreg_grad(x, theta, y)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)


SHAPES_PAR = [(128, 128, 128), (96, 200, 260), (256, 130, 64), (64, 64, 500)]


@pytest.mark.parametrize("u,l,q", SHAPES_PAR)
def test_parity_encode(u, l, q):
    g = _arr((u, l))
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(l,)), jnp.float32)
    x = _arr((l, q), scale=0.5)
    got = ops.parity_encode(g, w, x, use_pallas=True)
    want = ref.parity_encode(g, w, x)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)


DECODE_SHAPES = [
    # B, H, K, hd, hd_v, T, window
    (2, 8, 2, 64, 64, 256, 0),      # GQA
    (2, 8, 8, 64, 64, 300, 0),      # MHA, non-divisible T (padded)
    (1, 16, 4, 32, 32, 128, 48),    # sliding window
    (2, 4, 4, 16, 8, 64, 0),        # MLA-style hd_v != hd
]


@pytest.mark.parametrize("B,H,K,hd,hdv,T,win", DECODE_SHAPES)
def test_gqa_decode(B, H, K, hd, hdv, T, win):
    q = _arr((B, H, hd))
    k = _arr((B, T, K, hd), scale=0.3)
    v = _arr((B, T, K, hdv))
    kp = jnp.asarray(np.where(RNG.uniform(size=T) < 0.9,
                              np.arange(T), -1), jnp.int32)
    qp = jnp.int32(T - 1)
    got = ops.gqa_decode(q, k, v, kp, qp, window=win, use_pallas=True, bt=64)
    want = ref.gqa_decode(q, k, v, kp, qp, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gqa_decode_matches_model_attention():
    """Kernel oracle agrees with the model's _attend_single decode path."""
    from repro.models.attention import _attend_single
    B, H, K, hd, T = 2, 8, 4, 32, 96
    q = _arr((B, 1, H, hd))
    k = _arr((B, T, K, hd), scale=0.3)
    v = _arr((B, T, K, hd))
    kp = jnp.arange(T, dtype=jnp.int32)
    qp = jnp.full((1,), T - 1, jnp.int32)
    want = _attend_single(q, k, v, qp, kp, 0)[:, 0]
    got = ref.gqa_decode(q[:, 0], k, v, kp, jnp.int32(T - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_bf16_support():
    x = _arr((128, 128), jnp.bfloat16)
    omega = _arr((128, 128), jnp.bfloat16)
    delta = jnp.zeros((128,), jnp.bfloat16)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    want = ref.rff_embed(x, omega, delta)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.15)


def test_block_shape_sweep():
    """Kernel must be numerically invariant to BlockSpec tiling choices."""
    x = _arr((256, 256), scale=0.3)
    theta = _arr((256, 4), scale=0.3)
    y = _arr((256, 4))
    base = np.asarray(ref.linreg_grad(x, theta, y))
    for bm, bq in [(64, 64), (128, 256), (256, 128)]:
        got = np.asarray(ops.linreg_grad(x, theta, y, use_pallas=True,
                                         bm=bm, bq=bq))
        np.testing.assert_allclose(got, base, atol=1e-3)
