"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles in ref.py.

Every Pallas kernel (interpret mode on CPU) is asserted allclose against
the jnp oracle of the same name across shapes, dtypes (f32/bf16), padded
q_true, and ragged validity masks.  Marked `kernels` so CI can run the
kernel/property job separately from the system suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.kernels

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


SHAPES_RFF = [(128, 128, 128), (256, 384, 128), (200, 300, 100),
              (64, 512, 96), (130, 257, 70)]


@pytest.mark.parametrize("m,q,d", SHAPES_RFF)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_rff_embed(m, q, d, dtype):
    x = _arr((m, d), dtype)
    omega = _arr((d, q), dtype)
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), dtype)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    want = ref.rff_embed(x, omega, delta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


SHAPES_GRAD = [(128, 128, 8), (256, 256, 10), (200, 260, 3), (384, 128, 1),
               (130, 70, 5)]


@pytest.mark.parametrize("m,q,c", SHAPES_GRAD)
def test_linreg_grad(m, q, c):
    x = _arr((m, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((m, c))
    got = ops.linreg_grad(x, theta, y, use_pallas=True)
    want = ref.linreg_grad(x, theta, y)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)


SHAPES_PAR = [(128, 128, 128), (96, 200, 260), (256, 130, 64), (64, 64, 500)]


@pytest.mark.parametrize("u,l,q", SHAPES_PAR)
def test_parity_encode(u, l, q):
    g = _arr((u, l))
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(l,)), jnp.float32)
    x = _arr((l, q), scale=0.5)
    got = ops.parity_encode(g, w, x, use_pallas=True)
    want = ref.parity_encode(g, w, x)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)


SHAPES_PAR_BATCHED = [(1, 128, 128, 128), (4, 96, 200, 130), (7, 13, 20, 24),
                      (3, 64, 64, 500)]


@pytest.mark.parametrize("n,u,l,q", SHAPES_PAR_BATCHED)
def test_parity_encode_batched(n, u, l, q):
    """All-clients kernel (client axis = outer grid dim) vs the vmapped
    oracle AND the per-client single kernel (bit-equal: same dots, same
    accumulation order per client)."""
    g = _arr((n, u, l))
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(n, l)), jnp.float32)
    x = _arr((n, l, q), scale=0.5)
    got = ops.parity_encode_batched(g, w, x, use_pallas=True)
    want = jax.vmap(ref.parity_encode)(g, w, x)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)
    per_client = jnp.stack([
        ops.parity_encode(g[j], w[j], x[j], use_pallas=True)
        for j in range(n)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(per_client))


# n, l, q, c — deliberately non-divisible shapes to exercise the padding
SHAPES_MASKED = [(4, 128, 128, 8), (3, 100, 70, 3), (6, 257, 130, 1),
                 (1, 64, 300, 5)]


@pytest.mark.parametrize("n,l,q,c", SHAPES_MASKED)
def test_linreg_grad_masked(n, l, q, c):
    """Batched masked kernel == per-client masked jnp oracle, ragged masks."""
    x = _arr((n, l, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n, l, c))
    # ragged validity: client j keeps a random prefix-free subset of rows
    mask = jnp.asarray((RNG.uniform(size=(n, l)) < 0.6).astype(np.float32))
    got = ops.linreg_grad_masked(x, theta, y, mask, use_pallas=True)
    want = ops.linreg_grad_masked(x, theta, y, mask)
    denom = max(float(jnp.abs(want).max()), 1.0)
    np.testing.assert_allclose(np.asarray(got) / denom,
                               np.asarray(want) / denom, atol=3e-5)
    # and the jnp fallback against the scalar oracle, client by client
    for j in range(n):
        single = ref.linreg_grad_masked(x[j], theta, y[j], mask[j])
        np.testing.assert_allclose(np.asarray(want[j]), np.asarray(single),
                                   rtol=1e-5, atol=1e-5)


def test_linreg_grad_masked_ignores_unzeroed_padding():
    """Rows with mask 0 contribute nothing even when x/y are NOT pre-zeroed."""
    n, l, q, c = 3, 40, 24, 2
    x = _arr((n, l, q), scale=0.5)
    theta = _arr((q, c), scale=0.5)
    y = _arr((n, l, c))
    keep = np.zeros((n, l), np.float32)
    keep[:, : l // 2] = 1.0
    mask = jnp.asarray(keep)
    for use_pallas in (False, True):
        got = ops.linreg_grad_masked(x, theta, y, mask,
                                     use_pallas=use_pallas)
        want = jnp.stack([ref.linreg_grad(x[j, : l // 2], theta,
                                          y[j, : l // 2])
                          for j in range(n)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_linreg_grad_masked_matches_batched_all_ones():
    """All-ones mask reduces the masked kernel to the plain batched path."""
    n, l, q, c = 4, 60, 40, 4
    x = _arr((n, l, q), scale=0.3)
    theta = _arr((q, c), scale=0.3)
    y = _arr((n, l, c))
    ones = jnp.ones((n, l), jnp.float32)
    a = ops.linreg_grad_masked(x, theta, y, ones, use_pallas=True)
    b = ops.linreg_grad_batched(x, theta, y, use_pallas=True)
    cpl = ops.linreg_grad_batched(x, theta, y)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(cpl),
                               rtol=1e-4, atol=1e-4)


def test_linreg_grad_masked_bf16():
    n, l, q, c = 2, 128, 128, 4
    x = _arr((n, l, q), jnp.bfloat16, scale=0.3)
    theta = _arr((q, c), jnp.bfloat16, scale=0.3)
    y = _arr((n, l, c), jnp.bfloat16)
    mask = jnp.asarray((RNG.uniform(size=(n, l)) < 0.5), jnp.bfloat16)
    got = ops.linreg_grad_masked(x, theta, y, mask, use_pallas=True)
    want = ops.linreg_grad_masked(x, theta, y, mask)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.5, rtol=0.1)


def test_linreg_grad_c_too_wide_raises_clear_error():
    """Satellite: c that cannot fit a VMEM tile must raise a clear error,
    not an opaque Pallas shape assert."""
    x = jnp.zeros((128, 128), jnp.float32)
    wide = 300_000
    theta = jnp.zeros((128, wide), jnp.float32)
    y = jnp.zeros((128, wide), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        ops.linreg_grad(x, theta, y, use_pallas=True)
    with pytest.raises(ValueError, match="VMEM"):
        ops.linreg_grad_masked(x[None], theta, y[None],
                               jnp.ones((1, 128), jnp.float32),
                               use_pallas=True)


def test_rff_embed_padded_q_true():
    """Zero-padding q must keep the sqrt(2/q_true) scale of the real q."""
    from repro.kernels.rff_embed import rff_embed as kernel
    m, d, q = 128, 128, 100
    x = _arr((m, d))
    omega = _arr((d, q))
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    want = ref.rff_embed(x, omega, delta)
    # ops-level padding path (pads q 100 -> 128 and passes q_true=100)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # direct kernel call on hand-padded operands
    op = jnp.pad(omega, ((0, 0), (0, 28)))
    dp = jnp.pad(delta, (0, 28))
    direct = kernel(x, op, dp, q_true=q)[:, :q]
    np.testing.assert_allclose(np.asarray(direct), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    # omitting q_true silently rescales by sqrt(q_true/q_pad) — make sure
    # the guard actually matters
    wrong = kernel(x, op, dp)[:, :q]
    assert not np.allclose(np.asarray(wrong), np.asarray(want), atol=1e-3)


def test_rff_embed_batched_matches_vmapped_oracle():
    n, l, d, q = 3, 50, 33, 70
    x = _arr((n, l, d))
    omega = _arr((d, q))
    delta = jnp.asarray(RNG.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    got = ops.rff_embed_batched(x, omega, delta, use_pallas=True)
    want = jax.vmap(lambda xj: ref.rff_embed(xj, omega, delta))(x)
    assert got.shape == (n, l, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_parity_encode_bf16():
    u, l, q = 128, 128, 128
    g = _arr((u, l), jnp.bfloat16)
    w = jnp.asarray(RNG.uniform(0.2, 1.0, size=(l,)), jnp.bfloat16)
    x = _arr((l, q), jnp.bfloat16, scale=0.5)
    got = ops.parity_encode(g, w, x, use_pallas=True)
    want = ref.parity_encode(g, w, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=0.6, rtol=0.1)


DECODE_SHAPES = [
    # B, H, K, hd, hd_v, T, window
    (2, 8, 2, 64, 64, 256, 0),      # GQA
    (2, 8, 8, 64, 64, 300, 0),      # MHA, non-divisible T (padded)
    (1, 16, 4, 32, 32, 128, 48),    # sliding window
    (2, 4, 4, 16, 8, 64, 0),        # MLA-style hd_v != hd
]


@pytest.mark.parametrize("B,H,K,hd,hdv,T,win", DECODE_SHAPES)
def test_gqa_decode(B, H, K, hd, hdv, T, win):
    q = _arr((B, H, hd))
    k = _arr((B, T, K, hd), scale=0.3)
    v = _arr((B, T, K, hdv))
    kp = jnp.asarray(np.where(RNG.uniform(size=T) < 0.9,
                              np.arange(T), -1), jnp.int32)
    qp = jnp.int32(T - 1)
    got = ops.gqa_decode(q, k, v, kp, qp, window=win, use_pallas=True, bt=64)
    want = ref.gqa_decode(q, k, v, kp, qp, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gqa_decode_matches_model_attention():
    """Kernel oracle agrees with the model's _attend_single decode path."""
    from repro.models.attention import _attend_single
    B, H, K, hd, T = 2, 8, 4, 32, 96
    q = _arr((B, 1, H, hd))
    k = _arr((B, T, K, hd), scale=0.3)
    v = _arr((B, T, K, hd))
    kp = jnp.arange(T, dtype=jnp.int32)
    qp = jnp.full((1,), T - 1, jnp.int32)
    want = _attend_single(q, k, v, qp, kp, 0)[:, 0]
    got = ref.gqa_decode(q[:, 0], k, v, kp, jnp.int32(T - 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_bf16_support():
    x = _arr((128, 128), jnp.bfloat16)
    omega = _arr((128, 128), jnp.bfloat16)
    delta = jnp.zeros((128,), jnp.bfloat16)
    got = ops.rff_embed(x, omega, delta, use_pallas=True)
    want = ref.rff_embed(x, omega, delta)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.15)


def test_block_shape_sweep():
    """Kernel must be numerically invariant to BlockSpec tiling choices."""
    x = _arr((256, 256), scale=0.3)
    theta = _arr((256, 4), scale=0.3)
    y = _arr((256, 4))
    base = np.asarray(ref.linreg_grad(x, theta, y))
    for bm, bq in [(64, 64), (128, 256), (256, 128)]:
        got = np.asarray(ops.linreg_grad(x, theta, y, use_pallas=True,
                                         bm=bm, bq=bq))
        np.testing.assert_allclose(got, base, atol=1e-3)
