"""Batched scan engine vs. the legacy per-client Python oracle.

Both engines pre-sample the whole run's delays through the same vectorized
`delay_model.sample_round_times` call, so with equal seeds they must produce
the same returned-client counts, wall-clocks, and `theta` trajectory to fp32
tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.core import aggregation, delay_model
from repro.core.delay_model import NodeDelayParams


def _data(n=8, l=24, q=32, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _exp(xs, ys, scheme, engine="batched", kernel_backend="xla",
         fl_kw=None, **spec_kw):
    fl = FLConfig(n_clients=xs.shape[0], delta=0.25, psi=0.3, seed=3,
                  **(fl_kw or {}))
    tc = TrainConfig(learning_rate=0.5, l2_reg=1e-4,
                     lr_decay_epochs=(10, 18))
    spec = ExperimentSpec(fl=fl, train=tc, scheme=scheme, engine=engine,
                          kernel_backend=kernel_backend, **spec_kw)
    return api.build_experiment(spec, xs, ys)


def _run(xs, ys, scheme, engine, iters=25, kernel_backend="xla", **fl_kw):
    sim = _exp(xs, ys, scheme, engine, kernel_backend, fl_kw=fl_kw)
    trace = lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)
    return sim.run(iters, eval_fn=trace, eval_every=1)


@pytest.mark.parametrize("scheme", ["naive", "greedy", "coded"])
def test_batched_matches_legacy_trajectory(scheme):
    xs, ys = _data()
    res_l = _run(xs, ys, scheme, "legacy")
    res_b = _run(xs, ys, scheme, "batched")
    np.testing.assert_allclose(np.asarray(res_b.theta),
                               np.asarray(res_l.theta), atol=1e-5)
    for hl, hb in zip(res_l.history, res_b.history):
        assert hl.returned == hb.returned
        np.testing.assert_allclose(hb.wall_clock, hl.wall_clock, rtol=1e-5)
        # per-round theta trace (the eval_fn records |theta|_1 every round)
        np.testing.assert_allclose(hb.loss, hl.loss, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("scheme", ["naive", "greedy", "coded"])
def test_pallas_backend_matches_xla_and_legacy(scheme):
    """kernel_backend="pallas" (interpret mode in CI) must reproduce both
    the XLA batched trajectory and the legacy per-client oracle."""
    xs, ys = _data()
    res_p = _run(xs, ys, scheme, "batched", kernel_backend="pallas",
                 iters=15)
    res_x = _run(xs, ys, scheme, "batched", kernel_backend="xla", iters=15)
    res_l = _run(xs, ys, scheme, "legacy", iters=15)
    np.testing.assert_allclose(np.asarray(res_p.theta),
                               np.asarray(res_x.theta), atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_p.theta),
                               np.asarray(res_l.theta), atol=1e-5)
    for hp, hx, hl in zip(res_p.history, res_x.history, res_l.history):
        assert hp.returned == hx.returned == hl.returned
        np.testing.assert_allclose(hp.wall_clock, hl.wall_clock, rtol=1e-5)
        # per-round |theta|_1 trace recorded via eval_fn
        np.testing.assert_allclose(hp.loss, hx.loss, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(hp.loss, hl.loss, rtol=1e-4, atol=1e-5)


def test_bad_kernel_backend_raises():
    with pytest.raises(ValueError, match="kernel_backend"):
        ExperimentSpec(fl=FLConfig(n_clients=2), kernel_backend="cuda")
    with pytest.raises(ValueError, match="alloc_backend"):
        ExperimentSpec(fl=FLConfig(n_clients=2), alloc_backend="scipy")


@pytest.mark.parametrize("kernel_backend", ["xla", "pallas"])
def test_run_multi_deterministic_across_fresh_sims(kernel_backend):
    """Two identically-seeded deployments must give bit-identical run_multi
    surfaces — the determinism contract the Fig. 4/5 bands rely on."""
    xs, ys = _data(n=5, l=12, q=16, c=2)
    outs = []
    for _ in range(2):
        fl = FLConfig(n_clients=5, delta=0.25, psi=0.3, seed=3)
        tc = TrainConfig(learning_rate=0.5, l2_reg=0.0)
        sim = api.build_experiment(
            ExperimentSpec(fl=fl, train=tc, scheme="coded",
                           kernel_backend=kernel_backend), xs, ys)
        outs.append(sim.run_multi(8, 3))
    np.testing.assert_array_equal(outs[0].wall_clock, outs[1].wall_clock)
    np.testing.assert_array_equal(outs[0].returned, outs[1].returned)
    np.testing.assert_array_equal(np.asarray(outs[0].theta),
                                  np.asarray(outs[1].theta))


def test_run_multi_pallas_matches_xla():
    xs, ys = _data(n=5, l=12, q=16, c=2)
    res = {}
    for kb in ("xla", "pallas"):
        fl = FLConfig(n_clients=5, delta=0.25, psi=0.3, seed=3)
        tc = TrainConfig(learning_rate=0.5, l2_reg=0.0)
        sim = api.build_experiment(
            ExperimentSpec(fl=fl, train=tc, scheme="coded",
                           kernel_backend=kb), xs, ys)
        res[kb] = sim.run_multi(8, 3)
    np.testing.assert_allclose(res["pallas"].wall_clock,
                               res["xla"].wall_clock, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res["pallas"].theta),
                               np.asarray(res["xla"].theta), atol=1e-5)


def test_masked_padded_grads_match_ragged():
    """Dense mask-padded client gradients == ragged per-subset gradients."""
    rng = np.random.default_rng(7)
    n, l, q, c = 6, 20, 16, 4
    xs = rng.normal(size=(n, l, q)).astype(np.float32)
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    theta = rng.normal(size=(q, c)).astype(np.float32)
    loads = rng.integers(0, l + 1, size=n)
    idx = [np.sort(rng.permutation(l)[:k]) for k in loads]
    l_max = max(1, int(loads.max()))
    pad_x = np.zeros((n, l_max, q), np.float32)
    pad_y = np.zeros((n, l_max, c), np.float32)
    for j in range(n):
        pad_x[j, :loads[j]] = xs[j][idx[j]]
        pad_y[j, :loads[j]] = ys[j][idx[j]]
    dense = aggregation.batched_client_gradients(
        jnp.asarray(pad_x), jnp.asarray(pad_y), jnp.asarray(theta))
    for j in range(n):
        ragged = (xs[j][idx[j]].T @ (xs[j][idx[j]] @ theta - ys[j][idx[j]])
                  if loads[j] > 0 else np.zeros((q, c), np.float32))
        np.testing.assert_allclose(np.asarray(dense[j]), ragged,
                                   rtol=1e-4, atol=1e-4)


def test_vectorized_sampler_matches_expected_delay():
    """Vectorized 3-draw sampler reproduces E[T_j] per node."""
    nodes = [NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.2),
             NodeDelayParams(mu=2.0, alpha=1.0, tau=0.1, p=0.0),
             NodeDelayParams(mu=9.0, alpha=4.0, tau=0.02, p=0.4,
                             tau_up=0.05, p_up=0.1)]
    loads = np.array([10.0, 0.0, 25.0])
    rng = np.random.default_rng(0)
    t = delay_model.sample_round_times(nodes, loads, rng, rounds=200_000)
    assert t.shape == (200_000, 3)
    want = [nd.expected_delay(ld) for nd, ld in zip(nodes, loads)]
    np.testing.assert_allclose(t.mean(axis=0), want, rtol=0.02)


def test_sampler_rejects_bad_loads_shape():
    nodes = [NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.2)]
    with pytest.raises(ValueError):
        delay_model.sample_round_times(nodes, np.ones(3),
                                       np.random.default_rng(0))


def test_erasure_probability_one_raises():
    """Satellite: p = 1.0 must be a clear error, not inf wall-clock."""
    with pytest.raises(ValueError, match="erasure probability"):
        NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=1.0)
    with pytest.raises(ValueError, match="erasure probability"):
        NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.1, p_up=1.0)
    with pytest.raises(ValueError, match="tau_up"):
        NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.1, tau_up=-0.1)
    xs, ys = _data(n=4)
    with pytest.raises(ValueError, match="erasure probability"):
        _run(xs, ys, "coded", "batched", iters=1, p_erasure=1.0)


def test_run_multi_shapes_and_bands():
    xs, ys = _data(n=6)
    fl = FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3)
    tc = TrainConfig(learning_rate=0.5, l2_reg=0.0)
    sim = api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme="coded"), xs, ys)
    res = sim.run_multi(12, 5, eval_fn=lambda th: (0.0, 1.0))
    assert res.theta.shape == (5, sim.q, sim.c)
    assert res.wall_clock.shape == (5, 12)
    assert res.returned.shape == (5, 12)
    assert np.all(np.diff(res.wall_clock, axis=1) > 0)
    mean, std = res.wall_clock_bands()
    assert mean.shape == (12,) and std.shape == (12,)
    # coded rounds take exactly t*, so realizations agree and std is 0
    np.testing.assert_allclose(std, 0.0, atol=1e-6)
    assert res.accuracy is not None and res.accuracy.shape == (5,)


def test_run_multi_realizations_differ_uncoded():
    """Naive rounds depend on the sampled max delay -> realizations differ."""
    xs, ys = _data(n=6)
    fl = FLConfig(n_clients=6, seed=3)
    tc = TrainConfig(learning_rate=0.5, l2_reg=0.0)
    sim = api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme="naive"), xs, ys)
    res = sim.run_multi(10, 4)
    assert np.std(res.wall_clock[:, -1]) > 0.0


@pytest.mark.parametrize("kernel_backend", ["xla", "pallas"])
def test_fused_coded_round_matches_two_call_oracle(kernel_backend):
    """Fused parity-as-pseudo-client round == the historical two-call path
    (batched_client_gradients + separate coded_gradient launch)."""
    xs, ys = _data()
    res_f = _run(xs, ys, "coded", "batched", iters=15,
                 kernel_backend=kernel_backend)
    sim_u = _exp(xs, ys, "coded", kernel_backend=kernel_backend,
                 fused_coded=False)
    trace = lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)
    res_u = sim_u.run(15, eval_fn=trace, eval_every=1)
    np.testing.assert_allclose(np.asarray(res_f.theta),
                               np.asarray(res_u.theta), atol=1e-5)
    for hf, hu in zip(res_f.history, res_u.history):
        assert hf.returned == hu.returned
        np.testing.assert_allclose(hf.loss, hu.loss, rtol=1e-4, atol=1e-5)


def test_fused_tensor_single_call_equals_two_kernels():
    """One masked-kernel call over the (n+1)-row fused tensor == n client
    gradients + the separately scaled coded gradient."""
    rng = np.random.default_rng(5)
    n, l, q, c, u = 5, 12, 16, 3, 9
    sub_x = rng.normal(size=(n, l, q)).astype(np.float32)
    sub_y = rng.normal(size=(n, l, c)).astype(np.float32)
    mask = (rng.random((n, l)) < 0.7).astype(np.float32)
    sub_x *= mask[:, :, None]
    sub_y *= mask[:, :, None]
    par_x = rng.normal(size=(u, q)).astype(np.float32)
    par_y = rng.normal(size=(u, c)).astype(np.float32)
    theta = rng.normal(size=(q, c)).astype(np.float32)
    fx, fy, fmask = aggregation.fused_client_parity_tensors(
        jnp.asarray(sub_x), jnp.asarray(sub_y), jnp.asarray(mask),
        jnp.asarray(par_x), jnp.asarray(par_y))
    assert fx.shape == (n + 1, max(l, u), q)
    g_all = aggregation.batched_client_gradients(fx, fy, theta, mask=fmask)
    g_clients = aggregation.batched_client_gradients(
        jnp.asarray(sub_x), jnp.asarray(sub_y), theta,
        mask=jnp.asarray(mask))
    g_coded = aggregation.coded_gradient(par_x, par_y, theta, pnr_c=0.0)
    np.testing.assert_allclose(np.asarray(g_all[:n]), np.asarray(g_clients),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_all[n]), np.asarray(g_coded),
                               rtol=1e-4, atol=1e-5)


def test_vectorized_subset_sampling_spec():
    """Pin the v2 processed-subset sampling contract: one `rng.permuted`
    draw over an (n, l) index matrix, first loads[j] entries per row,
    sorted; weights sqrt(1 - p_return) on processed points, 1 elsewhere.
    (v1 drew rng.permutation per client — a different, unversioned
    stream.)"""
    xs, ys = _data(n=5, l=16, q=12, c=2)
    fl = FLConfig(n_clients=5, delta=0.3, seed=11)
    tc = TrainConfig(learning_rate=0.5)
    sim = api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme="coded"), xs, ys)
    # replay: the setup rng chain consumes the permuted draw first
    rng = np.random.default_rng(fl.seed + 17)
    perm = rng.permuted(np.tile(np.arange(sim.l), (sim.n, 1)), axis=1)
    for j in range(sim.n):
        want = np.sort(perm[j, : int(sim.loads[j])])
        np.testing.assert_array_equal(sim.processed_idx[j], want)


def test_encode_local_batched_pallas_single_call_bit_equal():
    """Satellite: the Pallas path of encode_local_batched is ONE batched
    kernel launch, bit-equal to the per-client encode_local loop."""
    from repro.core import encoding
    rng = np.random.default_rng(3)
    n, l, q, c, u = 6, 20, 24, 4, 13
    xs = jnp.asarray(rng.normal(size=(n, l, q)).astype(np.float32))
    ys = jnp.asarray(rng.normal(size=(n, l, c)).astype(np.float32))
    ws = rng.random((n, l)).astype(np.float32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n))
    batched = encoding.encode_local_batched(keys, xs, ys, ws, u,
                                            use_pallas=True)
    for j in range(n):
        one = encoding.encode_local(keys[j], xs[j], ys[j], ws[j], u,
                                    use_pallas=True)
        np.testing.assert_array_equal(np.asarray(batched.x[j]),
                                      np.asarray(one.x))
        np.testing.assert_array_equal(np.asarray(batched.y[j]),
                                      np.asarray(one.y))


def test_batched_parity_matches_sequential_encode():
    """Vmapped encode in _setup_coded == the sequential per-client chain."""
    from repro.core import encoding
    xs, ys = _data(n=5, l=16, q=12, c=2)
    fl = FLConfig(n_clients=5, delta=0.3, seed=11)
    tc = TrainConfig(learning_rate=0.5)
    sim = api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme="coded"), xs, ys)
    # replay the legacy sequential key chain + per-client encode
    key = jax.random.PRNGKey(fl.seed + 99)
    parities = []
    for j in range(sim.n):
        w = encoding.weight_vector(sim.l, sim.processed_idx[j],
                                   float(sim.p_return[j]))
        key, sub = jax.random.split(key)
        parities.append(encoding.encode_local(sub, sim.x[j], sim.y[j],
                                              w, sim.u))
    ref = encoding.aggregate_parity(parities)
    np.testing.assert_allclose(np.asarray(sim.parity.x), np.asarray(ref.x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sim.parity.y), np.asarray(ref.y),
                               rtol=1e-5, atol=1e-5)
