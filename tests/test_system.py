"""End-to-end behaviour tests of the CodedFedL system (paper §V claims)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, RFFConfig, TrainConfig
from repro.core import rff
from repro.core.delay_model import mec_network
from repro.data import sharding, synthetic


@pytest.fixture(scope="module")
def setup():
    fl = FLConfig(n_clients=12, delta=0.2, psi=0.2, seed=0)
    ds = synthetic.synthetic_classification(m_train=1200, m_test=400, d=32,
                                            seed=0)
    rcfg = RFFConfig(q=128, sigma=2.0)
    om, de = rff.rff_params(rcfg, 32)
    xh_tr = np.asarray(rff.rff_transform(jnp.asarray(ds.x_train), om, de))
    xh_te = np.asarray(rff.rff_transform(jnp.asarray(ds.x_test), om, de))
    lr = rff.suggest_lr(xh_tr)
    nodes = mec_network(fl, d_scalars_per_point=rcfg.q * ds.n_classes)
    shards = sharding.sort_and_shard(xh_tr, ds.y_train, fl.n_clients)
    per_client = sharding.assign_shards_by_speed(shards, nodes,
                                                 minibatch=100)
    xs = np.stack([c[0] for c in per_client])
    ys = np.stack([ds.one_hot(c[1]) for c in per_client])
    tcfg = TrainConfig(learning_rate=lr, lr_decay_epochs=(60, 90))

    def eval_fn(theta):
        th = np.asarray(theta)
        acc = float(((xh_te @ th).argmax(1) == ds.y_test).mean())
        return 0.0, acc

    results = {}
    for scheme in ("naive", "greedy", "coded"):
        sim = api.build_experiment(
            ExperimentSpec(fl=fl, train=tcfg, rff=rcfg, scheme=scheme),
            xs, ys)
        results[scheme] = sim.run(120, eval_fn=eval_fn, eval_every=119)
    return results


def test_all_schemes_learn_something(setup):
    for scheme, res in setup.items():
        acc = res.history[-1].accuracy
        assert acc > 0.3, (scheme, acc)


def test_coded_matches_naive_per_iteration(setup):
    """Paper Fig 4b/5b: coded ~= naive accuracy at equal iterations."""
    a_naive = setup["naive"].history[-1].accuracy
    a_coded = setup["coded"].history[-1].accuracy
    assert a_coded >= a_naive - 0.05


def test_greedy_degrades_under_noniid(setup):
    """Paper §V-B: greedy misses whole classes => accuracy gap."""
    assert setup["greedy"].history[-1].accuracy < \
        setup["naive"].history[-1].accuracy - 0.03


def test_coded_faster_wallclock(setup):
    """Paper Tables II/III: coded wall-clock < naive at equal iterations."""
    w_naive = setup["naive"].history[-1].wall_clock
    w_coded = setup["coded"].history[-1].wall_clock
    assert w_coded < w_naive


def test_deadline_certainty(setup):
    """Coded rounds always take exactly t* (plus one-time setup)."""
    res = setup["coded"]
    t = res.t_star
    times = np.diff([h.wall_clock for h in res.history])
    assert np.allclose(times, t, rtol=1e-6)


def test_loads_bounded(setup):
    res = setup["coded"]
    assert np.all(res.loads >= 0)
    assert np.all(res.loads <= 100)
