"""Asymmetric up/downlink generalization (paper §II-B footnote 1)."""
import numpy as np

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.core.delay_model import (NodeDelayParams, sample_round_times,
                                    scale_tau)
from repro.core import load_allocation as la


def test_symmetric_default_unchanged():
    nd = NodeDelayParams(mu=4.0, alpha=2.0, tau=0.25, p=0.1)
    assert nd._tau_up == nd.tau and nd._p_up == nd.p


def test_asym_expected_delay():
    nd = NodeDelayParams(mu=4.0, alpha=2.0, tau=0.2, p=0.1,
                         tau_up=0.6, p_up=0.3)
    # eq.15 generalized: l/mu(1+1/a) + tau/(1-p) + tau_up/(1-p_up)
    expect = 10 / 4 * 1.5 + 0.2 / 0.9 + 0.6 / 0.7
    assert abs(nd.expected_delay(10.0) - expect) < 1e-12


def test_asym_cdf_matches_montecarlo():
    nd = NodeDelayParams(mu=2.0, alpha=1.5, tau=0.3, p=0.2,
                         tau_up=0.8, p_up=0.4)
    rng = np.random.default_rng(0)
    s = nd.sample(rng, 5.0, size=300_000)
    for t in [3.0, 6.0, 12.0]:
        assert abs(np.mean(s <= t) - nd.cdf(t, 5.0)) < 5e-3, t


def test_asym_cdf_reduces_to_symmetric():
    sym = NodeDelayParams(mu=3.0, alpha=2.0, tau=0.4, p=0.15)
    asym = NodeDelayParams(mu=3.0, alpha=2.0, tau=0.4, p=0.15,
                           tau_up=0.4, p_up=0.15)
    for t in [1.5, 4.0, 9.0]:
        assert abs(sym.cdf(t, 6.0) - asym.cdf(t, 6.0)) < 1e-9


def test_asym_scale_tau():
    nd = NodeDelayParams(mu=1.0, alpha=1.0, tau=2.0, p=0.1, tau_up=3.0)
    nd2 = scale_tau(nd, 10.0)
    assert nd2.tau == 20.0 and nd2.tau_up == 30.0


def test_asym_two_step_allocation():
    rng = np.random.default_rng(3)
    clients = [NodeDelayParams(mu=float(rng.uniform(1, 10)), alpha=2.0,
                               tau=float(rng.uniform(0.01, 0.2)), p=0.1,
                               tau_up=float(rng.uniform(0.1, 0.5)), p_up=0.3)
               for _ in range(6)]
    m = 6 * 30.0
    alloc = la.two_step_allocate(clients, [30.0] * 6, None,
                                 u_max=0.2 * m, m=m)
    assert abs(alloc.total_return - m) < 1e-2 * m
    # slower uplinks must yield a larger deadline than reciprocal fast links
    fast = [NodeDelayParams(mu=c.mu, alpha=c.alpha, tau=c.tau, p=0.1)
            for c in clients]
    alloc_fast = la.two_step_allocate(fast, [30.0] * 6, None,
                                      u_max=0.2 * m, m=m)
    assert alloc.t_star > alloc_fast.t_star


def _asym_nodes(n, seed=11):
    rng = np.random.default_rng(seed)
    return [NodeDelayParams(
        mu=float(rng.uniform(50, 300)), alpha=2.0,
        tau=float(rng.uniform(0.002, 0.02)), p=float(rng.uniform(0, 0.25)),
        tau_up=float(rng.uniform(0.01, 0.06)),
        p_up=float(rng.uniform(0.1, 0.45))) for _ in range(n)]


def test_asym_vectorized_sampler_matches_expected_delay():
    """sample_round_times: each direction sampled with its own (tau, p) —
    the per-node mean must match the asymmetric eq. 15 expectation."""
    nodes = _asym_nodes(4, seed=2)
    loads = np.array([10.0, 0.0, 25.0, 3.0])
    t = sample_round_times(nodes, loads, np.random.default_rng(0),
                           rounds=200_000)
    want = [nd.expected_delay(ld) for nd, ld in zip(nodes, loads)]
    np.testing.assert_allclose(t.mean(axis=0), want, rtol=0.02)


def test_asym_vectorized_alloc_backend_end_to_end():
    """An asymmetric MEC network runs through build_experiment with the
    VECTORIZED allocation solver: same deployment (deadline, loads,
    trajectory) as the scalar backend, asymmetric delays sampled per
    direction throughout the run."""
    rng = np.random.default_rng(4)
    n, l, q, c = 5, 14, 16, 2
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    nodes = _asym_nodes(n)
    fl = FLConfig(n_clients=n, delta=0.3, seed=7)
    tc = TrainConfig(learning_rate=0.5, l2_reg=0.0)
    runs = {}
    for backend in ("scalar", "vectorized"):
        exp = api.build_experiment(
            ExperimentSpec(fl=fl, train=tc, scheme="coded",
                           alloc_backend=backend), xs, ys, nodes=nodes)
        assert all(nd.tau_up is not None for nd in exp.nodes)
        runs[backend] = (exp, exp.run(6))
    e_s, r_s = runs["scalar"]
    e_v, r_v = runs["vectorized"]
    assert abs(e_v.t_star - e_s.t_star) < 2e-5 * (1.0 + e_s.t_star)
    np.testing.assert_array_equal(e_v.loads, e_s.loads)
    # the deadline roots differ within solver tolerance, so the parity
    # weights (sqrt(1 - P(return by t*))) differ in the 4th decimal —
    # trajectories agree to that level, not to fp32 epsilon
    np.testing.assert_allclose(np.asarray(r_v.theta),
                               np.asarray(r_s.theta), atol=1e-4)
