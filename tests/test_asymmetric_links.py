"""Asymmetric up/downlink generalization (paper §II-B footnote 1)."""
import numpy as np

from repro.core.delay_model import NodeDelayParams, scale_tau
from repro.core import load_allocation as la


def test_symmetric_default_unchanged():
    nd = NodeDelayParams(mu=4.0, alpha=2.0, tau=0.25, p=0.1)
    assert nd._tau_up == nd.tau and nd._p_up == nd.p


def test_asym_expected_delay():
    nd = NodeDelayParams(mu=4.0, alpha=2.0, tau=0.2, p=0.1,
                         tau_up=0.6, p_up=0.3)
    # eq.15 generalized: l/mu(1+1/a) + tau/(1-p) + tau_up/(1-p_up)
    expect = 10 / 4 * 1.5 + 0.2 / 0.9 + 0.6 / 0.7
    assert abs(nd.expected_delay(10.0) - expect) < 1e-12


def test_asym_cdf_matches_montecarlo():
    nd = NodeDelayParams(mu=2.0, alpha=1.5, tau=0.3, p=0.2,
                         tau_up=0.8, p_up=0.4)
    rng = np.random.default_rng(0)
    s = nd.sample(rng, 5.0, size=300_000)
    for t in [3.0, 6.0, 12.0]:
        assert abs(np.mean(s <= t) - nd.cdf(t, 5.0)) < 5e-3, t


def test_asym_cdf_reduces_to_symmetric():
    sym = NodeDelayParams(mu=3.0, alpha=2.0, tau=0.4, p=0.15)
    asym = NodeDelayParams(mu=3.0, alpha=2.0, tau=0.4, p=0.15,
                           tau_up=0.4, p_up=0.15)
    for t in [1.5, 4.0, 9.0]:
        assert abs(sym.cdf(t, 6.0) - asym.cdf(t, 6.0)) < 1e-9


def test_asym_scale_tau():
    nd = NodeDelayParams(mu=1.0, alpha=1.0, tau=2.0, p=0.1, tau_up=3.0)
    nd2 = scale_tau(nd, 10.0)
    assert nd2.tau == 20.0 and nd2.tau_up == 30.0


def test_asym_two_step_allocation():
    rng = np.random.default_rng(3)
    clients = [NodeDelayParams(mu=float(rng.uniform(1, 10)), alpha=2.0,
                               tau=float(rng.uniform(0.01, 0.2)), p=0.1,
                               tau_up=float(rng.uniform(0.1, 0.5)), p_up=0.3)
               for _ in range(6)]
    m = 6 * 30.0
    alloc = la.two_step_allocate(clients, [30.0] * 6, None,
                                 u_max=0.2 * m, m=m)
    assert abs(alloc.total_return - m) < 1e-2 * m
    # slower uplinks must yield a larger deadline than reciprocal fast links
    fast = [NodeDelayParams(mu=c.mu, alpha=c.alpha, tau=c.tau, p=0.1)
            for c in clients]
    alloc_fast = la.two_step_allocate(fast, [30.0] * 6, None,
                                      u_max=0.2 * m, m=m)
    assert alloc.t_star > alloc_fast.t_star
