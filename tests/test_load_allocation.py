"""Load allocation optimizer (paper §III-C, §IV, Appendix A/C/D)."""
import math

import numpy as np
import pytest

from repro.core.delay_model import NodeDelayParams
from repro.core import load_allocation as la


def node(mu=2.0, alpha=20.0, tau=math.sqrt(3.0), p=0.9):
    """The paper's Fig. 3 illustration parameters."""
    return NodeDelayParams(mu=mu, alpha=alpha, tau=tau, p=p)


class TestLambertW:
    def test_inverse_identity(self):
        for x in [-0.367, -0.2, -0.05, -1e-4]:
            w = la.lambert_w_minus1(x)
            assert w <= -1.0
            assert abs(w * math.exp(w) - x) < 1e-10 * max(1, abs(x))

    def test_domain(self):
        with pytest.raises(ValueError):
            la.lambert_w_minus1(0.1)
        with pytest.raises(ValueError):
            la.lambert_w_minus1(-1.0)


class TestExpectedReturn:
    def test_zero_before_two_tau(self):
        nd = node()
        assert la.expected_return(nd, 2 * nd.tau * 0.99, 1.0) == 0.0

    def test_matches_montecarlo(self):
        nd = node(mu=5.0, alpha=2.0, tau=0.1, p=0.1)
        rng = np.random.default_rng(0)
        t, load = 3.0, 4.0
        samples = nd.sample(rng, load, size=200_000)
        mc = load * np.mean(samples <= t)
        an = la.expected_return(nd, t, load)
        assert abs(mc - an) < 0.02 * load

    def test_piecewise_concave_boundaries(self):
        """E[R] is increasing-then-decreasing within each concavity piece."""
        nd = node()
        t = 10.0
        ls = np.linspace(0.01, nd.mu * (t - 2 * nd.tau), 400)
        vals = [la.expected_return(nd, t, l) for l in ls]
        assert max(vals) > 0

    def test_awgn_closed_form_matches_numeric(self):
        nd = NodeDelayParams(mu=5.0, alpha=2.0, tau=0.1, p=0.0)
        for t in [0.5, 1.0, 3.0, 10.0]:
            l_c = la.awgn_optimal_load(nd, t, cap=30.0)
            l_n, r_n = la.optimal_load(nd, t, cap=30.0)
            r_c = la.awgn_optimal_return(nd, t, cap=30.0)
            assert abs(l_c - l_n) < 1e-3 * max(1.0, l_c)
            assert abs(r_c - r_n) < 1e-3 * max(1.0, r_c)


class TestOptimalLoad:
    def test_respects_cap(self):
        nd = node(p=0.1, tau=0.05, mu=10.0, alpha=2.0)
        l, r = la.optimal_load(nd, t=100.0, cap=7.0)
        assert l <= 7.0 + 1e-9
        assert r <= 7.0 + 1e-9

    def test_monotone_in_t(self):
        """Optimized expected return is monotone increasing in t (App. C)."""
        nd = node(p=0.3, tau=0.2, mu=3.0, alpha=2.0)
        rets = [la.optimal_load(nd, t, cap=50.0)[1]
                for t in np.linspace(0.5, 20, 30)]
        diffs = np.diff(rets)
        assert np.all(diffs >= -1e-6)


class TestTwoStep:
    def test_total_return_equals_m(self):
        rng = np.random.default_rng(1)
        clients = [NodeDelayParams(mu=rng.uniform(1, 10), alpha=2.0,
                                   tau=rng.uniform(0.01, 0.3), p=0.1)
                   for _ in range(8)]
        caps = [40.0] * 8
        m = 8 * 40.0
        alloc = la.two_step_allocate(clients, caps, server=None,
                                     u_max=0.2 * m, m=m)
        assert abs(alloc.total_return - m) < 1e-2 * m
        assert np.all(alloc.loads <= 40.0 + 1e-9)
        assert alloc.t_star > 0

    def test_more_redundancy_smaller_deadline(self):
        """Paper Fig 4a: larger delta (u_max) => smaller t*."""
        rng = np.random.default_rng(2)
        clients = [NodeDelayParams(mu=rng.uniform(1, 10), alpha=2.0,
                                   tau=rng.uniform(0.01, 0.3), p=0.1)
                   for _ in range(8)]
        caps = [40.0] * 8
        m = 8 * 40.0
        t1 = la.two_step_allocate(clients, caps, None, 0.1 * m, m).t_star
        t2 = la.two_step_allocate(clients, caps, None, 0.3 * m, m).t_star
        assert t2 < t1

    def test_with_server_node(self):
        clients = [NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.1)
                   for _ in range(4)]
        server = NodeDelayParams(mu=500.0, alpha=20.0, tau=0.001, p=0.01)
        m = 4 * 20.0
        alloc = la.two_step_allocate(clients, [20.0] * 4, server,
                                     u_max=0.5 * m, m=m)
        assert abs(alloc.total_return - m) < 1e-2 * m
        assert alloc.coded_return > 0

    def test_infeasible_raises(self):
        clients = [NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.1)]
        with pytest.raises(ValueError):
            la.two_step_allocate(clients, [10.0], None, u_max=1.0, m=100.0)


def _population(n, seed, p_max=0.5):
    rng = np.random.default_rng(seed)
    return [NodeDelayParams(mu=float(rng.uniform(1, 10)),
                            alpha=float(rng.uniform(0.5, 5)),
                            tau=float(rng.uniform(0.01, 0.3)),
                            p=float(rng.uniform(0, p_max)))
            for _ in range(n)]


class TestVectorizedSolver:
    """Vectorized fixed-iteration JAX solver vs the scalar NumPy oracle."""

    def test_step1_matches_scalar_node_for_node(self):
        clients = _population(40, seed=7)
        caps = [40.0] * 40
        for t in (0.5, 2.5, 8.0):
            lv, rv = la.vectorized_optimal_loads(clients, t, caps)
            for j, nd in enumerate(clients):
                l_s, r_s = la.optimal_load(nd, t, caps[j])
                assert abs(lv[j] - l_s) < 1e-6 * (1.0 + caps[j])
                assert abs(rv[j] - r_s) < 1e-6 * (1.0 + r_s)

    def test_step1_matches_lambert_w_closed_form_at_p0(self):
        """p=0 must reproduce the AWGN Lambert-W closed form (eq. 34/35)."""
        awgn = _population(12, seed=3, p_max=0.0)
        caps = [25.0] * 12
        for t in (0.2, 1.0, 4.0, 15.0):
            lv, rv = la.vectorized_optimal_loads(awgn, t, caps)
            for j, nd in enumerate(awgn):
                l_c = la.awgn_optimal_load(nd, t, caps[j])
                r_c = la.awgn_optimal_return(nd, t, caps[j])
                assert abs(lv[j] - l_c) < 1e-6 * (1.0 + caps[j])
                assert abs(rv[j] - r_c) < 1e-6 * (1.0 + r_c)

    def test_two_step_matches_scalar(self):
        clients = _population(10, seed=11)
        caps = [30.0] * 10
        m = 10 * 30.0
        a_s = la.two_step_allocate(clients, caps, None, 0.2 * m, m)
        a_v = la.two_step_allocate_vectorized(clients, caps, None,
                                              0.2 * m, m)
        # scalar bisection stops at tol=1e-6*(1+t); the vectorized root is
        # tighter, so agreement is bounded by the scalar's own tolerance
        assert abs(a_v.t_star - a_s.t_star) <= 2e-6 * (1.0 + a_s.t_star)
        np.testing.assert_allclose(a_v.loads, a_s.loads,
                                   atol=1e-4, rtol=1e-4)
        assert abs(a_v.total_return - m) < 1e-2 * m
        # node-for-node at the SAME deadline: within 1e-6
        lv, _ = la.vectorized_optimal_loads(clients, a_v.t_star, caps)
        for j, nd in enumerate(clients):
            l_s, _ = la.optimal_load(nd, a_v.t_star, caps[j])
            assert abs(lv[j] - l_s) < 1e-6 * (1.0 + caps[j])

    def test_two_step_with_server_node(self):
        """The n+1-th (MEC server) node is solved in the same call."""
        clients = [NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.1)
                   for _ in range(4)]
        server = NodeDelayParams(mu=500.0, alpha=20.0, tau=0.001, p=0.01)
        m = 4 * 20.0
        a_s = la.two_step_allocate(clients, [20.0] * 4, server,
                                   u_max=0.5 * m, m=m)
        a_v = la.two_step_allocate_vectorized(clients, [20.0] * 4, server,
                                              u_max=0.5 * m, m=m)
        assert abs(a_v.t_star - a_s.t_star) <= 2e-6 * (1.0 + a_s.t_star)
        assert abs(a_v.u_star - a_s.u_star) < 1e-4 * (1.0 + a_s.u_star)
        assert abs(a_v.coded_return - a_s.coded_return) < 1e-4
        assert a_v.loads.shape == (4,)

    def test_thousand_nodes_single_jitted_call(self):
        """n >= 1000 heterogeneous nodes in one fixed-iteration jitted solve."""
        n = 1000
        clients = _population(n, seed=5, p_max=0.1)
        caps = [40.0] * n
        m = float(n * 40.0)
        alloc = la.two_step_allocate_vectorized(
            clients, caps, None, u_max=0.2 * m, m=m, t_hi=8.0, n_bisect=44)
        assert alloc.t_star > 0
        assert alloc.loads.shape == (n,)
        assert np.all(alloc.loads >= -1e-9)
        assert np.all(alloc.loads <= 40.0 + 1e-6)
        assert abs(alloc.total_return - m) < 1e-4 * m
        # spot-check a handful of nodes against the scalar oracle at t*
        for j in (0, 123, 456, 789, 999):
            l_s, _ = la.optimal_load(clients[j], alloc.t_star, 40.0)
            assert abs(alloc.loads[j] - l_s) < 1e-6 * 41.0

    def test_infeasible_raises(self):
        clients = [NodeDelayParams(mu=5.0, alpha=2.0, tau=0.05, p=0.1)]
        with pytest.raises(ValueError, match="infeasible"):
            la.two_step_allocate_vectorized(clients, [10.0], None,
                                            u_max=1.0, m=100.0)

    def test_asymmetric_step1_matches_scalar(self):
        """tau_up/p_up links flow through the flattened per-direction
        transmission grid: node-for-node agreement with the scalar
        golden-section oracle (footnote 1 generalization)."""
        rng = np.random.default_rng(17)
        clients = [NodeDelayParams(
            mu=float(rng.uniform(1, 10)), alpha=float(rng.uniform(0.5, 4)),
            tau=float(rng.uniform(0.01, 0.2)), p=float(rng.uniform(0, 0.3)),
            tau_up=float(rng.uniform(0.05, 0.5)),
            p_up=float(rng.uniform(0, 0.4))) for _ in range(8)]
        caps = [30.0] * 8
        for t in (0.8, 3.0, 9.0):
            lv, rv = la.vectorized_optimal_loads(clients, t, caps)
            for j, nd in enumerate(clients):
                l_s, r_s = la.optimal_load(nd, t, caps[j])
                assert abs(lv[j] - l_s) < 1e-5 * (1.0 + caps[j]), (t, j)
                assert abs(rv[j] - r_s) < 1e-5 * (1.0 + r_s), (t, j)

    def test_asymmetric_two_step_matches_scalar(self):
        rng = np.random.default_rng(23)
        clients = [NodeDelayParams(
            mu=float(rng.uniform(1, 10)), alpha=2.0,
            tau=float(rng.uniform(0.01, 0.2)), p=0.1,
            tau_up=float(rng.uniform(0.1, 0.5)), p_up=0.3)
            for _ in range(6)]
        m = 6 * 30.0
        a_s = la.two_step_allocate(clients, [30.0] * 6, None, 0.2 * m, m)
        a_v = la.two_step_allocate_vectorized(clients, [30.0] * 6, None,
                                              0.2 * m, m)
        assert abs(a_v.t_star - a_s.t_star) <= 2e-6 * (1.0 + a_s.t_star)
        np.testing.assert_allclose(a_v.loads, a_s.loads,
                                   atol=1e-3, rtol=1e-3)
        assert abs(a_v.total_return - m) < 1e-2 * m
