"""MoE capacity dispatch correctness vs a dense-routing oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import MoEConfig, ModelConfig
from repro.models import moe


def _cfg(E=4, k=2, cap=64.0, shared=0):
    return ModelConfig(
        name="t", arch_type="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=48, vocab=64, dtype="float32",
        moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=48,
                      capacity_factor=cap, num_shared_experts=shared,
                      aux_loss_weight=0.0))


def _dense_oracle(p, x, cfg):
    """Route every token to its top-k experts with no capacity limit."""
    m = cfg.moe
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], axis=-1)
    gv, gi = jax.lax.top_k(probs, m.top_k)
    gv = gv / jnp.sum(gv, axis=-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(m.num_experts):
        h = jax.nn.silu(xt @ p["w1"][e]) * (xt @ p["w3"][e])
        ye = h @ p["w2"][e]
        w_e = jnp.sum(jnp.where(gi == e, gv, 0.0), axis=-1)
        out = out + ye * w_e[:, None]
    if "ws1" in p:
        h = jax.nn.silu(xt @ p["ws1"]) * (xt @ p["ws3"])
        out = out + h @ p["ws2"]
    return out.reshape(B, S, D)


def test_capacity_dispatch_matches_oracle_when_no_drops():
    cfg = _cfg(cap=64.0)          # capacity huge => nothing dropped
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 32)),
                    jnp.float32)
    got, aux = moe.moe_ffn(p, x, cfg)
    want = _dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


def test_shared_experts_included():
    cfg = _cfg(shared=1)
    p = moe.init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
    assert "ws1" in p
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 8, 32)),
                    jnp.float32)
    got, _ = moe.moe_ffn(p, x, cfg)
    want = _dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


def test_tight_capacity_drops_tokens():
    """With capacity_factor < 1 some tokens are dropped — output of the
    dropped slots must be the shared/zero path, not garbage."""
    cfg = _cfg(cap=0.5)
    p = moe.init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 16, 32)),
                    jnp.float32)
    got, _ = moe.moe_ffn(p, x, cfg)
    dense = _dense_oracle(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(got)))
    # dropped mass => dispatch output norm strictly below the no-drop oracle
    assert float(jnp.linalg.norm(got)) < float(jnp.linalg.norm(dense))


def test_aux_loss_uniform_router_near_one():
    """Balanced routing gives aux ~= aux_weight (GShard normalization)."""
    cfg = dataclasses.replace(
        _cfg(), moe=dataclasses.replace(_cfg().moe, aux_loss_weight=1.0))
    p = moe.init_moe(jax.random.PRNGKey(3), cfg, jnp.float32)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, 64, 32)),
                    jnp.float32)
    _, aux = moe.moe_ffn(p, x, cfg)
    # me = 1/E, ce ~ 1/E => E * sum(me*ce) ~ 1
    assert 0.5 < float(aux) < 2.0
