"""Assert every assigned architecture config matches the assignment table
exactly (layers / d_model / heads / kv / d_ff / vocab / family features)."""
import pytest

from repro.configs import ARCH_IDS, get_config

EXPECT = {
    "yi-6b": dict(arch_type="dense", n_layers=32, d_model=4096, n_heads=32,
                  n_kv_heads=4, d_ff=11008, vocab=64000),
    "command-r-plus-104b": dict(arch_type="dense", n_layers=64,
                                d_model=12288, n_heads=96, n_kv_heads=8,
                                d_ff=33792, vocab=256000),
    "internvl2-1b": dict(arch_type="vlm", n_layers=24, d_model=896,
                         n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151655),
    "mixtral-8x7b": dict(arch_type="moe", n_layers=32, d_model=4096,
                         n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000),
    "rwkv6-1.6b": dict(arch_type="ssm", n_layers=24, d_model=2048,
                       d_ff=7168, vocab=65536),
    "qwen3-4b": dict(arch_type="dense", n_layers=36, d_model=2560,
                     n_heads=32, n_kv_heads=8, d_ff=9728, vocab=151936),
    "jamba-1.5-large-398b": dict(arch_type="hybrid", n_layers=72,
                                 d_model=8192, n_heads=64, n_kv_heads=8,
                                 d_ff=24576, vocab=65536),
    "deepseek-v2-lite-16b": dict(arch_type="moe", n_layers=27, d_model=2048,
                                 n_heads=16, n_kv_heads=16, d_ff=1408,
                                 vocab=102400),
    "whisper-base": dict(arch_type="audio", n_layers=6, d_model=512,
                         n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865),
    "qwen3-32b": dict(arch_type="dense", n_layers=64, d_model=5120,
                      n_heads=64, n_kv_heads=8, d_ff=25600, vocab=151936),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    for field, value in EXPECT[arch].items():
        assert getattr(cfg, field) == value, (arch, field)
    assert cfg.source, arch      # every config cites its source


def test_family_features():
    mixtral = get_config("mixtral-8x7b")
    assert mixtral.moe.num_experts == 8 and mixtral.moe.top_k == 2
    assert mixtral.swa_window == 4096
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.mla.kv_lora_rank == 512
    assert ds.moe.num_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    jamba = get_config("jamba-1.5-large-398b")
    assert jamba.ssm.attn_every_n == 8          # 1:7 attn:mamba
    assert jamba.moe.num_experts == 16 and jamba.moe.top_k == 2
    rwkv = get_config("rwkv6-1.6b")
    assert rwkv.attention_free and rwkv.rwkv.head_size == 64
    for a in ("qwen3-4b", "qwen3-32b"):
        assert get_config(a).qk_norm
    w = get_config("whisper-base")
    assert w.is_encdec and w.n_encoder_layers == 6 and w.encoder_seq == 1500
    ivl = get_config("internvl2-1b")
    assert ivl.n_prefix_patches == 256 and ivl.tie_embeddings
