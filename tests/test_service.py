"""ExperimentService: multiplexed block scheduling with durable resume.

Contract: N concurrent runs interleaved block-by-block produce results
bit-identical to running each spec alone (blocks only read their own
RunState — no cross-run leakage through the shared process); killing the
service loses at most the in-flight block, and a fresh service pointed
at the same checkpoint root finishes every run bit-identically.
"""
import numpy as np
import pytest

from repro import api
from repro.checkpoint import io as ckpt_io
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.launch.service import ExperimentService


def _data(n=6, l=16, q=24, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _spec(scheme="coded", **over):
    base = dict(
        fl=FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme=scheme, checkpoint_every=4)
    base.update(over)
    return ExperimentSpec(**base)


def _three_specs():
    """Three heterogeneous jobs: static coded, greedy with a different
    block size, and an adaptive traced-channel run."""
    return {
        "a": _spec("coded"),
        "b": _spec("greedy", checkpoint_every=3),
        "c": _spec("adaptive_coded", channel_profile="drift_churn",
                   adapt_every=2),
    }


def test_multiplexed_runs_match_individual(tmp_path):
    xs, ys = _data()
    svc = ExperimentService(str(tmp_path))
    for rid, spec in _three_specs().items():
        svc.submit(spec, xs, ys, 12, run_id=rid)
    assert len(svc.pending) == 3
    results = svc.run_until_complete()
    assert not svc.pending
    for rid, spec in _three_specs().items():
        solo = api.build_experiment(spec, xs, ys).run(12)
        np.testing.assert_array_equal(np.asarray(solo.theta),
                                      np.asarray(results[rid].theta))
        assert [h.wall_clock for h in solo.history] \
            == [h.wall_clock for h in results[rid].history]


def test_step_round_robins_across_runs(tmp_path):
    xs, ys = _data()
    svc = ExperimentService(str(tmp_path))
    for rid, spec in _three_specs().items():
        svc.submit(spec, xs, ys, 12, run_id=rid)
    first_cycle = [svc.step() for _ in range(3)]
    assert sorted(first_cycle) == ["a", "b", "c"]
    # every run advanced exactly one block and has one checkpoint on disk
    for rid in ("a", "b", "c"):
        run = svc.runs[rid]
        assert run.state.rounds_done == run.spec.checkpoint_every
        assert ckpt_io.latest_checkpoint(run.ckpt_dir) is not None


def test_service_kill_and_resume_bit_identical(tmp_path):
    """Partial progress -> new service, same root, same submissions ->
    identical final results (checkpoints carry ALL the state)."""
    xs, ys = _data()
    control = ExperimentService(str(tmp_path / "control"))
    for rid, spec in _three_specs().items():
        control.submit(spec, xs, ys, 12, run_id=rid)
    expect = control.run_until_complete()

    svc1 = ExperimentService(str(tmp_path / "killed"))
    for rid, spec in _three_specs().items():
        svc1.submit(spec, xs, ys, 12, run_id=rid)
    for _ in range(5):
        svc1.step()
    del svc1                                   # the kill

    svc2 = ExperimentService(str(tmp_path / "killed"))
    for rid, spec in _three_specs().items():
        run = svc2.submit(spec, xs, ys, 12, run_id=rid)
        assert run.resumed
        assert 0 < run.state.rounds_done < 12
    results = svc2.run_until_complete()
    for rid in expect:
        np.testing.assert_array_equal(np.asarray(expect[rid].theta),
                                      np.asarray(results[rid].theta))
        assert expect[rid].privacy_eps == results[rid].privacy_eps


def test_resubmitting_finished_run_returns_result(tmp_path):
    xs, ys = _data()
    spec = _spec("coded")
    svc1 = ExperimentService(str(tmp_path))
    svc1.submit(spec, xs, ys, 8, run_id="done")
    expect = svc1.run_until_complete()["done"]

    svc2 = ExperimentService(str(tmp_path))
    run = svc2.submit(spec, xs, ys, 8, run_id="done")
    assert run.resumed and run.done
    np.testing.assert_array_equal(np.asarray(expect.theta),
                                  np.asarray(run.result.theta))
    assert svc2.step() is None


def test_submit_validation(tmp_path):
    xs, ys = _data()
    svc = ExperimentService(str(tmp_path))
    with pytest.raises(ValueError, match="checkpoint_every"):
        svc.submit(_spec(checkpoint_every=0), xs, ys, 8, run_id="x")
    svc.submit(_spec(), xs, ys, 8, run_id="x")
    with pytest.raises(ValueError, match="already submitted"):
        svc.submit(_spec(), xs, ys, 8, run_id="x")
    # run_id can ride in the spec itself (validated as a slug there)
    run = svc.submit(_spec(run_id="from-spec"), xs, ys, 8)
    assert run.run_id == "from-spec"
    with pytest.raises(ValueError, match="run_id"):
        _spec(run_id="bad/slash")


def test_resubmit_horizon_mismatch_rejected(tmp_path):
    xs, ys = _data()
    spec = _spec("coded")
    svc1 = ExperimentService(str(tmp_path))
    svc1.submit(spec, xs, ys, 12, run_id="x")
    svc1.step()
    svc2 = ExperimentService(str(tmp_path))
    with pytest.raises(ValueError, match="horizon"):
        svc2.submit(spec, xs, ys, 16, run_id="x")


def test_service_multi_realization_job(tmp_path):
    """run_multi jobs multiplex alongside single runs."""
    xs, ys = _data()
    spec = _spec("coded", checkpoint_every=3)
    svc = ExperimentService(str(tmp_path))
    svc.submit(spec, xs, ys, 6, run_id="multi", n_realizations=3)
    svc.submit(_spec("greedy"), xs, ys, 8, run_id="single")
    results = svc.run_until_complete()
    solo = api.build_experiment(spec, xs, ys).run_multi(6, 3)
    np.testing.assert_array_equal(np.asarray(solo.theta),
                                  np.asarray(results["multi"].theta))
    np.testing.assert_array_equal(solo.wall_clock,
                                  results["multi"].wall_clock)
    assert np.asarray(results["single"].theta).shape == (24, 3)
