"""RFF embedding (§III-A) and privacy budget (Appendix F)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RFFConfig
from repro.core import privacy, rff


def test_rff_kernel_approximation():
    """phi(v1) phi(v2)^T ~= exp(-||v1-v2||^2 / 2 sigma^2)  (paper eq. 8/17)."""
    rng = np.random.default_rng(0)
    d, q, sigma = 20, 8192, 2.0
    cfg = RFFConfig(q=q, sigma=sigma, seed=3)
    omega, delta = rff.rff_params(cfg, d)
    v = jnp.asarray(rng.normal(size=(30, d)), jnp.float32)
    phi = rff.rff_transform(v, omega, delta)
    approx = np.asarray(phi @ phi.T)
    d2 = np.sum((np.asarray(v)[:, None] - np.asarray(v)[None]) ** 2, -1)
    exact = np.exp(-d2 / (2 * sigma ** 2))
    assert np.max(np.abs(approx - exact)) < 0.06


def test_rff_shared_seed_determinism():
    cfg = RFFConfig(q=64, sigma=1.0, seed=11)
    o1, d1 = rff.rff_params(cfg, 10)
    o2, d2 = rff.rff_params(cfg, 10)
    assert jnp.array_equal(o1, o2) and jnp.array_equal(d1, d2)


def test_rff_feature_norm():
    """Rows of phi(X) have norm ~<= 1 (sum of q cos^2 * 2/q <= 2... mean 1)."""
    cfg = RFFConfig(q=2048, sigma=1.0)
    omega, delta = rff.rff_params(cfg, 8)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(50, 8)), jnp.float32)
    phi = rff.rff_transform(x, omega, delta)
    norms = np.linalg.norm(np.asarray(phi), axis=1)
    assert np.all(norms < 1.5) and abs(norms.mean() - 1.0) < 0.1


def test_median_sigma_positive():
    x = np.random.default_rng(0).normal(size=(100, 5))
    assert rff.median_sigma(x) > 0


def test_median_sigma_excludes_self_pairs():
    """rng.integers can draw (i, i) pairs whose zero distance biases the
    median low at small n_pairs — every pair must be distinct.  With two
    points the only distinct pair is (0, 1), so the median is exactly
    their distance (the old code returned ~0 half the time)."""
    x = np.array([[0.0, 0.0], [3.0, 4.0]])
    for seed in range(8):
        assert rff.median_sigma(x, seed=seed) == pytest.approx(5.0)
    # a duplicated point is a legitimate zero distance and must survive
    dup = np.array([[1.0, 1.0], [1.0, 1.0], [4.0, 5.0]])
    assert rff.median_sigma(dup) >= 0
    with pytest.raises(ValueError, match="at least 2"):
        rff.median_sigma(x[:1])


def test_privacy_budget_monotone_in_u():
    """eps grows with coding redundancy u (eq. 62)."""
    x = np.random.default_rng(0).normal(size=(50, 10))
    e1 = privacy.mi_dp_budget(x, u=10)
    e2 = privacy.mi_dp_budget(x, u=1000)
    assert 0 < e1 < e2


def test_privacy_concentrated_feature_leaks_more():
    rng = np.random.default_rng(1)
    spread = rng.normal(size=(50, 10))
    concentrated = spread.copy()
    concentrated[:, 0] = 0.0
    concentrated[0, 0] = 5.0         # all mass of feature 0 on one point
    assert privacy.mi_dp_budget(concentrated, 100) > \
        privacy.mi_dp_budget(spread, 100)


def test_feature_spread_formula():
    x = np.array([[1.0, 2.0], [2.0, 0.5], [0.5, 1.0]])
    col_sq = (x ** 2).sum(0)
    col_max = (x ** 2).max(0)
    expect = np.sqrt(np.min(col_sq - col_max))
    assert abs(privacy.feature_spread(x) - expect) < 1e-12
