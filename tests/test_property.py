"""Hypothesis property-based tests on system invariants.

Degrades to a pytest skip (not a collection error) when `hypothesis` is not
installed in the environment.  Marked `kernels` so the CI kernel/property
job picks these up alongside the kernel oracle-equivalence sweeps.
"""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import load_allocation as la
from repro.core.delay_model import NodeDelayParams
from repro.core import encoding

pytestmark = pytest.mark.kernels

node_st = st.builds(
    NodeDelayParams,
    mu=st.floats(0.5, 50.0),
    alpha=st.floats(0.2, 30.0),
    tau=st.floats(0.01, 2.0),
    p=st.floats(0.0, 0.95),
)


@settings(max_examples=60, deadline=None)
@given(node_st, st.floats(0.1, 50.0), st.floats(0.0, 100.0))
def test_expected_return_bounded_by_load(nd, t, load):
    """0 <= E[R_j(t; l)] <= l for any node/deadline/load."""
    r = la.expected_return(nd, t, load)
    assert -1e-9 <= r <= load + 1e-9


@settings(max_examples=40, deadline=None)
@given(node_st, st.floats(0.5, 30.0), st.floats(1.0, 60.0))
def test_optimal_load_beats_endpoints(nd, t, cap):
    """The optimizer returns at least the better of the endpoint loads."""
    l, r = la.optimal_load(nd, t, cap)
    assert 0.0 <= l <= cap + 1e-9
    for probe in (cap, cap / 2, cap / 7):
        assert r >= la.expected_return(nd, t, probe) - 1e-6 * max(r, 1.0)


@settings(max_examples=30, deadline=None)
@given(node_st, st.floats(1.0, 60.0),
       st.floats(0.5, 10.0), st.floats(1.05, 3.0))
def test_optimized_return_monotone_in_t(nd, cap, t, factor):
    """Appendix C: optimized return never decreases as t grows."""
    r1 = la.optimal_load(nd, t, cap)[1]
    r2 = la.optimal_load(nd, t * factor, cap)[1]
    assert r2 >= r1 - 1e-6 * max(r1, 1.0)


@settings(max_examples=30, deadline=None)
@given(node_st, st.floats(0.2, 40.0), st.floats(0.1, 40.0))
def test_cdf_is_cdf(nd, t, load):
    c = nd.cdf(t, load)
    assert -1e-12 <= c <= 1.0
    assert nd.cdf(t * 2, load) >= c - 1e-12


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(5, 25),
       st.floats(0.05, 0.4), st.integers(0, 10_000))
def test_two_step_meets_target_return(n, cap, delta, seed):
    rng = np.random.default_rng(seed)
    clients = [NodeDelayParams(mu=float(rng.uniform(1, 10)), alpha=2.0,
                               tau=float(rng.uniform(0.01, 0.5)),
                               p=float(rng.uniform(0, 0.5)))
               for _ in range(n)]
    m = float(n * cap)
    alloc = la.two_step_allocate(clients, [float(cap)] * n, None,
                                 u_max=delta * m, m=m)
    assert abs(alloc.total_return - m) <= 1e-2 * m
    assert np.all(alloc.loads >= -1e-12)
    assert np.all(alloc.loads <= cap + 1e-9)


@settings(max_examples=15, deadline=None)
@given(st.lists(node_st, min_size=1, max_size=8),
       st.floats(0.3, 20.0), st.floats(1.0, 60.0))
def test_vectorized_step1_matches_scalar_node_for_node(nodes, t, cap):
    """The jitted vectorized step-1 solver == the scalar golden-section
    loop, node for node, on randomized populations."""
    caps = [cap] * len(nodes)
    lv, rv = la.vectorized_optimal_loads(nodes, t, caps)
    for j, nd in enumerate(nodes):
        l_s, r_s = la.optimal_load(nd, t, cap)
        assert abs(lv[j] - l_s) <= 1e-6 * (1.0 + cap)
        assert abs(rv[j] - r_s) <= 1e-6 * (1.0 + r_s)


@settings(max_examples=15, deadline=None)
@given(st.floats(0.5, 50.0), st.floats(0.2, 30.0), st.floats(0.01, 2.0),
       st.floats(0.3, 20.0), st.floats(1.0, 60.0))
def test_vectorized_step1_matches_lambert_w_at_p0(mu, alpha, tau, t, cap):
    """At p = 0 the vectorized solver must reproduce the AWGN Lambert-W
    closed form (paper eq. 34/35, Appendix D)."""
    nd = NodeDelayParams(mu=mu, alpha=alpha, tau=tau, p=0.0)
    lv, rv = la.vectorized_optimal_loads([nd], t, [cap])
    l_c = la.awgn_optimal_load(nd, t, cap)
    r_c = la.awgn_optimal_return(nd, t, cap)
    assert abs(lv[0] - l_c) <= 1e-6 * (1.0 + cap)
    assert abs(rv[0] - r_c) <= 1e-6 * (1.0 + r_c)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(5, 25),
       st.floats(0.05, 0.4), st.integers(0, 10_000))
def test_vectorized_two_step_matches_scalar(n, cap, delta, seed):
    """Full two-step: vectorized t* within the scalar bisection tolerance,
    and the total expected return still hits m."""
    rng = np.random.default_rng(seed)
    clients = [NodeDelayParams(mu=float(rng.uniform(1, 10)), alpha=2.0,
                               tau=float(rng.uniform(0.01, 0.5)),
                               p=float(rng.uniform(0, 0.5)))
               for _ in range(n)]
    m = float(n * cap)
    a_s = la.two_step_allocate(clients, [float(cap)] * n, None,
                               u_max=delta * m, m=m)
    a_v = la.two_step_allocate_vectorized(clients, [float(cap)] * n, None,
                                          u_max=delta * m, m=m)
    assert abs(a_v.t_star - a_s.t_star) <= 2e-6 * (1.0 + a_s.t_star)
    assert abs(a_v.total_return - m) <= 1e-2 * m
    assert np.all(a_v.loads >= -1e-12)
    assert np.all(a_v.loads <= cap + 1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 30), st.floats(0.0, 1.0))
def test_weight_vector_invariants(l, p_ret):
    idx = np.arange(0, l, 2)
    w = encoding.weight_vector(l, idx, p_ret)
    assert w.shape == (l,)
    assert np.all((0.0 <= w) & (w <= 1.0))
    # processed points carry sqrt(1-p), unprocessed carry exactly 1
    mask = np.zeros(l, bool)
    mask[idx] = True
    assert np.allclose(w[mask], math.sqrt(1.0 - p_ret))
    assert np.allclose(w[~mask], 1.0)
