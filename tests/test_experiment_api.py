"""Declarative experiment API: ExperimentSpec round-trip, scheme registry,
and the removal of the FederatedSimulation shim.

The contract under test: (1) a spec survives spec -> dict -> JSON -> spec
bit-exactly, equal specs build bit-equal step constants, and a revived
spec produces IDENTICAL theta trajectories on both kernel backends;
(2) the removed kwargs constructor is a stub whose error points at the
spec entrypoint; (3) every registered scheme (including the new
partial-redundancy one) runs through `repro.api.build_experiment`.
"""
import json

import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.core import fed_runtime, schemes
from repro.core.delay_model import HETEROGENEITY_PROFILES


def _data(n=6, l=16, q=24, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _spec(scheme="coded", **over):
    base = dict(
        fl=FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme=scheme)
    base.update(over)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# ExperimentSpec
# ---------------------------------------------------------------------------

def test_spec_json_round_trip_equality():
    spec = _spec("partial_coded", scheme_params={"u_fraction": 0.3},
                 delay_profile="paper", kernel_backend="pallas",
                 alloc_backend="scalar", mesh=2, fused_coded=False)
    revived = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert revived == spec
    assert hash(revived) == hash(spec)


def test_spec_round_trip_build_consts_bit_equal():
    """spec -> dict -> spec reproduces bit-equal step constants (the arrays
    the whole compiled run is a pure function of)."""
    xs, ys = _data()
    spec = _spec("coded")
    revived = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    c1 = api.build_experiment(spec, xs, ys).build_consts()
    c2 = api.build_experiment(revived, xs, ys).build_consts()
    assert set(c1) == set(c2)
    for key in c1:
        np.testing.assert_array_equal(np.asarray(c1[key]),
                                      np.asarray(c2[key]), err_msg=key)


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="engine"):
        _spec(engine="warp")
    with pytest.raises(ValueError, match="kernel_backend"):
        _spec(kernel_backend="cuda")
    with pytest.raises(ValueError, match="alloc_backend"):
        _spec(alloc_backend="scipy")
    with pytest.raises(ValueError, match="delay_profile"):
        _spec(delay_profile="nonexistent")
    with pytest.raises(ValueError, match="mesh"):
        _spec(mesh=0)
    with pytest.raises(ValueError, match="steps_per_epoch"):
        _spec(steps_per_epoch=0)
    with pytest.raises(ValueError, match="unknown ExperimentSpec field"):
        ExperimentSpec.from_dict({"flux_capacitor": 1})


def test_spec_scheme_params_normalized_and_hashable():
    a = _spec("partial_coded", scheme_params={"u_fraction": 0.4, "z": 1})
    b = _spec("partial_coded", scheme_params=(("z", 1), ("u_fraction", 0.4)))
    assert a == b and hash(a) == hash(b)
    assert a.scheme_params_dict == {"u_fraction": 0.4, "z": 1}


def test_spec_delay_profile_overrides_fl():
    spec = _spec(delay_profile="extreme")
    fl = spec.resolved_fl()
    assert fl.rate_decay == HETEROGENEITY_PROFILES["extreme"]["rate_decay"]
    assert fl.mac_decay == HETEROGENEITY_PROFILES["extreme"]["mac_decay"]
    # equivalent to overriding the FLConfig fields by hand
    xs, ys = _data()
    by_profile = api.build_experiment(spec, xs, ys).run(4)
    import dataclasses
    manual = _spec(fl=dataclasses.replace(
        spec.fl, **HETEROGENEITY_PROFILES["extreme"]))
    by_fl = api.build_experiment(manual, xs, ys).run(4)
    np.testing.assert_array_equal(np.asarray(by_profile.theta),
                                  np.asarray(by_fl.theta))


def test_unknown_scheme_rejected_at_build_time():
    xs, ys = _data()
    with pytest.raises(ValueError, match="unknown scheme"):
        api.build_experiment(_spec("fountain_coded"), xs, ys)


def test_experiment_rejects_non_spec():
    xs, ys = _data()
    with pytest.raises(TypeError, match="ExperimentSpec"):
        fed_runtime.Experiment({"scheme": "coded"}, xs, ys)


def test_build_experiment_accepts_dict_spec():
    xs, ys = _data()
    exp = api.build_experiment(_spec("naive").to_dict(), xs, ys)
    assert exp.scheme == "naive"


# ---------------------------------------------------------------------------
# Removed shim + spec-path equivalence (acceptance criterion)
# ---------------------------------------------------------------------------

def test_removed_shim_raises_pointed_error():
    """The kwargs constructor is gone; the stub's error names the
    replacement entrypoint."""
    xs, ys = _data()
    with pytest.raises(TypeError, match="build_experiment"):
        fed_runtime.FederatedSimulation(
            xs, ys, FLConfig(n_clients=6), TrainConfig(), scheme="naive")


@pytest.mark.parametrize("kernel_backend", ["xla", "pallas"])
@pytest.mark.parametrize("scheme", ["coded", "naive", "greedy"])
def test_revived_spec_trajectory_identical(scheme, kernel_backend):
    """A spec revived from its serialized dict == the original spec,
    bit-for-bit, on both kernel backends (the trajectory is a pure
    function of the frozen spec — the equivalence the old shim tests
    pinned, now phrased without the removed kwargs path)."""
    xs, ys = _data()
    fl = FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3)
    tc = TrainConfig(learning_rate=0.5, l2_reg=1e-5, lr_decay_epochs=(5,))
    spec = ExperimentSpec(fl=fl, train=tc, scheme=scheme,
                          kernel_backend=kernel_backend)
    revived = ExperimentSpec.from_dict(
        json.loads(json.dumps(spec.to_dict())))
    trace = lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)
    res_old = api.build_experiment(spec, xs, ys).run(
        8, eval_fn=trace, eval_every=1)
    res_new = api.build_experiment(revived, xs, ys).run(
        8, eval_fn=trace, eval_every=1)
    np.testing.assert_array_equal(np.asarray(res_old.theta),
                                  np.asarray(res_new.theta))
    for ho, hn in zip(res_old.history, res_new.history):
        assert ho.returned == hn.returned
        assert ho.wall_clock == hn.wall_clock
        assert ho.loss == hn.loss


# ---------------------------------------------------------------------------
# Scheme registry + new schemes
# ---------------------------------------------------------------------------

def test_registry_contains_builtins_in_order():
    names = schemes.registered_names()
    assert set(("coded", "naive", "greedy", "ideal",
                "partial_coded")) <= set(names)
    assert set(schemes.coded_names()) >= {"coded", "partial_coded"}


def test_register_rejects_duplicates_and_bad_kinds():
    with pytest.raises(ValueError, match="already registered"):
        schemes.register(schemes.CodedScheme())

    class Nameless(schemes.Scheme):
        step_kind = "naive"

    with pytest.raises(ValueError, match="no name"):
        schemes.register(Nameless())

    class BadKind(schemes.Scheme):
        name = "bad_kind"
        step_kind = "quantum"

    with pytest.raises(ValueError, match="step_kind"):
        schemes.register(BadKind())


@pytest.mark.parametrize("scheme", ["coded", "naive", "greedy", "ideal",
                                    "partial_coded"])
def test_every_registered_scheme_runs_via_build_experiment(scheme):
    xs, ys = _data()
    res = api.build_experiment(_spec(scheme), xs, ys).run(5)
    assert np.isfinite(np.asarray(res.theta)).all()
    assert res.history[-1].wall_clock > 0


def test_partial_coded_uses_fraction_of_redundancy():
    xs, ys = _data()
    full = api.build_experiment(_spec("coded"), xs, ys)
    half = api.build_experiment(_spec("partial_coded"), xs, ys)
    third = api.build_experiment(
        _spec("partial_coded", scheme_params={"u_fraction": 1.0 / 3.0}),
        xs, ys)
    assert half.u == max(1, round(0.5 * full.u))
    assert third.u < half.u < full.u
    # less parity shared -> a later deadline but a smaller privacy budget
    assert half.t_star >= full.t_star
    assert half.privacy_eps < full.privacy_eps
    with pytest.raises(ValueError, match="u_fraction"):
        api.build_experiment(
            _spec("partial_coded", scheme_params={"u_fraction": 1.5}),
            xs, ys)


def test_partial_coded_batched_matches_legacy_oracle():
    """The new scheme rides the same engines: batched scan == per-client
    Python oracle on the same pre-sampled delays."""
    xs, ys = _data()
    res = {}
    for engine in ("batched", "legacy"):
        exp = api.build_experiment(_spec("partial_coded", engine=engine),
                                   xs, ys)
        res[engine] = exp.run(10)
    np.testing.assert_allclose(np.asarray(res["batched"].theta),
                               np.asarray(res["legacy"].theta), atol=1e-5)
    for hb, hl in zip(res["batched"].history, res["legacy"].history):
        assert hb.returned == hl.returned
        np.testing.assert_allclose(hb.wall_clock, hl.wall_clock, rtol=1e-5)


def test_ideal_scheme_deterministic_floor():
    xs, ys = _data()
    ideal = api.build_experiment(_spec("ideal"), xs, ys)
    naive = api.build_experiment(_spec("naive"), xs, ys)
    res_i = ideal.run(6)
    res_n = naive.run(6)
    # same gradients (all clients, full load) -> identical trajectories
    np.testing.assert_allclose(np.asarray(res_i.theta),
                               np.asarray(res_n.theta), atol=1e-6)
    # deterministic round clock at the full-load floor
    walls = np.array([h.wall_clock for h in res_i.history])
    np.testing.assert_allclose(np.diff(walls), ideal.t_ideal, rtol=1e-6)
    assert res_n.history[-1].wall_clock >= res_i.history[-1].wall_clock
    # and run_multi realizations collapse onto one curve
    multi = ideal.run_multi(5, 3)
    _, std = multi.wall_clock_bands()
    np.testing.assert_allclose(std, 0.0, atol=1e-9)


def test_privacy_eps_wired_into_results():
    xs, ys = _data()
    coded = api.build_experiment(_spec("coded"), xs, ys)
    res = coded.run(3)
    multi = coded.run_multi(3, 2)
    from repro.core import privacy
    want = max(privacy.mi_dp_budget(np.asarray(xs[j]), coded.u)
               for j in range(xs.shape[0]))
    assert res.privacy_eps == pytest.approx(want)
    assert multi.privacy_eps == pytest.approx(want)
    assert api.build_experiment(_spec("naive"), xs, ys).run(3).privacy_eps \
        is None


def test_experiment_sweep_method_matches_run_multi():
    """Experiment.sweep flows through the same build_consts/build_step
    machinery as run_multi — equal seeds, equal results."""
    xs, ys = _data()
    profiles = {"uniform": dict(rate_decay=1.0, mac_decay=1.0),
                "paper": dict(rate_decay=0.95, mac_decay=0.8)}
    exp = api.build_experiment(_spec("coded"), xs, ys)
    sw = exp.sweep(profiles=profiles, iterations=6, realizations=2)
    assert set(sw.results["coded"]) == set(profiles)
    import dataclasses
    for pname, knobs in profiles.items():
        loop = api.build_experiment(
            _spec(fl=dataclasses.replace(exp.spec.fl, **knobs)),
            xs, ys).run_multi(6, 2)
        got = sw.results["coded"][pname]
        np.testing.assert_allclose(got.wall_clock, loop.wall_clock,
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got.theta),
                                   np.asarray(loop.theta), atol=1e-5)


def test_mesh_in_spec_shards_like_mesh_kwarg():
    """spec.mesh (serializable device count) == Experiment mesh override."""
    import jax
    if jax.device_count() < 1:
        pytest.skip("no devices")
    k = jax.device_count()
    xs, ys = _data()
    by_spec = api.build_experiment(_spec("coded", mesh=k), xs, ys).run(4)
    by_override = api.build_experiment(_spec("coded"), xs, ys,
                                       mesh=k).run(4)
    unsharded = api.build_experiment(_spec("coded"), xs, ys).run(4)
    np.testing.assert_array_equal(np.asarray(by_spec.theta),
                                  np.asarray(by_override.theta))
    np.testing.assert_allclose(np.asarray(by_spec.theta),
                               np.asarray(unsharded.theta), atol=1e-5)


def test_step_static_exposes_step_kind():
    """Coded-family schemes compile the same step branch; the registry
    decides, not string comparison on the scheme name."""
    xs, ys = _data()
    partial = api.build_experiment(_spec("partial_coded"), xs, ys)
    assert partial.step_kind == "coded"
    assert partial.step_static()["scheme"] == "coded"
    ideal = api.build_experiment(_spec("ideal"), xs, ys)
    assert ideal.step_static()["scheme"] == "ideal"
    assert "t_ideal" in ideal.build_consts()
