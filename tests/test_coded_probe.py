"""Coded linear-probe head on a frozen deep backbone (DESIGN.md §4)."""
import jax
import numpy as np
import pytest

from repro.config import FLConfig
from repro.configs import get_config, smoke_variant
from repro.core import coded_probe
from repro.models.model_zoo import build


@pytest.fixture(scope="module")
def backbone():
    cfg = smoke_variant(get_config("qwen3-4b"))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, params


def _client_data(cfg, params, n=4, l=24, S=16, n_classes=3, seed=0):
    """Labels are a linear function of the backbone features by
    construction, so the probe is learnable."""
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab, size=(n, l, S)).astype(np.int32)
    feats = np.stack([coded_probe.extract_features(cfg, params, tokens[j])
                      for j in range(n)])
    w = rng.normal(size=(feats.shape[-1], n_classes))
    labels = np.argmax(np.einsum("nld,dc->nlc", feats, w), axis=-1)
    return tokens, labels.astype(np.int64)


def test_extract_features_shape(backbone):
    cfg, params = backbone
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (5, 16)).astype(np.int32)
    f = coded_probe.extract_features(cfg, params, toks, batch_size=2)
    assert f.shape == (5, cfg.d_model)
    assert np.all(np.isfinite(f))


def test_coded_probe_learns(backbone):
    cfg, params = backbone
    tokens, labels = _client_data(cfg, params)
    res, _ = coded_probe.coded_probe_training(
        cfg, params, tokens, labels, n_classes=3,
        fl_cfg=FLConfig(n_clients=4, delta=0.25), rff_q=128, iterations=60)
    theta = np.asarray(res.theta)
    assert np.all(np.isfinite(theta))
    assert res.t_star is not None and res.t_star > 0
    # training accuracy on the clients' own data beats chance
    feats = np.stack([coded_probe.extract_features(cfg, params, tokens[j])
                      for j in range(4)])
    import jax.numpy as jnp
    from repro.core import rff as rffmod
    # reuse the returned rff params via the second return value instead
    res2, (omega, delta) = coded_probe.coded_probe_training(
        cfg, params, tokens, labels, n_classes=3,
        fl_cfg=FLConfig(n_clients=4, delta=0.25), rff_q=128, iterations=60)
    xh = np.asarray(rffmod.rff_transform(
        jnp.asarray(feats.reshape(-1, feats.shape[-1])), omega, delta))
    pred = (xh @ np.asarray(res2.theta)).argmax(1)
    acc = (pred == labels.reshape(-1)).mean()
    assert acc > 0.5, acc
