"""Fault-injection subsystem + self-healing runtime.

Contracts under test:

  * `FaultProfile` is a frozen, validated, JSON-round-tripping value,
    resolved through the spec exactly like channel profiles.
  * Fault sampling draws from its own fixed-layout RNG stream, so
    toggling faults never shifts the delay realizations (hermeticity),
    and the guard machinery is IEEE-bit-exact a no-op on clean runs.
  * Under non-finite client returns, coded training degrades gracefully
    (parity absorbs the masked mass; trajectory stays finite and
    `FedResult.health` counts it), guarded naive detects-and-reports,
    and unguarded naive stalls through the divergence guard.
  * Fault-injected runs checkpoint/resume bit-identically (the fault
    RNG state lives in RunState).
  * The service retries injected crashes, quarantines hopeless runs,
    and recovers bit-identically from crash + checkpoint corruption.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import api
from repro.checkpoint import io as ckpt_io
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.faults import (CODE_CLEAN, CODE_INF, CODE_NAN, CODE_STALE,
                          FAULT_PROFILES, FaultProfile, get_fault_profile,
                          sample_fault_rows)
from repro.launch.service import ExperimentService


def _data(n=8, l=24, q=6, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.3
    theta_true = rng.normal(size=(q, c)).astype(np.float32)
    ys = (np.einsum("nlq,qc->nlc", xs, theta_true)
          + 0.005 * rng.normal(size=(n, l, c))).astype(np.float32)
    return xs, ys


def _spec(scheme="coded", **over):
    base = dict(fl=FLConfig(n_clients=8, seed=3),
                train=TrainConfig(learning_rate=0.05),
                scheme=scheme)
    base.update(over)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# FaultProfile: validation + serialization
# ---------------------------------------------------------------------------

def test_profile_round_trips_through_json():
    for name, prof in FAULT_PROFILES.items():
        revived = FaultProfile.from_dict(
            json.loads(json.dumps(prof.to_dict())))
        assert revived == prof, name


@pytest.mark.parametrize("bad", [
    dict(nan_prob=-0.1), dict(nan_prob=1.5), dict(nan_kind="bogus"),
    dict(stale_prob=2.0), dict(crash_prob=-1.0),
    dict(ckpt_corrupt_kind="shred"),
])
def test_profile_rejects_bad_values(bad):
    with pytest.raises(ValueError):
        FaultProfile(**bad)


def test_profile_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="tornado_prob"):
        FaultProfile.from_dict({"tornado_prob": 0.5})


def test_get_fault_profile_unknown_name():
    with pytest.raises(ValueError, match="no_such"):
        get_fault_profile("no_such")


def test_spec_resolves_and_overrides_fault_profile():
    spec = _spec(fault_profile="flaky_clients",
                 fault_params=(("nan_prob", 0.5),))
    faults = spec.resolved_faults()
    assert faults.nan_prob == 0.5
    revived = ExperimentSpec.from_dict(
        json.loads(json.dumps(spec.to_dict())))
    assert revived == spec
    with pytest.raises(ValueError, match="fault_params"):
        _spec(fault_profile="flaky_clients",
              fault_params=(("tornado_prob", 1.0),))
    with pytest.raises(ValueError):
        _spec(fault_profile="no_such")


def test_spec_rejects_return_faults_on_mesh():
    with pytest.raises((ValueError, NotImplementedError)):
        _spec(fault_profile="flaky_clients", mesh=2)


# ---------------------------------------------------------------------------
# fault sampling: fixed draw layout
# ---------------------------------------------------------------------------

def test_sample_fault_rows_shapes_and_codes():
    prof = FAULT_PROFILES["byzantine_lite"]
    codes, parity = sample_fault_rows(
        prof, np.random.default_rng(7), 50, 10)
    assert codes.shape == (50, 10) and codes.dtype == np.int32
    assert parity.shape == (50,)
    assert set(np.unique(codes)) <= {CODE_CLEAN, CODE_NAN, CODE_INF,
                                     CODE_STALE}
    assert np.any(codes != CODE_CLEAN)


def test_sample_layout_is_fixed_across_knobs():
    """The four draw blocks are always consumed, so turning one fault
    type off never shifts another type's realization."""
    base = FAULT_PROFILES["flaky_clients"]
    with_stale = dataclasses.replace(base, stale_prob=0.2)
    c_base, _ = sample_fault_rows(base, np.random.default_rng(11), 40, 8)
    c_stale, _ = sample_fault_rows(with_stale, np.random.default_rng(11),
                                   40, 8)
    nan_mask = np.isin(c_base, (CODE_NAN, CODE_INF))
    np.testing.assert_array_equal(
        nan_mask, np.isin(c_stale, (CODE_NAN, CODE_INF)))
    # stale only lands on rows that were clean
    assert not np.any((c_stale == CODE_STALE) & nan_mask)


# ---------------------------------------------------------------------------
# runtime degradation
# ---------------------------------------------------------------------------

def test_guard_is_bit_exact_noop_on_clean_runs():
    xs, ys = _data()
    on = api.build_experiment(_spec(nonfinite_guard=True), xs, ys).run(16)
    off = api.build_experiment(_spec(nonfinite_guard=False), xs, ys).run(16)
    np.testing.assert_array_equal(np.asarray(on.theta),
                                  np.asarray(off.theta))
    assert on.health.returns_masked == 0
    assert on.health.rounds_skipped == 0
    assert on.health.lr_scale == 1.0


def test_faults_do_not_shift_delay_realizations():
    """Fault RNG hermeticity: wall-clocks (pure delay draws) are
    identical with and without client faults."""
    xs, ys = _data()
    clean = api.build_experiment(_spec(), xs, ys).run(16)
    faulty = api.build_experiment(
        _spec(fault_profile="flaky_clients"), xs, ys).run(16)
    assert [h.wall_clock for h in clean.history] \
        == [h.wall_clock for h in faulty.history]
    assert [h.returned for h in clean.history] \
        == [h.returned for h in faulty.history]


@pytest.mark.parametrize("profile", ["flaky_clients", "byzantine_lite"])
def test_coded_degrades_gracefully(profile):
    xs, ys = _data()
    res = api.build_experiment(_spec(fault_profile=profile), xs, ys).run(20)
    assert np.all(np.isfinite(np.asarray(res.theta)))
    assert res.health.returns_masked > 0
    assert res.health.rounds_degraded > 0


def test_naive_guarded_detects_and_reports():
    xs, ys = _data()
    res = api.build_experiment(
        _spec("naive", fault_profile="flaky_clients"), xs, ys).run(20)
    assert np.all(np.isfinite(np.asarray(res.theta)))
    assert res.health.returns_masked > 0


def test_naive_unguarded_stalls():
    """The ablation: without the guard a NaN return poisons the round;
    the divergence guard skips it and backs the lr off — repeatedly."""
    xs, ys = _data()
    res = api.build_experiment(
        _spec("naive", fault_profile="flaky_clients",
              nonfinite_guard=False), xs, ys).run(20)
    assert np.all(np.isfinite(np.asarray(res.theta)))   # skips kept it
    assert res.health.rounds_skipped > 0
    assert res.health.lr_scale < 1.0


def test_run_multi_threads_health():
    xs, ys = _data()
    multi = api.build_experiment(
        _spec(fault_profile="flaky_clients"), xs, ys).run_multi(10, 3)
    assert multi.health is not None
    assert multi.health.returns_masked > 0


def test_faulty_run_resumes_bit_identically(tmp_path):
    """The fault RNG state lives in RunState: kill/resume mid-run under
    active fault injection reproduces the uninterrupted run exactly."""
    xs, ys = _data()
    spec = _spec(fault_profile="byzantine_lite", checkpoint_every=4)
    control = api.build_experiment(spec, xs, ys).run(12)

    exp = api.build_experiment(spec, xs, ys)
    state = exp.run_block(exp.init_state(12))
    exp.save_state(
        str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000004.npz"), state)
    resumed = api.build_experiment(spec, xs, ys).run(
        12, checkpoint_dir=str(tmp_path), resume=True)
    np.testing.assert_array_equal(np.asarray(control.theta),
                                  np.asarray(resumed.theta))
    assert dataclasses.asdict(control.health) \
        == dataclasses.asdict(resumed.health)


# ---------------------------------------------------------------------------
# self-healing service
# ---------------------------------------------------------------------------

def _submit(svc, spec, xs, ys, rid, iters=20):
    return svc.submit(spec, xs, ys, iters, run_id=rid)


def test_service_survives_crash_loop_bit_identically(tmp_path):
    xs, ys = _data()
    base = _spec(checkpoint_every=4)
    ctrl = ExperimentService(str(tmp_path / "ctrl"))
    _submit(ctrl, base, xs, ys, "a")
    expect = ctrl.run_until_complete()["a"]

    chaos = ExperimentService(str(tmp_path / "chaos"), fault_seed=5,
                              max_retries=10)
    _submit(chaos, dataclasses.replace(base, fault_profile="crash_loop"),
            xs, ys, "a")
    got = chaos.run_until_complete()["a"]
    health = chaos.last_health["a"]
    assert health["total_retries"] >= 1          # crashes actually fired
    assert not health["quarantined"]
    np.testing.assert_array_equal(np.asarray(expect.theta),
                                  np.asarray(got.theta))


def test_service_quarantines_hopeless_run_and_isolates_it(tmp_path):
    xs, ys = _data()
    base = _spec(checkpoint_every=4)
    dead_spec = dataclasses.replace(base,
                                    fault_params=(("crash_prob", 1.0),))
    svc = ExperimentService(str(tmp_path), max_retries=2)
    _submit(svc, dead_spec, xs, ys, "dead")
    _submit(svc, base, xs, ys, "ok")
    results = svc.run_until_complete()
    health = svc.last_health
    assert results["dead"] is None
    assert health["dead"]["quarantined"]
    assert health["dead"]["total_retries"] == 3   # max_retries + 1
    assert "InjectedCrashError" in health["dead"]["last_error"]
    assert results["ok"] is not None
    solo = api.build_experiment(base, xs, ys).run(20)
    np.testing.assert_array_equal(np.asarray(solo.theta),
                                  np.asarray(results["ok"].theta))


def test_service_restart_falls_back_past_corrupt_checkpoints(tmp_path):
    """bad_disk corrupts checkpoints after writing; a restarted service
    must resume from the newest intact one and finish bit-identically."""
    xs, ys = _data()
    base = _spec(checkpoint_every=4)
    ctrl = ExperimentService(str(tmp_path / "ctrl"))
    _submit(ctrl, base, xs, ys, "a")
    expect = ctrl.run_until_complete()["a"]

    disk_spec = dataclasses.replace(base, fault_profile="bad_disk")
    svc = ExperimentService(str(tmp_path / "disk"), fault_seed=5)
    _submit(svc, disk_spec, xs, ys, "a")
    svc.run_until_complete()
    ckpt_dir = str(tmp_path / "disk" / "a")
    assert ckpt_io.latest_checkpoint(ckpt_dir) \
        != ckpt_io.latest_checkpoint(ckpt_dir, valid_only=True)

    svc2 = ExperimentService(str(tmp_path / "disk"))   # the restart
    run = _submit(svc2, disk_spec, xs, ys, "a")
    assert run.resumed and run.fallback_resume
    got = svc2.run_until_complete()["a"]
    np.testing.assert_array_equal(np.asarray(expect.theta),
                                  np.asarray(got.theta))


def test_service_health_surfaces_runtime_degradation(tmp_path):
    xs, ys = _data()
    svc = ExperimentService(str(tmp_path))
    _submit(svc, _spec(fault_profile="flaky_clients", checkpoint_every=4),
            xs, ys, "f")
    svc.run_until_complete()
    health = svc.last_health["f"]["health"]
    assert health is not None and health["returns_masked"] > 0
