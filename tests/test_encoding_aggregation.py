"""Distributed encoding + coded aggregation (paper §III-B/D/E)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, encoding


def _data(m=60, q=16, c=3, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, q)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(m, c)), jnp.float32)
    return x, y


def test_generator_moments():
    for kind in ("normal", "rademacher"):
        g = encoding.generator_matrix(jax.random.PRNGKey(0), 2000, 50, kind)
        assert abs(float(jnp.mean(g))) < 0.02
        assert abs(float(jnp.var(g)) - 1.0) < 0.05


def test_weight_vector():
    idx = np.array([0, 2, 4])
    w = encoding.weight_vector(6, idx, p_return=0.75)
    assert np.allclose(w[idx], 0.5)           # sqrt(1 - 0.75)
    assert np.allclose(w[[1, 3, 5]], 1.0)     # unprocessed -> pnr = 1


def test_parity_unbiasedness():
    """E[(1/u) Xt^T (Xt th - Yt)] == Xh^T W^2 (Xh th - Y) (paper eq. 31)."""
    x, y = _data()
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.uniform(0.3, 1.0, size=(x.shape[0],)), jnp.float32)
    theta = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
    target = (x * w[:, None] ** 2).T @ (x @ theta - y)
    u = 20000
    acc = None
    key = jax.random.PRNGKey(0)
    par = encoding.encode_local(key, x, y, np.asarray(w), u)
    est = aggregation.coded_gradient(par.x, par.y, theta)
    rel = float(jnp.linalg.norm(est - target) / jnp.linalg.norm(target))
    assert rel < 0.15, rel


def test_global_parity_is_sum():
    x1, y1 = _data(seed=1)
    x2, y2 = _data(seed=2)
    w = np.ones(x1.shape[0], np.float32)
    p1 = encoding.encode_local(jax.random.PRNGKey(1), x1, y1, w, 8)
    p2 = encoding.encode_local(jax.random.PRNGKey(2), x2, y2, w, 8)
    g = encoding.aggregate_parity([p1, p2])
    assert jnp.allclose(g.x, p1.x + p2.x)
    assert jnp.allclose(g.y, p1.y + p2.y)


def test_federated_gradient_masking():
    x, y = _data()
    theta = jnp.zeros((16, 3), jnp.float32)
    g1 = aggregation.client_gradient(x, y, theta)
    g2 = aggregation.client_gradient(x * 2, y, theta)
    out = aggregation.federated_gradient(None, [g1, g2], [True, False], m=60)
    assert jnp.allclose(out, g1 / 60)


def test_coded_compensates_in_expectation():
    """Full-information check of E[g_M] ~= g (paper §III-E).

    With p_return = P(T_j <= t*) and weights built per §III-D, averaging the
    simulated aggregate over many straggler draws approaches the full
    gradient over the entire dataset.
    """
    rng = np.random.default_rng(0)
    n, l, q, c = 4, 30, 12, 2
    xs = [jnp.asarray(rng.normal(size=(l, q)), jnp.float32) for _ in range(n)]
    ys = [jnp.asarray(rng.normal(size=(l, c)), jnp.float32) for _ in range(n)]
    theta = jnp.asarray(rng.normal(size=(q, c)), jnp.float32)
    p_ret = np.array([0.9, 0.7, 0.5, 0.3])
    m = n * l
    u = 60000    # large coding redundancy => G^T G / u ~ I

    parities = []
    key = jax.random.PRNGKey(7)
    for j in range(n):
        w = encoding.weight_vector(l, np.arange(l), float(p_ret[j]))
        key, sub = jax.random.split(key)
        parities.append(encoding.encode_local(sub, xs[j], ys[j], w, u))
    gp = encoding.aggregate_parity(parities)
    coded = aggregation.coded_gradient(gp.x, gp.y, theta)

    grads = [aggregation.client_gradient(xs[j], ys[j], theta)
             for j in range(n)]
    trials = 600
    acc = jnp.zeros((q, c))
    for t in range(trials):
        returned = rng.uniform(size=n) < p_ret
        g_m = aggregation.federated_gradient(coded, grads, returned, m)
        acc = acc + g_m
    est = acc / trials
    full = sum(grads) / m
    rel = float(jnp.linalg.norm(est - full) / jnp.linalg.norm(full))
    assert rel < 0.1, rel
