"""Integration: the multi-pod dry-run lowers+compiles in a fresh process
(XLA_FLAGS device-count override requires pre-jax-init env)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape,extra", [
    ("whisper-base", "decode_32k", []),
    ("internvl2-1b", "prefill_32k", []),
    ("rwkv6-1.6b", "long_500k", ["--multi-pod"]),
])
def test_dryrun_subprocess(tmp_path, arch, shape, extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out_dir = str(tmp_path)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out-dir", out_dir] + extra
    res = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         cwd=REPO, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    files = [f for f in os.listdir(out_dir) if f.endswith(".json")]
    assert len(files) == 1
    rec = json.load(open(os.path.join(out_dir, files[0])))
    assert rec["arch"] == arch and rec["shape"] == shape
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0
    assert rec["chips"] == (512 if "--multi-pod" in extra else 256)
