"""Run-telemetry subsystem (`repro.obs`): spans, journal, attribution.

The hard invariant under test: telemetry never touches an RNG stream or
changes a trajectory — runs with spans/journal enabled are bit-identical
to runs with telemetry off, and the journal is a deterministic function
of (spec, seed).  Also covered: byte-exact journal determinism, replay
reconstructing `FedResult.history`, kill/resume appending to (not
corrupting) an existing journal, torn-tail repair, the per-round
`RoundLog.n_masked`/`skipped` counters, straggler attribution bounds,
and the `ExperimentService` per-run timing surface.
"""
import json
import os

import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.launch.report import REQUIRED_SPANS
from repro.obs import (RunJournal, attribution_from_blocks,
                       histories_equal, history_from_journal, load_events,
                       round_deadlines)
from repro.obs import spans as obs_spans
from repro.obs.events import EVENTS_NAME


@pytest.fixture(autouse=True)
def _spans_off():
    """Every test starts (and leaves) with the collector disabled."""
    obs_spans.disable()
    obs_spans.reset()
    yield
    obs_spans.disable()
    obs_spans.reset()


def _data(n=6, l=16, q=24, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _spec(scheme="coded", **over):
    base = dict(
        fl=FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme=scheme, checkpoint_every=4)
    base.update(over)
    return ExperimentSpec(**base)


def _eval():
    return lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_records_nothing_when_disabled():
    with obs_spans.span("solver/two_step"):
        pass
    assert obs_spans.totals() == {}
    obs_spans.enable()
    with obs_spans.span("solver/two_step"):
        pass
    with obs_spans.span("solver/two_step"):
        pass
    rec = obs_spans.totals()["solver/two_step"]
    assert rec["count"] == 2
    assert rec["total_s"] >= rec["max_s"] >= rec["min_s"] >= 0.0


def test_forced_span_measures_without_recording_globally():
    with obs_spans.span("service/block", force=True) as sp:
        pass
    assert sp.elapsed_s is not None and sp.elapsed_s >= 0.0
    assert obs_spans.totals() == {}   # global collector stays untouched


def test_collecting_context_restores_prior_flag():
    assert not obs_spans.enabled()
    with obs_spans.collecting() as mod:
        assert obs_spans.enabled()
        with obs_spans.span("trace/generate"):
            pass
        assert "trace/generate" in mod.totals()
    assert not obs_spans.enabled()


def test_write_json_roundtrip(tmp_path):
    obs_spans.enable()
    with obs_spans.span("encode/parity"):
        pass
    path = tmp_path / obs_spans.SPANS_NAME
    obs_spans.write_json(str(path))
    loaded = json.loads(path.read_text())
    assert loaded["encode/parity"]["count"] == 1


# ---------------------------------------------------------------------------
# the hard invariant: telemetry never perturbs a trajectory
# ---------------------------------------------------------------------------

CASES = {
    "coded": dict(scheme="coded"),
    "adaptive_coded": dict(scheme="adaptive_coded",
                           channel_profile="drift_churn", adapt_every=2),
}


@pytest.mark.parametrize("kernel_backend", ["xla", "pallas"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_telemetry_on_off_bit_identical(case, kernel_backend, tmp_path):
    xs, ys = _data()
    spec = _spec(kernel_backend=kernel_backend, **CASES[case])
    ev = _eval()

    off = api.build_experiment(spec, xs, ys).run(12, eval_fn=ev,
                                                 eval_every=1)
    obs_spans.enable()
    on = api.build_experiment(spec, xs, ys).run(
        12, eval_fn=ev, eval_every=1, journal_dir=str(tmp_path / "j"))

    np.testing.assert_array_equal(np.asarray(off.theta),
                                  np.asarray(on.theta))
    assert histories_equal(off.history, on.history)
    # and the journal replays the exact history the run returned
    assert histories_equal(
        history_from_journal(str(tmp_path / "j")), on.history)


def test_hier_telemetry_on_off_bit_identical(tmp_path):
    xs, ys = _data(n=12, l=4, q=6, c=2)
    spec = ExperimentSpec(
        fl=FLConfig(n_clients=12, delta=0.25, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5),
        scheme="coded", hier_shards=2, sample_fraction=0.5,
        checkpoint_every=3)

    off = api.build_experiment(spec, xs, ys).run(9)
    obs_spans.enable()
    exp_on = api.build_experiment(spec, xs, ys)
    on = exp_on.run(9, journal_dir=str(tmp_path / "j"))

    np.testing.assert_array_equal(np.asarray(off.theta),
                                  np.asarray(on.theta))
    events = load_events(str(tmp_path / "j"))
    assert len(events) == 9
    # hier rounds journal every shard's coded deadline
    assert all(len(e["t_star_s"]) == 2 for e in events)
    attr = exp_on.attribution()
    assert set(attr) == {0, 1}


# ---------------------------------------------------------------------------
# journal determinism / replay / resume
# ---------------------------------------------------------------------------

def test_journal_byte_deterministic(tmp_path):
    xs, ys = _data()
    spec = _spec()
    obs_spans.enable()
    for d in ("a", "b"):
        api.build_experiment(spec, xs, ys).run(
            12, eval_fn=_eval(), eval_every=1,
            journal_dir=str(tmp_path / d))
    a = (tmp_path / "a" / EVENTS_NAME).read_bytes()
    assert a == (tmp_path / "b" / EVENTS_NAME).read_bytes()
    assert len(a.splitlines()) == 12


def test_journal_event_shape(tmp_path):
    xs, ys = _data()
    api.build_experiment(_spec(), xs, ys).run(
        8, eval_fn=_eval(), eval_every=1, journal_dir=str(tmp_path))
    events = load_events(str(tmp_path))
    assert [e["round"] for e in events] == list(range(8))
    wall = 0.0
    for e in events:
        assert e["t_round_s"] > 0 and e["wall_clock_s"] > wall
        wall = e["wall_clock_s"]
        assert e["returned"] >= 1
        assert e["n_masked"] == 0 and e["skipped"] == 0
        assert e["lr_scale"] == 1.0
        assert e["loss"] is not None   # collect=True, eval_every=1


def test_kill_resume_appends_to_existing_journal(tmp_path):
    """Interrupt at a block boundary, resume in a FRESH Experiment with
    the same journal dir: the final journal is byte-identical to the
    uninterrupted run's (appended, never rewritten)."""
    xs, ys = _data()
    spec = _spec()
    ev = _eval()
    ref_dir, jdir = str(tmp_path / "ref"), str(tmp_path / "resumed")
    ckpt = str(tmp_path / "ckpt")

    api.build_experiment(spec, xs, ys).run(
        12, eval_fn=ev, eval_every=1, journal_dir=ref_dir)

    # partial run: one block (4 rounds), checkpoint + journal, then "kill"
    interrupted = api.build_experiment(spec, xs, ys)
    state = interrupted.init_state(12, collect=True)
    state = interrupted.run_block(state, eval_fn=ev, eval_every=1)
    interrupted.save_state(os.path.join(ckpt, "ckpt_000004.npz"), state)
    journal = RunJournal(jdir)
    assert journal.sync(interrupted, state) == 4
    partial = (tmp_path / "resumed" / EVENTS_NAME).read_bytes()

    resumed = api.build_experiment(spec, xs, ys)
    resumed.run(12, eval_fn=ev, eval_every=1, checkpoint_dir=ckpt,
                resume=True, journal_dir=jdir)
    final = (tmp_path / "resumed" / EVENTS_NAME).read_bytes()
    assert final.startswith(partial)
    assert final == (tmp_path / "ref" / EVENTS_NAME).read_bytes()


def test_torn_tail_repaired_on_open(tmp_path):
    xs, ys = _data()
    spec = _spec()
    exp = api.build_experiment(spec, xs, ys)
    state = exp.init_state(8, collect=True)
    state = exp.run_block(state, eval_fn=_eval(), eval_every=1)
    journal = RunJournal(str(tmp_path))
    journal.sync(exp, state)
    clean = (tmp_path / EVENTS_NAME).read_bytes()

    # simulate a crash mid-append: a torn, newline-less partial record
    with open(tmp_path / EVENTS_NAME, "ab") as fh:
        fh.write(b'{"round": 99, "t_round_s"')
    # read-only loader skips the torn tail and leaves the file alone
    assert len(load_events(str(tmp_path))) == 4
    assert (tmp_path / EVENTS_NAME).read_bytes() != clean
    # the write-path journal truncates it and continues cleanly
    reopened = RunJournal(str(tmp_path))
    assert reopened.rounds_logged == 4
    assert (tmp_path / EVENTS_NAME).read_bytes() == clean
    state = exp.run_block(state, eval_fn=_eval(), eval_every=1)
    reopened.sync(exp, state)
    assert [e["round"] for e in load_events(str(tmp_path))] == \
        list(range(8))


def test_journal_dir_rejected_on_legacy_engine(tmp_path):
    xs, ys = _data()
    exp = api.build_experiment(_spec(engine="legacy", checkpoint_every=0),
                               xs, ys)
    with pytest.raises(ValueError, match="batched engine"):
        exp.run(4, journal_dir=str(tmp_path))


# ---------------------------------------------------------------------------
# RoundLog degradation counters
# ---------------------------------------------------------------------------

def test_roundlog_carries_guard_counters():
    xs, ys = _data()
    res = api.build_experiment(_spec(), xs, ys).run(6)
    for log in res.history:
        assert log.n_masked == 0 and log.skipped == 0


def test_legacy_engine_fills_zero_counters():
    xs, ys = _data()
    res = api.build_experiment(_spec(engine="legacy", checkpoint_every=0),
                               xs, ys).run(4)
    assert all(log.n_masked == 0 and log.skipped == 0
               for log in res.history)


# ---------------------------------------------------------------------------
# attribution
# ---------------------------------------------------------------------------

def test_attribution_requires_enabled_telemetry():
    xs, ys = _data()
    exp = api.build_experiment(_spec(), xs, ys)
    exp.run(4)
    with pytest.raises(RuntimeError, match="enable"):
        exp.attribution()


def test_attribution_bounds_and_report():
    xs, ys = _data()
    exp = api.build_experiment(_spec(), xs, ys)
    obs_spans.enable()
    exp.run(10)
    attr = exp.attribution(k=2)
    n = 6
    assert attr.rounds == 10 and attr.k == 2
    assert attr.miss_rate.shape == (n,)
    assert np.all((attr.miss_rate >= 0) & (attr.miss_rate <= 1))
    assert np.all(attr.miss_counts <= attr.active_rounds)
    assert attr.slowest_k_counts.sum() == 10 * 2
    assert np.all((attr.comp_share >= 0) & (attr.comp_share <= 1))
    top = attr.top_stragglers(3)
    assert len(top) == 3
    assert [r for _, r in top] == sorted((r for _, r in top),
                                         reverse=True)
    d = attr.to_dict()
    assert d["rounds"] == 10
    assert len(d["miss_rate"]) == n
    assert 0.0 <= d["comp_share_mean"] <= 1.0


def test_round_deadlines_per_step_kind():
    rng = np.random.default_rng(0)
    times = rng.uniform(1.0, 5.0, size=(4, 5))
    active = np.ones((4, 5), dtype=bool)
    active[2, :3] = False

    coded = round_deadlines("coded", times, active, t_star=2.5)
    np.testing.assert_array_equal(coded, np.full(4, 2.5))
    per_round = np.array([1.0, 2.0, 3.0, 4.0])
    np.testing.assert_array_equal(
        round_deadlines("adaptive_coded", times, active,
                        t_star_r=per_round), per_round)
    naive = round_deadlines("naive", times, active)
    np.testing.assert_array_equal(
        naive, np.where(active, times, 0.0).max(axis=1))
    greedy = round_deadlines("greedy", times, active, n_wait=3)
    srt = np.sort(np.where(active, times, np.inf), axis=1)
    # row 2 has only 2 active clients -> waits clamps to its live count
    expect = np.array([srt[0, 2], srt[1, 2], srt[2, 1], srt[3, 2]])
    np.testing.assert_array_equal(greedy, expect)


def test_attribution_from_blocks_concatenates():
    blocks = [{"times": np.full((3, 4), 1.0), "active": None},
              {"times": np.full((2, 4), 9.0), "active": None}]
    attr = attribution_from_blocks(
        blocks, "coded", t_star=2.0, t_ideal=1.0, n_wait=2,
        loads=np.full(4, 0.5), m=2.0, k=1)
    assert attr.rounds == 5
    # rounds in block 2 all miss the coded deadline
    np.testing.assert_array_equal(attr.miss_counts, np.full(4, 2))
    np.testing.assert_allclose(attr.miss_rate, 0.4)
    np.testing.assert_allclose(attr.comp_share[:3], 0.0)
    np.testing.assert_allclose(attr.comp_share[3:], 1.0)


# ---------------------------------------------------------------------------
# spans through a real run + service surface
# ---------------------------------------------------------------------------

def test_required_spans_recorded_by_journaled_run(tmp_path):
    xs, ys = _data()
    with obs_spans.collecting() as mod:
        api.build_experiment(_spec(), xs, ys).run(
            8, journal_dir=str(tmp_path))
        names = set(mod.totals())
    assert set(REQUIRED_SPANS) <= names
    assert "checkpoint/save" not in names   # no checkpoint_dir given


def test_service_health_timing_and_journal(tmp_path):
    xs, ys = _data()
    spec = _spec()
    svc = api.ExperimentService(str(tmp_path))
    obs_spans.enable()
    svc.submit(spec, xs, ys, 8, run_id="r0")
    while svc.step() is not None:
        pass
    timing = svc.health_report()["r0"]["timing"]
    assert timing["blocks_run"] == 2
    assert timing["block_seconds"] > 0
    assert timing["ckpt_save_seconds"] > 0
    assert timing["backoff_seconds"] == 0.0
    events = load_events(str(tmp_path / "r0"))
    assert [e["round"] for e in events] == list(range(8))
