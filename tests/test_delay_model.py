"""Delay model (paper §II-B, eq. 11-15, Theorem 1 cdf)."""
import numpy as np

from repro.config import FLConfig
from repro.core.delay_model import (NodeDelayParams, mec_network, packet_bits,
                                    scale_tau)


def test_expected_delay_formula():
    nd = NodeDelayParams(mu=4.0, alpha=2.0, tau=0.25, p=0.1)
    load = 10.0
    # eq. 15: l/mu (1 + 1/alpha) + 2 tau / (1-p)
    expect = 10 / 4 * 1.5 + 2 * 0.25 / 0.9
    assert abs(nd.expected_delay(load) - expect) < 1e-12


def test_sample_mean_matches_eq15():
    nd = NodeDelayParams(mu=4.0, alpha=2.0, tau=0.25, p=0.3)
    rng = np.random.default_rng(0)
    s = nd.sample(rng, 10.0, size=300_000)
    assert abs(np.mean(s) - nd.expected_delay(10.0)) < 0.02 * nd.expected_delay(10.0)


def test_cdf_monotone_and_bounded():
    nd = NodeDelayParams(mu=4.0, alpha=2.0, tau=0.25, p=0.3)
    ts = np.linspace(0, 50, 200)
    cdf = [nd.cdf(t, 10.0) for t in ts]
    assert cdf[0] == 0.0
    assert all(b >= a - 1e-12 for a, b in zip(cdf, cdf[1:]))
    assert cdf[-1] <= 1.0
    assert cdf[-1] > 0.95


def test_cdf_matches_montecarlo():
    nd = NodeDelayParams(mu=2.0, alpha=1.5, tau=0.4, p=0.25)
    rng = np.random.default_rng(1)
    s = nd.sample(rng, 5.0, size=300_000)
    for t in [2.0, 4.0, 8.0]:
        assert abs(np.mean(s <= t) - nd.cdf(t, 5.0)) < 5e-3


def test_mec_network_heterogeneity():
    fl = FLConfig(n_clients=30)
    nodes = mec_network(fl, d_scalars_per_point=1000)
    assert len(nodes) == 30
    mus = sorted(nd.mu for nd in nodes)
    # paper §V-A: processing rates span k2^29 = 0.8^29
    assert mus[0] / mus[-1] == FLConfig().mac_decay ** 29 or \
        abs(mus[0] / mus[-1] - FLConfig().mac_decay ** 29) < 1e-9


def test_packet_bits_overhead():
    fl = FLConfig()
    assert packet_bits(fl, 100) == 100 * 32 * 1.1


def test_scale_tau():
    nd = NodeDelayParams(mu=1.0, alpha=1.0, tau=2.0, p=0.1)
    nd2 = scale_tau(nd, 10.0)
    assert nd2.tau == 20.0 and nd2.mu == nd.mu
