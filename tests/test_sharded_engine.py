"""Client-mesh (shard_map + psum) engine vs. the single-device batched engine.

The mesh mode partitions the dense client tensor over a `clients` axis,
computes per-shard gradients locally, and psum-aggregates — the device-level
mirror of the paper's MEC server aggregation.  With equal seeds it must
reproduce the single-device trajectory to fp32 tolerance at ANY device
count; padding rows injected to make the client axis divisible carry an
all-zero mask and must contribute exactly nothing.

Runs meaningfully under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the `multidevice`
CI job); with fewer host devices the higher device counts skip.
"""
import jax
import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.launch.mesh import make_client_mesh

pytestmark = pytest.mark.multidevice

DEVICE_COUNTS = (1, 2, 4, 8)


def _data(n=8, l=24, q=32, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _sim(xs, ys, scheme, mesh=None, **spec_kw):
    fl = FLConfig(n_clients=xs.shape[0], delta=0.25, psi=0.3, seed=3)
    tc = TrainConfig(learning_rate=0.5, l2_reg=1e-4, lr_decay_epochs=(10, 18))
    spec = ExperimentSpec(fl=fl, train=tc, scheme=scheme, **spec_kw)
    # mesh goes through the build_experiment override so tests can pass a
    # concrete Mesh object (not spec-serializable) as well as a count
    return api.build_experiment(spec, xs, ys, mesh=mesh)


def _skip_unless(ndev):
    if jax.device_count() < ndev:
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()} "
                    "(set XLA_FLAGS=--xla_force_host_platform_device_count)")


@pytest.mark.parametrize("ndev", DEVICE_COUNTS)
@pytest.mark.parametrize("scheme", ["naive", "greedy", "coded"])
def test_mesh_matches_single_device_trajectory(scheme, ndev):
    """Same seeds => same theta trajectory and history at every mesh size.

    n=8 divides evenly at every count here; the zero-row padding path is
    covered by test_mesh_pads_indivisible_client_axis (6 clients over 4
    devices)."""
    _skip_unless(ndev)
    xs, ys = _data()
    trace = lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)
    res_1 = _sim(xs, ys, scheme).run(20, eval_fn=trace, eval_every=1)
    res_m = _sim(xs, ys, scheme, mesh=ndev).run(20, eval_fn=trace,
                                                eval_every=1)
    np.testing.assert_allclose(np.asarray(res_m.theta),
                               np.asarray(res_1.theta), atol=1e-5)
    for h1, hm in zip(res_1.history, res_m.history):
        assert h1.returned == hm.returned
        np.testing.assert_allclose(hm.wall_clock, h1.wall_clock, rtol=1e-5)
        np.testing.assert_allclose(hm.loss, h1.loss, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("scheme", ["naive", "coded"])
def test_mesh_pads_indivisible_client_axis(scheme):
    """n=6 clients (7 fused rows for coded) over 4 devices: the zero-mask
    padding rows must not perturb the trajectory."""
    _skip_unless(4)
    xs, ys = _data(n=6)
    res_1 = _sim(xs, ys, scheme).run(15)
    res_m = _sim(xs, ys, scheme, mesh=4).run(15)
    np.testing.assert_allclose(np.asarray(res_m.theta),
                               np.asarray(res_1.theta), atol=1e-5)


@pytest.mark.parametrize("ndev", [2, 8])
@pytest.mark.parametrize("scheme", ["naive", "greedy", "coded"])
def test_mesh_run_multi_matches_single_device(scheme, ndev):
    """vmapped realizations over the sharded step == single-device."""
    _skip_unless(ndev)
    xs, ys = _data()
    m1 = _sim(xs, ys, scheme).run_multi(8, 3)
    mm = _sim(xs, ys, scheme, mesh=ndev).run_multi(8, 3)
    np.testing.assert_allclose(mm.wall_clock, m1.wall_clock, rtol=1e-6)
    np.testing.assert_array_equal(mm.returned, m1.returned)
    np.testing.assert_allclose(np.asarray(mm.theta), np.asarray(m1.theta),
                               atol=1e-5)


def test_mesh_pallas_backend_matches_xla():
    """Pallas kernels inside shard_map (check_rep=False) == XLA mesh path."""
    _skip_unless(2)
    xs, ys = _data()
    res_x = _sim(xs, ys, "coded", mesh=2).run(10)
    res_p = _sim(xs, ys, "coded", mesh=2, kernel_backend="pallas").run(10)
    np.testing.assert_allclose(np.asarray(res_p.theta),
                               np.asarray(res_x.theta), atol=1e-5)


def test_mesh_accepts_mesh_object_and_rejects_bad_axes():
    _skip_unless(2)
    xs, ys = _data(n=4)
    mesh = make_client_mesh(2)
    res = _sim(xs, ys, "naive", mesh=mesh).run(3)
    assert np.isfinite(np.asarray(res.theta)).all()
    bad = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("model",))
    with pytest.raises(ValueError, match="clients"):
        _sim(xs, ys, "naive", mesh=bad)


def test_make_client_mesh_validates_device_count():
    with pytest.raises(ValueError, match="device"):
        make_client_mesh(jax.device_count() + 1)
