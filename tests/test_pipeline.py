"""Data pipeline: packing, sharding, deterministic resume."""
import numpy as np

from repro.data.pipeline import PackedLMDataset, PipelineConfig, \
    shard_pipelines


def _ds(**kw):
    base = dict(vocab=128, seq_len=64, batch=3, seed=7)
    base.update(kw)
    return PackedLMDataset(PipelineConfig(**base))


def test_shapes_and_ranges():
    b = _ds().batch_at(0)
    assert b["tokens"].shape == (3, 64) and b["labels"].shape == (3, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
    assert b["labels"].max() < 128


def test_deterministic_resume():
    a = _ds().batch_at(5)
    b = _ds().batch_at(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["labels"], b["labels"])
    c = _ds().batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_next_token_alignment():
    ds = _ds(mask_cross_doc=False)
    b = ds.batch_at(0)
    # labels are tokens shifted by one within the packed row
    cfg = ds.cfg
    for r in range(cfg.batch):
        row, _ = ds._packed_row(
            np.random.default_rng(abs(hash((cfg.seed, 0, 0, r))) % 2**63))
        assert np.array_equal(b["tokens"][r], row[:-1])
        assert np.array_equal(b["labels"][r], row[1:])


def test_cross_doc_masking():
    b = _ds(mean_doc_len=10).batch_at(0)
    assert (b["labels"] == -100).sum() > 0


def test_shards_differ_and_cover():
    pipes = shard_pipelines(vocab=64, seq_len=32, global_batch=8, n_shards=4)
    assert len(pipes) == 4
    batches = [p.batch_at(0)["tokens"] for p in pipes]
    assert all(b.shape == (2, 32) for b in batches)
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i], batches[j])


def test_iterator_protocol():
    it = iter(_ds())
    first = next(it)
    second = next(it)
    assert not np.array_equal(first["tokens"], second["tokens"])
    assert np.array_equal(first["tokens"], _ds().batch_at(0)["tokens"])
