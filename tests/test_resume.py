"""Bit-identical checkpoint/resume of block-structured runs.

The acceptance contract of the RunState refactor: save a run's state at
any block boundary, kill the process, restore in a FRESH Experiment, and
finishing the run produces results bit-identical to the uninterrupted
blocked run — theta, wall-clock log, returned counts, loss curve,
privacy_eps, and (adaptive family) the assembled schedule.  Covered for
the stationary, traced-channel, and adaptive paths on both kernel
backends, plus run_multi at both its granularities, the trace-stream
counter regression (the former hidden ``_next_trace_rng`` call index now
lives in RunState), and the hardened checkpoint/io error contract.
"""
import os

import numpy as np
import pytest

from repro import api
from repro.checkpoint import io as ckpt_io
from repro.config import ExperimentSpec, FLConfig, TrainConfig


def _data(n=6, l=16, q=24, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _spec(scheme="coded", **over):
    base = dict(
        fl=FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme=scheme, checkpoint_every=4)
    base.update(over)
    return ExperimentSpec(**base)


def _eval():
    return lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))
    assert a.privacy_eps == b.privacy_eps
    for ha, hb in zip(a.history, b.history):
        assert ha.wall_clock == hb.wall_clock
        assert ha.returned == hb.returned
        assert (ha.loss == hb.loss
                or (np.isnan(ha.loss) and np.isnan(hb.loss)))


CASES = {
    "coded": dict(scheme="coded"),
    "coded_channel": dict(scheme="coded", channel_profile="drift_churn"),
    "adaptive_coded": dict(scheme="adaptive_coded",
                           channel_profile="drift_churn", adapt_every=2),
    "adaptive_greedy": dict(scheme="adaptive_greedy",
                            channel_profile="drift_churn", adapt_every=2),
}


@pytest.mark.parametrize("kernel_backend", ["xla", "pallas"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_kill_and_resume_bit_identical(case, kernel_backend, tmp_path):
    """Save at the first block boundary (simulated kill: the restoring
    Experiment is built from scratch) -> resume -> finish == the
    uninterrupted blocked run, bit for bit."""
    xs, ys = _data()
    spec = _spec(kernel_backend=kernel_backend, **CASES[case])
    ev = _eval()

    control = api.build_experiment(spec, xs, ys).run(
        12, eval_fn=ev, eval_every=1)

    interrupted = api.build_experiment(spec, xs, ys)
    state = interrupted.init_state(12, collect=True)
    state = interrupted.run_block(state, eval_fn=ev, eval_every=1)
    assert state.rounds_done == 4
    path = interrupted.save_state(
        str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000004.npz"), state)
    assert os.path.exists(path)
    del interrupted, state       # the kill

    resumed = api.build_experiment(spec, xs, ys).run(
        12, eval_fn=ev, eval_every=1, checkpoint_dir=str(tmp_path),
        resume=True)
    _assert_same_result(control, resumed)


def test_adaptive_schedule_survives_resume(tmp_path):
    """The assembled AdaptiveSchedule (loads trajectory, deadlines,
    estimator snapshots) is identical between control and resumed run."""
    xs, ys = _data()
    spec = _spec("adaptive_coded", channel_profile="drift_churn",
                 adapt_every=2)
    exp_a = api.build_experiment(spec, xs, ys)
    exp_a.run(8)
    sched_a = exp_a.last_schedule

    exp_b = api.build_experiment(spec, xs, ys)
    state = exp_b.run_block(exp_b.init_state(8))
    exp_b.save_state(str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000004.npz"),
                     state)
    exp_c = api.build_experiment(spec, xs, ys)
    exp_c.run(8, checkpoint_dir=str(tmp_path), resume=True)
    sched_c = exp_c.last_schedule

    assert sched_a.n_blocks == sched_c.n_blocks
    np.testing.assert_array_equal(sched_a.loads_blocks,
                                  sched_c.loads_blocks)
    np.testing.assert_array_equal(sched_a.times, sched_c.times)
    np.testing.assert_array_equal(sched_a.t_star, sched_c.t_star)
    np.testing.assert_array_equal(np.asarray(sched_a.gmask_blocks),
                                  np.asarray(sched_c.gmask_blocks))
    for ea, ec in zip(sched_a.estimates, sched_c.estimates):
        for key in ("mu", "tau", "p", "avail"):
            np.testing.assert_array_equal(ea[key], ec[key])
        assert ea["rounds_seen"] == ec["rounds_seen"]


@pytest.mark.parametrize("channel", [None, "drift_churn"])
def test_run_multi_kill_and_resume(channel, tmp_path):
    """run_multi resumes at its block granularity: all-realization round
    blocks (stationary) or one-realization blocks (traced)."""
    xs, ys = _data()
    spec = _spec("coded", channel_profile=channel, checkpoint_every=3)
    control = api.build_experiment(spec, xs, ys).run_multi(6, 3)

    exp_b = api.build_experiment(spec, xs, ys)
    state = exp_b.run_block(exp_b.init_state(6, n_realizations=3))
    exp_b.save_state(
        str(tmp_path / f"{ckpt_io.CKPT_PREFIX}{state.rounds_done:06d}.npz"),
        state)
    resumed = api.build_experiment(spec, xs, ys).run_multi(
        6, 3, checkpoint_dir=str(tmp_path), resume=True)

    np.testing.assert_array_equal(np.asarray(control.theta),
                                  np.asarray(resumed.theta))
    np.testing.assert_array_equal(control.wall_clock, resumed.wall_clock)
    np.testing.assert_array_equal(control.returned, resumed.returned)


def test_trace_stream_counter_lives_in_state(tmp_path):
    """Regression for the folded-in `_next_trace_rng` counter: restoring
    an old state replays its ORIGINAL trace stream even after the same
    Experiment instance has since started other runs (which advance the
    instance-level reservation cursor)."""
    xs, ys = _data()
    spec = _spec("coded", channel_profile="drift_churn")
    exp = api.build_experiment(spec, xs, ys)
    state0 = exp.init_state(8)
    path = exp.save_state(
        str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000000.npz"), state0)
    immediate = exp._drive(state0, None)

    # burn more trace streams + RNG draws on the same instance
    exp.run(8)
    assert exp._trace_calls >= 2

    replayed = exp.restore_state(path)
    assert replayed.trace_call == state0.trace_call
    replayed = exp._drive(replayed, None)
    np.testing.assert_array_equal(np.asarray(immediate.theta),
                                  np.asarray(replayed.theta))
    np.testing.assert_array_equal(immediate.t_rounds, replayed.t_rounds)


def test_restore_bumps_reservation_past_checkpoint(tmp_path):
    """A fresh Experiment that restores a run must hand NEW runs trace
    streams disjoint from the restored reservation."""
    xs, ys = _data()
    spec = _spec("coded", channel_profile="drift_churn")
    exp_a = api.build_experiment(spec, xs, ys)
    state = exp_a.init_state(6, n_realizations=3)    # reserves 3 streams
    path = exp_a.save_state(
        str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000000.npz"), state)

    exp_b = api.build_experiment(spec, xs, ys)
    restored = exp_b.restore_state(path)
    assert exp_b._trace_calls == restored.trace_call + 3
    fresh = exp_b.init_state(6)
    assert fresh.trace_call == restored.trace_call + 3


def test_checkpoint_every_partitioning_is_self_consistent():
    """Different checkpoint_every values are different (equally valid)
    stream partitions; equal partitions agree bit-exactly."""
    xs, ys = _data()
    r4a = api.build_experiment(_spec(checkpoint_every=4), xs, ys).run(12)
    r4b = api.build_experiment(_spec(checkpoint_every=4), xs, ys).run(12)
    _assert_same_result(r4a, r4b)
    r0 = api.build_experiment(_spec(checkpoint_every=0), xs, ys).run(12)
    np.testing.assert_array_equal(np.asarray(r0.theta), np.asarray(
        api.build_experiment(_spec(checkpoint_every=0), xs, ys)
        .run(12).theta))


def test_run_block_validation_errors(tmp_path):
    xs, ys = _data()
    exp = api.build_experiment(_spec(), xs, ys)
    state = exp.init_state(4)
    with pytest.raises(ValueError, match="collect"):
        exp.run_block(state, eval_fn=_eval())
    done = exp._drive(state, None)
    with pytest.raises(ValueError, match="complete"):
        exp.run_block(done)
    with pytest.raises(ValueError, match="complete"):
        exp.finish(state)      # original state: 0/4 rounds
    with pytest.raises(ValueError, match="checkpoint_dir"):
        exp.run(4, resume=True)
    with pytest.raises(ValueError, match="iterations"):
        exp.init_state(0)


def test_checkpoint_requires_batched_engine():
    with pytest.raises(ValueError, match="batched"):
        _spec(engine="legacy", checkpoint_every=4)
    xs, ys = _data()
    exp = api.build_experiment(_spec(engine="legacy", checkpoint_every=0),
                               xs, ys)
    with pytest.raises(ValueError, match="batched"):
        exp.run(4, checkpoint_dir="/tmp/nope")


def test_checkpoint_every_must_align_with_adapt_every():
    xs, ys = _data()
    with pytest.raises(ValueError, match="adapt_every"):
        api.build_experiment(
            _spec("adaptive_coded", adapt_every=3, checkpoint_every=4),
            xs, ys)


def test_provenance_mismatch_rejected(tmp_path):
    """A checkpoint from one spec cannot be resumed by another."""
    xs, ys = _data()
    exp_a = api.build_experiment(_spec("coded"), xs, ys)
    path = exp_a.save_state(
        str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000000.npz"),
        exp_a.init_state(4))
    exp_b = api.build_experiment(_spec("greedy"), xs, ys)
    with pytest.raises(ValueError, match="provenance"):
        exp_b.restore_state(path)


def test_mode_mismatch_rejected(tmp_path):
    xs, ys = _data()
    exp = api.build_experiment(_spec("coded"), xs, ys)
    exp.save_state(str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000000.npz"),
                   exp.init_state(4, n_realizations=2))
    exp2 = api.build_experiment(_spec("coded"), xs, ys)
    with pytest.raises(ValueError, match="run_multi"):
        exp2.run(4, checkpoint_dir=str(tmp_path), resume=True)


# ---------------------------------------------------------------------------
# checkpoint/io hardening
# ---------------------------------------------------------------------------

def test_restore_rejects_missing_and_extra_keys(tmp_path):
    tree = {"a": np.ones((2, 3), np.float32), "b": np.zeros(4, np.float32)}
    path = str(tmp_path / "t.npz")
    ckpt_io.save(path, tree)
    with pytest.raises(ValueError, match="'b'"):
        ckpt_io.restore(path, {"a": np.zeros((2, 3), np.float32)})
    with pytest.raises(ValueError, match="absent from like_tree"):
        ckpt_io.restore(path, {"a": tree["a"]})
    with pytest.raises(ValueError, match="shape"):
        ckpt_io.restore(path, {"a": np.zeros((3, 2), np.float32),
                               "b": tree["b"]})


def test_restore_shape_error_names_key_and_shapes(tmp_path):
    path = str(tmp_path / "t.npz")
    ckpt_io.save(path, {"theta": np.ones((2, 3), np.float32)})
    with pytest.raises(ValueError) as err:
        ckpt_io.restore(path, {"theta": np.zeros((5, 7), np.float32)})
    msg = str(err.value)
    assert "theta" in msg and "(2, 3)" in msg and "(5, 7)" in msg


def test_state_payload_round_trip_and_meta_required(tmp_path):
    arrays = {"x": np.arange(6.0).reshape(2, 3),
              "nested/y": np.ones(3, bool)}
    meta = {"cursor": 7, "rng": {"state": 123}}
    path = ckpt_io.save_state(str(tmp_path / "s.npz"), arrays, meta)
    got_arrays, got_meta = ckpt_io.restore_state(path)
    assert got_meta == meta
    for key in arrays:
        np.testing.assert_array_equal(got_arrays[key], arrays[key])
    with pytest.raises(ValueError, match="reserved"):
        ckpt_io.save_state(str(tmp_path / "bad.npz"),
                           {"__meta__": np.zeros(1)}, {})
    # a plain tree checkpoint is not a state payload
    ckpt_io.save(str(tmp_path / "plain.npz"), {"a": np.zeros(2)})
    with pytest.raises(ValueError, match="__meta__"):
        ckpt_io.restore_state(str(tmp_path / "plain.npz"))


def test_latest_checkpoint_orders_numerically(tmp_path):
    for step in (4, 12, 8):
        ckpt_io.save_state(
            str(tmp_path / f"{ckpt_io.CKPT_PREFIX}{step:06d}.npz"),
            {"x": np.zeros(1)}, {"step": step})
    (tmp_path / "notes.txt").write_text("ignore me")
    latest = ckpt_io.latest_checkpoint(str(tmp_path))
    assert latest.endswith(f"{ckpt_io.CKPT_PREFIX}000012.npz")
    assert ckpt_io.latest_checkpoint(str(tmp_path / "empty")) is None


# ---------------------------------------------------------------------------
# restore-path failures: every way a checkpoint can be bad on disk
# ---------------------------------------------------------------------------

def _truncate(path):
    from repro.faults import truncate_file
    truncate_file(path, frac=0.5)


def _bitflip(path):
    from repro.faults import bitflip_file
    bitflip_file(path)


def _tamper_digest(path):
    """Rewrite the npz with one array's bytes changed but the original
    ``__meta__`` (and its embedded digest) kept — a structurally valid
    file whose content no longer matches its digest."""
    with np.load(path) as data:
        raw = {k: data[k] for k in data.files}
    key = next(k for k in raw if not k.startswith("__"))
    raw[key] = np.asarray(raw[key]) + 1
    np.savez(path[:-len(".npz")], **raw)


@pytest.mark.parametrize("corrupt, match", [
    (_truncate, "unreadable"),
    (_bitflip, "unreadable|digest"),
    (_tamper_digest, "digest"),
], ids=["truncated", "bitflipped", "digest_mismatch"])
def test_restore_state_detects_corruption(tmp_path, corrupt, match):
    path = ckpt_io.save_state(str(tmp_path / "s.npz"),
                              {"x": np.arange(64.0)}, {"cursor": 3})
    ckpt_io.restore_state(path)                    # sanity: intact loads
    corrupt(path)
    with pytest.raises(ckpt_io.CheckpointCorruptError, match=match):
        ckpt_io.restore_state(path)


def test_latest_checkpoint_valid_only_falls_back(tmp_path):
    for step in (4, 8, 12):
        ckpt_io.save_state(
            str(tmp_path / f"{ckpt_io.CKPT_PREFIX}{step:06d}.npz"),
            {"x": np.full(8, float(step))}, {"step": step})
    _truncate(str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000012.npz"))
    # plain mode still returns the (corrupt) newest; valid_only skips it
    assert ckpt_io.latest_checkpoint(str(tmp_path)).endswith("000012.npz")
    assert ckpt_io.latest_checkpoint(
        str(tmp_path), valid_only=True).endswith("000008.npz")
    _tamper_digest(str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000008.npz"))
    assert ckpt_io.latest_checkpoint(
        str(tmp_path), valid_only=True).endswith("000004.npz")


def test_stale_tmp_files_swept_and_never_resumed(tmp_path):
    """A mid-save kill's ``*.tmp`` leftover is never a resume candidate
    and is swept by the next successful save."""
    stale = tmp_path / f"{ckpt_io.CKPT_PREFIX}000008.npz.tmp.npz"
    stale.write_bytes(b"half-written garbage")
    assert ckpt_io.latest_checkpoint(str(tmp_path)) is None
    ckpt_io.save_state(
        str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000004.npz"),
        {"x": np.zeros(2)}, {})
    assert not stale.exists()
    assert ckpt_io.latest_checkpoint(str(tmp_path)).endswith("000004.npz")


def test_resume_from_empty_dir_starts_fresh(tmp_path):
    """resume=True over a checkpoint-less directory falls back to a
    fresh run (and produces the same result as not resuming at all)."""
    xs, ys = _data()
    control = api.build_experiment(_spec(), xs, ys).run(8)
    resumed = api.build_experiment(_spec(), xs, ys).run(
        8, checkpoint_dir=str(tmp_path / "nothing_here"), resume=True)
    _assert_same_result(control, resumed)


def test_resume_falls_back_past_corrupt_latest(tmp_path):
    """End-to-end: corrupt the newest checkpoint mid-run; resume must
    restore the older intact one and still finish bit-identically."""
    xs, ys = _data()
    spec = _spec()
    control = api.build_experiment(spec, xs, ys).run(12)

    exp = api.build_experiment(spec, xs, ys)
    state = exp.init_state(12)
    for _ in range(2):                             # two block boundaries
        state = exp.run_block(state)
        exp.save_state(
            str(tmp_path / f"{ckpt_io.CKPT_PREFIX}"
                f"{state.rounds_done:06d}.npz"), state)
    _truncate(str(tmp_path / f"{ckpt_io.CKPT_PREFIX}000008.npz"))
    resumed = api.build_experiment(spec, xs, ys).run(
        12, checkpoint_dir=str(tmp_path), resume=True)
    _assert_same_result(control, resumed)
