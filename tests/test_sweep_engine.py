"""Compiled sweep engine vs. the looped `run_multi` path.

`repro.launch.sweep.run_sweep` stacks per-deployment step constants along a
profile axis and vmaps the SAME scan step over the (profile x realization)
grid — one compiled call per scheme.  With equal seeds it must reproduce a
Python loop of independent, identically-seeded `run_multi` calls exactly
(the step math is shared; profile-axis padding contributes zero through the
validity mask).
"""
import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.launch import sweep as sweep_mod

# grouped with the sharded-engine suite in the `multidevice` CI job (the
# sweep itself is single-device, but the suites ship together); runs at
# any device count.
pytestmark = pytest.mark.multidevice

PROFILES = {
    "uniform": dict(rate_decay=1.0, mac_decay=1.0),
    "paper": dict(rate_decay=0.95, mac_decay=0.8),
    "extreme": dict(rate_decay=0.9, mac_decay=0.6),
}
BASE = dict(n_clients=6, delta=0.25, psi=0.3, seed=3)


def _data(n=6, l=16, q=24, c=3, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _tc():
    return TrainConfig(learning_rate=0.5, l2_reg=1e-5, lr_decay_epochs=(5,))


def _exp(xs, ys, scheme, knobs):
    """Spec-built deployment matching one sweep grid cell."""
    spec = ExperimentSpec(fl=FLConfig(**{**BASE, **knobs}), train=_tc(),
                          scheme=scheme)
    return api.build_experiment(spec, xs, ys)


@pytest.fixture(scope="module")
def sweep_result():
    xs, ys = _data()
    return xs, ys, sweep_mod.run_sweep(
        xs, ys, profiles=PROFILES, train_cfg=_tc(), iterations=10,
        realizations=4, fl_kwargs=BASE)


@pytest.mark.parametrize("scheme", sweep_mod.SCHEMES)
def test_sweep_matches_looped_run_multi(sweep_result, scheme):
    """Every (scheme, profile) cell reproduces an identically-seeded
    standalone run_multi — wall-clock, return counts, and final iterates."""
    xs, ys, sw = sweep_result
    for pname, knobs in PROFILES.items():
        loop = _exp(xs, ys, scheme, knobs).run_multi(10, 4)
        got = sw.results[scheme][pname]
        np.testing.assert_allclose(got.wall_clock, loop.wall_clock,
                                   rtol=1e-6)
        np.testing.assert_array_equal(got.returned, loop.returned)
        np.testing.assert_allclose(np.asarray(got.theta),
                                   np.asarray(loop.theta), atol=1e-5)
        assert got.setup_time == loop.setup_time
        if scheme == "coded":
            assert got.t_star == loop.t_star
            np.testing.assert_array_equal(got.loads, loop.loads)


def test_sweep_shapes_and_metadata(sweep_result):
    xs, ys, sw = sweep_result
    n, q, c = xs.shape[0], xs.shape[2], ys.shape[2]
    for scheme in sweep_mod.SCHEMES:
        assert set(sw.results[scheme]) == set(PROFILES)
        assert sw.host_seconds[scheme] > 0
        for res in sw.results[scheme].values():
            assert res.theta.shape == (4, q, c)
            assert res.wall_clock.shape == (4, 10)
            assert res.returned.shape == (4, 10)
            assert np.all(np.diff(res.wall_clock, axis=1) > 0)


def test_sweep_accepts_prebuilt_sims():
    """The benchmark launcher times setup separately and hands sims in."""
    xs, ys = _data()
    sims = {"coded": {}}
    for pname, knobs in PROFILES.items():
        sims["coded"][pname] = _exp(xs, ys, "coded", knobs)
    sw = sweep_mod.run_sweep(xs, ys, profiles=PROFILES, train_cfg=_tc(),
                             iterations=6, realizations=2,
                             schemes=("coded",), fl_kwargs=BASE, sims=sims)
    assert sw.sims["coded"] is sims["coded"]
    assert set(sw.results["coded"]) == set(PROFILES)


def test_sweep_pads_coded_profiles_to_common_length():
    """Profiles with different load allocations (different dense l_max)
    stack via l_target padding without perturbing any cell."""
    xs, ys = _data()
    sw = sweep_mod.run_sweep(xs, ys, profiles=PROFILES, train_cfg=_tc(),
                             iterations=6, realizations=2,
                             schemes=("coded",), fl_kwargs=BASE)
    lens = set()
    for pname in PROFILES:
        sim = sw.sims["coded"][pname]
        lens.add(sim.build_consts()["gx"].shape[1])
        got = sw.results["coded"][pname]
        loop = _exp(xs, ys, "coded", PROFILES[pname]).run_multi(6, 2)
        np.testing.assert_allclose(np.asarray(got.theta),
                                   np.asarray(loop.theta), atol=1e-5)
    # the deployments genuinely differ in allocated loads across this grid
    assert len({sw.sims["coded"][p].t_star for p in PROFILES}) > 1


def test_sweep_rejects_sims_profile_mismatch():
    """Prebuilt sims must cover exactly the sweep's profile grid."""
    xs, ys = _data()
    partial = {"coded": {"paper": _exp(xs, ys, "coded",
                                       PROFILES["paper"])}}
    with pytest.raises(ValueError, match="cover profiles"):
        sweep_mod.run_sweep(xs, ys, profiles=PROFILES, train_cfg=_tc(),
                            iterations=3, realizations=2,
                            schemes=("coded",), fl_kwargs=BASE, sims=partial)


def test_sweep_rejects_step_static_overrides():
    """Profiles share ONE compiled step: overriding a scheme-static knob
    like psi must fail loudly, not silently diverge from the loop."""
    xs, ys = _data()
    bad_profiles = {"a": dict(psi=0.1), "b": dict(psi=0.9)}
    with pytest.raises(ValueError, match="n_wait"):
        sweep_mod.run_sweep(xs, ys, profiles=bad_profiles, train_cfg=_tc(),
                            iterations=3, realizations=2,
                            schemes=("greedy",), fl_kwargs=BASE)


def test_run_multi_eval_vmapped_matches_loop():
    """Satellite: the final-iterate eval is vmapped over realizations when
    traceable; non-traceable eval_fns fall back to the loop — both agree."""
    import jax.numpy as jnp
    xs, ys = _data()

    def traceable(th):
        return jnp.mean(th ** 2), jnp.sum(jnp.abs(th))

    def host_only(th):
        arr = np.asarray(th)          # numpy forces the fallback path
        return float((arr ** 2).mean()), float(np.abs(arr).sum())

    res_t = _exp(xs, ys, "coded", {}).run_multi(6, 3, eval_fn=traceable)
    res_h = _exp(xs, ys, "coded", {}).run_multi(6, 3, eval_fn=host_only)
    assert res_t.accuracy is not None and res_t.accuracy.shape == (3,)
    np.testing.assert_allclose(res_t.accuracy, res_h.accuracy, rtol=1e-6)
