"""Sharding policy consistency: every param/cache leaf gets a valid spec."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models.model_zoo import build
from repro.sharding import policy as sh


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_tree_and_divide(arch):
    cfg = get_config(arch)
    model = build(cfg)
    abs_params = model.abstract_params()
    specs = sh.param_pspecs(abs_params, "fsdp_tp")
    leaves = jax.tree_util.tree_leaves_with_path(abs_params)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for (path, leaf), spec in zip(leaves, spec_leaves):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([sh.AXIS_SIZE[a] for a in axes]))
            assert dim % n == 0, (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b", "whisper-base",
                                  "rwkv6-1.6b"])
@pytest.mark.parametrize("long_ctx", [False, True])
def test_cache_specs_divide(arch, long_ctx):
    cfg = get_config(arch)
    model = build(cfg)
    batch = 1 if long_ctx else 128
    seq = 524288 if long_ctx else 32768
    cache = model.abstract_cache(batch, seq, 0)
    specs = sh.cache_pspecs(cache, long_ctx, False)
    leaves = jax.tree_util.tree_leaves_with_path(cache)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(leaves, spec_leaves):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([sh.AXIS_SIZE[a] for a in axes]))
            assert dim % n == 0, (path, spec, leaf.shape)


def test_tp_only_removes_fsdp_axis():
    cfg = get_config("yi-6b")
    model = build(cfg)
    abs_params = model.abstract_params()
    specs = jax.tree_util.tree_leaves(
        sh.param_pspecs(abs_params, "tp_only"),
        is_leaf=lambda x: isinstance(x, P))
    flat = [a for s in specs for a in s if a is not None]
    assert all(a == "model" for a in flat)
    assert len(flat) > 0


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b"])
def test_expert_parallel_policy(arch):
    """_ep suffix shards the expert dim over `model` (divisible archs)."""
    cfg = get_config(arch)
    model = build(cfg)
    abs_params = model.abstract_params()
    specs = sh.param_pspecs(abs_params, "fsdp_tp_ep")
    found = []

    def visit(path, leaf):
        keys = [str(getattr(k, "key", k)) for k in path]
        if "moe" in keys and keys[-1] in ("w1", "w2", "w3"):
            found.append((keys[-1], leaf))
    jax.tree_util.tree_map_with_path(
        visit, specs, is_leaf=lambda x: isinstance(x, P))
    assert found
    for name, spec in found:
        # stacked leading dim, then expert dim sharded over model
        assert spec[1] == "model", (name, spec)
    # mixtral (8 experts < 16) must fall back to TP-within-expert
    mx = build(get_config("mixtral-8x7b")).abstract_params()
    mx_specs = sh.param_pspecs(mx, "fsdp_tp_ep")
    bad = []

    def visit2(path, leaf):
        keys = [str(getattr(k, "key", k)) for k in path]
        if "moe" in keys and keys[-1] == "w1":
            bad.append(leaf)
    jax.tree_util.tree_map_with_path(
        visit2, mx_specs, is_leaf=lambda x: isinstance(x, P))
    assert all(s[1] != "model" for s in bad)


def test_fsdp_tp_uses_both_axes():
    cfg = get_config("qwen3-32b")
    model = build(cfg)
    specs = jax.tree_util.tree_leaves(
        sh.param_pspecs(model.abstract_params(), "fsdp_tp"),
        is_leaf=lambda x: isinstance(x, P))
    flat = [a for s in specs for a in s if a is not None]
    assert "model" in flat and "data" in flat
