"""Substrate tests: data pipeline, optimizers, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core.delay_model import mec_network
from repro.checkpoint import io as ckpt
from repro.data import sharding, synthetic
from repro.optim import optimizers
from repro.optim.schedule import cosine, step_decay


def test_synthetic_dataset_shapes_and_range():
    ds = synthetic.synthetic_classification(m_train=500, m_test=100, d=20)
    assert ds.x_train.shape == (500, 20)
    assert ds.x_train.min() >= 0.0 and ds.x_train.max() <= 1.0
    assert set(np.unique(ds.y_train)) <= set(range(10))
    oh = ds.one_hot(ds.y_train[:5])
    assert oh.shape == (5, 10) and np.allclose(oh.sum(1), 1.0)


def test_synthetic_task_is_learnable():
    ds = synthetic.synthetic_classification(m_train=2000, m_test=400, d=32,
                                            seed=1)
    # linear probe on raw features beats chance by a wide margin
    y = ds.one_hot(ds.y_train)
    theta = np.linalg.lstsq(ds.x_train, y, rcond=None)[0]
    acc = ((ds.x_test @ theta).argmax(1) == ds.y_test).mean()
    assert acc > 0.5


def test_sort_and_shard_noniid():
    ds = synthetic.synthetic_classification(m_train=1000, m_test=10, d=8)
    shards = sharding.sort_and_shard(ds.x_train, ds.y_train, 10)
    assert len(shards) == 10
    # label-sorted shards are class-concentrated: few distinct labels each
    distinct = [len(np.unique(y)) for _, y in shards]
    assert np.mean(distinct) <= 3


def test_assign_shards_by_speed():
    fl = FLConfig(n_clients=5)
    nodes = mec_network(fl, d_scalars_per_point=100)
    shards = [(np.full((4, 2), i), np.full((4,), i)) for i in range(5)]
    per_client = sharding.assign_shards_by_speed(shards, nodes, minibatch=4)
    assert len(per_client) == 5
    # fastest client gets shard 0 (lowest labels)
    exp = [nd.expected_delay(4) for nd in nodes]
    fastest = int(np.argmin(exp))
    assert per_client[fastest][1][0] == 0


def test_synthetic_tokens_zipf():
    toks = synthetic.synthetic_tokens(1000, 8, 64, seed=0)
    assert toks.shape == (8, 64)
    assert toks.min() >= 0 and toks.max() < 1000


def _quad_params():
    return {"a": jnp.array([2.0, -3.0]), "b": {"c": jnp.array([[1.5]])}}


def _quad_grads(p):
    return jax.tree_util.tree_map(lambda x: 2 * x, p)   # grad of sum(x^2)


def test_optimizers_descend():
    for name in ("sgd", "momentum", "adam"):
        init, update = optimizers.get(name)
        p = _quad_params()
        s = init(p)
        for _ in range(200):
            p, s = update(p, _quad_grads(p), s, 0.05)
        norm = sum(float(jnp.sum(jnp.square(l)))
                   for l in jax.tree_util.tree_leaves(p))
        assert norm < 1e-2, (name, norm)


def test_schedules():
    lr = step_decay(6.0, 0.8, (40, 65))
    assert lr(0) == 6.0 and abs(lr(41) - 4.8) < 1e-9
    assert abs(lr(66) - 6.0 * 0.64) < 1e-9
    c = cosine(1.0, 100, warmup=10)
    assert c(0) < c(9) <= 1.0
    assert c(99) < c(50)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "stack": [jnp.zeros((2,)), jnp.full((2,), 7.0)]}
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, tree, step=42)
    restored = ckpt.restore(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))
    assert ckpt.restore_step(path) == 42
