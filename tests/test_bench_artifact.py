"""Scheme-comparison benchmark harness + BENCH_fed_training.json artifact.

Runs a tiny deployment through `repro.launch.bench` and asserts the artifact
is written, well-formed, and that the validator actually rejects malformed
artifacts (the CI smoke step relies on both directions).
"""
import json

import pytest

from repro.core import schemes as schemes_registry
from repro.launch import bench as launch_bench
from repro.launch import kernel_bench

# the scale section's ladder is shrunk to toy rungs — the strict
# REQUIRED_NS ladder belongs to the CLI/CI artifact, so tests validate
# with the matching override (see _validate below)
TINY_NS = (64, 128)
TINY = dict(n_clients=4, l=8, q=12, c=2, iters=5, realizations=2,
            profiles={"uniform": dict(rate_decay=1.0, mac_decay=1.0),
                      "paper": dict(rate_decay=0.95, mac_decay=0.8)},
            scenario_kwargs=dict(n_clients=4, l=8, q=8, c=2, iters=12,
                                 adapt_every=4),
            service_kwargs=dict(n_clients=4, l=8, q=8, c=2, iters=8,
                                block=4),
            kernel_kwargs=dict(n_clients=2, l=16, d=8, q=16, c=2, u=8,
                               iters=3),
            scale_kwargs=dict(ns=TINY_NS, l=4, q=6, c=2, rounds=2,
                              cohort=16, sample_fraction=0.5,
                              trace_block=32),
            telemetry_kwargs=dict(n_clients=4, l=8, q=8, c=2, iters=8,
                                  block=4, repeats=1))

# the strict < 1.05 overhead ceiling belongs to the compute-dominated
# CLI/CI probe; at the toy sizes above, fixed journal/span cost is a
# visible fraction of a ~ms round, so tests validate with a loose cap
TINY_TELEMETRY_RATIO = 50.0


def _validate(obj):
    return launch_bench.validate_artifact(
        obj, scale_required_ns=TINY_NS,
        telemetry_max_ratio=TINY_TELEMETRY_RATIO)


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    result = launch_bench.run_schemes(**TINY)
    path = tmp_path_factory.mktemp("bench") / "BENCH_fed_training.json"
    launch_bench.write_artifact(result, str(path))
    return result, path


def test_artifact_written_and_valid(artifact):
    result, path = artifact
    assert path.exists()
    assert _validate(str(path)) == []
    assert _validate(result) == []
    # the strict default ladder rejects the toy ladder — exactly the
    # committed-artifact enforcement the CLI/CI path relies on (strict
    # mode may also flag the toy telemetry probe's unamortized overhead
    # ratio; nothing else is allowed to fail)
    strict = launch_bench.validate_artifact(result)
    assert strict and any("population rung" in p for p in strict)
    assert all("population rung" in p or "overhead_ratio" in p
               for p in strict)


def test_artifact_contents(artifact):
    result, path = artifact
    loaded = json.loads(path.read_text())
    assert loaded["benchmark"] == "fed_training_scheme_compare"
    assert loaded["schema_version"] == launch_bench.SCHEMA_VERSION
    assert set(loaded["profiles"]) == {"uniform", "paper"}
    # schema v3/v4: the grid is the LIVE grid-eligible registry at run
    # time (adaptive schemes live in the scenarios section instead)
    grid = loaded["config"]["schemes"]
    assert tuple(grid) == schemes_registry.grid_names()
    assert set(loaded["config"]["coded_schemes"]) == \
        set(schemes_registry.coded_names()) & set(grid)
    for prof in loaded["profiles"].values():
        schemes = prof["schemes"]
        assert set(schemes) == set(grid)
        # ideal is the deterministic FULL-LOAD floor: naive/greedy cannot
        # beat it (coded can — its clients process reduced loads)
        ideal = schemes["ideal"]["final_wall_clock_mean"]
        for s in ("naive", "greedy"):
            assert schemes[s]["final_wall_clock_mean"] >= ideal - 1e-9
        assert schemes["ideal"]["final_wall_clock_std"] == 0.0
        assert schemes["coded"]["t_star"] > 0
        assert prof["coded_speedup_vs_naive"] > 0
        assert prof["coded_overhead_vs_ideal"] > 0
        # coded-family entries report the parity privacy leakage; the
        # partial scheme shares fewer rows, so it must leak no more
        for s in loaded["config"]["coded_schemes"]:
            assert schemes[s]["privacy_eps_max_bits"] > 0
            assert schemes[s]["total_load"] > 0
        assert schemes["partial_coded"]["privacy_eps_max_bits"] <= \
            schemes["coded"]["privacy_eps_max_bits"]
    # schema v4: the static-vs-adaptive drift comparison rides along
    scen = loaded["scenarios"]
    assert set(scen["cases"]) == {"speedup_drift", "degrade_drift"}
    for case in scen["cases"].values():
        assert case["adaptive_speedup"] > 0
        assert case["static"]["time_to_target"] > 0
        assert case["adaptive"]["time_to_target"] > 0
    # schema v5: the RunState block-restructuring + service resume section
    service = loaded["service"]
    assert service["multiplexed_runs"] >= 3
    assert service["resumed_bit_identical"] is True
    assert service["oneshot_seconds"] > 0
    assert service["blocked_seconds"] > 0
    assert service["overhead_ratio"] > 0
    assert service["iters"] % service["block_rounds"] == 0
    # schema v6: the per-kernel microbenchmark section with the
    # fused-vs-two-pass ratio the CI kernel-bench job gates on
    kernels = loaded["kernels"]
    assert kernels["backend"] in ("xla", "pallas")
    for name in kernel_bench.KERNEL_NAMES:
        assert kernels["entries"][name]["us_per_call"] > 0
    assert kernels["fused_vs_two_pass_ratio"] > 0
    # schema v8: the hierarchical population-scaling section
    scale = loaded["scale"]
    assert [e["n"] for e in scale["entries"]] == list(TINY_NS)
    for entry in scale["entries"]:
        assert entry["wall_seconds"] > 0
        assert entry["peak_client_tensor_bytes"] <= \
            entry["dense_client_tensor_bytes"]
    assert scale["identity"]["routes_flat_engine"] is True
    assert scale["identity"]["bit_identical"] is True
    # schema v9: the run-telemetry section — the hard invariant is that
    # telemetry never perturbs a trajectory or the deterministic journal
    telemetry = loaded["telemetry"]
    assert telemetry["trajectory_bit_identical"] is True
    assert telemetry["journal_deterministic"] is True
    assert telemetry["journal_replay_matches"] is True
    assert telemetry["enabled_seconds"] > 0
    assert telemetry["disabled_seconds"] > 0
    assert telemetry["overhead_ratio"] > 0
    for name in launch_bench.report_mod.REQUIRED_SPANS:
        assert telemetry["span_totals"][name]["count"] >= 1


def test_newly_registered_scheme_lands_in_artifact(tmp_path):
    """Satellite: the bench grid is driven by the registry — registering a
    scheme makes it appear in the artifact (and validate) automatically."""
    class TinyParity(schemes_registry.PartialCodedScheme):
        name = "tiny_parity"
        default_u_fraction = 0.25

    schemes_registry.register(TinyParity())
    try:
        result = launch_bench.run_schemes(**TINY)
        assert _validate(result) == []
        assert "tiny_parity" in result["config"]["schemes"]
        assert "tiny_parity" in result["config"]["coded_schemes"]
        for prof in result["profiles"].values():
            entry = prof["schemes"]["tiny_parity"]
            assert entry["t_star"] > 0
            assert entry["privacy_eps_max_bits"] > 0
    finally:
        schemes_registry.unregister("tiny_parity")


def test_ideal_round_time_is_naive_lower_bound(artifact):
    """E[naive round] can never beat the deterministic ideal round."""
    result, _ = artifact
    for prof in result["profiles"].values():
        naive = prof["schemes"]["naive"]
        ideal = prof["schemes"]["ideal"]
        assert naive["per_round_mean"] >= ideal["per_round_mean"] - 1e-9


@pytest.mark.parametrize("mutate,frag", [
    (lambda d: d.pop("profiles"), "profiles"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d["profiles"]["uniform"]["schemes"].pop("ideal"), "ideal"),
    (lambda d: d["profiles"]["uniform"]["schemes"]["coded"].update(
        final_wall_clock_mean=float("nan")), "final_wall_clock_mean"),
    (lambda d: d["profiles"]["uniform"].update(
        coded_speedup_vs_naive=-1.0), "coded_speedup_vs_naive"),
    (lambda d: d["config"].pop("schemes"), "config.schemes"),
    (lambda d: d["config"].update(coded_schemes=[]), "coded_schemes"),
    (lambda d: d["profiles"]["paper"]["schemes"]["coded"].pop(
        "privacy_eps_max_bits"), "privacy_eps_max_bits"),
    (lambda d: d["profiles"]["paper"]["schemes"]["partial_coded"].update(
        t_star=None), "t_star"),
    (lambda d: d.pop("scenarios"), "scenarios"),
    (lambda d: d["scenarios"].pop("cases"), "cases"),
    (lambda d: d["scenarios"]["cases"]["degrade_drift"].update(
        adaptive_speedup=-2.0), "adaptive_speedup"),
    (lambda d: d["scenarios"]["cases"]["speedup_drift"]["static"].update(
        time_to_target=float("nan")), "time_to_target"),
    (lambda d: d.pop("service"), "service"),
    (lambda d: d["service"].update(multiplexed_runs=2), "multiplexed_runs"),
    (lambda d: d["service"].update(resumed_bit_identical=False),
     "resumed_bit_identical"),
    (lambda d: d["service"].update(overhead_ratio=-1.0), "overhead_ratio"),
    (lambda d: d["service"].update(oneshot_seconds=float("nan")),
     "oneshot_seconds"),
    (lambda d: d.pop("kernels"), "kernels"),
    (lambda d: d["kernels"]["entries"].pop("rff_linreg_grad_fused"),
     "rff_linreg_grad_fused"),
    (lambda d: d["kernels"]["entries"]["rff_embed"].update(
        us_per_call=float("nan")), "rff_embed"),
    (lambda d: d["kernels"].update(fused_vs_two_pass_ratio=-1.0),
     "fused_vs_two_pass_ratio"),
    (lambda d: d["kernels"].update(backend="cuda"), "backend"),
    (lambda d: d.pop("scale"), "scale"),
    (lambda d: d["scale"].pop("entries"), "entries"),
    (lambda d: d["scale"]["entries"].pop(0), "population rung"),
    (lambda d: d["scale"]["entries"][0].update(
        wall_seconds=float("nan")), "wall_seconds"),
    (lambda d: d["scale"]["entries"][0].update(
        peak_client_tensor_bytes=10 ** 12), "peak client tensor"),
    (lambda d: d["scale"]["entries"][1].update(sample_fraction=1.5),
     "sample_fraction"),
    (lambda d: d["scale"].pop("identity"), "identity"),
    (lambda d: d["scale"]["identity"].update(bit_identical=False),
     "bit_identical"),
    (lambda d: d.pop("telemetry"), "telemetry"),
    (lambda d: d["telemetry"].update(trajectory_bit_identical=False),
     "trajectory_bit_identical"),
    (lambda d: d["telemetry"].update(journal_deterministic=False),
     "journal_deterministic"),
    (lambda d: d["telemetry"].update(overhead_ratio=1e9),
     "overhead_ratio"),
    (lambda d: d["telemetry"].update(enabled_seconds=float("nan")),
     "enabled_seconds"),
    (lambda d: d["telemetry"]["span_totals"].pop("solver/two_step"),
     "solver/two_step"),
])
def test_validator_rejects_malformed(artifact, mutate, frag):
    result, _ = artifact
    broken = json.loads(json.dumps(result))   # deep copy
    mutate(broken)
    problems = _validate(broken)
    assert problems, "validator accepted a malformed artifact"
    assert any(frag in p for p in problems)


def test_kernel_regression_gate(artifact):
    """The CI gate is one-sided: speedups pass, slowdowns past threshold
    fail, and a ratio regression is reported on its own."""
    result, _ = artifact
    committed = result["kernels"]
    fresh = json.loads(json.dumps(committed))
    assert kernel_bench.compare_kernels(fresh, committed) == []
    fresh["entries"]["rff_embed"]["us_per_call"] /= 100       # speedup: OK
    assert kernel_bench.compare_kernels(fresh, committed) == []
    fresh["entries"]["rff_embed"]["us_per_call"] = \
        committed["entries"]["rff_embed"]["us_per_call"] * 10
    problems = kernel_bench.compare_kernels(fresh, committed)
    assert problems and any("rff_embed" in p for p in problems)
    fresh = json.loads(json.dumps(committed))
    fresh["fused_vs_two_pass_ratio"] *= 10
    problems = kernel_bench.compare_kernels(fresh, committed)
    assert problems and any("fused_vs_two_pass_ratio" in p for p in problems)
    assert kernel_bench.compare_kernels(fresh, committed, threshold=100) == []
    # nonsense thresholds and malformed sections are structural errors
    assert kernel_bench.compare_kernels(committed, committed, threshold=0.5)
    assert kernel_bench.compare_kernels({}, committed)


def test_kernel_micro_cli(artifact, tmp_path):
    """bench_kernels_micro: --validate, --compare (writes fresh artifact
    BEFORE judging so CI can upload it on failure)."""
    from benchmarks import bench_kernels_micro as cli
    _, path = artifact
    assert cli.main(["--validate", str(path)]) == 0
    fresh = tmp_path / "fresh_kernels.json"
    rc = cli.main(["--smoke", "--iters", "2", "--out", str(fresh),
                   "--compare", str(path), "--threshold", "1e9"])
    assert rc == 0
    assert kernel_bench.validate_kernels(
        json.loads(fresh.read_text())) == []
    # an impossible committed artifact must fail the gate yet still
    # leave the fresh timings on disk for upload
    tight = json.loads(path.read_text())
    for entry in tight["kernels"]["entries"].values():
        entry["us_per_call"] = 1e-6
    tight_path = tmp_path / "tight.json"
    tight_path.write_text(json.dumps(tight))
    fresh2 = tmp_path / "fresh2.json"
    rc = cli.main(["--smoke", "--iters", "2", "--out", str(fresh2),
                   "--compare", str(tight_path)])
    assert rc == 1
    assert fresh2.exists()


def test_validator_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert launch_bench.validate_artifact(str(bad))
    assert launch_bench.validate_artifact([1, 2, 3])
    assert launch_bench.validate_artifact(str(tmp_path / "missing.json"))


def test_cli_validate_roundtrip(artifact, capsys, monkeypatch):
    from benchmarks import bench_scheme_compare as cli
    from repro.launch import report as report_mod
    from repro.launch import scale as scale_mod
    _, path = artifact
    # the CLI pins the CI rung ladder; the tiny fixture's scale section
    # must fail it with the pointed missing-rung error...
    assert cli.main(["--validate", str(path)]) == 1
    assert "population rung" in capsys.readouterr().err
    # ...and pass once the pinned ladder is the fixture's own (the toy
    # telemetry probe's ratio is unamortized, so loosen that pin too)
    monkeypatch.setattr(scale_mod, "REQUIRED_NS", TINY_NS)
    monkeypatch.setattr(report_mod, "MAX_OVERHEAD_RATIO",
                        TINY_TELEMETRY_RATIO)
    assert cli.main(["--validate", str(path)]) == 0
    assert cli.main(["--validate", str(path) + ".nope"]) == 1
