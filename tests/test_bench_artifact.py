"""Scheme-comparison benchmark harness + BENCH_fed_training.json artifact.

Runs a tiny deployment through `repro.launch.bench` and asserts the artifact
is written, well-formed, and that the validator actually rejects malformed
artifacts (the CI smoke step relies on both directions).
"""
import json

import numpy as np
import pytest

from repro.launch import bench as launch_bench

TINY = dict(n_clients=4, l=8, q=12, c=2, iters=5, realizations=2,
            profiles={"uniform": dict(rate_decay=1.0, mac_decay=1.0),
                      "paper": dict(rate_decay=0.95, mac_decay=0.8)})


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    result = launch_bench.run_schemes(**TINY)
    path = tmp_path_factory.mktemp("bench") / "BENCH_fed_training.json"
    launch_bench.write_artifact(result, str(path))
    return result, path


def test_artifact_written_and_valid(artifact):
    result, path = artifact
    assert path.exists()
    assert launch_bench.validate_artifact(str(path)) == []
    assert launch_bench.validate_artifact(result) == []


def test_artifact_contents(artifact):
    result, path = artifact
    loaded = json.loads(path.read_text())
    assert loaded["benchmark"] == "fed_training_scheme_compare"
    assert loaded["schema_version"] == launch_bench.SCHEMA_VERSION
    assert set(loaded["profiles"]) == {"uniform", "paper"}
    for prof in loaded["profiles"].values():
        schemes = prof["schemes"]
        assert set(schemes) == {"coded", "naive", "greedy", "ideal"}
        # ideal is the deterministic FULL-LOAD floor: naive/greedy cannot
        # beat it (coded can — its clients process reduced loads)
        ideal = schemes["ideal"]["final_wall_clock_mean"]
        for s in ("naive", "greedy"):
            assert schemes[s]["final_wall_clock_mean"] >= ideal - 1e-9
        assert schemes["coded"]["t_star"] > 0
        assert prof["coded_speedup_vs_naive"] > 0
        assert prof["coded_overhead_vs_ideal"] > 0


def test_ideal_round_time_is_naive_lower_bound(artifact):
    """E[naive round] can never beat the deterministic ideal round."""
    result, _ = artifact
    for prof in result["profiles"].values():
        naive = prof["schemes"]["naive"]
        ideal = prof["schemes"]["ideal"]
        assert naive["per_round_mean"] >= ideal["per_round_mean"] - 1e-9


@pytest.mark.parametrize("mutate,frag", [
    (lambda d: d.pop("profiles"), "profiles"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d["profiles"]["uniform"]["schemes"].pop("ideal"), "ideal"),
    (lambda d: d["profiles"]["uniform"]["schemes"]["coded"].update(
        final_wall_clock_mean=float("nan")), "final_wall_clock_mean"),
    (lambda d: d["profiles"]["uniform"].update(
        coded_speedup_vs_naive=-1.0), "coded_speedup_vs_naive"),
])
def test_validator_rejects_malformed(artifact, mutate, frag):
    result, _ = artifact
    broken = json.loads(json.dumps(result))   # deep copy
    mutate(broken)
    problems = launch_bench.validate_artifact(broken)
    assert problems, "validator accepted a malformed artifact"
    assert any(frag in p for p in problems)


def test_validator_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert launch_bench.validate_artifact(str(bad))
    assert launch_bench.validate_artifact([1, 2, 3])
    assert launch_bench.validate_artifact(str(tmp_path / "missing.json"))


def test_cli_validate_roundtrip(artifact, capsys):
    from benchmarks import bench_scheme_compare as cli
    _, path = artifact
    assert cli.main(["--validate", str(path)]) == 0
    assert cli.main(["--validate", str(path) + ".nope"]) == 1
