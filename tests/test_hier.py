"""Hierarchical tier: edge-aggregator shards, sampled cohorts, identity.

The contracts under test (ISSUE PR 9):

  * the identity configuration (hier_shards=1, sample_fraction=1.0)
    routes to the flat `Experiment` and its trajectory is bit-identical
    to the pre-hier runtime;
  * sampling draws from its OWN seeded stream — toggling
    ``sample_fraction`` never shifts the delay, channel-trace, or fault
    realizations;
  * coded compensation: `parity_reweight` is exactly 1.0 at f = 1 and
    grows as f shrinks;
  * kill/resume and block partitions of a hierarchical run replay
    bit-identically (both RNG stream positions live in `RunState`);
  * spec growth: hier fields validate with pointed errors, survive the
    JSON round-trip, and the flat engine / sweep / scheme-bench surfaces
    reject hier-active specs with errors that say where to go instead.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.core import fed_runtime, schemes
from repro.hier import HierExperiment, sampling
from repro.hier.topology import shard_ranges
from repro.launch import bench as launch_bench
from repro.launch import scale as launch_scale
from repro.launch.sweep import run_sweep

N, L, Q, C = 12, 4, 6, 2


def _data(n=N, l=L, q=Q, c=C, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _spec(n=N, shards=3, f=0.6, **over):
    base = dict(
        fl=FLConfig(n_clients=n, delta=0.25, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5),
        scheme="coded", hier_shards=shards, sample_fraction=f)
    base.update(over)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# shard_ranges / sampling primitives
# ---------------------------------------------------------------------------

def test_shard_ranges_balanced():
    assert shard_ranges(10, 3) == [(0, 4), (4, 7), (7, 10)]
    assert shard_ranges(6, 6) == [(j, j + 1) for j in range(6)]
    assert shard_ranges(7, 1) == [(0, 7)]
    sizes = [hi - lo for lo, hi in shard_ranges(101, 8)]
    assert sum(sizes) == 101 and max(sizes) - min(sizes) <= 1


def test_shard_ranges_rejections():
    with pytest.raises(ValueError, match="hier_shards"):
        shard_ranges(10, 0)
    with pytest.raises(ValueError, match="exceeds"):
        shard_ranges(3, 4)
    with pytest.raises(ValueError, match="hier_shards"):
        shard_ranges(10, True)


def test_sampling_stream_is_disjoint_by_offset():
    # delay +17, subset +99, secure-agg +1234, faults +7717, traces +9973
    assert sampling.SAMPLE_SEED_OFFSET not in {17, 99, 1234, 7717, 9973}


def test_cohort_rows_fixed_layout():
    """f toggles re-interpret the SAME uniforms: identical stream
    position afterwards, and smaller-f cohorts nest inside larger-f."""
    r1 = sampling.sampling_rng(3)
    m_half = sampling.sample_cohort_rows(r1, 5, 32, 0.5)
    r2 = sampling.sampling_rng(3)
    m_quarter = sampling.sample_cohort_rows(r2, 5, 32, 0.25)
    r3 = sampling.sampling_rng(3)
    m_full = sampling.sample_cohort_rows(r3, 5, 32, 1.0)
    assert r1.bit_generator.state == r2.bit_generator.state
    assert r1.bit_generator.state == r3.bit_generator.state
    assert np.all(m_quarter <= m_half)          # u<0.25 implies u<0.5
    assert np.all(m_full)
    assert m_half.shape == (5, 32) and m_half.dtype == bool


def test_parity_reweight():
    assert sampling.parity_reweight(100.0, 60.0, 1.0) == 1.0
    w = sampling.parity_reweight(100.0, 60.0, 0.5)
    assert w == pytest.approx((100.0 - 30.0) / (100.0 - 60.0))
    assert w > 1.0
    # R ~= m degrades to a finite reweight, never a zero division
    assert np.isfinite(sampling.parity_reweight(100.0, 100.0, 0.5))
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="sample_fraction"):
            sampling.parity_reweight(100.0, 60.0, bad)


# ---------------------------------------------------------------------------
# spec growth: validation, round-trip, enumerated errors
# ---------------------------------------------------------------------------

def test_hier_spec_json_round_trip():
    spec = _spec(shards=4, f=0.5)
    revived = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert revived == spec
    assert hash(revived) == hash(spec)
    assert revived.hier_shards == 4
    assert revived.sample_fraction == 0.5
    assert revived.hier_active
    assert not _spec(shards=1, f=1.0).hier_active


def test_hier_spec_validation():
    with pytest.raises(ValueError, match="hier_shards"):
        _spec(shards=0)
    with pytest.raises(ValueError, match="hier_shards"):
        _spec(shards=True)
    with pytest.raises(ValueError, match="exceeds"):
        _spec(shards=N + 1)
    for bad in (0.0, 1.5, False):
        with pytest.raises(ValueError, match="sample_fraction"):
            _spec(f=bad)
    with pytest.raises(ValueError, match="batched engine"):
        _spec(engine="legacy")
    with pytest.raises(ValueError, match="channel"):
        _spec(channel_profile="drift_churn")
    with pytest.raises(ValueError, match="fault"):
        _spec(fault_profile="flaky_cohort")
    with pytest.raises(ValueError, match="adapt"):
        _spec(adapt_every=5)
    with pytest.raises(ValueError, match="secure"):
        _spec(secure_aggregation=True)
    with pytest.raises(ValueError, match="mesh"):
        _spec(mesh=2)


def test_validation_errors_enumerate_registered_names():
    """Unknown scheme/channel/fault names list what IS registered."""
    with pytest.raises(ValueError, match="registered:"):
        schemes.get_scheme("nonexistent")
    with pytest.raises(ValueError, match=r"expected one of.*drift_churn"):
        _spec(shards=1, f=1.0, channel_profile="nonexistent")
    with pytest.raises(ValueError, match="expected one of"):
        _spec(shards=1, f=1.0, fault_profile="nonexistent")
    xs, ys = _data()
    with pytest.raises(ValueError, match="registered:"):
        api.build_experiment(_spec(scheme="nonexistent"), xs, ys)


def test_hier_rejects_non_coded_scheme():
    non_coded = [n for n in schemes.registered_names()
                 if schemes.get_scheme(n).step_kind != "coded"]
    assert non_coded, "registry should hold at least one non-coded scheme"
    xs, ys = _data()
    with pytest.raises(ValueError, match="coded-family"):
        HierExperiment(_spec(scheme=non_coded[0]), xs, ys)


# ---------------------------------------------------------------------------
# routing: identity -> flat engine, hier-active -> HierExperiment
# ---------------------------------------------------------------------------

def test_build_experiment_routing():
    xs, ys = _data()
    flat = api.build_experiment(_spec(shards=1, f=1.0), xs, ys)
    assert isinstance(flat, fed_runtime.Experiment)
    hier = api.build_experiment(_spec(), xs, ys)
    assert isinstance(hier, HierExperiment)
    assert len(hier.plans) == 3


def test_identity_is_bit_identical_to_flat_engine():
    """The acceptance criterion, via the scale module's own check."""
    ident = launch_scale._identity_check(l=L, q=Q, c=C, rounds=3, seed=0)
    assert ident["routes_flat_engine"] is True
    assert ident["bit_identical"] is True


def test_flat_engine_rejects_hier_spec():
    xs, ys = _data()
    with pytest.raises(ValueError, match="hierarchical tier"):
        fed_runtime.Experiment(_spec(), xs, ys)


def test_build_experiment_hier_rejects_overrides():
    xs, ys = _data()
    with pytest.raises(ValueError, match="nodes/mesh"):
        api.build_experiment(_spec(), xs, ys, nodes=[])
    with pytest.raises(ValueError, match="hierarchical tier"):
        api.build_experiment(_spec(shards=1, f=1.0), None, None,
                             data_fn=lambda lo, hi: (None, None))


def test_launch_surfaces_reject_hier_specs():
    xs, ys = _data(n=6)
    spec = _spec(n=6, shards=2, f=0.5)
    with pytest.raises(ValueError, match="edge-aggregator"):
        run_sweep(xs, ys, profiles={"p0": {}},
                  train_cfg=TrainConfig(learning_rate=0.5),
                  iterations=1, realizations=1, schemes=("coded",),
                  base_spec=spec)
    with pytest.raises(ValueError, match="scale"):
        launch_bench.run_schemes(base_spec=spec)


# ---------------------------------------------------------------------------
# the hierarchical run itself
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_data():
    return _data()


@pytest.fixture(scope="module")
def hier_exp(dense_data):
    xs, ys = dense_data
    return HierExperiment(_spec(), xs, ys)


def test_hier_run_shapes_and_plans(hier_exp):
    exp = hier_exp
    st = exp.run_block(exp.init_state(4))
    assert st.done
    res = exp.finish(st)
    assert res.theta.shape == (Q, C)
    assert np.all(np.isfinite(np.asarray(res.theta)))
    assert res.t_rounds.shape == (4,)
    assert np.all(res.t_rounds == res.t_round)
    assert res.t_round == max(p.t_star for p in exp.plans)
    assert res.shards == 3
    assert [(p.lo, p.hi) for p in exp.plans] == shard_ranges(N, 3)
    assert all(p.parity_weight > 1.0 for p in exp.plans)  # f=0.6 < 1
    # in-cohort returns can never exceed the sampled population
    assert np.all(res.n_ret <= N)


def test_sample_fraction_toggle_never_shifts_delay_stream(dense_data):
    """The satellite invariant: the delay stream position and draws are
    IDENTICAL whether or not rounds are sampled (and population traces /
    fault streams are keyed by explicit seeds the sampler never touches)."""
    xs, ys = dense_data
    runs = {}
    for f in (1.0, 0.5):
        exp = HierExperiment(_spec(f=f), xs, ys)
        st = exp.run_block(exp.init_state(5))
        runs[f] = st
    assert runs[1.0].rng_state == runs[0.5].rng_state
    np.testing.assert_array_equal(runs[1.0].t_rounds, runs[0.5].t_rounds)
    # the sampled run saw a strictly sparser cohort over 5 rounds
    assert int(runs[0.5].n_ret.sum()) <= int(runs[1.0].n_ret.sum())
    # sampling streams themselves moved in lockstep regardless of f
    assert runs[1.0].sample_rng_state == runs[0.5].sample_rng_state


def test_block_partitions_and_kill_resume_bit_identical(dense_data,
                                                        tmp_path):
    xs, ys = dense_data
    spec = _spec()
    one = HierExperiment(spec, xs, ys)
    st_a = one.run_block(one.init_state(6), 6)

    two = HierExperiment(spec, xs, ys)
    st = two.run_block(two.init_state(6), 2)
    path = two.save_state(str(tmp_path / "ckpt_000002.npz"), st)
    st = two.restore_state(path)          # kill/resume at the boundary
    st = two.run_block(st, 3)
    st = two.run_block(st, 1)

    np.testing.assert_array_equal(np.asarray(st_a.theta),
                                  np.asarray(st.theta))
    np.testing.assert_array_equal(st_a.n_ret, st.n_ret)
    assert st_a.rng_state == st.rng_state
    assert st_a.sample_rng_state == st.sample_rng_state


def test_restore_rejects_foreign_spec(dense_data, tmp_path):
    xs, ys = dense_data
    exp = HierExperiment(_spec(), xs, ys)
    path = exp.save_state(str(tmp_path / "ckpt_000001.npz"),
                          exp.run_block(exp.init_state(2), 1))
    other = HierExperiment(_spec(f=0.5), xs, ys)
    with pytest.raises(ValueError, match="provenance"):
        other.restore_state(path)


def test_data_fn_streaming_matches_dense(dense_data):
    xs, ys = dense_data
    spec = _spec()
    dense = HierExperiment(spec, xs, ys)
    streamed = HierExperiment(spec, data_fn=lambda lo, hi: (xs[lo:hi],
                                                            ys[lo:hi]))
    a = dense.run_block(dense.init_state(3))
    b = streamed.run_block(streamed.init_state(3))
    np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))
    np.testing.assert_array_equal(a.n_ret, b.n_ret)


def test_data_fn_probe_validation():
    with pytest.raises(ValueError, match=r"data_fn\(0, 1\)"):
        HierExperiment(_spec(), data_fn=lambda lo, hi: (
            np.zeros((hi - lo, L)), np.zeros((hi - lo, L))))
    xs, ys = _data()
    with pytest.raises(ValueError, match="not both"):
        HierExperiment(_spec(), xs, ys,
                       data_fn=lambda lo, hi: (xs[lo:hi], ys[lo:hi]))
    with pytest.raises(ValueError, match="needs x_stack"):
        HierExperiment(_spec())


def test_memory_helpers(hier_exp):
    exp = hier_exp
    n_s = max(hi - lo for lo, hi in shard_ranges(N, 3))
    assert exp.peak_client_tensor_bytes() == \
        4 * n_s * (L * (Q + C) + Q * C)
    assert exp.population_tensor_bytes() == 8 * N * 7
    # the O(active cohort) contract at this scale: sharded peak < dense
    assert exp.peak_client_tensor_bytes() < 4 * N * (L * (Q + C) + Q * C)


def test_finish_guards(hier_exp):
    exp = hier_exp
    st = exp.run_block(exp.init_state(3), 1)
    with pytest.raises(ValueError, match="not complete"):
        exp.finish(st)
    done = exp.run_block(st, 2)
    with pytest.raises(ValueError, match="already complete"):
        exp.run_block(done)
    with pytest.raises(ValueError, match="hier"):
        exp.run_block(dataclasses.replace(st, mode="single"))
