"""Unit tests for launch-layer helpers (HLO collective parser, input specs,
decode-window policy, pad_vocab correctness)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import SHAPES
from repro.configs import ARCH_IDS, decode_window, get_config, input_specs, \
    smoke_variant
from repro.models.model_zoo import build


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %all-gather.5 = bf16[2048,512]{1,0} all-gather(%x), dimensions={0}
  %all-reduce.1 = (f32[16,16]{1,0}, f32[4]{0}) all-reduce(%a, %b)
  %add.1 = f32[8]{0} add(%p, %q)
  ROOT %ag = u32[10]{0} all-to-all(%y)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 2048 * 512 * 2
    assert got["all-reduce"] == 16 * 16 * 4 + 4 * 4
    assert got["all-to-all"] == 10 * 4
    assert got["reduce-scatter"] == 0


def test_input_specs_all_archs_all_shapes():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            spec = input_specs(cfg, shape)
            assert "tokens" in spec
            if shape.kind == "decode":
                assert spec["tokens"].shape == (shape.global_batch, 1)
            else:
                total = spec["tokens"].shape[1] + (cfg.n_prefix_patches or 0)
                assert total == shape.seq_len
                assert spec["tokens"].shape[0] == shape.global_batch
            if shape.kind == "train":
                assert spec["labels"].shape == spec["tokens"].shape
            if cfg.is_encdec and shape.kind != "decode":
                assert spec["frames"].shape == (shape.global_batch,
                                                cfg.encoder_seq, cfg.d_model)


def test_decode_window_policy():
    # ssm/hybrid: native sub-quadratic, no forced window
    assert decode_window(get_config("rwkv6-1.6b"), "long_500k") == 0
    assert decode_window(get_config("jamba-1.5-large-398b"), "long_500k") == 0
    # mixtral: native SWA everywhere
    assert decode_window(get_config("mixtral-8x7b"), "decode_32k") == 4096
    # dense archs: full attention at 32k, SWA variant at 500k
    assert decode_window(get_config("yi-6b"), "decode_32k") == 0
    assert decode_window(get_config("yi-6b"), "long_500k") == 4096
    assert decode_window(get_config("whisper-base"), "long_500k") == 4096


def test_pad_vocab_loss_equivalence():
    """Padded-vocab model must produce the same loss as unpadded (masked)."""
    base = smoke_variant(get_config("qwen3-4b"))
    base = dataclasses.replace(base, vocab=509)       # not divisible by 16
    padded = dataclasses.replace(base, pad_vocab=True)
    assert padded.vocab_padded == 512
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 509, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    m1, m2 = build(base), build(padded)
    p1 = m1.init_params(jax.random.PRNGKey(0))
    p2 = m2.init_params(jax.random.PRNGKey(0))
    # copy the unpadded params into the padded tree
    p2 = jax.tree_util.tree_map(lambda a: a, p2)
    emb = np.zeros(p2["embed"].shape, np.float32)
    emb[:509] = np.asarray(p1["embed"], np.float32)
    p2["embed"] = jnp.asarray(emb, p2["embed"].dtype)
    head = np.zeros(p2["lm_head"].shape, np.float32)
    head[:, :509] = np.asarray(p1["lm_head"], np.float32)
    p2["lm_head"] = jnp.asarray(head, p2["lm_head"].dtype)
    for k in p1:
        if k not in ("embed", "lm_head"):
            p2[k] = p1[k]
    l1 = float(m1.loss_fn(p1, batch))
    l2 = float(m2.loss_fn(p2, batch))
    assert abs(l1 - l2) < 1e-3, (l1, l2)


def test_mesh_shapes():
    import pytest
    if jax.device_count() < 512:
        pytest.skip("production mesh needs 512 placeholder devices "
                    "(dryrun.py sets XLA_FLAGS before jax init)")
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh()
    assert m1.devices.size == 256 and m1.axis_names == ("data", "model")
    m2 = make_production_mesh(multi_pod=True)
    assert m2.devices.size == 512 and m2.axis_names == ("pod", "data", "model")
