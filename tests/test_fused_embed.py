"""Fused embed->gradient round path (`ExperimentSpec.fused_embed`).

The contract: a fused run consumes RAW (n, l, d) client features and the
per-round gradient kernel embeds them on the fly — and its theta
trajectory is BIT-IDENTICAL (f32) to the two-pass control that pre-embeds
the same features with the same shared-seed (Omega, delta) and runs the
ordinary path, on both kernel backends.  Parity encoding, load
allocation, t_star, privacy accounting and the RNG streams all see the
same embedded values, so nothing but the kernel launch structure differs.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro import api
from repro.config import ExperimentSpec, FLConfig, RFFConfig, TrainConfig
from repro.core import rff as rff_mod
from repro.kernels import ops

N, L, D, Q, C = 6, 16, 8, 24, 3


def _raw_data(n=N, l=L, d=D, c=C, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, d)).astype(np.float32) * 0.5
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def _spec(scheme="coded", **over):
    base = dict(
        fl=FLConfig(n_clients=N, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme=scheme,
        rff=RFFConfig(q=Q, sigma=1.5, seed=7),
        fused_embed=True)
    base.update(over)
    return ExperimentSpec(**base)


def _control(spec, xs_raw, ys):
    """The two-pass control: pre-embed with the SAME shared-seed RFF
    params and backend, then run with fused_embed off."""
    fused = api.build_experiment(spec, xs_raw, ys)
    phi = np.asarray(fused.embedded_x())
    control = api.build_experiment(
        dataclasses.replace(spec, fused_embed=False), phi, ys)
    return fused, control


@pytest.mark.parametrize("kernel_backend", ["xla", "pallas"])
@pytest.mark.parametrize("scheme,fused_coded", [
    ("coded", True), ("coded", False), ("naive", True),
    ("partial_coded", True)])
def test_fused_embed_trajectory_equivalent(scheme, fused_coded,
                                           kernel_backend):
    xs, ys = _raw_data()
    spec = _spec(scheme, kernel_backend=kernel_backend,
                 fused_coded=fused_coded)
    fused, control = _control(spec, xs, ys)
    trace = lambda th: (float(np.abs(np.asarray(th)).sum()), 0.0)
    rf = fused.run(6, eval_fn=trace, eval_every=1)
    rc = control.run(6, eval_fn=trace, eval_every=1)
    np.testing.assert_array_equal(np.asarray(rf.theta),
                                  np.asarray(rc.theta))
    for hf, hc in zip(rf.history, rc.history):
        assert hf.returned == hc.returned
        assert hf.wall_clock == hc.wall_clock
        assert hf.loss == hc.loss


def test_fused_embed_run_multi_and_privacy_match_control():
    xs, ys = _raw_data()
    spec = _spec("coded")
    fused, control = _control(spec, xs, ys)
    mf = fused.run_multi(5, 3)
    mc = control.run_multi(5, 3)
    np.testing.assert_array_equal(np.asarray(mf.theta),
                                  np.asarray(mc.theta))
    np.testing.assert_array_equal(mf.wall_clock, mc.wall_clock)
    # deployment metadata is a function of the same embedded values
    assert fused.t_star == control.t_star
    assert fused.u == control.u
    assert fused.privacy_eps == pytest.approx(control.privacy_eps)
    np.testing.assert_array_equal(fused.loads, control.loads)


def test_embedded_x_matches_kernel_embed():
    xs, ys = _raw_data()
    exp = api.build_experiment(_spec("coded"), xs, ys)
    omega, delta = rff_mod.rff_params(exp.spec.rff, D)
    want = ops.rff_embed_batched(xs, omega, delta)
    got = exp.embedded_x()
    assert got.shape == (N, L, Q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # transient: the round path never keeps the embedded tensor; the
    # experiment's x stays the raw features
    assert exp.x.shape == (N, L, D)
    # and the accessor is fused-embed-only
    plain = api.build_experiment(
        dataclasses.replace(_spec("coded"), fused_embed=False),
        np.asarray(want), ys)
    with pytest.raises(ValueError, match="fused_embed"):
        plain.embedded_x()


def test_fused_embed_spec_round_trip():
    spec = _spec("partial_coded", scheme_params={"u_fraction": 0.4},
                 kernel_backend="pallas")
    revived = ExperimentSpec.from_dict(
        json.loads(json.dumps(spec.to_dict())))
    assert revived == spec and revived.fused_embed
    assert revived.rff == spec.rff


def test_fused_embed_spec_validation():
    with pytest.raises(ValueError, match="rff"):
        _spec(rff=None)
    with pytest.raises(ValueError, match="legacy"):
        _spec(engine="legacy")
    with pytest.raises(ValueError, match="mesh"):
        _spec(mesh=2)


def test_fused_embed_runtime_rejections():
    xs, ys = _raw_data()
    with pytest.raises(NotImplementedError, match="adaptive"):
        api.build_experiment(
            _spec("adaptive_coded", channel_profile="compute_drift",
                  adapt_every=2), xs, ys)
    from repro.launch.sweep import run_sweep
    with pytest.raises(ValueError, match="fused_embed"):
        run_sweep(xs, ys, profiles={"uniform": {}},
                  train_cfg=TrainConfig(), iterations=2, realizations=1,
                  base_spec=_spec("coded"))
