"""FL runtime mechanics (scheme semantics, determinism, logging)."""
import numpy as np

from repro import api
from repro.config import ExperimentSpec, FLConfig, TrainConfig


def _sim(scheme, n=6, l=20, q=32, c=3, **fl_kw):
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    fl = FLConfig(n_clients=n, **fl_kw)
    tc = TrainConfig(learning_rate=0.5, l2_reg=0.0)
    return api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme=scheme), xs, ys)


def test_naive_waits_for_all():
    sim = _sim("naive")
    res = sim.run(5)
    assert all(h.returned == 6 for h in res.history)


def test_greedy_waits_for_fraction():
    sim = _sim("greedy", psi=0.5)
    res = sim.run(5)
    assert all(h.returned == 3 for h in res.history)


def test_coded_setup_builds_parity():
    sim = _sim("coded", delta=0.2)
    assert sim.parity is not None
    assert sim.parity.x.shape[0] == sim.u
    assert sim.u == int(round(0.2 * 6 * 20))
    assert sim.setup_time > 0
    assert sim.t_star > 0


def test_coded_loads_leq_capacity():
    sim = _sim("coded", delta=0.3)
    assert np.all(sim.loads <= 20)
    assert np.all(sim.loads >= 0)


def test_wallclock_accumulates():
    sim = _sim("naive")
    res = sim.run(4)
    walls = [h.wall_clock for h in res.history]
    assert all(b > a for a, b in zip(walls, walls[1:]))


def test_theta_updates():
    sim = _sim("coded", delta=0.2)
    res = sim.run(3)
    assert float(np.abs(np.asarray(res.theta)).sum()) > 0


def test_secure_aggregation_identical_parity():
    """The spec's secure_aggregation flag routes parity uploads through
    mask_parity/secure_aggregate, and the masked aggregate equals the
    plain parity sum (pairwise masks cancel exactly in the sum)."""
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(6, 20, 32)).astype(np.float32) * 0.2
    ys = rng.normal(size=(6, 20, 3)).astype(np.float32)
    fl = FLConfig(n_clients=6, delta=0.2)
    tc = TrainConfig(learning_rate=0.5, l2_reg=0.0)
    plain = api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme="coded"), xs, ys)
    secure = api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme="coded",
                       secure_aggregation=True), xs, ys)
    assert secure.secure_aggregation and not plain.secure_aggregation
    np.testing.assert_allclose(np.asarray(plain.parity.x),
                               np.asarray(secure.parity.x), atol=1e-3)
    np.testing.assert_allclose(np.asarray(plain.parity.y),
                               np.asarray(secure.parity.y), atol=1e-3)
    # identical parity + identical delay stream => identical trajectories
    res_p = plain.run(5)
    res_s = secure.run(5)
    np.testing.assert_allclose(np.asarray(res_p.theta),
                               np.asarray(res_s.theta), atol=1e-4)


def test_loss_decreases_naive():
    rng = np.random.default_rng(1)
    n, l, q, c = 4, 30, 16, 2
    theta_true = rng.normal(size=(q, c)).astype(np.float32)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.3
    ys = np.einsum("nlq,qc->nlc", xs, theta_true)
    fl = FLConfig(n_clients=n)
    tc = TrainConfig(learning_rate=2.0, l2_reg=0.0)
    sim = api.build_experiment(
        ExperimentSpec(fl=fl, train=tc, scheme="naive"), xs, ys)

    def eval_fn(theta):
        pred = np.einsum("nlq,qc->nlc", xs, np.asarray(theta))
        return float(np.mean((pred - ys) ** 2)), 0.0

    res = sim.run(50, eval_fn=eval_fn, eval_every=1)
    losses = [h.loss for h in res.history]
    assert losses[-1] < 0.1 * losses[0]
