"""Per-architecture smoke tests: reduced same-family config, one forward /
train step / prefill+decode on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.models.model_zoo import build

RNG = np.random.default_rng(0)


def _batch(cfg, B=2, S=64, labels=True):
    out = {}
    ntok = S
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            RNG.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    elif cfg.n_prefix_patches:
        out["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(B, cfg.n_prefix_patches, cfg.d_model)),
            jnp.float32)
        ntok = S - cfg.n_prefix_patches
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, ntok)), jnp.int32)
    out["tokens"] = toks
    if labels:
        out["labels"] = toks
    return out


@pytest.fixture(scope="module")
def models():
    return {}


def _get(models, arch):
    if arch not in models:
        cfg = smoke_variant(get_config(arch))
        m = build(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        models[arch] = (cfg, m, params)
    return models[arch]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss(models, arch):
    cfg, model, params = _get(models, arch)
    batch = _batch(cfg)
    loss = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    # loss should be near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nan(models, arch):
    cfg, model, params = _get(models, arch)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch))(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(not bool(jnp.any(jnp.isnan(g))) for g in flat)
    norm = sum(float(jnp.sum(jnp.square(g))) for g in flat)
    assert norm > 0.0
    # one SGD step changes the loss
    new = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = model.loss_fn(new, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(models, arch):
    cfg, model, params = _get(models, arch)
    B, S = 2, 64
    batch = _batch(cfg, B, S, labels=False)
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits2)))


def test_decode_matches_prefill_continuation():
    """Decoding token-by-token must agree with a longer prefill (dense)."""
    cfg = smoke_variant(get_config("yi-6b"))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (1, 17)), jnp.int32)
    # full prefill over 17 tokens
    full_logits, _ = model.prefill(params, {"tokens": toks})
    # prefill 16, decode the 17th
    l16, cache = model.prefill(params, {"tokens": toks[:, :16]})
    cache = jax.tree_util.tree_map(
        lambda a: (jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, 1)],
                           constant_values=-1)
                   if a.dtype == jnp.int32 and a.ndim == 2 else
                   (jnp.pad(a, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])
                    if a.ndim == 5 else a)), cache)
    dec_logits, _ = model.decode_step(params, cache, toks[:, 16:17],
                                      jnp.int32(16))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), atol=2e-3, rtol=1e-2)


def test_rwkv_chunked_equals_naive_end_to_end():
    cfg = smoke_variant(get_config("rwkv6-1.6b"))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    batch = _batch(cfg, 2, 128)
    l1 = model.loss_fn(params, batch, chunked=True)
    l2 = model.loss_fn(params, batch, chunked=False)
    assert abs(float(l1) - float(l2)) < 1e-3


def test_swa_variant_lowers_window():
    """Dense arch with a window behaves causally and differs from full."""
    cfg = smoke_variant(get_config("qwen3-4b"))
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    batch = _batch(cfg, 1, 128)
    full = model.loss_fn(params, batch, window=0)
    win = model.loss_fn(params, batch, window=16)
    assert float(full) != float(win)
