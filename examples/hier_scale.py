"""Hierarchical population scale: 20,000 clients on a laptop-class CPU.

The flat engine materializes a dense ``(n, l, q)`` client tensor and
solves the two-step allocation over all n nodes at once — fine at the
paper's n <= 1000, hopeless at a population.  The hierarchical tier
(`repro.hier`) partitions the population into edge-aggregator shards,
runs the static coded round per shard (chunked O(block)-memory solver),
samples a Bernoulli(f) cohort per round from a dedicated RNG stream, and
reweights each shard's parity gradient so the update stays an unbiased
SGD step at every f.  Client tensors exist one shard at a time, streamed
through ``data_fn(lo, hi)`` — nothing O(n * l * q) is ever resident.

This example builds a 10k-client deployment (10 shards of 1k, 25%%
cohorts), runs a few rounds, shows the O(active cohort) memory contract
and the kill/resume round-trip, then prints a tiny scaling curve.  The
real curve (n = 1e3..1e5, recorded in the schema-v8 ``scale`` section of
`BENCH_fed_training.json`) is produced by
``python -m benchmarks.bench_hier_scale``.  Hier-active specs also
build through the usual ``repro.api.build_experiment(spec,
data_fn=...)`` — this example constructs `HierExperiment` directly only
to pass ``solver_kwargs`` (shallower deterministic solver iterations,
the knob the scale bench uses on its largest rungs).

    PYTHONPATH=src python examples/hier_scale.py
"""
import time

from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.hier import HierExperiment
from repro.launch import scale as launch_scale

N, SHARDS, L, Q, C = 10_000, 10, 8, 16, 3


def main():
    # heterogeneity knobs re-exponentiated so the population spans the
    # same rate/compute range as the paper's 12-client cell at any n
    fl = FLConfig(n_clients=N, delta=0.2, seed=0,
                  rate_decay=0.95 ** (12.0 / N),
                  mac_decay=0.8 ** (12.0 / N))
    spec = ExperimentSpec(
        fl=fl, train=TrainConfig(learning_rate=0.5, l2_reg=1e-5),
        scheme="coded", hier_shards=SHARDS, sample_fraction=0.25)

    # streamed client blocks: deterministic synthetic data generated per
    # (lo, hi) range on demand — the dense (N, L, Q) tensor never exists
    def data_fn(lo, hi):
        return launch_scale.synthetic_block(lo, hi, L, Q, C)

    t0 = time.perf_counter()
    exp = HierExperiment(spec, data_fn=data_fn,
                         solver_kwargs=dict(n_golden_search=12, n_bisect=20))
    print(f"setup: {SHARDS} edge aggregators over n={N} clients in "
          f"{time.perf_counter() - t0:.1f}s host time "
          f"(simulated parity-upload overhead {exp.setup_time:.2f}s)")
    peak, dense = exp.peak_client_tensor_bytes(), 4 * N * L * (Q + C)
    print(f"peak client-tensor memory: {peak / 1e6:.2f} MB "
          f"(dense flat engine would hold {dense / 1e6:.2f} MB; "
          f"{dense / peak:.0f}x less — O(active cohort))")

    t0 = time.perf_counter()
    state = exp.run_block(exp.init_state(4), 2)     # two rounds...
    mid = exp.save_state("/tmp/hier_example_ckpt_000002.npz", state)
    state = exp.run_block(exp.restore_state(mid), 2)   # ...kill/resume
    res = exp.finish(state)
    print(f"4 rounds in {time.perf_counter() - t0:.1f}s host time; "
          f"server deadline t_round={res.t_round:.4f}s, "
          f"mean in-cohort returns/round "
          f"{res.n_ret.mean():.0f}/{N} (f=0.25)")
    w = max(p.parity_weight for p in res.plans)
    print(f"coded compensation: max shard parity reweight w(f)={w:.3f} "
          f"(unbiased update; w=1 exactly at f=1)\n")

    print("tiny scaling curve (the bench records n=1e3..1e5):")
    section = launch_scale.run_scale(
        ns=(1_000, 4_000), l=4, q=8, c=2, rounds=2, trace_rounds=1,
        solver_kwargs=dict(n_golden_search=12, n_bisect=20))
    for e in section["entries"]:
        print(f"  n={e['n']:>6d}: setup {e['setup_seconds']:6.1f}s  "
              f"rounds {e['round_seconds']:5.2f}s  "
              f"peak {e['peak_client_tensor_bytes'] / 1e6:6.2f} MB  "
              f"(dense {e['dense_client_tensor_bytes'] / 1e6:6.2f} MB)")
    ident = section["identity"]
    print(f"identity config (shards=1, f=1.0) routes to the flat engine "
          f"bit-identically: {ident['bit_identical']}")


if __name__ == "__main__":
    main()
