"""Run telemetry end to end: spans, journal, attribution, text report.

One CodedFedL run with the `repro.obs` subsystem switched on:

  * ``obs_spans.collecting()`` — span timers over setup, the two-step
    allocation solve, parity encode, trace generation, scan
    compile-vs-execute, checkpoint save, and journal appends.  Zero
    overhead when disabled; bit-identical trajectories either way (the
    collector never touches an RNG stream).
  * ``journal_dir=...`` — an append-only ``events.jsonl``, one event per
    round (wall clock, returned count, guard counters, lr scale, loss),
    deterministic to the byte given (spec, seed), and replayable into
    the exact ``FedResult.history`` via `history_from_journal`.
  * ``Experiment.attribution()`` — post-hoc straggler attribution from
    the realized delay tensors: per-client deadline-miss rates, the
    per-round slowest-k counts, and the coded-compensation share.

Everything lands in one run directory, and the same text report the CI
telemetry job prints is rendered from those files alone:

    PYTHONPATH=src python examples/run_report.py
    PYTHONPATH=src python -m benchmarks.obs_report --report /tmp/obs_demo
"""
import json
import os

import numpy as np

from repro.api import (ExperimentSpec, build_experiment, histories_equal,
                       history_from_journal, obs_spans)
from repro.config import FLConfig, TrainConfig
from repro.launch.report import ATTR_NAME, render_report

RUN_DIR = "/tmp/obs_demo"
ITERS = 24


def main():
    rng = np.random.default_rng(0)
    n, l, q, c = 10, 24, 32, 3
    theta_true = rng.normal(size=(q, c)).astype(np.float32)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.3
    ys = (np.einsum("nlq,qc->nlc", xs, theta_true)
          + 0.005 * rng.normal(size=(n, l, c)).astype(np.float32))
    spec = ExperimentSpec(
        fl=FLConfig(n_clients=n, delta=0.25, psi=0.2, seed=0),
        train=TrainConfig(learning_rate=1.0, l2_reg=0.0),
        scheme="coded", checkpoint_every=6)

    def eval_fn(theta):
        pred = np.einsum("nlq,qc->nlc", xs, np.asarray(theta))
        return float(np.mean((pred - ys) ** 2)), 0.0

    # reference run with telemetry OFF — the invariant under test below
    ref = build_experiment(spec, xs, ys).run(ITERS, eval_fn=eval_fn,
                                             eval_every=1)

    with obs_spans.collecting():
        exp = build_experiment(spec, xs, ys)
        res = exp.run(ITERS, eval_fn=eval_fn, eval_every=1,
                      journal_dir=RUN_DIR)
        attr = exp.attribution()
        obs_spans.write_json(os.path.join(RUN_DIR, obs_spans.SPANS_NAME))
    with open(os.path.join(RUN_DIR, ATTR_NAME), "w") as fh:
        json.dump(attr.to_dict(), fh, indent=2, sort_keys=True)
        fh.write("\n")

    assert np.array_equal(np.asarray(ref.theta), np.asarray(res.theta)), \
        "telemetry must never perturb a trajectory"
    assert histories_equal(history_from_journal(RUN_DIR), res.history), \
        "journal replay must reconstruct the exact history"

    print(render_report(RUN_DIR))
    print(f"run dir: {RUN_DIR} (events.jsonl, spans.json, "
          f"{ATTR_NAME})")
    print("telemetry-on trajectory == telemetry-off trajectory: OK")
    print("journal replay == FedResult.history: OK")


if __name__ == "__main__":
    main()
