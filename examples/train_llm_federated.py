"""End-to-end driver: federated training of a transformer LM with the
paper's deadline-based aggregation (DESIGN.md §4 generalization).

Each simulated MEC client owns a data shard; per round, client round-trip
delays are sampled from the paper's §II-B models, the load allocator picks
the deadline t*, stragglers are dropped, and surviving gradients are
reweighted by 1/P(T_j <= t*) so the aggregate stays unbiased.

Default is a ~20M-param qwen3-family model for a quick CPU run; use
--params 100m --steps 300 for the full deliverable-scale run.

    PYTHONPATH=src python examples/train_llm_federated.py
    PYTHONPATH=src python examples/train_llm_federated.py --params 100m --steps 300
"""
import argparse
import dataclasses
import time

from repro.config import FLConfig
from repro.configs import get_config, smoke_variant
from repro.launch.train import train


def model_cfg(size: str):
    base = smoke_variant(get_config("qwen3-4b"))
    if size == "20m":
        return dataclasses.replace(base, n_layers=4, d_model=256, d_ff=1024,
                                   vocab=8192, n_heads=4, n_kv_heads=2)
    if size == "100m":
        return dataclasses.replace(base, n_layers=8, d_model=512, d_ff=2048,
                                   vocab=32768, n_heads=8, n_kv_heads=4)
    raise ValueError(size)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="20m", choices=["20m", "100m"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    cfg = model_cfg(args.params)
    n_param_est = (cfg.n_layers * (4 * cfg.d_model * cfg.d_ff
                                   + 4 * cfg.d_model * cfg.d_model)
                   + 2 * cfg.vocab * cfg.d_model)
    print(f"arch=qwen3-family ~{n_param_est / 1e6:.0f}M params, "
          f"{args.clients} federated clients, deadline aggregation")
    t0 = time.time()
    _, losses, sim_wall = train(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        federated=True, fl_cfg=FLConfig(n_clients=args.clients),
        log_every=max(1, args.steps // 10))
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps "
          f"({time.time() - t0:.0f}s real, {sim_wall:.0f}s simulated "
          f"MEC wall-clock)")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
