"""Batched serving example: prefill + decode loop across architectures.

Exercises the same serve_step the decode dry-run shapes lower — full KV
cache for dense archs, rolling window for SWA, latent cache for MLA,
recurrent state for RWKV6 — at reduced config on CPU.

    PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-1.6b]
"""
import argparse

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ["qwen3-4b", "mixtral-8x7b",
                                           "rwkv6-1.6b",
                                           "deepseek-v2-lite-16b"]
    for arch in archs:
        print(f"--- {arch}")
        cfg = smoke_variant(get_config(arch))
        serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
              gen_len=args.gen_len)


if __name__ == "__main__":
    main()
