"""Adaptive allocation under network drift, end-to-end in ~30s on CPU.

The paper's load allocation is solved ONCE from round-0 delay statistics.
This example runs the same CodedFedL deployment over a *drifting* wireless
channel (`repro.net`: the network steadily degrades — compute throttles,
links fall down the LTE CQI ladder) twice:

  * ``scheme="coded"``           — the static round-0 allocation;
  * ``scheme="adaptive_coded"``  — online (mu, tau, p) estimation from
    round telemetry + re-solving the allocation every ``adapt_every``
    rounds, applied as pure mask re-weighting (one compiled scan, zero
    recompiles).

Both face the SAME realized channel trace (equal seeds), so the printed
gap is pure allocation policy.  Time-to-target-loss is the metric the
committed ``BENCH_fed_training.json`` tracks in its ``scenarios`` section.

    PYTHONPATH=src python examples/adaptive_drift.py
"""
import numpy as np

from repro.api import CHANNEL_PROFILES, ExperimentSpec, build_experiment
from repro.config import FLConfig, TrainConfig

PROFILE = "degrade_drift"
ITERS = 60
ADAPT_EVERY = 5


def main():
    rng = np.random.default_rng(0)
    n, l, q, c = 10, 24, 32, 3
    theta_true = rng.normal(size=(q, c)).astype(np.float32)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.3
    ys = (np.einsum("nlq,qc->nlc", xs, theta_true)
          + 0.005 * rng.normal(size=(n, l, c)).astype(np.float32))
    fl = FLConfig(n_clients=n, delta=0.25, psi=0.2, seed=0)
    tc = TrainConfig(learning_rate=1.0, l2_reg=0.0)

    def eval_fn(theta):
        pred = np.einsum("nlq,qc->nlc", xs, np.asarray(theta))
        return float(np.mean((pred - ys) ** 2)), 0.0

    print(f"channel profile {PROFILE!r}: {CHANNEL_PROFILES[PROFILE]}\n")
    base = dict(fl=fl, train=tc, channel_profile=PROFILE)
    static = build_experiment(
        ExperimentSpec(**base, scheme="coded"), xs, ys)
    res_s = static.run(ITERS, eval_fn=eval_fn, eval_every=1)

    adaptive = build_experiment(
        ExperimentSpec(**base, scheme="adaptive_coded",
                       adapt_every=ADAPT_EVERY), xs, ys)
    res_a = adaptive.run(ITERS, eval_fn=eval_fn, eval_every=1)
    sched = adaptive.last_schedule

    target = max(res_s.history[-1].loss, res_a.history[-1].loss)

    def tt(res):
        return next(h.wall_clock for h in res.history if h.loss <= target)

    print(f"{'':12s} {'final loss':>11s} {'wall-clock':>11s} "
          f"{'t(loss<={:.3g})':>16s}".format(target))
    print(f"{'static':12s} {res_s.history[-1].loss:11.4f} "
          f"{res_s.history[-1].wall_clock:10.2f}s {tt(res_s):15.2f}s")
    print(f"{'adaptive':12s} {res_a.history[-1].loss:11.4f} "
          f"{res_a.history[-1].wall_clock:10.2f}s {tt(res_a):15.2f}s")
    print(f"\nadaptive reaches the target "
          f"{tt(res_s) / tt(res_a):.2f}x sooner")
    print(f"deadline trajectory: t* {static.t_star:.3f}s (static, fixed) "
          f"vs {sched.t_star[0]:.3f}s -> {sched.t_star[-1]:.3f}s over "
          f"{sched.n_blocks} re-allocations (adaptive)")
    print(f"allocated load: {sched.loads_blocks[0].sum():.0f} -> "
          f"{sched.loads_blocks[-1].sum():.0f} points/round as the "
          f"network degrades")


if __name__ == "__main__":
    main()
