"""Client-mesh sharding + compiled profile sweep in one script.

1. Runs one CodedFedL deployment with its client axis sharded over every
   available device (`FederatedSimulation(..., mesh=...)`): per-shard
   gradients are computed locally and psum-aggregated, mirroring the MEC
   server reduction of paper §III.
2. Sweeps all three schemes over the heterogeneity profile grid in ONE
   compiled call per scheme (`repro.launch.sweep.run_sweep`).

Fake a multi-device host before running (must be set before jax starts):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/mesh_sweep.py
"""
import numpy as np
import jax

from repro.config import FLConfig, TrainConfig
from repro.core.fed_runtime import FederatedSimulation
from repro.launch.bench import HETEROGENEITY_PROFILES
from repro.launch.sweep import run_sweep

N, L, Q, C = 12, 32, 64, 5
ITERS, REALIZATIONS = 30, 4

rng = np.random.default_rng(0)
xs = rng.normal(size=(N, L, Q)).astype(np.float32) * 0.2
ys = rng.normal(size=(N, L, C)).astype(np.float32)
fl = FLConfig(n_clients=N, delta=0.2, psi=0.2, seed=0)
tc = TrainConfig(learning_rate=0.5, l2_reg=1e-5, lr_decay_epochs=(15,))

# --- 1. sharded single deployment -----------------------------------------
ndev = jax.device_count()
print(f"[mesh] sharding {N} clients over {ndev} device(s)")
sim = FederatedSimulation(xs, ys, fl, tc, scheme="coded", mesh=ndev)
res = sim.run(ITERS)
print(f"[mesh] coded: t*={res.t_star:.3f}s  "
      f"finished {ITERS} rounds at {res.history[-1].wall_clock:.1f} "
      f"simulated seconds")

# --- 2. compiled (profile x realization) sweep ----------------------------
print(f"[sweep] {len(HETEROGENEITY_PROFILES)} profiles x "
      f"{REALIZATIONS} realizations, one compiled call per scheme")
sw = run_sweep(xs, ys, profiles=HETEROGENEITY_PROFILES, train_cfg=tc,
               iterations=ITERS, realizations=REALIZATIONS,
               fl_kwargs=dict(n_clients=N, delta=0.2, psi=0.2, seed=0))
for scheme, per_profile in sw.results.items():
    print(f"[sweep] {scheme}: compiled grid call took "
          f"{sw.host_seconds[scheme]:.2f}s host-side")
    for pname, multi in per_profile.items():
        mean, std = multi.wall_clock_bands()
        print(f"    {pname:>10}: {mean[-1]:8.1f} ± {std[-1]:5.1f} "
              f"simulated s")
