"""Client-mesh sharding + compiled profile sweep via the experiment API.

1. Builds one frozen `ExperimentSpec` (scheme, delay profile, mesh, all
   declarative) and runs CodedFedL with its client axis sharded over every
   available device: per-shard gradients are computed locally and
   psum-aggregated, mirroring the MEC server reduction of paper §III.
2. Sweeps EVERY registered scheme over the heterogeneity profile grid in
   ONE compiled call per scheme (`Experiment.sweep` — the
   `repro.launch.sweep.run_sweep` engine replaying the same spec).

Fake a multi-device host before running (must be set before jax starts):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/mesh_sweep.py
"""
import numpy as np
import jax

from repro.api import ExperimentSpec, build_experiment, grid_names
from repro.config import FLConfig, TrainConfig
from repro.core.delay_model import HETEROGENEITY_PROFILES

N, L, Q, C = 12, 32, 64, 5
ITERS, REALIZATIONS = 30, 4

rng = np.random.default_rng(0)
xs = rng.normal(size=(N, L, Q)).astype(np.float32) * 0.2
ys = rng.normal(size=(N, L, C)).astype(np.float32)

# --- 1. sharded single deployment: everything in one frozen spec ----------
ndev = jax.device_count()
spec = ExperimentSpec(
    fl=FLConfig(n_clients=N, delta=0.2, psi=0.2, seed=0),
    train=TrainConfig(learning_rate=0.5, l2_reg=1e-5, lr_decay_epochs=(15,)),
    scheme="coded",
    mesh=ndev,
)
print(f"[mesh] sharding {N} clients over {ndev} device(s); "
      f"spec round-trips JSON: "
      f"{ExperimentSpec.from_dict(spec.to_dict()) == spec}")
exp = build_experiment(spec, xs, ys)
res = exp.run(ITERS)
print(f"[mesh] coded: t*={res.t_star:.3f}s  "
      f"finished {ITERS} rounds at {res.history[-1].wall_clock:.1f} "
      f"simulated seconds")

# --- 2. compiled (profile x realization) sweep over the registry ----------
print(f"[sweep] {len(HETEROGENEITY_PROFILES)} profiles x "
      f"{REALIZATIONS} realizations x schemes {grid_names()}, "
      f"one compiled call per scheme")
unsharded = build_experiment(ExperimentSpec(
    fl=spec.fl, train=spec.train, scheme="coded"), xs, ys)
sw = unsharded.sweep(profiles=HETEROGENEITY_PROFILES, iterations=ITERS,
                     realizations=REALIZATIONS, schemes=grid_names())
for scheme, per_profile in sw.results.items():
    print(f"[sweep] {scheme}: compiled grid call took "
          f"{sw.host_seconds[scheme]:.2f}s host-side")
    for pname, multi in per_profile.items():
        mean, std = multi.wall_clock_bands()
        print(f"    {pname:>10}: {mean[-1]:8.1f} ± {std[-1]:5.1f} "
              f"simulated s")
