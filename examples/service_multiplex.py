"""ExperimentService: many concurrent CodedFedL runs in one process.

Submits three heterogeneous jobs — a static coded run, a greedy run with
a different block size, and an adaptive run over a drifting channel — to
one `ExperimentService`, which round-robins one block per job per step
and checkpoints every run under ``root/<run_id>/``.  Midway through, the
service is "killed" (dropped) and a fresh one pointed at the same root
resumes every run from its latest checkpoint; the final models are
bit-identical to an uninterrupted service.

    PYTHONPATH=src python examples/service_multiplex.py
"""
import dataclasses
import tempfile

import numpy as np

from repro.api import ExperimentService, build_experiment
from repro.config import ExperimentSpec, FLConfig, TrainConfig


def make_data(n=8, l=64, q=128, c=4, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n, l, c)).astype(np.float32)
    return xs, ys


def main():
    xs, ys = make_data()
    base = ExperimentSpec(
        fl=FLConfig(n_clients=8, delta=0.25, psi=0.25, seed=11),
        train=TrainConfig(learning_rate=0.3),
        scheme="coded", checkpoint_every=20)
    jobs = {
        "coded-static": base,
        "greedy-static": dataclasses.replace(base, scheme="greedy",
                                             checkpoint_every=25),
        "adaptive-drift": dataclasses.replace(
            base, scheme="adaptive_coded", channel_profile="drift_churn",
            adapt_every=10, checkpoint_every=20),
    }
    iterations = 100
    root = tempfile.mkdtemp(prefix="service_runs_")
    print(f"checkpoint root: {root}\n")

    # uninterrupted service = the reference
    control = ExperimentService(root + "_control")
    for rid, spec in jobs.items():
        control.submit(spec, xs, ys, iterations, run_id=rid)
    expect = control.run_until_complete()

    # interleave blocks, then kill the service mid-flight
    svc = ExperimentService(root)
    for rid, spec in jobs.items():
        svc.submit(spec, xs, ys, iterations, run_id=rid)
    for k in range(7):
        rid = svc.step()
        run = svc.runs[rid]
        print(f"step {k}: advanced {rid!r:18s} -> "
              f"{run.state.rounds_done:3d}/{iterations} rounds")
    print("\n-- service killed --\n")
    del svc

    # a fresh service on the same root picks every run back up
    svc2 = ExperimentService(root)
    for rid, spec in jobs.items():
        run = svc2.submit(spec, xs, ys, iterations, run_id=rid)
        print(f"resubmitted {rid!r:18s} resumed={run.resumed} "
              f"at {run.state.rounds_done} rounds")
    results = svc2.run_until_complete()

    print()
    for rid in jobs:
        same = bool(np.array_equal(np.asarray(expect[rid].theta),
                                   np.asarray(results[rid].theta)))
        wall = results[rid].history[-1].wall_clock
        print(f"{rid:18s} final wall-clock {wall:8.1f}s   "
              f"bit-identical to uninterrupted = {same}")


if __name__ == "__main__":
    main()
