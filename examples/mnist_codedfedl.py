"""Paper reproduction driver (Fig 4 / Tables II-III, MNIST-scale).

Trains the paper's exact workload — RFF kernel regression, (sigma, q) =
(5, 2000)-style embedding, 30 clients, LTE-parameterized delay network,
non-IID sort-and-shard split — under all three schemes, and reports
time-to-accuracy speedups.  MNIST itself is not downloadable in this
container; a statistically matched synthetic task stands in (DESIGN.md §7).
Wall-clock numbers are simulated seconds from the paper's delay models.

    PYTHONPATH=src python examples/mnist_codedfedl.py             # reduced
    PYTHONPATH=src python examples/mnist_codedfedl.py --full      # paper scale
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import bench_fed_training  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper scale: m=12000, q=2000, d=784, 350 iters")
    ap.add_argument("--delta", type=float, default=0.2,
                    help="coding redundancy u_max/m (paper: 0.1 / 0.2)")
    ap.add_argument("--psi", type=float, default=0.2,
                    help="greedy drop fraction (paper: 0.1 / 0.2)")
    args = ap.parse_args()
    kw = dict(delta=args.delta, psi=args.psi)
    if args.full:
        kw.update(m_train=12000, q=2000, d=784, iters=350)
    rows, results = bench_fed_training.run(return_histories=True, **kw)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print("\naccuracy vs iteration (coded should track naive, greedy lag):")
    hist = {s: results[s].history for s in results}
    for i in range(0, len(hist["naive"]), max(1, len(hist["naive"]) // 12)):
        row = {s: hist[s][i].accuracy for s in hist}
        print(f"  iter {i:4d}  naive={row['naive']:.3f} "
              f"greedy={row['greedy']:.3f} coded={row['coded']:.3f}")


if __name__ == "__main__":
    main()
