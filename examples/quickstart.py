"""Quickstart: CodedFedL end-to-end in ~30 seconds on CPU.

Builds a small federated deployment (10 clients over a simulated wireless
MEC network) and runs every registered straggler-mitigation scheme through
the declarative experiment API: one frozen `ExperimentSpec` per scheme,
`repro.api.build_experiment(spec, xs, ys)` for the runnable deployment.
Prints the headline comparison (per-iteration accuracy parity + wall-clock
speedup), demonstrates the kill/resume round-trip of the block-structured
runtime (save a RunState checkpoint mid-run, rebuild the experiment from
scratch, resume — bit-identical result), then finishes with a
multi-realization run (8 independent delay draws, one vmapped call)
showing the wall-clock confidence band.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build_experiment
from repro.config import FLConfig, RFFConfig, TrainConfig
from repro.core import rff
from repro.core.delay_model import mec_network
from repro.data import sharding, synthetic


def main():
    fl = FLConfig(n_clients=10, delta=0.2, psi=0.2)
    ds = synthetic.synthetic_classification(m_train=2000, m_test=500, d=64)

    # 1. distributed kernel embedding (shared-seed RFF, paper §III-A)
    rcfg = RFFConfig(q=256, sigma=2.0)
    omega, delta = rff.rff_params(rcfg, d=64)
    xh_tr = np.asarray(rff.rff_transform(jnp.asarray(ds.x_train), omega, delta))
    xh_te = np.asarray(rff.rff_transform(jnp.asarray(ds.x_test), omega, delta))

    # 2. non-IID partition over the simulated MEC network (paper §V-A)
    nodes = mec_network(fl, d_scalars_per_point=rcfg.q * ds.n_classes)
    shards = sharding.sort_and_shard(xh_tr, ds.y_train, fl.n_clients)
    per_client = sharding.assign_shards_by_speed(shards, nodes, minibatch=200)
    xs = np.stack([c[0] for c in per_client])
    ys = np.stack([ds.one_hot(c[1]) for c in per_client])

    tcfg = TrainConfig(learning_rate=rff.suggest_lr(xh_tr))

    def eval_fn(theta):
        acc = ((xh_te @ np.asarray(theta)).argmax(1) == ds.y_test).mean()
        return 0.0, float(acc)

    # 3. one frozen spec per scheme (the declarative experiment API); the
    # base spec is JSON-serializable — log it next to the results
    base_spec = ExperimentSpec(fl=fl, train=tcfg, rff=rcfg)
    print(f"base spec: {base_spec.to_dict()}\n")
    print(f"{'scheme':14s} {'accuracy':>9s} {'wall-clock':>11s}"
          f" {'deadline':>9s} {'eps(bits)':>10s}")
    base_wall = None
    for scheme in ("naive", "greedy", "ideal", "coded", "partial_coded"):
        spec = dataclasses.replace(base_spec, scheme=scheme)
        res = build_experiment(spec, xs, ys).run(100, eval_fn=eval_fn,
                                                 eval_every=25)
        h = res.history[-1]
        if scheme == "naive":
            base_wall = h.wall_clock
        speed = f"({base_wall / h.wall_clock:.1f}x)" if scheme != "naive" else ""
        t_star = f"{res.t_star:.2f}s" if res.t_star else "-"
        eps = f"{res.privacy_eps:.2f}" if res.privacy_eps else "-"
        print(f"{scheme:14s} {h.accuracy:9.3f} {h.wall_clock:9.0f}s "
              f"{speed:>6s} {t_star:>9s} {eps:>10s}")

    # 4. kill/resume round-trip: checkpoint_every=25 makes the run a chain
    # of 4 blocks, each saving a RunState checkpoint; we "kill" after one
    # block and resume in a FRESH experiment — the final model is
    # bit-identical to the uninterrupted run
    import tempfile
    ckpt_spec = dataclasses.replace(base_spec, scheme="coded",
                                    checkpoint_every=25)
    control = build_experiment(ckpt_spec, xs, ys).run(100)
    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    interrupted = build_experiment(ckpt_spec, xs, ys)
    state = interrupted.run_block(interrupted.init_state(100))  # 25 rounds
    interrupted.save_state(f"{ckpt_dir}/ckpt_{state.rounds_done:06d}.npz",
                           state)
    del interrupted, state                                      # the kill
    resumed = build_experiment(ckpt_spec, xs, ys).run(
        100, checkpoint_dir=ckpt_dir, resume=True)
    identical = bool(np.array_equal(np.asarray(control.theta),
                                    np.asarray(resumed.theta)))
    print(f"\nkill at round 25 -> resume from {ckpt_dir}: "
          f"bit-identical = {identical}")

    # 5. confidence bands: 8 independent delay realizations, one vmapped call
    print("\nwall-clock over 8 delay realizations (mean ± std, final round):")
    for scheme in ("naive", "coded"):
        exp = build_experiment(dataclasses.replace(base_spec, scheme=scheme),
                               xs, ys)
        mean, std = exp.run_multi(100, 8).wall_clock_bands()
        print(f"  {scheme:6s} {mean[-1]:8.0f}s ± {std[-1]:.1f}s")


if __name__ == "__main__":
    main()
