"""Benchmark: static vs adaptive load allocation under network drift.

Thin CLI over `repro.launch.scenarios`: runs the drift-scenario
comparison (same deployment, same realized channel trace, static round-0
allocation vs the adaptive controller) and writes the standalone
``BENCH_drift_scenarios.json`` artifact the CI `scenarios` smoke step
uploads.  The same section also rides inside ``BENCH_fed_training.json``
(schema v4) via ``benchmarks.bench_scheme_compare``.

  PYTHONPATH=src python -m benchmarks.bench_drift_scenarios [--smoke|--full]
      [--out BENCH_drift_scenarios.json]
  PYTHONPATH=src python -m benchmarks.bench_drift_scenarios \
      --validate BENCH_drift_scenarios.json    # exit 1 on malformed artifact
"""
from __future__ import annotations

import argparse
import datetime
import json
import sys

from repro.launch import scenarios as launch_scenarios

ARTIFACT_NAME = "BENCH_drift_scenarios.json"

_SCALES = {
    "smoke": dict(n_clients=6, l=16, q=16, c=3, iters=50, adapt_every=5),
    "default": dict(),          # repro.launch.scenarios defaults
    "full": dict(n_clients=20, l=48, q=64, c=5, iters=120, adapt_every=8),
}


def run(out_path: str = ARTIFACT_NAME, scale: str = "default",
        kernel_backend: str = "xla"):
    """Run the comparison, write the artifact, return CSV rows."""
    section = launch_scenarios.run_scenarios(
        kernel_backend=kernel_backend, **_SCALES[scale])
    problems = launch_scenarios.validate_scenarios(section)
    if problems:
        raise RuntimeError(f"scenario section failed validation: {problems}")
    artifact = {
        "benchmark": "fed_drift_scenarios",
        "generated": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "scenarios": section,
    }
    with open(out_path, "w") as fh:
        json.dump(artifact, fh, indent=2, sort_keys=True)
        fh.write("\n")
    rows = []
    for name, case in section["cases"].items():
        rows.append((
            f"fed_scenario_{name}", case["host_seconds"] * 1e6,
            f"adaptive_speedup={case['adaptive_speedup']:.2f}x;"
            f"tt_static={case['static']['time_to_target']:.2f}s;"
            f"tt_adaptive={case['adaptive']['time_to_target']:.2f}s"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=ARTIFACT_NAME)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (seconds, not minutes)")
    ap.add_argument("--full", action="store_true", help="larger run")
    ap.add_argument("--kernel-backend", default="xla",
                    choices=("xla", "pallas"))
    ap.add_argument("--validate", metavar="PATH",
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.validate:
        try:
            with open(args.validate) as fh:
                artifact = json.load(fh)
        except (OSError, json.JSONDecodeError) as exc:
            print(f"INVALID: cannot load artifact: {exc}", file=sys.stderr)
            return 1
        problems = launch_scenarios.validate_scenarios(
            artifact.get("scenarios"))
        if artifact.get("benchmark") != "fed_drift_scenarios":
            problems.append(
                f"bad benchmark id: {artifact.get('benchmark')!r}")
        if problems:
            for pr in problems:
                print(f"INVALID: {pr}", file=sys.stderr)
            return 1
        print(f"{args.validate}: OK")
        return 0

    scale = "full" if args.full else ("smoke" if args.smoke else "default")
    for name, us, derived in run(args.out, scale=scale,
                                 kernel_backend=args.kernel_backend):
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
