"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape) on the single-pod 16x16 mesh (TPU v5e):
    compute    = FLOPs / (chips * 197e12)      [s]
    memory     = bytes / (chips * 819e9)       [s]
    collective = coll_bytes / (chips * 50e9)   [s]

Two FLOPs/bytes sources are reported side by side:
  * hlo_*      — straight from compiled.cost_analysis() / HLO parsing.
    CAVEAT (documented in EXPERIMENTS.md): XLA's CPU cost analysis counts
    while-loop bodies ONCE, so scanned layer stacks and kv-chunk loops are
    undercounted; these columns are lower bounds.
  * analytic_* — transparent napkin-math accounting from the config
    (per-component matmul FLOPs, x3 for backward, x4/3 with remat; bytes =
    param + optimizer + activation + cache traffic), used for the roofline
    terms.  collective bytes use the HLO-parsed per-instance sizes scaled by
    the known scan trip counts.

MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_TRAIN_MULT = 3.0            # fwd + bwd
_REMAT_MULT = 4.0 / 3.0      # one extra forward


def _cfg(arch):
    from repro.configs import get_config
    return get_config(arch)


def param_counts(cfg):
    """(total_params, active_params) from the abstract param tree."""
    import jax
    from repro.models.model_zoo import build
    abs_p = build(cfg).abstract_params()
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(abs_p):
        n = int(np.prod(leaf.shape))
        total += n
        keys = [str(getattr(k, "key", k)) for k in path]
        if "moe" in keys and keys[-1] in ("w1", "w2", "w3"):
            routed += n
    active = total
    if cfg.moe is not None and routed:
        active = total - routed * (1.0 - cfg.moe.top_k / cfg.moe.num_experts)
    return total, active


def seq_tokens(shape):
    from repro.config import SHAPES
    s = SHAPES[shape] if isinstance(shape, str) else shape
    if s.kind == "decode":
        return s.global_batch * 1
    return s.global_batch * s.seq_len


def model_flops(cfg, shape):
    """6*N_active*D for training, 2*N_active*D for inference shapes."""
    from repro.config import SHAPES
    s = SHAPES[shape] if isinstance(shape, str) else shape
    _, active = param_counts(cfg)
    mult = 6.0 if s.kind == "train" else 2.0
    return mult * active * seq_tokens(s)


def attention_flops(cfg, shape, window: int) -> float:
    """Global attention-score/-value FLOPs (excluded from 6ND)."""
    from repro.config import SHAPES
    s = SHAPES[shape] if isinstance(shape, str) else shape
    if cfg.arch_type == "ssm":
        return 0.0
    n_attn = cfg.n_layers
    if cfg.arch_type == "hybrid":
        n_attn = cfg.n_layers // cfg.ssm.attn_every_n
    if cfg.is_encdec:
        n_attn = cfg.n_layers + cfg.n_encoder_layers
    hd = cfg.head_dim
    H = cfg.n_heads
    if s.kind == "decode":
        ctx = min(s.seq_len, window) if window else s.seq_len
        per_tok = 2 * 2 * H * hd * ctx
        return n_attn * s.global_batch * per_tok
    S = s.seq_len
    eff = min(S, window) if window else S
    per_seq = 2 * 2 * H * hd * S * eff / 2.0      # causal halves it
    mult = _TRAIN_MULT if s.kind == "train" else 1.0
    return n_attn * s.global_batch * per_seq * mult


def analytic_flops(cfg, shape, window: int = 0, remat: bool = True) -> float:
    """Global compiled-compute estimate: matmul params-FLOPs + attention."""
    from repro.config import SHAPES
    s = SHAPES[shape] if isinstance(shape, str) else shape
    base = model_flops(cfg, s)                   # already kind-multiplied
    if s.kind == "train" and remat:
        base *= _REMAT_MULT
    return base + attention_flops(cfg, s, window)


def analytic_bytes(cfg, shape, window: int = 0, policy: str = "fsdp_tp",
                   chips: int = 256) -> float:
    """Global HBM traffic estimate per step (weights + activations + cache)."""
    from repro.config import SHAPES
    s = SHAPES[shape] if isinstance(shape, str) else shape
    total, _ = param_counts(cfg)
    bpe = 2 if cfg.dtype == "bfloat16" else 4
    weight_traffic = total * bpe                 # read once per step
    if s.kind == "train":
        weight_traffic *= 3                      # read fwd+bwd, write update
    act = 0.0
    if s.kind != "decode":
        # layer boundary activations r/w per layer
        n_layers = cfg.n_layers + cfg.n_encoder_layers
        act = 4.0 * s.global_batch * s.seq_len * cfg.d_model * bpe * n_layers
    cache = 0.0
    if s.kind == "decode":
        ctx = min(s.seq_len, window) if window else s.seq_len
        if cfg.arch_type in ("ssm",):
            hs = cfg.rwkv.head_size
            cache = cfg.n_layers * s.global_batch * \
                (cfg.d_model // hs) * hs * hs * 4 * 2
        else:
            n_attn = cfg.n_layers
            if cfg.arch_type == "hybrid":
                n_attn = cfg.n_layers // cfg.ssm.attn_every_n
            kvdim = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) \
                if cfg.mla else 2 * cfg.n_kv_heads * cfg.head_dim
            cache = n_attn * s.global_batch * ctx * kvdim * bpe
    return weight_traffic + act + cache


def load_records(art_dir="artifacts/dryrun", mesh="16x16", policy=None,
                 include_variants=False):
    recs = {}
    for f in glob.glob(os.path.join(art_dir, "*.json")):
        r = json.load(open(f))
        if r["mesh"] != mesh:
            continue
        if policy and r["policy"] != policy:
            continue
        if not include_variants and (r.get("microbatch", 1) > 1
                                     or r.get("pad_vocab", False)):
            continue
        recs[(r["arch"], r["shape"], r["policy"])] = r
    return recs


def roofline_row(rec, window: int = 0):
    cfg = _cfg(rec["arch"])
    from repro.configs import decode_window
    window = decode_window(cfg, rec["shape"])
    chips = rec["chips"]
    a_fl = analytic_flops(cfg, rec["shape"], window)
    a_by = analytic_bytes(cfg, rec["shape"], window, rec["policy"], chips)
    coll = sum(rec["collective_bytes_per_device"].values())
    m_fl = model_flops(cfg, rec["shape"])
    t_comp = a_fl / (chips * PEAK_FLOPS)
    t_mem = a_by / (chips * HBM_BW)
    t_coll = coll / ICI_BW                    # per-device bytes over its link
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "policy": rec["policy"],
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": m_fl, "analytic_flops": a_fl,
        "useful_ratio": m_fl / a_fl if a_fl else float("nan"),
        "hlo_flops_per_dev": rec.get("flops_per_device"),
        "hlo_bytes_per_dev": rec.get("bytes_per_device"),
        "hlo_temp_bytes_per_dev": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes"),
        "collective_bytes_per_dev": coll,
    }


def full_table(art_dir="artifacts/dryrun", policy="fsdp_tp"):
    recs = load_records(art_dir, policy=policy)
    rows = [roofline_row(r) for r in recs.values()]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def print_table(rows):
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} "
           f"{'temp_GB/dev':>11s}")
    print(hdr)
    for r in rows:
        tmp = r["hlo_temp_bytes_per_dev"]
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
              f"{r['t_collective_s']:10.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f} "
              f"{(tmp or 0) / 1e9:11.1f}")


if __name__ == "__main__":
    rows = full_table()
    print_table(rows)
    with open("artifacts/roofline.json", "w") as f:
        json.dump(rows, f, indent=2)
