"""Benchmark: coded vs uncoded vs ideal-no-straggler scheme comparison.

Thin CLI/CSV front-end over `repro.launch.bench`: runs the comparison
across heterogeneity profiles with `run_multi`, writes the
``BENCH_fed_training.json`` artifact (the recorded perf trajectory; CI
asserts it exists and is well-formed every push) and emits the usual
``name,us_per_call,derived`` rows for `benchmarks.run`.

  PYTHONPATH=src python -m benchmarks.bench_scheme_compare [--smoke|--full]
      [--out BENCH_fed_training.json]
  PYTHONPATH=src python -m benchmarks.bench_scheme_compare \
      --validate BENCH_fed_training.json     # exit 1 on malformed artifact
"""
from __future__ import annotations

import argparse
import sys

from repro.launch import bench as launch_bench
from repro.launch import kernel_bench

# (n_clients, l, q, c, iters, realizations) for the profile grid, plus
# the drift-scenario (static vs adaptive) comparison's, the RunState
# service benchmark's, the per-kernel microbenchmark's, the
# fault-injection resilience benchmark's, and the hierarchical
# population-scaling benchmark's own sizes
_SCALES = {
    "smoke": dict(n_clients=5, l=12, q=16, c=3, iters=8, realizations=3,
                  scenario_kwargs=dict(n_clients=6, l=16, q=16, c=3,
                                       iters=50, adapt_every=5),
                  service_kwargs=dict(n_clients=6, l=16, q=16, c=3,
                                      iters=24, block=6),
                  kernel_kwargs=dict(kernel_bench.SCALES["smoke"], iters=10),
                  resilience_kwargs=dict(iters=24),
                  # the full REQUIRED_NS ladder (the artifact validator
                  # pins it) at the shallowest deterministic solver depth
                  scale_kwargs=dict(rounds=2, trace_rounds=2,
                                    solver_kwargs=dict(n_golden_search=12,
                                                       n_bisect=20))),
    "default": dict(n_clients=12, l=32, q=64, c=5, iters=40,
                    realizations=6, scenario_kwargs=None,
                    service_kwargs=None,
                    kernel_kwargs=dict(kernel_bench.SCALES["default"],
                                       iters=20),
                    resilience_kwargs=None, scale_kwargs=None),
    "full": dict(n_clients=30, l=100, q=256, c=10, iters=150,
                 realizations=8,
                 scenario_kwargs=dict(n_clients=20, l=48, q=64, c=5,
                                      iters=120, adapt_every=8),
                 service_kwargs=None,
                 kernel_kwargs=dict(kernel_bench.SCALES["full"], iters=20),
                 resilience_kwargs=dict(iters=80),
                 scale_kwargs=dict(
                     ns=(1_000, 10_000, 100_000, 1_000_000))),
}


def run(out_path: str = launch_bench.ARTIFACT_NAME, scale: str = "default",
        kernel_backend: str = "xla", engine: str = "sweep",
        measure_loop: bool = True):
    """Run the comparison, write the artifact, return CSV rows."""
    result = launch_bench.run_schemes(kernel_backend=kernel_backend,
                                      engine=engine,
                                      measure_loop=measure_loop,
                                      **_SCALES[scale])
    launch_bench.write_artifact(result, out_path)
    problems = launch_bench.validate_artifact(out_path)
    if problems:
        raise RuntimeError(f"benchmark artifact failed validation: {problems}")
    rows = []
    for pname, prof in result["profiles"].items():
        for scheme, entry in prof["schemes"].items():
            rows.append((
                f"fed_compare_{pname}_{scheme}",
                entry["host_seconds"] * 1e6,
                f"wall={entry['final_wall_clock_mean']:.1f}s"
                f"±{entry['final_wall_clock_std']:.1f}"))
        rows.append((f"fed_compare_{pname}_speedup", 0.0,
                     f"vs_naive={prof['coded_speedup_vs_naive']:.2f}x;"
                     f"vs_ideal={prof['coded_overhead_vs_ideal']:.2f}x"))
    sweep = result.get("sweep")
    if sweep:
        derived = (f"loop={sweep['loop_host_seconds']:.2f}s;"
                   f"speedup={sweep['speedup']:.2f}x"
                   if sweep.get("speedup") else "loop=unmeasured")
        rows.append(("fed_sweep_grid", sweep["host_seconds"] * 1e6, derived))
    service = result.get("service")
    if service:
        rows.append((
            "fed_service_block_overhead",
            service["blocked_seconds"] * 1e6,
            f"oneshot={service['oneshot_seconds']:.3f}s;"
            f"ratio={service['overhead_ratio']:.3f};"
            f"resumed_ok={service['resumed_bit_identical']}"))
    kernels = result.get("kernels")
    if kernels:
        for kname, entry in kernels["entries"].items():
            rows.append((f"kernel_{kname}", entry["us_per_call"],
                         f"backend={kernels['backend']}"))
        rows.append(("kernel_fused_vs_two_pass", 0.0,
                     f"ratio={kernels['fused_vs_two_pass_ratio']:.3f}"))
    for name, case in result.get("scenarios", {}).get("cases", {}).items():
        rows.append((
            f"fed_scenario_{name}", case["host_seconds"] * 1e6,
            f"adaptive_speedup={case['adaptive_speedup']:.2f}x;"
            f"tt_static={case['static']['time_to_target']:.2f}s;"
            f"tt_adaptive={case['adaptive']['time_to_target']:.2f}s"))
    resilience = result.get("resilience")
    if resilience:
        for name, case in resilience["cases"].items():
            rows.append((
                f"fed_resilience_{name}", case["host_seconds"] * 1e6,
                f"masked={case['coded']['health']['returns_masked']};"
                f"naive_skipped="
                f"{case['naive_unguarded']['health']['rounds_skipped']};"
                f"graceful={case['coded']['degraded_gracefully']}"))
        chaos = resilience["service"]
        rows.append((
            "fed_resilience_chaos", chaos["host_seconds"] * 1e6,
            f"crash_retries={chaos['crash_retries']};"
            f"chaos_ok={chaos['chaos_bit_identical']};"
            f"fallback_ok={chaos['fallback_recovery_bit_identical']}"))
    telemetry = result.get("telemetry")
    if telemetry:
        rows.append((
            "fed_telemetry_overhead", telemetry["enabled_seconds"] * 1e6,
            f"ratio={telemetry['overhead_ratio']:.3f};"
            f"bit_identical={telemetry['trajectory_bit_identical']};"
            f"journal_ok={telemetry['journal_deterministic']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=launch_bench.ARTIFACT_NAME)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (seconds, not minutes)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale run")
    ap.add_argument("--kernel-backend", default="xla",
                    choices=("xla", "pallas"))
    ap.add_argument("--engine", default="sweep", choices=("sweep", "loop"),
                    help="compiled (profile x realization) sweep per scheme "
                         "(default) or the pre-sweep per-profile loop")
    ap.add_argument("--no-loop-baseline", action="store_true",
                    help="skip timing the looped path (sweep engine only); "
                         "the artifact then omits the measured speedup")
    ap.add_argument("--validate", metavar="PATH",
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.validate:
        problems = launch_bench.validate_artifact(args.validate)
        if problems:
            for pr in problems:
                print(f"INVALID: {pr}", file=sys.stderr)
            return 1
        print(f"{args.validate}: OK")
        return 0

    scale = "full" if args.full else ("smoke" if args.smoke else "default")
    for name, us, derived in run(args.out, scale=scale,
                                 kernel_backend=args.kernel_backend,
                                 engine=args.engine,
                                 measure_loop=not args.no_loop_baseline):
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
