"""Per-kernel microbenchmark CLI with a committed-artifact regression gate.

Thin front-end over `repro.launch.kernel_bench`: times every kernel the
federated round path is built from (`rff_embed`, `linreg_grad_masked`,
`parity_encode_batched`, the fused embed->gradient kernel, and its
two-pass equivalent), prints the usual ``name,us_per_call,derived`` rows,
and — when given a committed ``BENCH_fed_training.json`` — fails if any
kernel regressed past the threshold.

  PYTHONPATH=src python -m benchmarks.bench_kernels_micro [--smoke|--full]
      [--kernel-backend {xla,pallas}] [--iters N] [--out fresh.json]
  PYTHONPATH=src python -m benchmarks.bench_kernels_micro --smoke \
      --compare BENCH_fed_training.json [--threshold 3.0] \
      [--out fresh_kernels.json]        # exit 1 on regression
  PYTHONPATH=src python -m benchmarks.bench_kernels_micro \
      --validate BENCH_fed_training.json  # exit 1 on malformed section

``--compare`` writes the fresh section to ``--out`` BEFORE judging it, so
a failing CI run can upload the fresh numbers for inspection.  The gate
is one-sided (speedups always pass) and its threshold is generous
(`kernel_bench.DEFAULT_THRESHOLD`) — it exists to catch wrapper-level
regressions (accidental de-jitting, shape blowups), not host jitter.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.launch import kernel_bench


def _load_section(path: str) -> dict:
    """The ``kernels`` section of an artifact, or a bare section file."""
    with open(path) as fh:
        obj = json.load(fh)
    if isinstance(obj, dict) and "kernels" in obj:
        return obj["kernels"]
    return obj


def run(scale: str = "default", kernel_backend: str = "xla",
        iters: int | None = None, seed: int = 0) -> dict:
    """Run the microbenchmark at a named scale; return the section dict."""
    kwargs = dict(kernel_bench.SCALES[scale])
    if iters is not None:
        kwargs["iters"] = iters
    return kernel_bench.run_kernel_bench(kernel_backend=kernel_backend,
                                         seed=seed, **kwargs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized shapes")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes (784-dim features, q=2000)")
    ap.add_argument("--kernel-backend", default="xla",
                    choices=("xla", "pallas"))
    ap.add_argument("--iters", type=int, default=None,
                    help="timed iterations per kernel (default: per-scale)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", metavar="PATH",
                    help="write the fresh kernels section as JSON")
    ap.add_argument("--compare", metavar="PATH",
                    help="committed artifact (or bare kernels section) to "
                         "gate against; exit 1 on regression")
    ap.add_argument("--threshold", type=float,
                    default=kernel_bench.DEFAULT_THRESHOLD,
                    help="regression factor: fresh us_per_call may not "
                         "exceed threshold x committed (default %(default)s)")
    ap.add_argument("--validate", metavar="PATH",
                    help="validate an artifact's kernels section and exit")
    args = ap.parse_args(argv)

    if args.validate:
        problems = kernel_bench.validate_kernels(_load_section(args.validate))
        if problems:
            for pr in problems:
                print(f"INVALID: {pr}", file=sys.stderr)
            return 1
        print(f"{args.validate}: kernels section OK")
        return 0

    scale = "full" if args.full else ("smoke" if args.smoke else "default")
    fresh = run(scale=scale, kernel_backend=args.kernel_backend,
                iters=args.iters, seed=args.seed)
    for name in kernel_bench.KERNEL_NAMES:
        print(f"kernel_{name},{fresh['entries'][name]['us_per_call']:.1f},"
              f"backend={fresh['backend']}")
    print(f"kernel_fused_vs_two_pass,0.0,"
          f"ratio={fresh['fused_vs_two_pass_ratio']:.3f}")

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(fresh, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.out}")

    if args.compare:
        problems = kernel_bench.compare_kernels(
            fresh, _load_section(args.compare), threshold=args.threshold)
        if problems:
            for pr in problems:
                print(f"REGRESSION: {pr}", file=sys.stderr)
            return 1
        print(f"{args.compare}: within {args.threshold:.2f}x of committed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
