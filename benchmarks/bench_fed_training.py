"""Benchmark: the paper's headline experiment (Fig 4/5, Tables II/III).

Runs naive-uncoded / greedy-uncoded / CodedFedL on the synthetic MNIST
stand-in with the paper's §V-A MEC network, and reports:
  * per-iteration accuracy parity (coded vs naive)      — Fig 4b/5b
  * simulated wall-clock per scheme + time-to-accuracy  — Fig 4c, Tables II/III
  * host wall-clock speedup of the batched scan engine over the legacy
    per-client Python loop (coded scheme, n=32 clients)
  * multi-realization wall-clock bands (mean ± std over independent delay
    realizations, one vmapped call) — the Fig 4/5 confidence bands
Scale is reduced by default so `python -m benchmarks.run` stays fast; pass
--full for the paper-scale (m=12000, q=2000) run.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import build_experiment
from repro.config import ExperimentSpec, FLConfig, RFFConfig, TrainConfig
from repro.core import rff
from repro.core.delay_model import mec_network
from repro.data import sharding, synthetic


def engine_speedup(n_clients=32, l=64, q=128, c=10, iters=150, seed=0):
    """Host wall-clock: batched scan engine vs. legacy per-client loop.

    Coded scheme at n_clients (>= 32 by default, the regime stochastic-coded
    follow-ups sweep).  The batched timing includes jit compilation, i.e.
    this is the end-to-end cost of one cold `run()` call.
    """
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n_clients, l, c)).astype(np.float32)
    fl = FLConfig(n_clients=n_clients, delta=0.2, psi=0.2, seed=seed)
    tcfg = TrainConfig(learning_rate=0.5, l2_reg=1e-5)
    timings = {}
    for engine in ("batched", "legacy"):
        exp = build_experiment(ExperimentSpec(fl=fl, train=tcfg,
                                              scheme="coded", engine=engine),
                               xs, ys)
        t0 = time.perf_counter()
        exp.run(iters)
        timings[engine] = time.perf_counter() - t0
    speed = timings["legacy"] / timings["batched"]
    return [(f"fed_engine_speedup_coded_n{n_clients}",
             timings["batched"] * 1e6,
             f"legacy_us={timings['legacy'] * 1e6:.0f};speedup={speed:.1f}x")]


def run(m_train=3000, q=256, d=64, n_clients=30, iters=200,
        delta=0.2, psi=0.2, seed=0, return_histories=False):
    fl = FLConfig(n_clients=n_clients, delta=delta, psi=psi, seed=seed)
    ds = synthetic.synthetic_classification(m_train=m_train,
                                            m_test=max(500, m_train // 6),
                                            d=d, seed=seed)
    rcfg = RFFConfig(q=q, sigma=2.0 if d < 256 else 5.0)
    om, de = rff.rff_params(rcfg, d)
    xh_tr = np.asarray(rff.rff_transform(jnp.asarray(ds.x_train), om, de))
    xh_te = np.asarray(rff.rff_transform(jnp.asarray(ds.x_test), om, de))
    lr = rff.suggest_lr(xh_tr)
    nodes = mec_network(fl, d_scalars_per_point=q * ds.n_classes)
    shards = sharding.sort_and_shard(xh_tr, ds.y_train, n_clients)
    minibatch = xh_tr.shape[0] // n_clients
    per_client = sharding.assign_shards_by_speed(shards, nodes, minibatch)
    xs = np.stack([c[0] for c in per_client])
    ys = np.stack([ds.one_hot(c[1]) for c in per_client])
    tcfg = TrainConfig(learning_rate=lr,
                       lr_decay_epochs=(int(iters * 0.55), int(iters * 0.8)))

    def eval_fn(theta):
        th = np.asarray(theta)
        return 0.0, float(((xh_te @ th).argmax(1) == ds.y_test).mean())

    results, sims, rows = {}, {}, []
    for scheme in ("naive", "greedy", "coded"):
        t0 = time.perf_counter()
        sim = build_experiment(ExperimentSpec(fl=fl, train=tcfg,
                                              rff=rcfg, scheme=scheme),
                               xs, ys)
        res = sim.run(iters, eval_fn=eval_fn, eval_every=5)
        us = (time.perf_counter() - t0) * 1e6
        results[scheme] = res
        sims[scheme] = sim
        final = res.history[-1]
        rows.append((f"fed_{scheme}_sim", us,
                     f"acc={final.accuracy:.3f};wall={final.wall_clock:.0f}s"))

    # time-to-accuracy speedups (Tables II/III analog)
    target = 0.95 * results["naive"].history[-1].accuracy

    def t_gamma(res):
        for h in res.history:
            if not np.isnan(h.accuracy) and h.accuracy >= target:
                return h.wall_clock
        return float("inf")

    tU, tG, tC = (t_gamma(results[s]) for s in ("naive", "greedy", "coded"))
    rows.append(("fed_speedup_vs_naive", 0.0,
                 f"gamma={target:.3f};tU/tC={tU / tC:.2f}x"))
    rows.append(("fed_speedup_vs_greedy", 0.0,
                 f"tG/tC={tG / tC if np.isfinite(tG) else float('inf'):.2f}x"
                 if np.isfinite(tG) else "greedy_never_reaches_target"))
    acc_gap = (results["naive"].history[-1].accuracy
               - results["greedy"].history[-1].accuracy)
    rows.append(("fed_noniid_acc_gap_naive_minus_greedy", 0.0,
                 f"{acc_gap:.3f}"))

    # Fig 4/5 confidence bands: R independent delay realizations, vmapped
    # (reuses the sims above — parity setup and scan cache are already warm)
    for scheme in ("naive", "coded"):
        t0 = time.perf_counter()
        multi = sims[scheme].run_multi(iters, 8)
        us = (time.perf_counter() - t0) * 1e6
        mean, std = multi.wall_clock_bands()
        rows.append((f"fed_{scheme}_wall_bands_r8", us,
                     f"final={mean[-1]:.0f}s±{std[-1]:.1f}s"))

    rows += engine_speedup()
    if return_histories:
        return rows, results
    return rows


if __name__ == "__main__":
    import sys
    full = "--full" in sys.argv
    kw = dict(m_train=12000, q=2000, d=784, iters=350) if full else {}
    for r in run(**kw):
        print(",".join(str(x) for x in r))
