"""Benchmark: privacy budget computation (paper Appendix F / eq. 62) across
coding redundancy levels — the paper's privacy-vs-redundancy trade-off."""
from __future__ import annotations

import time

import numpy as np

from repro.core import privacy


def run(l=400, q=2000, deltas=(0.05, 0.1, 0.2, 0.5)):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(l, q)) * np.sqrt(2.0 / q)
    m = 12000
    rows = []
    for delta in deltas:
        u = int(delta * m)
        t0 = time.perf_counter()
        eps = privacy.mi_dp_budget(x, u)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"privacy_eps_delta_{delta}", us, f"eps={eps:.3f}bits"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
