"""Kill/resume smoke: SIGKILL a checkpointing run mid-flight, resume it,
and require bit-equality with the uninterrupted control run.

Driver mode (default) runs the control in-process, spawns this same file
in ``--child`` mode (a block-structured run that checkpoints every block
and sleeps between blocks to widen the kill window), SIGKILLs the child
once at least two checkpoints are on disk, resumes from the latest one,
and asserts the final model / wall-clock log / returned counts match the
control exactly.  Exit code 0 = bit-identical; anything else fails CI.

    PYTHONPATH=src python benchmarks/resume_smoke.py --ckpt-dir /tmp/ck
"""
import argparse
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np

ITERATIONS = 24
BLOCK = 4           # checkpoint_every
KILL_AFTER = 8      # SIGKILL once >= this many rounds are checkpointed


def build():
    """One deterministic deployment shared by control, child, and resume."""
    from repro.api import build_experiment
    from repro.config import ExperimentSpec, FLConfig, TrainConfig
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(6, 16, 24)).astype(np.float32) * 0.2
    ys = rng.normal(size=(6, 16, 3)).astype(np.float32)
    spec = ExperimentSpec(
        fl=FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme="adaptive_coded", channel_profile="drift_churn",
        adapt_every=2, checkpoint_every=BLOCK, run_id="resume-smoke")
    return build_experiment(spec, xs, ys)


def child(ckpt_dir: str) -> None:
    """Checkpoint every block, sleeping in between so the driver can
    SIGKILL between (not during) block computations."""
    from repro.checkpoint import io as ckpt_io
    exp = build()
    state = exp.init_state(ITERATIONS)
    while not state.done:
        state = exp.run_block(state)
        exp.save_state(
            os.path.join(ckpt_dir,
                         f"{ckpt_io.CKPT_PREFIX}{state.rounds_done:06d}.npz"),
            state)
        time.sleep(0.5)


def driver(ckpt_dir: str, out: str) -> int:
    from repro.checkpoint import io as ckpt_io
    os.makedirs(ckpt_dir, exist_ok=True)

    control = build().run(ITERATIONS)

    proc = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__),
         "--child", "--ckpt-dir", ckpt_dir],
        env=dict(os.environ))
    deadline = time.time() + 300
    killed_at = None
    try:
        while time.time() < deadline:
            latest = ckpt_io.latest_checkpoint(ckpt_dir)
            if latest is not None:
                rounds = int(os.path.basename(latest)
                             [len(ckpt_io.CKPT_PREFIX):-len(".npz")])
                if rounds >= KILL_AFTER:
                    killed_at = rounds
                    break
            if proc.poll() is not None:
                print(f"FAIL: child exited early (rc={proc.returncode}) "
                      "before reaching the kill point", file=sys.stderr)
                return 2
            time.sleep(0.05)
    finally:
        if proc.poll() is None:
            proc.send_signal(signal.SIGKILL)
            proc.wait()
    if killed_at is None:
        print("FAIL: no checkpoint appeared within the deadline",
              file=sys.stderr)
        return 2
    assert killed_at < ITERATIONS, "child finished before the kill"

    resumed = build().run(ITERATIONS, checkpoint_dir=ckpt_dir, resume=True)

    theta_ok = bool(np.array_equal(np.asarray(control.theta),
                                   np.asarray(resumed.theta)))
    wall_ok = [h.wall_clock for h in control.history] \
        == [h.wall_clock for h in resumed.history]
    ret_ok = [h.returned for h in control.history] \
        == [h.returned for h in resumed.history]
    eps_ok = control.privacy_eps == resumed.privacy_eps
    ok = theta_ok and wall_ok and ret_ok and eps_ok

    report = {
        "iterations": ITERATIONS, "checkpoint_every": BLOCK,
        "killed_at_round": killed_at, "theta_bit_identical": theta_ok,
        "wall_clock_identical": wall_ok, "returned_identical": ret_ok,
        "privacy_eps_identical": eps_ok, "ok": ok,
    }
    if out:
        with open(out, "w") as fh:
            json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    if not ok:
        print("FAIL: resumed run diverged from control", file=sys.stderr)
        return 1
    print(f"OK: SIGKILL at round {killed_at}, resumed bit-identically")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", action="store_true",
                    help="run the killable checkpointing loop")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--out", default="",
                    help="optional JSON report path (driver mode)")
    args = ap.parse_args()
    if args.child:
        child(args.ckpt_dir)
        return 0
    return driver(args.ckpt_dir, args.out)


if __name__ == "__main__":
    sys.exit(main())
