"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  bench_fed_training    -- Fig 4/5 + Tables II/III (scheme accuracy/
                           wall-clock, time-to-accuracy speedups, non-IID
                           accuracy gap)
  bench_scheme_compare  -- coded vs uncoded vs ideal-no-straggler across
                           heterogeneity profiles; writes the
                           BENCH_fed_training.json perf-trajectory artifact
  bench_load_alloc      -- SV footnote 2 (two-step optimizer solve time)
  bench_kernels         -- compute hot-spot kernels (RFF / gradient / parity)
  bench_privacy         -- Appendix F privacy budget vs redundancy
Roofline terms (SRoofline) are produced by benchmarks.roofline from the
dry-run artifacts.
"""
from __future__ import annotations


def main() -> None:
    from benchmarks import (bench_fed_training, bench_fig3, bench_kernels,
                            bench_load_alloc, bench_privacy,
                            bench_scheme_compare)
    print("name,us_per_call,derived")
    rows = []
    rows += bench_load_alloc.run()
    rows += bench_fig3.run()
    rows += bench_kernels.run()
    rows += bench_privacy.run()
    rows += bench_fed_training.run()
    rows += bench_scheme_compare.run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
