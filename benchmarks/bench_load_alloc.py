"""Benchmark: load-allocation optimizer (paper §V footnote 2 — the paper's
MATLAB fminbnd two-step solve takes <2 min; this measures ours)."""
from __future__ import annotations

import time

from repro.config import FLConfig
from repro.core import load_allocation as la
from repro.core.delay_model import mec_network, packet_bits, scale_tau


def run(n_clients=30, minibatch=400, q=2000, c=10, deltas=(0.05, 0.1, 0.2)):
    fl = FLConfig(n_clients=n_clients)
    nodes = [scale_tau(nd, packet_bits(fl, q * c))
             for nd in mec_network(fl, d_scalars_per_point=q * c)]
    m = n_clients * minibatch
    rows = []
    for delta in deltas:
        t0 = time.perf_counter()
        alloc = la.two_step_allocate(nodes, [float(minibatch)] * n_clients,
                                     None, u_max=delta * m, m=float(m))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"load_alloc_delta_{delta}", us,
                     f"t_star={alloc.t_star:.3f}s"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
