"""Telemetry smoke benchmark + run-report CLI (`repro.obs` surface).

Two jobs in one front-end:

* ``--smoke`` / ``--full`` — probe the telemetry subsystem end to end:
  run a journaled + span-timed + attributed demo run into
  ``<out-dir>/demo_run/`` (events.jsonl, spans.json, attribution.json),
  render its text report, then run the schema-v9 ``telemetry`` benchmark
  section (`repro.launch.report.run_telemetry`) and write it to
  ``<out-dir>/telemetry.json``.  With ``--validate`` the section is
  checked against the strict invariants (trajectory bit-identity,
  journal determinism/replay, overhead ratio < 1.05) and the process
  exits 1 on any problem — the CI telemetry job's contract.

* ``--report DIR`` — render the text report for an existing run
  directory (one written by ``Experiment.run(journal_dir=...)`` or an
  `ExperimentService` with telemetry enabled) and exit.

  PYTHONPATH=src python -m benchmarks.obs_report --smoke --validate \
      --out-dir obs_smoke
  PYTHONPATH=src python -m benchmarks.obs_report --report runs/myrun
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.launch import report as report_mod
from repro.obs import spans as obs_spans

# telemetry-probe sizes: "smoke" IS `run_telemetry`'s compute-dominated
# default (CI-sized, seconds); "full" lengthens the horizon so the ratio
# is averaged over more rounds
SCALES = {
    "smoke": dict(),
    "full": dict(iters=400, repeats=5),
}

# demo-run size (report rendering only — invariants are pinned by the
# probe and tests/test_obs.py, so this just needs to be fast)
_DEMO = dict(n_clients=8, l=32, q=32, c=4, iters=16, block=4, seed=0)


def _demo_run(out_dir: str, kernel_backend: str) -> str:
    """One journaled, span-timed, attributed coded run -> its run dir."""
    from repro.config import ExperimentSpec, FLConfig, TrainConfig
    from repro.core.fed_runtime import Experiment

    run_dir = os.path.join(out_dir, "demo_run")
    rng = np.random.default_rng(_DEMO["seed"])
    xs = rng.normal(size=(_DEMO["n_clients"], _DEMO["l"],
                          _DEMO["q"])).astype(np.float32) * 0.2
    ys = rng.normal(size=(_DEMO["n_clients"], _DEMO["l"],
                          _DEMO["c"])).astype(np.float32)
    spec = ExperimentSpec(
        fl=FLConfig(n_clients=_DEMO["n_clients"], delta=0.2, psi=0.2,
                    seed=_DEMO["seed"]),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5),
        scheme="coded", kernel_backend=kernel_backend,
        checkpoint_every=_DEMO["block"])
    with obs_spans.collecting():
        exp = Experiment(spec, xs, ys)
        exp.run(_DEMO["iters"], journal_dir=run_dir)
        attr = exp.attribution()
        obs_spans.write_json(os.path.join(run_dir, obs_spans.SPANS_NAME))
    with open(os.path.join(run_dir, report_mod.ATTR_NAME), "w") as fh:
        json.dump(attr.to_dict(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    return run_dir


def run(out_dir: str, scale: str = "smoke", kernel_backend: str = "xla",
        validate: bool = False) -> int:
    os.makedirs(out_dir, exist_ok=True)
    run_dir = _demo_run(out_dir, kernel_backend)
    print(report_mod.render_report(run_dir))

    telemetry = report_mod.run_telemetry(kernel_backend=kernel_backend,
                                         **SCALES[scale])
    out_path = os.path.join(out_dir, "telemetry.json")
    with open(out_path, "w") as fh:
        json.dump(telemetry, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"telemetry section -> {out_path}")
    print(f"  overhead_ratio={telemetry['overhead_ratio']:.4f} "
          f"(enabled {telemetry['enabled_seconds']:.3f}s / "
          f"disabled {telemetry['disabled_seconds']:.3f}s)")
    print(f"  trajectory_bit_identical="
          f"{telemetry['trajectory_bit_identical']} "
          f"journal_deterministic={telemetry['journal_deterministic']} "
          f"journal_replay_matches={telemetry['journal_replay_matches']}")
    if validate:
        problems = report_mod.validate_telemetry(telemetry)
        if problems:
            print("telemetry section FAILED validation:", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print("telemetry section validates (strict ceiling "
              f"{report_mod.MAX_OVERHEAD_RATIO})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="obs_smoke",
                    help="where the demo run dir + telemetry.json land")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized probe (the default scale)")
    ap.add_argument("--full", action="store_true",
                    help="longer-horizon probe")
    ap.add_argument("--validate", action="store_true",
                    help="enforce the strict telemetry invariants; "
                         "exit 1 on any problem")
    ap.add_argument("--kernel-backend", default="xla",
                    choices=("xla", "pallas"))
    ap.add_argument("--report", metavar="DIR",
                    help="render the text report for an existing run "
                         "directory and exit")
    args = ap.parse_args(argv)
    if args.report:
        print(report_mod.render_report(args.report))
        return 0
    scale = "full" if args.full else "smoke"
    return run(args.out_dir, scale=scale,
               kernel_backend=args.kernel_backend, validate=args.validate)


if __name__ == "__main__":
    sys.exit(main())
