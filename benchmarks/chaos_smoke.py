"""Chaos smoke: SIGKILL a service mid-block AND corrupt its latest
checkpoint; the restarted service must recover bit-identically.

Extends ``benchmarks/resume_smoke.py`` from kill-tolerance to full
infrastructure-fault tolerance.  Driver mode (default) runs the control
service in-process, spawns this same file in ``--child`` mode (an
`ExperimentService` stepping one block at a time with sleeps to widen
the kill window), SIGKILLs the child once enough rounds are
checkpointed, then *corrupts the newest checkpoint on disk* (bit-flips
through `repro.faults.bitflip_file`) before restarting the service over
the same root.  The restart must detect the corruption through digest
verification, fall back to the newest intact checkpoint
(``fallback_resume``), recompute the lost blocks, and finish with a
final theta bit-identical to the never-interrupted control.  Exit code
0 = recovered bit-identically; anything else fails CI.

    PYTHONPATH=src python benchmarks/chaos_smoke.py --root /tmp/chaos
"""
import argparse
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np

ITERATIONS = 24
BLOCK = 4           # checkpoint_every
KILL_AFTER = 8      # SIGKILL once >= this many rounds are checkpointed
RUN_ID = "chaos-smoke"


def build_spec():
    from repro.config import ExperimentSpec, FLConfig, TrainConfig
    return ExperimentSpec(
        fl=FLConfig(n_clients=6, delta=0.25, psi=0.3, seed=3),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(5,)),
        scheme="coded", checkpoint_every=BLOCK, run_id=RUN_ID)


def data():
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(6, 16, 24)).astype(np.float32) * 0.2
    ys = rng.normal(size=(6, 16, 3)).astype(np.float32)
    return xs, ys


def make_service(root: str):
    from repro.launch.service import ExperimentService
    svc = ExperimentService(root)
    svc.submit(build_spec(), *data(), ITERATIONS, run_id=RUN_ID)
    return svc


def child(root: str) -> None:
    """Step the service one block at a time, sleeping in between so the
    driver can SIGKILL between (not during) block computations."""
    svc = make_service(root)
    while svc.pending:
        svc.step()
        time.sleep(0.5)


def driver(root: str, out: str) -> int:
    from repro.checkpoint import io as ckpt_io
    from repro.faults import bitflip_file
    ckpt_dir = os.path.join(root, RUN_ID)
    os.makedirs(ckpt_dir, exist_ok=True)

    control = make_service(os.path.join(root, "control")) \
        .run_until_complete()[RUN_ID]

    proc = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__),
         "--child", "--root", root],
        env=dict(os.environ))
    deadline = time.time() + 300
    killed_at = None
    try:
        while time.time() < deadline:
            latest = ckpt_io.latest_checkpoint(ckpt_dir)
            if latest is not None:
                rounds = int(os.path.basename(latest)
                             [len(ckpt_io.CKPT_PREFIX):-len(".npz")])
                if rounds >= KILL_AFTER:
                    killed_at = rounds
                    break
            if proc.poll() is not None:
                print(f"FAIL: child exited early (rc={proc.returncode}) "
                      "before reaching the kill point", file=sys.stderr)
                return 2
            time.sleep(0.05)
    finally:
        if proc.poll() is None:
            proc.send_signal(signal.SIGKILL)
            proc.wait()
    if killed_at is None:
        print("FAIL: no checkpoint appeared within the deadline",
              file=sys.stderr)
        return 2
    assert killed_at < ITERATIONS, "child finished before the kill"

    # the second fault: bit rot on the newest checkpoint the kill left
    corrupted = ckpt_io.latest_checkpoint(ckpt_dir)
    bitflip_file(corrupted)

    svc = make_service(root)                   # the restart
    run = svc.runs[RUN_ID]
    results = svc.run_until_complete()
    health = svc.health_report()[RUN_ID]

    theta_ok = results[RUN_ID] is not None and bool(np.array_equal(
        np.asarray(control.theta), np.asarray(results[RUN_ID].theta)))
    wall_ok = results[RUN_ID] is not None and (
        [h.wall_clock for h in control.history]
        == [h.wall_clock for h in results[RUN_ID].history])
    fallback_ok = bool(run.fallback_resume)
    ok = theta_ok and wall_ok and fallback_ok

    report = {
        "iterations": ITERATIONS, "checkpoint_every": BLOCK,
        "killed_at_round": killed_at,
        "corrupted_checkpoint": os.path.basename(corrupted),
        "fallback_resume": fallback_ok,
        "resumed_at_round": (killed_at - BLOCK if run.resumed else None),
        "theta_bit_identical": theta_ok,
        "wall_clock_identical": wall_ok,
        "health": health, "ok": ok,
    }
    if out:
        with open(out, "w") as fh:
            json.dump(report, fh, indent=2)
    print(json.dumps(report, indent=2))
    if not ok:
        print("FAIL: chaos recovery diverged from control",
              file=sys.stderr)
        return 1
    print(f"OK: SIGKILL at round {killed_at} + corrupted "
          f"{os.path.basename(corrupted)}, recovered bit-identically")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--child", action="store_true",
                    help="run the killable service loop")
    ap.add_argument("--root", required=True,
                    help="service checkpoint root")
    ap.add_argument("--out", default="",
                    help="optional JSON report path (driver mode)")
    args = ap.parse_args()
    if args.child:
        child(args.root)
        return 0
    return driver(args.root, args.out)


if __name__ == "__main__":
    sys.exit(main())
