"""Benchmark: hierarchical-tier population scaling (n = 1e3 .. 1e6).

Thin CLI over `repro.launch.scale.run_scale`: runs the edge-aggregator
tier with sampled cohorts and streamed synthetic client blocks across a
ladder of population sizes, writes a standalone ``BENCH_hier_scale.json``
(the same section `repro.launch.bench` embeds into
``BENCH_fed_training.json`` under schema v8), and emits the usual
``name,us_per_call,derived`` rows for `benchmarks.run`.

  PYTHONPATH=src python -m benchmarks.bench_hier_scale [--smoke|--full]
      [--out BENCH_hier_scale.json]
  PYTHONPATH=src python -m benchmarks.bench_hier_scale \
      --validate BENCH_hier_scale.json     # exit 1 on malformed artifact

--smoke covers n in {1e3, 1e4} (the CI ``scale`` job's budget), the
default ladder is the committed-artifact n in {1e3, 1e4, 1e5}, and
--full adds the 1e6 rung.  Validation of a standalone artifact pins the
ladder the run itself recorded (``ns``); the committed
BENCH_fed_training.json ladder is pinned to `scale.REQUIRED_NS` by
`repro.launch.bench.validate_artifact`.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.launch import scale as scale_mod

_NS = {
    "smoke": (1_000, 10_000),
    "default": scale_mod.REQUIRED_NS,
    "full": (1_000, 10_000, 100_000, 1_000_000),
}


def run(out_path: str = "BENCH_hier_scale.json", ladder: str = "default",
        rounds: int = 2):
    """Run the ladder, write the artifact, return CSV rows."""
    ns = _NS[ladder]
    section = scale_mod.run_scale(ns=ns, rounds=rounds)
    with open(out_path, "w") as fh:
        json.dump(section, fh, indent=2, sort_keys=True)
        fh.write("\n")
    problems = scale_mod.validate_scale(section, required_ns=ns)
    if problems:
        raise RuntimeError(f"scale artifact failed validation: {problems}")
    rows = []
    for entry in section["entries"]:
        n = entry["n"]
        rows.append((
            f"hier_scale_n{n}", entry["wall_seconds"] * 1e6,
            f"setup={entry['setup_seconds']:.2f}s;"
            f"rounds={entry['round_seconds']:.2f}s;"
            f"shards={entry['shards']};"
            f"peak_bytes={entry['peak_client_tensor_bytes']};"
            f"dense_bytes={entry['dense_client_tensor_bytes']}"))
        rows.append((
            f"hier_trace_n{n}", entry["trace_seconds"] * 1e6,
            f"rounds={entry['trace_rounds']}"))
    ident = section["identity"]
    rows.append(("hier_identity", 0.0,
                 f"routes_flat={ident['routes_flat_engine']};"
                 f"bit_identical={ident['bit_identical']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_hier_scale.json")
    ap.add_argument("--smoke", action="store_true",
                    help="n in {1e3, 1e4} (the CI scale job's budget)")
    ap.add_argument("--full", action="store_true",
                    help="adds the 1e6 rung to the default ladder")
    ap.add_argument("--rounds", type=int, default=2,
                    help="federated rounds per rung")
    ap.add_argument("--validate", metavar="PATH",
                    help="validate an existing artifact and exit")
    args = ap.parse_args(argv)

    if args.validate:
        try:
            with open(args.validate) as fh:
                section = json.load(fh)
        except (OSError, json.JSONDecodeError) as exc:
            print(f"INVALID: cannot load artifact: {exc}", file=sys.stderr)
            return 1
        ns = section.get("ns") if isinstance(section, dict) else None
        problems = scale_mod.validate_scale(
            section, required_ns=tuple(ns) if ns else scale_mod.REQUIRED_NS)
        if not isinstance(ns, list) or not ns:
            problems = [f"missing/empty 'ns' ladder: {ns!r}"] + problems
        if problems:
            for pr in problems:
                print(f"INVALID: {pr}", file=sys.stderr)
            return 1
        print(f"{args.validate}: OK")
        return 0

    ladder = "full" if args.full else ("smoke" if args.smoke else "default")
    for name, us, derived in run(args.out, ladder=ladder,
                                 rounds=args.rounds):
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
