"""Benchmark: per-kernel timings (jnp reference path on CPU; the Pallas
kernels are validated in interpret mode — wall time there is not meaningful
for the TPU target, so the jit'd jnp path is what's timed)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(m=12000, d=784, q=2000, c=10, u=1200):
    rng = np.random.default_rng(0)
    x_raw = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    omega = jnp.asarray(rng.normal(size=(d, q)), jnp.float32)
    delta = jnp.asarray(rng.uniform(0, 6.28, size=(q,)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, q)) * 0.03, jnp.float32)
    theta = jnp.zeros((q, c), jnp.float32)
    y = jnp.asarray(rng.normal(size=(m, c)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(u, m)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1, size=(m,)), jnp.float32)

    rff = jax.jit(ref.rff_embed)
    grad = jax.jit(ref.linreg_grad)
    par = jax.jit(ref.parity_encode)
    rows = [
        ("kernel_rff_embed_12kx784x2000", _time(rff, x_raw, omega, delta),
         f"flops={2 * m * d * q:.2e}"),
        ("kernel_linreg_grad_12kx2000x10", _time(grad, x, theta, y),
         f"flops={4 * m * q * c:.2e}"),
        ("kernel_parity_encode_1200x12k", _time(par, g, w, x),
         f"flops={2 * u * m * q:.2e}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
