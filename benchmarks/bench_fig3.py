"""Benchmark: paper Fig. 3 — properties of the expected return.

(a) piece-wise concavity of E[R_j(t; l)] in l at the paper's illustration
    parameters (p=0.9, tau=sqrt(3), mu=2, alpha=20, t=10);
(b) monotonicity of the optimized return E[R_j(t; l*_j(t))] in t.
Emits summary rows; full curves land in artifacts/fig3.json.
"""
from __future__ import annotations

import json
import math
import os
import time

import numpy as np

from repro.core import load_allocation as la
from repro.core.delay_model import NodeDelayParams


def run():
    nd = NodeDelayParams(mu=2.0, alpha=20.0, tau=math.sqrt(3.0), p=0.9)
    t0 = time.perf_counter()
    ls = np.linspace(0.05, nd.mu * 10.0, 300)
    curve_a = [la.expected_return(nd, 10.0, float(l)) for l in ls]
    ts = np.linspace(0.5, 40.0, 120)
    curve_b = [la.optimal_load(nd, float(t), cap=25.0)[1] for t in ts]
    us = (time.perf_counter() - t0) * 1e6
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/fig3.json", "w") as f:
        json.dump({"l": ls.tolist(), "ER": curve_a,
                   "t": ts.tolist(), "ER_opt": curve_b}, f)
    mono = bool(np.all(np.diff(curve_b) >= -1e-9))
    return [("fig3_expected_return_curves", us,
             f"peak_ER={max(curve_a):.3f};opt_return_monotone={mono}")]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
