"""Production mesh construction.

FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and nothing else should.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_client_mesh(num_devices: int | None = None, *, devices=None):
    """1-D mesh over a single ``"clients"`` axis for the federated engine.

    `FederatedSimulation(..., mesh=...)` partitions its dense client tensor
    over this axis and psum-aggregates per-shard gradients (the MEC server
    reduction of paper §III).  CI exercises it on CPU host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    devs = list(devices) if devices is not None else jax.devices()
    k = len(devs) if num_devices is None else int(num_devices)
    if not 1 <= k <= len(devs):
        raise ValueError(
            f"requested {k} devices for the client mesh but "
            f"{len(devs)} are available (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=<k> before jax init "
            "to fake host devices)")
    return jax.sharding.Mesh(np.array(devs[:k]), ("clients",))
