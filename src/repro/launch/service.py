"""ExperimentService: multiplex many resumable runs over one process.

The block-structured runtime (`repro.core.fed_runtime.Experiment.run_block`
over an explicit `RunState`) turns a training run into a sequence of
resumable steps.  This module adds the scheduler on top: a service accepts
frozen `ExperimentSpec`s as jobs, round-robins one block per job per
`step()`, and checkpoints every run at its own ``checkpoint_every``
boundary under ``root/<run_id>/``.  Because every block boundary is a
durable `RunState`, killing the process (or the machine) loses at most
the in-flight block: a fresh service pointed at the same root resumes
every run from its latest checkpoint and finishes bit-identically to the
uninterrupted service — theta, loss curve, wall-clock log, and adaptive
schedule alike (tests/test_service.py).

    svc = ExperimentService("runs/")
    svc.submit(spec_a, xs, ys, iterations=200, run_id="a")
    svc.submit(spec_b, xs, ys, iterations=200, run_id="b")
    results = svc.run_until_complete()     # {"a": FedResult, "b": ...}

Checkpoint layout: ``root/<run_id>/ckpt_<rounds_done>.npz`` — atomic
writes, numeric suffix ordering, spec provenance embedded per file
(`repro.checkpoint.io`).

Self-healing (`repro.faults`): a failed block — an injected
`InjectedCrashError` from the run's `FaultProfile.crash_prob`, or any
organic exception — never advances the run's state; the service retries
it with exponential backoff (``retry_backoff * 2**(attempt-1)`` seconds)
and quarantines the run after ``max_retries`` consecutive failures so
one sick job cannot stall its siblings.  Checkpoint corruption
(``ckpt_corrupt_prob``) damages the just-written file on disk; the
in-memory state is unaffected, but a *restarted* service resumes through
``latest_checkpoint(valid_only=True)`` — the digest-verified fallback to
the newest intact snapshot — and re-computes the lost blocks, finishing
bit-identically to a fault-free-infrastructure control
(benchmarks/chaos_smoke.py).  `health_report` summarizes all of it.
"""
from __future__ import annotations

import dataclasses
import os
import time
import zlib
from typing import Callable, Optional

import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.config import ExperimentSpec
from repro.core.fed_runtime import Experiment
from repro.core.run_state import RunState
from repro.faults.inject import InjectedCrashError, corrupt_checkpoint
from repro.obs import spans as obs_spans
from repro.obs.events import RunJournal

__all__ = ["ExperimentService", "ServiceRun"]


@dataclasses.dataclass
class ServiceRun:
    """One submitted job: its experiment, live state, and destination."""
    run_id: str
    spec: ExperimentSpec
    exp: Experiment
    state: RunState
    ckpt_dir: str
    eval_fn: Optional[Callable] = None
    eval_every: int = 10
    result: object = None
    resumed: bool = False          # True if submit() found a checkpoint
    fallback_resume: bool = False  # resumed past a corrupt latest ckpt
    retries: int = 0               # consecutive failures of the CURRENT block
    total_retries: int = 0         # failures over the run's lifetime
    quarantined: bool = False      # gave up after max_retries failures
    last_error: Optional[str] = None
    journal: object = None         # RunJournal when telemetry is enabled
    # always-on per-run wall-clock accounting (host time, forced spans)
    blocks_run: int = 0            # blocks computed (successful _advance)
    block_seconds: float = 0.0     # wall-clock inside run_block
    ckpt_save_seconds: float = 0.0  # wall-clock inside save_state
    backoff_seconds: float = 0.0   # wall-clock slept in retry backoff

    @property
    def done(self) -> bool:
        return self.result is not None


class ExperimentService:
    """Round-robin block scheduler over many concurrent resumable runs.

    Each `submit` builds (or resumes) one run; each `step` advances the
    next unfinished run by ONE block and checkpoints it, so N concurrent
    runs interleave fairly regardless of their horizons.  All runs of
    the same spec share compiled scans through their own `Experiment`
    cache; the service itself holds no state outside `self.runs` and the
    checkpoint root, so it is trivially restartable.

    Retry knobs: ``max_retries`` consecutive block failures quarantine a
    run; ``retry_backoff`` (seconds, default 0 so tests never sleep) is
    the base of the exponential backoff between attempts.  ``fault_seed``
    keys the service-level chaos stream — injected crashes and
    checkpoint corruption draw from ``(fault_seed, crc32(run_id),
    rounds_done, total_retries)``, so every retry of a crashed block
    redraws its fate (no deterministic crash loops) while the sequence
    stays reproducible per seed.
    """

    def __init__(self, root: str, *, mesh=None, max_retries: int = 3,
                 retry_backoff: float = 0.0, fault_seed: int = 0):
        if max_retries < 0:
            raise ValueError(f"max_retries={max_retries} must be >= 0")
        if retry_backoff < 0:
            raise ValueError(f"retry_backoff={retry_backoff} must be >= 0")
        self.root = str(root)
        self.mesh = mesh
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.fault_seed = int(fault_seed)
        self.runs: "dict[str, ServiceRun]" = {}
        self._order: "list[str]" = []
        self._cursor = 0
        self.last_health: Optional[dict] = None

    # ------------------------------------------------------------ submission
    def submit(self, spec: "ExperimentSpec | dict", x_stack, y_stack,
               iterations: int, *, run_id: Optional[str] = None,
               n_realizations: Optional[int] = None,
               eval_fn: Optional[Callable] = None, eval_every: int = 10,
               nodes=None, rng=None) -> ServiceRun:
        """Register a run; auto-resumes from ``root/<run_id>/`` when a
        checkpoint already exists there (validating spec provenance).

        ``run_id`` defaults to ``spec.run_id``, then to ``run<k>``; it
        names the checkpoint directory, so resubmitting the same id
        after a kill is exactly how a run is recovered.  A corrupt or
        truncated latest checkpoint is skipped in favor of the newest
        one that passes digest verification (``fallback_resume`` flags
        that this happened — the lost blocks are simply re-computed).
        """
        from repro.api import build_experiment
        if isinstance(spec, dict):
            spec = ExperimentSpec.from_dict(spec)
        rid = run_id or spec.run_id or f"run{len(self.runs)}"
        if rid in self.runs:
            raise ValueError(f"run_id {rid!r} already submitted")
        if spec.checkpoint_every <= 0:
            raise ValueError(
                f"run {rid!r}: service jobs need spec.checkpoint_every > 0 "
                "(a whole-horizon block would starve the other runs)")
        exp = build_experiment(spec, x_stack, y_stack, nodes=nodes, rng=rng,
                               mesh=self.mesh)
        ckpt_dir = os.path.join(self.root, rid)
        state = None
        resumed = False
        latest_any = ckpt_io.latest_checkpoint(ckpt_dir)
        latest = ckpt_io.latest_checkpoint(ckpt_dir, valid_only=True)
        fallback = latest_any is not None and latest != latest_any
        if latest is not None:
            state = exp.restore_state(latest)
            if state.iterations != int(iterations) or (
                    (state.n_realizations or None)
                    != (int(n_realizations) if n_realizations else None)):
                raise ValueError(
                    f"run {rid!r}: checkpoint {latest!r} does not match the "
                    f"submitted horizon ({state.iterations} rounds x "
                    f"{state.n_realizations} realizations vs {iterations} "
                    f"x {n_realizations})")
            resumed = True
        if state is None:
            state = exp.init_state(iterations,
                                   n_realizations=n_realizations,
                                   collect=eval_fn is not None)
        run = ServiceRun(run_id=rid, spec=spec, exp=exp, state=state,
                         ckpt_dir=ckpt_dir, eval_fn=eval_fn,
                         eval_every=eval_every, resumed=resumed,
                         fallback_resume=fallback)
        # with telemetry on, journal single-trajectory runs next to their
        # checkpoints (root/<run_id>/events.jsonl) — trimmed/regrown to
        # the restored state, so a resumed journal is extended in place
        if obs_spans.enabled() and state.mode in ("single", "hier"):
            run.journal = RunJournal(ckpt_dir)
            run.journal.reset_to(state.rounds_done)
            run.journal.sync(exp, state)
        self.runs[rid] = run
        self._order.append(rid)
        if state.done:   # resumed a run that was already finished
            run.result = exp.finish(state, eval_fn)
        return run

    # ------------------------------------------------------------ scheduling
    @property
    def pending(self) -> "list[str]":
        return [rid for rid in self._order
                if not (self.runs[rid].done or self.runs[rid].quarantined)]

    def _chaos_rng(self, run: ServiceRun) -> np.random.Generator:
        """Per-(run, block, attempt) chaos stream — `total_retries` in
        the key means a retried block redraws its crash/corruption fate
        instead of deterministically crashing forever."""
        return np.random.default_rng(
            (self.fault_seed, zlib.crc32(run.run_id.encode()),
             run.state.rounds_done, run.total_retries))

    def _advance(self, run: ServiceRun) -> None:
        """One block of `run`, with injected infrastructure faults: a
        crash fires BEFORE the block computes (SIGKILL-style — no state
        advance, no checkpoint); checkpoint corruption damages the file
        just written (detected by the digest on any later restore)."""
        faults = run.exp.faults
        chaos = (self._chaos_rng(run)
                 if faults is not None and faults.has_service_faults
                 else None)
        if chaos is not None:
            # fixed draw order (crash, then corruption) so toggling one
            # knob never shifts the other's realization
            u_crash, u_ckpt = chaos.random(2)
            if u_crash < faults.crash_prob:
                raise InjectedCrashError(
                    f"run {run.run_id!r}: injected crash at block "
                    f"rounds_done={run.state.rounds_done} "
                    f"(attempt {run.retries + 1})")
        with obs_spans.span("service/block", force=True) as sp_block:
            run.state = run.exp.run_block(run.state, eval_fn=run.eval_fn,
                                          eval_every=run.eval_every)
        run.blocks_run += 1
        run.block_seconds += sp_block.elapsed_s
        with obs_spans.span("service/ckpt_save", force=True) as sp_save:
            path = run.exp.save_state(
                os.path.join(run.ckpt_dir,
                             f"{ckpt_io.CKPT_PREFIX}"
                             f"{run.state.rounds_done:06d}.npz"),
                run.state)
        run.ckpt_save_seconds += sp_save.elapsed_s
        if chaos is not None and u_ckpt < faults.ckpt_corrupt_prob:
            corrupt_checkpoint(path, kind=faults.ckpt_corrupt_kind,
                               rng=chaos)
        if run.journal is not None:
            run.journal.sync(run.exp, run.state)

    def step(self) -> Optional[str]:
        """Advance the next unfinished run by one block, checkpoint it,
        and finish it if that block completed the run.  A failed block
        is retried with exponential backoff on the run's next turn;
        after ``max_retries`` consecutive failures the run is
        quarantined (its checkpoints stay on disk for a later resume).
        Returns the run_id acted on, or None when nothing is pending."""
        pending = self.pending
        if not pending:
            return None
        rid = pending[self._cursor % len(pending)]
        self._cursor += 1
        run = self.runs[rid]
        if run.retries > 0 and self.retry_backoff > 0:
            with obs_spans.span("service/backoff", force=True) as sp:
                time.sleep(self.retry_backoff * 2 ** (run.retries - 1))
            run.backoff_seconds += sp.elapsed_s
        try:
            self._advance(run)
        except Exception as exc:           # noqa: BLE001 — quarantine path
            run.retries += 1
            run.total_retries += 1
            run.last_error = f"{type(exc).__name__}: {exc}"
            if run.retries > self.max_retries:
                run.quarantined = True
            return rid
        run.retries = 0
        run.last_error = None
        if run.state.done:
            run.result = run.exp.finish(run.state, run.eval_fn)
        return rid

    def run_until_complete(self) -> dict:
        """Drive every submitted run to completion (or quarantine);
        {run_id: result} — a quarantined run's result is None.  The full
        per-run health report lands in ``self.last_health``."""
        while self.step() is not None:
            pass
        self.last_health = self.health_report()
        return {rid: self.runs[rid].result for rid in self._order}

    # --------------------------------------------------------------- health
    def health_report(self) -> dict:
        """{run_id: status dict} across every submitted run: progress,
        resume provenance, retry/quarantine counters, per-run wall-clock
        timing (block compute / checkpoint save / retry backoff, always
        measured), and — for finished runs — the runtime's `RunHealth`
        degradation counters."""
        report = {}
        for rid in self._order:
            run = self.runs[rid]
            health = getattr(run.result, "health", None)
            report[rid] = {
                "done": run.done,
                "quarantined": run.quarantined,
                "rounds_done": int(run.state.rounds_done),
                "iterations": int(run.state.iterations),
                "resumed": run.resumed,
                "fallback_resume": run.fallback_resume,
                "total_retries": run.total_retries,
                "last_error": run.last_error,
                "health": (dataclasses.asdict(health)
                           if health is not None else None),
                "timing": {
                    "blocks_run": run.blocks_run,
                    "block_seconds": run.block_seconds,
                    "ckpt_save_seconds": run.ckpt_save_seconds,
                    "backoff_seconds": run.backoff_seconds,
                },
            }
        return report
