"""ExperimentService: multiplex many resumable runs over one process.

The block-structured runtime (`repro.core.fed_runtime.Experiment.run_block`
over an explicit `RunState`) turns a training run into a sequence of
resumable steps.  This module adds the scheduler on top: a service accepts
frozen `ExperimentSpec`s as jobs, round-robins one block per job per
`step()`, and checkpoints every run at its own ``checkpoint_every``
boundary under ``root/<run_id>/``.  Because every block boundary is a
durable `RunState`, killing the process (or the machine) loses at most
the in-flight block: a fresh service pointed at the same root resumes
every run from its latest checkpoint and finishes bit-identically to the
uninterrupted service — theta, loss curve, wall-clock log, and adaptive
schedule alike (tests/test_service.py).

    svc = ExperimentService("runs/")
    svc.submit(spec_a, xs, ys, iterations=200, run_id="a")
    svc.submit(spec_b, xs, ys, iterations=200, run_id="b")
    results = svc.run_until_complete()     # {"a": FedResult, "b": ...}

Checkpoint layout: ``root/<run_id>/ckpt_<rounds_done>.npz`` — atomic
writes, numeric suffix ordering, spec provenance embedded per file
(`repro.checkpoint.io`).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional

from repro.checkpoint import io as ckpt_io
from repro.config import ExperimentSpec
from repro.core.fed_runtime import Experiment
from repro.core.run_state import RunState

__all__ = ["ExperimentService", "ServiceRun"]


@dataclasses.dataclass
class ServiceRun:
    """One submitted job: its experiment, live state, and destination."""
    run_id: str
    spec: ExperimentSpec
    exp: Experiment
    state: RunState
    ckpt_dir: str
    eval_fn: Optional[Callable] = None
    eval_every: int = 10
    result: object = None
    resumed: bool = False          # True if submit() found a checkpoint

    @property
    def done(self) -> bool:
        return self.result is not None


class ExperimentService:
    """Round-robin block scheduler over many concurrent resumable runs.

    Each `submit` builds (or resumes) one run; each `step` advances the
    next unfinished run by ONE block and checkpoints it, so N concurrent
    runs interleave fairly regardless of their horizons.  All runs of
    the same spec share compiled scans through their own `Experiment`
    cache; the service itself holds no state outside `self.runs` and the
    checkpoint root, so it is trivially restartable.
    """

    def __init__(self, root: str, *, mesh=None):
        self.root = str(root)
        self.mesh = mesh
        self.runs: "dict[str, ServiceRun]" = {}
        self._order: "list[str]" = []
        self._cursor = 0

    # ------------------------------------------------------------ submission
    def submit(self, spec: "ExperimentSpec | dict", x_stack, y_stack,
               iterations: int, *, run_id: Optional[str] = None,
               n_realizations: Optional[int] = None,
               eval_fn: Optional[Callable] = None, eval_every: int = 10,
               nodes=None, rng=None) -> ServiceRun:
        """Register a run; auto-resumes from ``root/<run_id>/`` when a
        checkpoint already exists there (validating spec provenance).

        ``run_id`` defaults to ``spec.run_id``, then to ``run<k>``; it
        names the checkpoint directory, so resubmitting the same id
        after a kill is exactly how a run is recovered.
        """
        from repro.api import build_experiment
        if isinstance(spec, dict):
            spec = ExperimentSpec.from_dict(spec)
        rid = run_id or spec.run_id or f"run{len(self.runs)}"
        if rid in self.runs:
            raise ValueError(f"run_id {rid!r} already submitted")
        if spec.checkpoint_every <= 0:
            raise ValueError(
                f"run {rid!r}: service jobs need spec.checkpoint_every > 0 "
                "(a whole-horizon block would starve the other runs)")
        exp = build_experiment(spec, x_stack, y_stack, nodes=nodes, rng=rng,
                               mesh=self.mesh)
        ckpt_dir = os.path.join(self.root, rid)
        state = None
        resumed = False
        latest = ckpt_io.latest_checkpoint(ckpt_dir)
        if latest is not None:
            state = exp.restore_state(latest)
            if state.iterations != int(iterations) or (
                    (state.n_realizations or None)
                    != (int(n_realizations) if n_realizations else None)):
                raise ValueError(
                    f"run {rid!r}: checkpoint {latest!r} does not match the "
                    f"submitted horizon ({state.iterations} rounds x "
                    f"{state.n_realizations} realizations vs {iterations} "
                    f"x {n_realizations})")
            resumed = True
        if state is None:
            state = exp.init_state(iterations,
                                   n_realizations=n_realizations,
                                   collect=eval_fn is not None)
        run = ServiceRun(run_id=rid, spec=spec, exp=exp, state=state,
                         ckpt_dir=ckpt_dir, eval_fn=eval_fn,
                         eval_every=eval_every, resumed=resumed)
        self.runs[rid] = run
        self._order.append(rid)
        if state.done:   # resumed a run that was already finished
            run.result = exp.finish(state, eval_fn)
        return run

    # ------------------------------------------------------------ scheduling
    @property
    def pending(self) -> "list[str]":
        return [rid for rid in self._order if not self.runs[rid].done]

    def step(self) -> Optional[str]:
        """Advance the next unfinished run by one block, checkpoint it,
        and finish it if that block completed the run.  Returns the
        run_id advanced, or None when everything is done."""
        pending = self.pending
        if not pending:
            return None
        rid = pending[self._cursor % len(pending)]
        self._cursor += 1
        run = self.runs[rid]
        run.state = run.exp.run_block(run.state, eval_fn=run.eval_fn,
                                      eval_every=run.eval_every)
        run.exp.save_state(
            os.path.join(run.ckpt_dir,
                         f"{ckpt_io.CKPT_PREFIX}"
                         f"{run.state.rounds_done:06d}.npz"),
            run.state)
        if run.state.done:
            run.result = run.exp.finish(run.state, run.eval_fn)
        return rid

    def run_until_complete(self) -> dict:
        """Drive every submitted run to completion; {run_id: result}."""
        while self.step() is not None:
            pass
        return {rid: self.runs[rid].result for rid in self._order}
