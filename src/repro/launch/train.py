"""Training driver.

Two modes:
  * plain      — single-host (reduced-config) LM training on synthetic
                 tokens; used by smoke tests and examples.
  * federated  — CodedFedL-style deadline aggregation generalized to deep
                 models: each data-parallel shard is a simulated MEC client
                 with the paper's delay model; gradients that miss the
                 optimized deadline t* are dropped and the survivors are
                 reweighted by 1/P(T_j <= t*) (unbiasedness logic of
                 §III-E applied at the gradient-aggregation layer — see
                 DESIGN.md §4 for why the parity-coded gradient itself is
                 linear-model-only).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.core import load_allocation
from repro.core.delay_model import mec_network, packet_bits, scale_tau
from repro.data.pipeline import PackedLMDataset, PipelineConfig
from repro.models.model_zoo import build
from repro.optim import optimizers
from repro.optim.schedule import cosine


def make_batch(cfg, batch: int, seq: int, seed: int, shard_id: int = 0):
    """Training batch from the packed-LM pipeline (+ modality stubs)."""
    rng = np.random.default_rng(seed)
    out = {}
    ntok = seq
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    elif cfg.n_prefix_patches:
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_prefix_patches, cfg.d_model)),
            jnp.dtype(cfg.dtype))
        ntok = seq - cfg.n_prefix_patches
    ds = PackedLMDataset(PipelineConfig(
        vocab=cfg.vocab, seq_len=ntok, batch=batch, seed=seed * 1000003,
        n_shards=max(shard_id + 1, 1), shard_id=shard_id))
    b = ds.batch_at(0)
    out["tokens"] = jnp.asarray(b["tokens"])
    out["labels"] = jnp.asarray(b["labels"])
    return out


def train(cfg, steps: int = 20, batch: int = 4, seq: int = 64,
          lr: float = 3e-3, optimizer: str = "adam", *,
          federated: bool = False, fl_cfg: FLConfig | None = None,
          log_every: int = 5, seed: int = 0):
    """Returns (params, losses, wall_clock_sim)."""
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    opt_init, opt_update = optimizers.get(optimizer)
    opt_state = opt_init(params)
    lr_fn = cosine(lr, steps, warmup=min(10, steps // 10))

    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: model.loss_fn(p, b, remat=False)))

    # federated setup: n simulated clients, one delay node each
    sim_wall = 0.0
    if federated:
        fl = fl_cfg or FLConfig(n_clients=8)
        n_param = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
        nodes = [scale_tau(nd, packet_bits(fl, int(n_param)))
                 for nd in mec_network(fl, d_scalars_per_point=seq * 4)]
        alloc = load_allocation.two_step_allocate(
            nodes, [float(batch)] * fl.n_clients, server=None,
            u_max=0.25 * batch * fl.n_clients,
            m=float(batch * fl.n_clients))
        t_star = alloc.t_star
        p_ret = np.array([nd.cdf(t_star, float(l))
                          for nd, l in zip(nodes, alloc.loads)])
        rng = np.random.default_rng(seed + 5)

    losses = []
    for step in range(steps):
        if not federated:
            b = make_batch(cfg, batch, seq, seed + step)
            loss, grads = grad_fn(params, b)
        else:
            # every client computes a gradient on its shard; stragglers drop
            total, got, loss_acc = None, 0, 0.0
            for j in range(fl.n_clients):
                t_j = nodes[j].sample(rng, float(alloc.loads[j]))[0]
                if t_j > t_star:
                    continue
                b = make_batch(cfg, batch, seq, seed + step * 131 + j)
                loss_j, g_j = grad_fn(params, b)
                w = 1.0 / max(p_ret[j], 1e-3)      # expected-return reweight
                g_j = jax.tree_util.tree_map(lambda g: g * w, g_j)
                total = g_j if total is None else jax.tree_util.tree_map(
                    jnp.add, total, g_j)
                loss_acc += float(loss_j)
                got += 1
            sim_wall += t_star
            if total is None:
                losses.append(float("nan"))
                continue
            grads = jax.tree_util.tree_map(lambda g: g / fl.n_clients, total)
            loss = loss_acc / max(got, 1)
        params, opt_state = opt_update(params, grads, opt_state, lr_fn(step))
        losses.append(float(loss))
        if log_every and step % log_every == 0:
            print(f"step {step:4d} loss {float(loss):.4f}")
    return params, losses, sim_wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--federated", action="store_true")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-smoke) config — not for CPU")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_variant(cfg)
    t0 = time.time()
    _, losses, sim_wall = train(cfg, steps=args.steps, batch=args.batch,
                                seq=args.seq, federated=args.federated)
    print(f"final loss {losses[-1]:.4f}  ({time.time() - t0:.1f}s"
          + (f", simulated FL wall-clock {sim_wall:.1f}s" if args.federated
               else "") + ")")


if __name__ == "__main__":
    main()
