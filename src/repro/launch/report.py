"""Run-report surface over the `repro.obs` telemetry artifacts.

A run directory (``root/<run_id>/``) accumulates up to three telemetry
files next to its checkpoints:

  * ``events.jsonl``     — the per-round run journal (`repro.obs.events`)
  * ``spans.json``       — span-timer totals (`repro.obs.spans.write_json`)
  * ``attribution.json`` — straggler attribution (`Attribution.to_dict`)

`render_report` turns whatever subset is present into the text report the
``benchmarks/obs_report.py`` CLI prints (round table, span breakdown, top
stragglers).  `run_telemetry` is the benchmark probe behind the
schema-v9 ``telemetry`` section of ``BENCH_fed_training.json``: it pins
the subsystem's hard invariants (telemetry-on trajectories bit-identical
to telemetry-off, journal byte-deterministic per (spec, seed), journal
replay reconstructing `FedResult.history` exactly) and measures the
enabled-vs-disabled overhead ratio, which `validate_telemetry` enforces
below `MAX_OVERHEAD_RATIO`.

Usage (CLI lives in benchmarks/obs_report.py):
  PYTHONPATH=src python -m benchmarks.obs_report --smoke --validate \
      --out-dir obs_smoke
  PYTHONPATH=src python -m benchmarks.obs_report --report runs/myrun
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.obs import spans as obs_spans
from repro.obs.events import histories_equal, history_from_journal, load_events

__all__ = ["render_report", "run_telemetry", "validate_telemetry",
           "ATTR_NAME", "MAX_OVERHEAD_RATIO", "REQUIRED_SPANS"]

#: attribution filename inside a run directory
ATTR_NAME = "attribution.json"

#: validator ceiling on the enabled/disabled wall-clock ratio at smoke
#: scale (the compute-dominated default probe size)
MAX_OVERHEAD_RATIO = 1.05

#: span names every telemetry probe run must record (the probe runs the
#: coded scheme end to end: setup, solve, encode, compile, execute,
#: journal)
REQUIRED_SPANS = ("setup/experiment", "solver/two_step", "encode/parity",
                  "scan/compile", "scan/execute", "journal/append")


# ------------------------------------------------------------- rendering
def _fmt_row(cols, widths) -> str:
    return "  ".join(str(c).rjust(w) for c, w in zip(cols, widths))


def render_report(run_dir: str, *, top: int = 5, max_rounds: int = 12) -> str:
    """Text run report from a run directory's telemetry artifacts.

    Sections appear for whichever artifacts exist: the round table and
    summary need ``events.jsonl``; the span breakdown ``spans.json``; the
    top-straggler table ``attribution.json``.  ``max_rounds`` bounds the
    round table (head + tail around an ellipsis).
    """
    lines = [f"run report: {run_dir}"]
    try:
        events = load_events(run_dir)
    except FileNotFoundError:
        events = None
    if events:
        lines.append(f"\nrounds journaled: {len(events)}")
        header = ("round", "t_round_s", "wall_s", "ret", "mask",
                  "skip", "lr_scale", "loss")
        rows = []
        for e in events:
            loss = e.get("loss")
            rows.append((e["round"], f"{e['t_round_s']:.4f}",
                         f"{e['wall_clock_s']:.3f}", e["returned"],
                         e["n_masked"], e["skipped"],
                         f"{e['lr_scale']:.3g}",
                         "-" if loss is None else f"{loss:.5f}"))
        if len(rows) > max_rounds:
            head = rows[:max_rounds - max_rounds // 2]
            tail = rows[len(rows) - max_rounds // 2:]
            rows = head + [("...",) * len(header)] + tail
        widths = [max(len(str(header[i])),
                      *(len(str(r[i])) for r in rows))
                  for i in range(len(header))]
        lines.append(_fmt_row(header, widths))
        lines.extend(_fmt_row(r, widths) for r in rows)
        lines.append(
            f"total simulated wall clock: "
            f"{events[-1]['wall_clock_s']:.3f} s | "
            f"mean returned: "
            f"{np.mean([e['returned'] for e in events]):.2f} | "
            f"rounds degraded: "
            f"{sum(e['n_masked'] > 0 for e in events)} | "
            f"rounds skipped: {sum(e['skipped'] for e in events)}")
        if "t_star_s" in events[-1]:
            stars = ", ".join(f"{t:.4f}" for t in events[-1]["t_star_s"])
            lines.append(f"per-shard deadlines t*_s: [{stars}]")
    else:
        lines.append("\n(no events.jsonl — run with journal_dir= or "
                     "through an enabled ExperimentService)")
    spans_path = os.path.join(run_dir, obs_spans.SPANS_NAME)
    if os.path.exists(spans_path):
        with open(spans_path) as fh:
            totals = json.load(fh)
        lines.append("\nspan breakdown:")
        header = ("span", "count", "total_s", "mean_s", "max_s")
        rows = [(name, rec["count"], f"{rec['total_s']:.4f}",
                 f"{rec['total_s'] / max(rec['count'], 1):.4f}",
                 f"{rec['max_s']:.4f}")
                for name, rec in sorted(
                    totals.items(),
                    key=lambda kv: -kv[1]["total_s"])]
        widths = [max(len(str(header[i])),
                      *(len(str(r[i])) for r in rows)) if rows else
                  len(str(header[i])) for i in range(len(header))]
        lines.append(_fmt_row(header, widths))
        lines.extend(_fmt_row(r, widths) for r in rows)
    attr_path = os.path.join(run_dir, ATTR_NAME)
    if os.path.exists(attr_path):
        with open(attr_path) as fh:
            attr = json.load(fh)
        # one flat dict, or {shard: dict} from the hierarchical tier
        shards = (attr if "miss_rate" not in attr else {"": attr})
        for label, a in shards.items():
            title = "top stragglers" + (f" (shard {label})" if label else "")
            lines.append(f"\n{title} (k={a['k']}, {a['rounds']} rounds):")
            header = ("client", "miss_rate", "missed", "active", "slowest_k")
            rows = [(j, f"{r:.3f}", a["miss_counts"][j],
                     a["active_rounds"][j], a["slowest_k_counts"][j])
                    for j, r in a["top_stragglers"][:top]]
            widths = [max(len(str(header[i])),
                          *(len(str(r[i])) for r in rows)) if rows else
                      len(str(header[i])) for i in range(len(header))]
            lines.append(_fmt_row(header, widths))
            lines.extend(_fmt_row(r, widths) for r in rows)
            if a.get("comp_share_mean") is not None:
                lines.append(f"mean coded-compensation share: "
                             f"{a['comp_share_mean']:.3f}")
    return "\n".join(lines) + "\n"


# ------------------------------------------------------------- benchmark
def run_telemetry(kernel_backend: str = "xla", n_clients: int = 12,
                  l: int = 256, q: int = 256, c: int = 8, iters: int = 120,
                  block: int = 40, repeats: int = 3, seed: int = 0) -> dict:
    """The schema-v9 ``telemetry`` section: invariants + overhead.

    Runs the coded scheme at a compute-dominated size and records

      * ``trajectory_bit_identical`` — a telemetry-on run (spans +
        journal) reproduces the telemetry-off trajectory bit-for-bit;
      * ``journal_deterministic`` — two fresh same-(spec, seed) runs
        write byte-identical ``events.jsonl``;
      * ``journal_replay_matches`` — `history_from_journal` reconstructs
        the run's `FedResult.history` exactly;
      * ``overhead_ratio`` — min-of-``repeats`` warm wall-clock of the
        telemetry-on run over the telemetry-off run, interleaved so host
        noise hits both alike.  The default size keeps per-round compute
        dominant; at toy sizes the ratio measures journal I/O against
        nothing and the validator ceiling is meaningless (tests override
        it).

    Restores the caller's span-enable flag on exit.
    """
    from repro.config import ExperimentSpec, FLConfig, TrainConfig
    from repro.core.fed_runtime import Experiment

    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n_clients, l, c)).astype(np.float32)
    spec = ExperimentSpec(
        fl=FLConfig(n_clients=n_clients, delta=0.2, psi=0.2, seed=seed),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                          lr_decay_epochs=(max(1, iters // 2),)),
        scheme="coded", kernel_backend=kernel_backend,
        checkpoint_every=block)

    def build():
        return Experiment(spec, xs, ys)

    prev_enabled = obs_spans.enabled()
    try:
        obs_spans.disable()
        exp_off = build()
        res_off = exp_off.run(iters)       # compiles + reference trajectory

        obs_spans.reset()
        obs_spans.enable()
        with tempfile.TemporaryDirectory() as tmp:
            exp_on = build()
            res_on = exp_on.run(iters, journal_dir=f"{tmp}/j1")
            exp_on2 = build()
            exp_on2.run(iters, journal_dir=f"{tmp}/j2")
            with open(f"{tmp}/j1/events.jsonl", "rb") as fh:
                j1 = fh.read()
            with open(f"{tmp}/j2/events.jsonl", "rb") as fh:
                j2 = fh.read()
            journal_deterministic = j1 == j2
            replay_matches = histories_equal(
                history_from_journal(f"{tmp}/j1"), res_on.history)
            bit_identical = bool(np.array_equal(np.asarray(res_off.theta),
                                                np.asarray(res_on.theta)))
            # warm interleaved timing: fresh init_state per call, cached
            # compiled scans; each enabled run journals to a fresh dir so
            # journal I/O (part of telemetry's cost) is in the numerator
            t_off = t_on = float("inf")
            for r in range(repeats):
                obs_spans.disable()
                t0 = time.perf_counter()
                exp_off.run(iters)
                t_off = min(t_off, time.perf_counter() - t0)
                obs_spans.enable()
                t0 = time.perf_counter()
                exp_on.run(iters, journal_dir=f"{tmp}/t{r}")
                t_on = min(t_on, time.perf_counter() - t0)
        span_totals = obs_spans.totals()
    finally:
        (obs_spans.enable if prev_enabled else obs_spans.disable)()

    return {
        "config": {"n_clients": n_clients, "l": l, "q": q, "c": c,
                   "iters": iters, "block_rounds": block,
                   "repeats": repeats, "seed": seed,
                   "kernel_backend": kernel_backend},
        "trajectory_bit_identical": bit_identical,
        "journal_deterministic": bool(journal_deterministic),
        "journal_replay_matches": bool(replay_matches),
        "disabled_seconds": float(t_off),
        "enabled_seconds": float(t_on),
        "overhead_ratio": float(t_on / t_off),
        "span_totals": span_totals,
    }


def validate_telemetry(section, *,
                       max_overhead_ratio: float = MAX_OVERHEAD_RATIO
                       ) -> "list[str]":
    """Problems with a ``telemetry`` section (empty list == valid).

    Enforces the three boolean invariants, finite positive timings, the
    overhead ceiling (``max_overhead_ratio``, overridable for toy-scale
    test fixtures where journal I/O is not amortized), and presence of
    every `REQUIRED_SPANS` name in the span totals.
    """
    errs = []
    if not isinstance(section, dict):
        return [f"telemetry: must be a dict, got {type(section).__name__}"]
    for flag in ("trajectory_bit_identical", "journal_deterministic",
                 "journal_replay_matches"):
        if section.get(flag) is not True:
            errs.append(f"telemetry/{flag}: must be True, "
                        f"got {section.get(flag)!r}")
    for field in ("disabled_seconds", "enabled_seconds", "overhead_ratio"):
        val = section.get(field)
        if not isinstance(val, (int, float)) or not np.isfinite(val) \
                or val <= 0:
            errs.append(f"telemetry/{field}: bad value {val!r}")
    ratio = section.get("overhead_ratio")
    if isinstance(ratio, (int, float)) and np.isfinite(ratio) \
            and ratio >= max_overhead_ratio:
        errs.append(f"telemetry/overhead_ratio: {ratio:.4f} >= "
                    f"ceiling {max_overhead_ratio}")
    totals = section.get("span_totals")
    if not isinstance(totals, dict):
        errs.append(f"telemetry/span_totals: missing ({totals!r})")
    else:
        for name in REQUIRED_SPANS:
            rec = totals.get(name)
            if not isinstance(rec, dict) or not isinstance(
                    rec.get("count"), int) or rec["count"] < 1:
                errs.append(f"telemetry/span_totals/{name}: missing or "
                            f"never recorded ({rec!r})")
                continue
            total = rec.get("total_s")
            if not isinstance(total, (int, float)) \
                    or not np.isfinite(total) or total < 0:
                errs.append(f"telemetry/span_totals/{name}/total_s: "
                            f"bad value {total!r}")
    return errs
