"""Resilience benchmark: coded degradation vs naive stall under faults.

The scheme grid (`repro.launch.bench`) and drift scenarios
(`repro.launch.scenarios`) bench the *healthy* system.  This runner
benches what `repro.faults` + the self-healing runtime add: the same
deployment run under client-fault profiles (non-finite gradient returns,
stale replays) with three variants per profile —

  * ``coded``            — guard on: masked faulty returns are absorbed
    by the global parity gradient (the CodedFedL aggregation already
    compensates missing client mass), so training *degrades gracefully*:
    the trajectory stays finite, ``health.returns_masked`` counts what
    was absorbed, and time-to-target barely moves.
  * ``naive`` (guard on) — faults are *detected and reported*: masked
    returns simply vanish from the average, so the run survives but
    pays for every lost contribution.
  * ``naive_unguarded``  — the ablation: with ``nonfinite_guard=False``
    a single NaN return poisons the round's gradient, the divergence
    guard skips round after round with lr backoff, and the run *stalls*
    (``rounds_skipped`` piles up, ``lr_scale`` collapses).

A second section exercises the self-healing service under infrastructure
faults: an injected crash-loop run must finish bit-identical to a
fault-free-infrastructure control (retries recompute the lost blocks),
and a ``bad_disk`` run restarted over its partially corrupted checkpoint
directory must fall back to the newest intact snapshot and still finish
bit-identical.

Results land in the ``resilience`` section of
``BENCH_fed_training.json`` (schema v7); `validate_resilience` enforces
the headline claims — coded degraded gracefully, naive (unguarded)
stalled, chaos recovery was bit-exact.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.api import build_experiment
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.faults import get_fault_profile

#: default fault grid: the pure non-finite profile and the harsher
#: stale+mixed-non-finite one (see repro.faults.profile.FAULT_PROFILES)
DEFAULT_FAULT_PROFILES = ("flaky_clients", "byzantine_lite")


def _tt(history, target: float) -> Optional[float]:
    """First simulated wall-clock at which the loss reaches `target`."""
    for h in history:
        if h.loss <= target:
            return float(h.wall_clock)
    return None


def _variant(result) -> dict:
    health = result.health
    return {
        "final_loss": float(result.history[-1].loss),
        "final_wall_clock": float(result.history[-1].wall_clock),
        "final_theta_finite": bool(np.all(np.isfinite(
            np.asarray(result.theta)))),
        "health": None if health is None else dataclasses.asdict(health),
    }


def run_resilience(n_clients: int = 10, l: int = 24, q: int = 32, c: int = 3,
                   iters: int = 40, delta: float = 0.25, psi: float = 0.3,
                   seed: int = 0, fault_profiles=DEFAULT_FAULT_PROFILES,
                   kernel_backend: str = "xla",
                   service_iters: int = 20, service_block: int = 4,
                   service_fault_seed: int = 5) -> dict:
    """Coded-vs-naive time-to-target under fault profiles + service chaos.

    Returns the ``resilience`` artifact section.  Data is the synthetic
    linear problem the drift scenarios use (known ground truth + noise),
    so the loss trajectory is a real convergence signal.  The
    time-to-target target is the worse of the two *guarded* finals
    (coded, naive), so both provably reach it; the unguarded naive run
    is excluded from the target — stalling out of reach is its result.
    """
    rng = np.random.default_rng(seed)
    theta_true = rng.normal(size=(q, c)).astype(np.float32)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.3
    ys = (np.einsum("nlq,qc->nlc", xs, theta_true)
          + 0.005 * rng.normal(size=(n_clients, l, c)).astype(np.float32))
    fl = FLConfig(n_clients=n_clients, delta=delta, psi=psi, seed=seed)
    tc = TrainConfig(learning_rate=1.0, l2_reg=0.0)

    def eval_fn(theta):
        pred = np.einsum("nlq,qc->nlc", xs, np.asarray(theta))
        return float(np.mean((pred - ys) ** 2)), 0.0

    def run_one(scheme, prof, guard=True):
        spec = ExperimentSpec(fl=fl, train=tc, scheme=scheme,
                              kernel_backend=kernel_backend,
                              fault_profile=prof, nonfinite_guard=guard)
        return build_experiment(spec, xs, ys).run(
            iters, eval_fn=eval_fn, eval_every=1)

    clean = run_one("coded", None)
    cases = {}
    for prof in fault_profiles:
        get_fault_profile(prof)     # fail loudly on an unknown name
        t0 = time.perf_counter()
        coded = run_one("coded", prof)
        naive = run_one("naive", prof)
        naive_raw = run_one("naive", prof, guard=False)
        host = time.perf_counter() - t0

        v_coded = _variant(coded)
        v_naive = _variant(naive)
        v_raw = _variant(naive_raw)
        # graceful degradation: faults were absorbed (masked > 0), not
        # skipped around, and the trajectory stayed finite
        v_coded["degraded_gracefully"] = bool(
            v_coded["final_theta_finite"]
            and v_coded["health"]["returns_masked"] > 0)
        v_naive["faults_detected"] = bool(
            v_naive["health"]["returns_masked"] > 0)
        # stall: the divergence guard kept skipping poisoned rounds and
        # backing the lr off — progress died while theta stayed finite
        v_raw["stalled"] = bool(
            v_raw["health"]["rounds_skipped"] > 0
            and v_raw["health"]["lr_scale"] < 1.0)

        target = max(v_coded["final_loss"], v_naive["final_loss"])
        v_coded["time_to_target"] = _tt(coded.history, target)
        v_naive["time_to_target"] = _tt(naive.history, target)
        v_raw["time_to_target"] = _tt(naive_raw.history, target)
        cases[prof] = {
            "fault_profile": prof,
            "target_loss": float(target),
            "clean_final_loss": float(clean.history[-1].loss),
            "coded": v_coded,
            "naive": v_naive,
            "naive_unguarded": v_raw,
            "coded_speedup_vs_naive": (
                None if not v_coded["time_to_target"]
                or not v_naive["time_to_target"]
                else float(v_naive["time_to_target"]
                           / v_coded["time_to_target"])),
            "host_seconds": float(host),
        }

    service = _run_service_chaos(kernel_backend=kernel_backend,
                                 iters=service_iters, block=service_block,
                                 fault_seed=service_fault_seed)
    return {
        "config": {
            "n_clients": n_clients, "l": l, "q": q, "c": c, "iters": iters,
            "delta": delta, "psi": psi, "seed": seed,
            "kernel_backend": kernel_backend,
            "fault_profiles": list(fault_profiles),
        },
        "cases": cases,
        "service": service,
    }


def _run_service_chaos(kernel_backend: str = "xla", n_clients: int = 8,
                       l: int = 24, q: int = 6, c: int = 3,
                       iters: int = 20, block: int = 4, seed: int = 3,
                       fault_seed: int = 5) -> dict:
    """Self-healing service under injected infrastructure faults.

    Three services over the same job: a fault-free control, a crash-loop
    chaos service (every crashed block is retried until it lands), and a
    bad-disk service whose checkpoint files are corrupted after writing
    — then *restarted*, forcing a fallback resume past the corrupt
    latest checkpoint.  Both fault paths must reproduce the control's
    final theta bit-exactly.
    """
    import tempfile

    from repro.checkpoint import io as ckpt_io
    from repro.launch.service import ExperimentService

    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.3
    theta_true = rng.normal(size=(q, c)).astype(np.float32)
    ys = (np.einsum("nlq,qc->nlc", xs, theta_true)
          + 0.005 * rng.normal(size=(n_clients, l, c))).astype(np.float32)
    base = ExperimentSpec(
        fl=FLConfig(n_clients=n_clients, seed=seed),
        train=TrainConfig(learning_rate=0.05), scheme="coded",
        kernel_backend=kernel_backend, checkpoint_every=block)

    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as root:
        ctrl = ExperimentService(f"{root}/control")
        ctrl.submit(base, xs, ys, iters, run_id="a")
        expect = ctrl.run_until_complete()["a"]

        crash_spec = dataclasses.replace(base, fault_profile="crash_loop")
        chaos = ExperimentService(f"{root}/crash", fault_seed=fault_seed,
                                  max_retries=10)
        chaos.submit(crash_spec, xs, ys, iters, run_id="a")
        crashed = chaos.run_until_complete()["a"]
        crash_health = chaos.last_health["a"]

        disk_spec = dataclasses.replace(base, fault_profile="bad_disk")
        disk = ExperimentService(f"{root}/disk", fault_seed=fault_seed)
        disk.submit(disk_spec, xs, ys, iters, run_id="a")
        disk.run_until_complete()
        latest_any = ckpt_io.latest_checkpoint(f"{root}/disk/a")
        latest_ok = ckpt_io.latest_checkpoint(f"{root}/disk/a",
                                              valid_only=True)
        disk2 = ExperimentService(f"{root}/disk")   # the restart
        rerun = disk2.submit(disk_spec, xs, ys, iters, run_id="a")
        recovered = disk2.run_until_complete()["a"]
    host = time.perf_counter() - t0

    def same(res):
        return bool(res is not None and np.array_equal(
            np.asarray(expect.theta), np.asarray(res.theta)))

    return {
        "iters": int(iters),
        "block_rounds": int(block),
        "crash_retries": int(crash_health["total_retries"]),
        "crash_quarantined": bool(crash_health["quarantined"]),
        "chaos_bit_identical": same(crashed),
        "ckpt_corruption_seen": bool(latest_any != latest_ok),
        "fallback_resume": bool(rerun.fallback_resume),
        "fallback_recovery_bit_identical": same(recovered),
        "host_seconds": float(host),
    }


def validate_resilience(section) -> list[str]:
    """Structural + headline check of a ``resilience`` section.

    Beyond shape, this enforces the claims the section exists to make:
    coded degraded gracefully (finite trajectory, faults absorbed),
    guarded naive detected the faults, unguarded naive stalled, and the
    chaos service recovered bit-identically from injected crashes and
    checkpoint corruption.
    """
    errs = []
    if not isinstance(section, dict):
        return [f"resilience section must be an object, "
                f"got {type(section).__name__}"]
    config = section.get("config")
    if not isinstance(config, dict) or not config.get("fault_profiles"):
        errs.append("resilience/config: missing or empty fault profiles")
    cases = section.get("cases")
    if not isinstance(cases, dict) or not cases:
        errs.append("resilience/cases: missing or empty")
        cases = {}
    for name, case in cases.items():
        if not isinstance(case, dict):
            errs.append(f"resilience/{name}: not an object")
            continue
        for variant in ("coded", "naive", "naive_unguarded"):
            entry = case.get(variant)
            if not isinstance(entry, dict):
                errs.append(f"resilience/{name}/{variant}: missing")
                continue
            val = entry.get("final_loss")
            if not isinstance(val, (int, float)) or not np.isfinite(val):
                errs.append(f"resilience/{name}/{variant}/final_loss: "
                            f"bad value {val!r}")
            if not isinstance(entry.get("health"), dict):
                errs.append(f"resilience/{name}/{variant}/health: missing")
        coded = case.get("coded") or {}
        raw = case.get("naive_unguarded") or {}
        if coded.get("degraded_gracefully") is not True:
            errs.append(f"resilience/{name}: coded did not degrade "
                        "gracefully (trajectory non-finite or no faults "
                        "absorbed)")
        if coded.get("time_to_target") is None:
            errs.append(f"resilience/{name}/coded/time_to_target: missing")
        if (case.get("naive") or {}).get("faults_detected") is not True:
            errs.append(f"resilience/{name}: guarded naive did not detect "
                        "the injected faults")
        if raw.get("stalled") is not True:
            errs.append(f"resilience/{name}: unguarded naive did not "
                        "stall (the ablation contrast is the point)")
    service = section.get("service")
    if not isinstance(service, dict):
        errs.append("resilience/service: missing")
        return errs
    if not (isinstance(service.get("crash_retries"), int)
            and service["crash_retries"] >= 1):
        errs.append(f"resilience/service/crash_retries: expected >= 1 "
                    f"injected crash, got {service.get('crash_retries')!r}")
    for flag in ("chaos_bit_identical", "ckpt_corruption_seen",
                 "fallback_resume", "fallback_recovery_bit_identical"):
        if service.get(flag) is not True:
            errs.append(f"resilience/service/{flag}: expected True, "
                        f"got {service.get(flag)!r}")
    return errs
