"""Scheme-comparison benchmark launcher (Fig. 4/5 trajectory artifact).

Runs EVERY registered straggler-mitigation scheme (repro.core.schemes —
coded / partial_coded / naive / greedy / ideal, plus anything registered
since) across a set of heterogeneity profiles and writes the
``BENCH_fed_training.json`` artifact so the repo's perf trajectory is
recorded run over run (CI asserts the artifact is written and well-formed).
The grid is enumerated from the scheme registry at run time, so a newly
registered scheme appears in the artifact automatically; coded-family
schemes additionally report their parity privacy leakage
(``privacy_eps_max_bits``, core/privacy.py eq. 62).

Engine: by default the whole (profile x realization) grid runs through the
compiled sweep engine (``repro.launch.sweep.run_sweep``) — ONE compiled
call per scheme instead of a Python loop of per-profile ``run_multi``
compilations.  ``engine="loop"`` keeps the looped path; with
``measure_loop=True`` (default in sweep mode) the loop is ALSO timed so the
artifact records the measured sweep speedup (``sweep.speedup``).

The ideal baseline is the deterministic lower bound for the FULL-LOAD
(naive/greedy) schemes: every client processes its full minibatch with no
stochastic compute tail and exactly one transmission per link direction, so
a round costs ``max_j (l / mu_j + tau_j^down + tau_j^up)`` simulated
seconds.  The coded scheme assigns *reduced* per-client loads (the parity
set substitutes for the rest), so it may legitimately finish below this
baseline — ``coded_overhead_vs_ideal`` < 1 means coding beat the full-load
floor, not a measurement error.

Profiles sweep the paper's §V-A geometric decay knobs (k1 = rate_decay for
link rates, k2 = mac_decay for MAC rates): ``uniform`` is a homogeneous
network, ``paper`` the §V-A operating point, ``extreme`` a heavier-tailed
straggler population.

Usage (CLI lives in benchmarks/bench_scheme_compare.py):
  PYTHONPATH=src python -m benchmarks.bench_scheme_compare --smoke \
      --out BENCH_fed_training.json
  PYTHONPATH=src python -m benchmarks.bench_scheme_compare \
      --validate BENCH_fed_training.json
"""
from __future__ import annotations

import datetime
import json
import time
from typing import Optional

import numpy as np

from repro.config import TrainConfig
from repro.core import schemes as schemes_registry
# re-exported names: the profile grid and the analytic round-time floor
# moved to repro.core.delay_model so ExperimentSpec.delay_profile can name
# profiles without importing the launch layer
from repro.core.delay_model import HETEROGENEITY_PROFILES  # noqa: F401
from repro.core.delay_model import ideal_round_time  # noqa: F401
from repro.launch import kernel_bench as kernel_bench_mod
from repro.launch import report as report_mod
from repro.launch import resilience as resilience_mod
from repro.launch import scale as scale_mod
from repro.launch import scenarios as scenarios_mod
from repro.launch import sweep as sweep_mod

SCHEMA_VERSION = 9
ARTIFACT_NAME = "BENCH_fed_training.json"
# core grid every artifact must cover; the live registry may add more
CORE_SCHEMES = ("coded", "naive", "greedy", "ideal")
#: grid-eligible registry snapshot at import — prefer
#: `schemes_registry.grid_names()` (adaptive schemes are benched by the
#: drift-scenario section, not the profile grid)
SCHEMES = schemes_registry.grid_names()


def _build_sims(xs, ys, profiles, fl_base, tc, kernel_backend, scheme_names,
                base_spec=None):
    """{scheme: {profile: Experiment}} — the per-deployment setup
    (load allocation, parity encode, delay network) both engines share."""
    return {scheme: sweep_mod._build_sims(xs, ys, profiles, tc, scheme,
                                          fl_base, kernel_backend, base_spec)
            for scheme in scheme_names}


def _run_loop(sims, iters, realizations):
    """Pre-sweep grid execution: one `run_multi` compilation + call per
    (scheme, profile).  Returns {profile: {scheme: (sim, multi, secs)}}."""
    out = {}
    for scheme, per_profile in sims.items():
        for pname, sim in per_profile.items():
            t0 = time.perf_counter()
            multi = sim.run_multi(iters, realizations)
            out.setdefault(pname, {})[scheme] = (
                sim, multi, time.perf_counter() - t0)
    return out


def run_schemes(n_clients: int = 12, l: int = 32, q: int = 64, c: int = 5,
                iters: int = 40, realizations: int = 6, delta: float = 0.2,
                psi: float = 0.2, seed: int = 0,
                profiles: Optional[dict] = None,
                kernel_backend: str = "xla",
                engine: str = "sweep",
                measure_loop: bool = True,
                scenario_kwargs: Optional[dict] = None,
                service_kwargs: Optional[dict] = None,
                kernel_kwargs: Optional[dict] = None,
                resilience_kwargs: Optional[dict] = None,
                scale_kwargs: Optional[dict] = None,
                telemetry_kwargs: Optional[dict] = None,
                base_spec=None) -> dict:
    """Run the scheme comparison over heterogeneity profiles.

    The scheme grid is the LIVE grid-eligible registry
    (`repro.core.schemes.grid_names`), so a newly registered scheme lands
    in the artifact without touching this module.  Returns the artifact
    dict (see `write_artifact` / `validate_artifact`).  Simulated
    wall-clocks come from the multi-realization scan (mean ± std over
    independent delay realizations); host timing depends on `engine`:
    "sweep" (default) compiles one (profile x realization) call per
    scheme and, with `measure_loop`, also times the looped per-profile
    path so the artifact records the measured speedup.

    Schema v4 additionally records a ``scenarios`` section — the
    static-vs-adaptive drift comparison (`repro.launch.scenarios`), keyed
    off `scenario_kwargs` (None -> that runner's defaults; pass
    ``{"skip": True}`` to omit the section, which fails validation and is
    only for partial reruns).  Schema v5 adds the ``service`` section
    (`run_service_bench`): the block-restructuring overhead of the
    RunState runtime vs the one-shot scan, plus the multiplexed
    kill/resume bit-identity check; `service_kwargs` follows the same
    None-defaults / ``{"skip": True}`` convention.  Schema v6 adds the
    ``kernels`` section (`repro.launch.kernel_bench.run_kernel_bench`):
    per-kernel microbenchmark timings including the fused-vs-two-pass
    embed->gradient ratio; `kernel_kwargs` follows the same convention.
    Schema v7 adds the ``resilience`` section
    (`repro.launch.resilience.run_resilience`): coded-vs-naive
    time-to-target under client-fault profiles plus the self-healing
    service chaos check; `resilience_kwargs` follows the same
    convention.  Schema v8 adds the ``scale`` section
    (`repro.launch.scale.run_scale`): the hierarchical-tier
    population-scaling curve (wall-clock/memory over the n ladder) plus
    the flat-routing identity check; `scale_kwargs` follows the same
    convention.  Schema v9 adds the ``telemetry`` section
    (`repro.launch.report.run_telemetry`): the `repro.obs` subsystem's
    invariants (telemetry-on trajectory bit-identity, journal
    determinism and replay) plus span totals and the enabled-vs-disabled
    overhead ratio; `telemetry_kwargs` follows the same convention.

    `base_spec` replays a full `ExperimentSpec` across the profile grid
    (see `run_sweep`).  Hierarchical/sampled specs are rejected here: the
    scheme-comparison grid is a flat-engine benchmark.
    """
    if engine not in ("sweep", "loop"):
        raise ValueError(f"unknown engine {engine!r}")
    if base_spec is not None and base_spec.hier_active:
        raise ValueError(
            "the scheme-comparison benchmark runs the flat engine over a "
            "small dense grid and has no edge-aggregator path; drop "
            f"hier_shards (got {base_spec.hier_shards}) / sample_fraction "
            f"(got {base_spec.sample_fraction}) from base_spec — the "
            "hierarchical tier is benched by the schema-v8 'scale' "
            "section (repro.launch.scale.run_scale / "
            "benchmarks/bench_hier_scale.py)")
    scheme_names = schemes_registry.grid_names()
    missing = set(CORE_SCHEMES) - set(scheme_names)
    if missing:
        raise RuntimeError(f"core scheme(s) unregistered: {sorted(missing)}")
    # coded-family columns of the grid (adaptive_coded is coded-family but
    # not grid-eligible — it reports under `scenarios` instead)
    coded_names = tuple(n for n in schemes_registry.coded_names()
                        if n in scheme_names)
    profiles = profiles if profiles is not None else HETEROGENEITY_PROFILES
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n_clients, l, c)).astype(np.float32)
    fl_base = dict(n_clients=n_clients, delta=delta, psi=psi, seed=seed)
    tc = TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                     lr_decay_epochs=(max(1, iters // 2),))

    t0 = time.perf_counter()
    sims = _build_sims(xs, ys, profiles, fl_base, tc, kernel_backend,
                       scheme_names, base_spec)
    setup_seconds = time.perf_counter() - t0

    sweep_info = None
    if engine == "sweep":
        # grid execution through the compiled sweep: ONE call per scheme
        t0 = time.perf_counter()
        sw = sweep_mod.run_sweep(
            xs, ys, profiles=profiles, train_cfg=tc, iterations=iters,
            realizations=realizations, schemes=scheme_names,
            fl_kwargs=fl_base, kernel_backend=kernel_backend, sims=sims,
            base_spec=base_spec)
        sweep_total = time.perf_counter() - t0
        loop_total = None
        if measure_loop:
            # the pre-sweep grid execution over the SAME deployments: one
            # run_multi compilation per (scheme, profile).  Results are
            # discarded (fresh delay draws); only the wall-clock matters.
            t0 = time.perf_counter()
            _run_loop(sims, iters, realizations)
            loop_total = time.perf_counter() - t0
        sweep_info = {
            "setup_host_seconds": float(setup_seconds),
            "host_seconds": float(sweep_total),
            "loop_host_seconds": (None if loop_total is None
                                  else float(loop_total)),
            "speedup": (None if loop_total is None
                        else float(loop_total / sweep_total)),
            "per_scheme_host_seconds": {
                s: float(t) for s, t in sw.host_seconds.items()},
        }
        # per-cell host cost: the scheme's ONE compiled grid call amortized
        # over its profiles, so the fed_compare_* metric series stays
        # comparable with the looped engine's per-cell run_multi timings
        per_profile = {
            pname: {scheme: (sw.sims[scheme][pname],
                             sw.results[scheme][pname],
                             sw.host_seconds[scheme] / len(profiles))
                    for scheme in scheme_names}
            for pname in profiles}
    else:
        per_profile = _run_loop(sims, iters, realizations)

    out_profiles = {}
    for pname, knobs in profiles.items():
        schemes = {}
        for scheme in scheme_names:
            sim, multi, host = per_profile[pname][scheme]
            mean, std = multi.wall_clock_bands()
            schemes[scheme] = {
                "final_wall_clock_mean": float(mean[-1]),
                "final_wall_clock_std": float(std[-1]),
                "per_round_mean": float(np.diff(
                    mean, prepend=sim.setup_time).mean()),
                "setup_time": float(sim.setup_time),
                "t_star": None if sim.t_star is None else float(sim.t_star),
                "returned_mean": float(np.asarray(multi.returned).mean()),
                "host_seconds": float(host),
            }
            if scheme in coded_names:
                schemes[scheme]["total_load"] = float(np.sum(sim.loads))
                # parity privacy leakage (paper Appendix F): worst-client
                # eps-MI-DP budget of the shared parity rows
                schemes[scheme]["privacy_eps_max_bits"] = float(
                    sim.privacy_eps)
        # the ideal scheme is runnable now (registry entry "ideal"); its
        # deterministic wall-clock is the full-load floor the overhead
        # metric is measured against
        ideal_final = schemes["ideal"]["final_wall_clock_mean"]
        naive_f = schemes["naive"]["final_wall_clock_mean"]
        coded_f = schemes["coded"]["final_wall_clock_mean"]
        out_profiles[pname] = {
            "knobs": dict(knobs),
            "schemes": schemes,
            "coded_speedup_vs_naive": float(naive_f / coded_f),
            "coded_overhead_vs_ideal": float(coded_f / ideal_final),
        }

    artifact = {
        "benchmark": "fed_training_scheme_compare",
        "schema_version": SCHEMA_VERSION,
        "generated": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "config": {
            "n_clients": n_clients, "l": l, "q": q, "c": c, "iters": iters,
            "realizations": realizations, "delta": delta, "psi": psi,
            "seed": seed, "kernel_backend": kernel_backend,
            "engine": engine,
            # schema v3: the registry-driven grid is recorded so the
            # validator checks exactly the schemes this run covered
            "schemes": list(scheme_names),
            "coded_schemes": list(coded_names),
        },
        "profiles": out_profiles,
    }
    if sweep_info is not None:
        artifact["sweep"] = sweep_info
    scenario_kwargs = dict(scenario_kwargs or {})
    if not scenario_kwargs.pop("skip", False):
        # schema v4: static-vs-adaptive time-to-target under drift
        artifact["scenarios"] = scenarios_mod.run_scenarios(
            kernel_backend=kernel_backend, **scenario_kwargs)
    service_kwargs = dict(service_kwargs or {})
    if not service_kwargs.pop("skip", False):
        # schema v5: RunState block-restructuring overhead + service resume
        artifact["service"] = run_service_bench(
            kernel_backend=kernel_backend, **service_kwargs)
    kernel_kwargs = dict(kernel_kwargs or {})
    if not kernel_kwargs.pop("skip", False):
        # schema v6: per-kernel microbenchmark timings + fused ratio
        kernel_kwargs.setdefault("kernel_backend", kernel_backend)
        artifact["kernels"] = kernel_bench_mod.run_kernel_bench(
            **kernel_kwargs)
    resilience_kwargs = dict(resilience_kwargs or {})
    if not resilience_kwargs.pop("skip", False):
        # schema v7: fault-injection degradation + service chaos recovery
        artifact["resilience"] = resilience_mod.run_resilience(
            kernel_backend=kernel_backend, **resilience_kwargs)
    scale_kwargs = dict(scale_kwargs or {})
    if not scale_kwargs.pop("skip", False):
        # schema v8: hierarchical-tier population-scaling curve
        artifact["scale"] = scale_mod.run_scale(**scale_kwargs)
    telemetry_kwargs = dict(telemetry_kwargs or {})
    if not telemetry_kwargs.pop("skip", False):
        # schema v9: repro.obs invariants + telemetry overhead ratio
        telemetry_kwargs.setdefault("kernel_backend", kernel_backend)
        artifact["telemetry"] = report_mod.run_telemetry(**telemetry_kwargs)
    return artifact


def run_service_bench(kernel_backend: str = "xla", n_clients: int = 10,
                      l: int = 256, q: int = 256, c: int = 8,
                      iters: int = 200, block: int = 50,
                      seed: int = 0) -> dict:
    """Measure the block-structured runtime against the one-shot scan.

    Times a warm (pre-compiled) whole-horizon run with
    ``checkpoint_every=0`` (one block == the historical single compiled
    call) against the same run cut into ``iters / block`` blocks — the
    recorded ``overhead_ratio`` is the price of block restructuring
    alone (no checkpoint I/O in either timing).  Then exercises the
    `repro.launch.service.ExperimentService` contract: three multiplexed
    runs, killed mid-flight and resumed by a fresh service from their
    checkpoints, must reproduce the uninterrupted results bit-exactly
    (``resumed_bit_identical``).

    The default problem size is deliberately large enough that per-round
    compute dominates per-block host dispatch; at toy sizes (e.g. the
    smoke scale) the ratio mostly measures dispatch latency instead.
    """
    import dataclasses
    import tempfile

    from repro.api import build_experiment
    from repro.config import ExperimentSpec, FLConfig
    from repro.launch.service import ExperimentService

    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n_clients, l, c)).astype(np.float32)
    fl = FLConfig(n_clients=n_clients, delta=0.2, psi=0.2, seed=seed)
    tc = TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                     lr_decay_epochs=(max(1, iters // 2),))
    oneshot_spec = ExperimentSpec(fl=fl, train=tc, scheme="coded",
                                  kernel_backend=kernel_backend,
                                  checkpoint_every=0)
    blocked_spec = dataclasses.replace(oneshot_spec, checkpoint_every=block)

    def timed(spec):
        exp = build_experiment(spec, xs, ys)
        exp.run(iters)                      # warm-up: compile the scan
        t0 = time.perf_counter()
        exp.run(iters)
        return time.perf_counter() - t0

    oneshot_seconds = timed(oneshot_spec)
    blocked_seconds = timed(blocked_spec)

    # multiplexed kill/resume round-trip over three heterogeneous jobs
    jobs = {
        "coded": blocked_spec,
        "greedy": dataclasses.replace(blocked_spec, scheme="greedy"),
        "adaptive": dataclasses.replace(
            blocked_spec, scheme="adaptive_coded",
            channel_profile="drift_churn", adapt_every=block),
    }
    with tempfile.TemporaryDirectory() as root:
        control = ExperimentService(f"{root}/control")
        for rid, spec in jobs.items():
            control.submit(spec, xs, ys, iters, run_id=rid)
        expect = control.run_until_complete()

        svc = ExperimentService(f"{root}/killed")
        for rid, spec in jobs.items():
            svc.submit(spec, xs, ys, iters, run_id=rid)
        for _ in range(len(jobs) + 1):
            svc.step()                      # partial progress, then "kill"
        del svc
        svc2 = ExperimentService(f"{root}/killed")
        for rid, spec in jobs.items():
            svc2.submit(spec, xs, ys, iters, run_id=rid)
        results = svc2.run_until_complete()
    identical = all(
        np.array_equal(np.asarray(expect[rid].theta),
                       np.asarray(results[rid].theta))
        for rid in jobs)

    return {
        "iters": int(iters),
        "block_rounds": int(block),
        "oneshot_seconds": float(oneshot_seconds),
        "blocked_seconds": float(blocked_seconds),
        "overhead_ratio": float(blocked_seconds / oneshot_seconds),
        "multiplexed_runs": len(jobs),
        "resumed_bit_identical": bool(identical),
    }


def write_artifact(result: dict, out_path: str = ARTIFACT_NAME) -> str:
    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return out_path


_SCHEME_FIELDS = ("final_wall_clock_mean", "final_wall_clock_std",
                  "per_round_mean", "setup_time", "returned_mean",
                  "host_seconds")


def validate_artifact(obj, *, scale_required_ns=None,
                      telemetry_max_ratio=None) -> list[str]:
    """Structural check of the BENCH_fed_training.json artifact (schema 9).

    `obj` is a dict or a path.  Returns a list of problems (empty == valid)
    rather than raising, so CI can print every issue at once.

    Schema v3 (registry-driven grid): ``config.schemes`` records the scheme
    list the run covered (must include the core coded/naive/greedy/ideal
    grid) and ``config.coded_schemes`` the coded-family subset; every
    profile must carry an entry per recorded scheme, and coded-family
    entries must report ``t_star``, ``total_load``, and the parity privacy
    leakage ``privacy_eps_max_bits``.  Schema v4 adds the required
    ``scenarios`` section (static-vs-adaptive drift comparison, validated
    by `repro.launch.scenarios.validate_scenarios`).  Schema v5 adds the
    required ``service`` section: finite positive block-vs-oneshot
    timings/ratio, >= 3 multiplexed runs, and the kill/resume bit-identity
    flag, which must be True (the timing ratio itself is recorded but not
    thresholded — host timing noise is not a correctness failure).
    Schema v6 adds the required ``kernels`` section (per-kernel
    microbenchmark timings incl. the fused-vs-two-pass ratio, validated
    by `repro.launch.kernel_bench.validate_kernels`; the regression
    threshold against a committed artifact is enforced separately by
    `kernel_bench.compare_kernels` in the CI kernel-bench job).
    Schema v7 adds the required ``resilience`` section (fault-injection
    degradation + service chaos recovery, validated by
    `repro.launch.resilience.validate_resilience` — which enforces the
    headline claims: coded degrades gracefully, unguarded naive stalls,
    chaos recovery is bit-identical).  Schema v8 adds the required
    ``scale`` section (hierarchical-tier population-scaling curve,
    validated by `repro.launch.scale.validate_scale` — which enforces the
    n ladder, the O(active cohort) memory contract, and the flat-routing
    identity).  ``scale_required_ns`` overrides the enforced ladder
    (default `scale.REQUIRED_NS`) for reduced-ladder artifacts, e.g. the
    tiny test fixture; the CLI/CI path always uses the strict default.
    Schema v9 adds the required ``telemetry`` section (`repro.obs`
    invariants + overhead, validated by
    `repro.launch.report.validate_telemetry` — bit-identity, journal
    determinism/replay, required span totals, and the overhead-ratio
    ceiling).  ``telemetry_max_ratio`` overrides that ceiling (default
    `report.MAX_OVERHEAD_RATIO`) for toy-scale artifacts where journal
    I/O is not amortized by compute, e.g. the tiny test fixture; the
    CLI/CI path always uses the strict default.
    """
    if isinstance(obj, str):
        try:
            with open(obj) as fh:
                obj = json.load(fh)
        except (OSError, json.JSONDecodeError) as exc:
            return [f"cannot load artifact: {exc}"]
    errs = []
    if not isinstance(obj, dict):
        return [f"artifact must be a JSON object, got {type(obj).__name__}"]
    if obj.get("benchmark") != "fed_training_scheme_compare":
        errs.append(f"bad benchmark id: {obj.get('benchmark')!r}")
    if obj.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"bad schema_version: {obj.get('schema_version')!r}")
    for key in ("generated", "config"):
        if key not in obj:
            errs.append(f"missing top-level key {key!r}")
    config = obj.get("config") if isinstance(obj.get("config"), dict) else {}
    scheme_list = config.get("schemes")
    if not isinstance(scheme_list, list) or not scheme_list:
        errs.append("config.schemes: missing/empty scheme list")
        scheme_list = list(CORE_SCHEMES)
    missing_core = set(CORE_SCHEMES) - set(scheme_list)
    if missing_core:
        errs.append(f"config.schemes: core scheme(s) absent "
                    f"{sorted(missing_core)}")
    coded_list = config.get("coded_schemes")
    if not isinstance(coded_list, list) or "coded" not in (coded_list or []):
        errs.append("config.coded_schemes: missing or lacks 'coded'")
        coded_list = ["coded"]
    if config.get("engine") == "sweep":
        sweep = obj.get("sweep")
        if not isinstance(sweep, dict):
            errs.append("sweep engine artifact missing 'sweep' section")
        else:
            if not _is_pos(sweep.get("host_seconds")):
                errs.append(
                    f"sweep/host_seconds: bad value "
                    f"{sweep.get('host_seconds')!r}")
            for field in ("loop_host_seconds", "speedup"):
                val = sweep.get(field)
                if val is not None and not _is_pos(val):
                    errs.append(f"sweep/{field}: bad value {val!r}")
    if "scenarios" not in obj:
        errs.append("schema v4 artifact missing 'scenarios' section")
    else:
        errs.extend(scenarios_mod.validate_scenarios(obj["scenarios"]))
    service = obj.get("service")
    if not isinstance(service, dict):
        errs.append("schema v5 artifact missing 'service' section")
    else:
        for field in ("oneshot_seconds", "blocked_seconds",
                      "overhead_ratio"):
            if not _is_pos(service.get(field)):
                errs.append(f"service/{field}: bad value "
                            f"{service.get(field)!r}")
        for field in ("iters", "block_rounds"):
            val = service.get(field)
            if not isinstance(val, int) or val < 1:
                errs.append(f"service/{field}: bad value {val!r}")
        runs = service.get("multiplexed_runs")
        if not isinstance(runs, int) or runs < 3:
            errs.append(f"service/multiplexed_runs: need an int >= 3, "
                        f"got {runs!r}")
        if service.get("resumed_bit_identical") is not True:
            errs.append("service/resumed_bit_identical: kill/resume was "
                        "not bit-identical "
                        f"({service.get('resumed_bit_identical')!r})")
    if "kernels" not in obj:
        errs.append("schema v6 artifact missing 'kernels' section")
    else:
        errs.extend(kernel_bench_mod.validate_kernels(obj["kernels"]))
    if "resilience" not in obj:
        errs.append("schema v7 artifact missing 'resilience' section")
    else:
        errs.extend(resilience_mod.validate_resilience(obj["resilience"]))
    if "scale" not in obj:
        errs.append("schema v8 artifact missing 'scale' section")
    else:
        errs.extend(scale_mod.validate_scale(
            obj["scale"],
            required_ns=(scale_mod.REQUIRED_NS if scale_required_ns is None
                         else scale_required_ns)))
    if "telemetry" not in obj:
        errs.append("schema v9 artifact missing 'telemetry' section")
    else:
        errs.extend(report_mod.validate_telemetry(
            obj["telemetry"],
            max_overhead_ratio=(report_mod.MAX_OVERHEAD_RATIO
                                if telemetry_max_ratio is None
                                else telemetry_max_ratio)))
    profiles = obj.get("profiles")
    if not isinstance(profiles, dict) or not profiles:
        return errs + ["missing/empty 'profiles'"]
    for pname, prof in profiles.items():
        schemes = prof.get("schemes", {})
        for scheme in scheme_list:
            entry = schemes.get(scheme)
            if not isinstance(entry, dict):
                errs.append(f"{pname}: missing scheme {scheme!r}")
                continue
            for field in _SCHEME_FIELDS:
                val = entry.get(field)
                if not isinstance(val, (int, float)) or not np.isfinite(val) \
                        or val < 0:
                    errs.append(f"{pname}/{scheme}/{field}: bad value {val!r}")
            if scheme in coded_list:
                if not _is_pos(entry.get("t_star")):
                    errs.append(f"{pname}/{scheme}: t_star missing")
                for field in ("total_load", "privacy_eps_max_bits"):
                    if not _is_pos(entry.get(field)):
                        errs.append(f"{pname}/{scheme}/{field}: bad value "
                                    f"{entry.get(field)!r}")
        for field in ("coded_speedup_vs_naive", "coded_overhead_vs_ideal"):
            val = prof.get(field)
            if not isinstance(val, (int, float)) or not np.isfinite(val) \
                    or val <= 0:
                errs.append(f"{pname}/{field}: bad value {val!r}")
    return errs


def _is_pos(val) -> bool:
    return isinstance(val, (int, float)) and np.isfinite(val) and val > 0
