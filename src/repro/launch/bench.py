"""Scheme-comparison benchmark launcher (Fig. 4/5 trajectory artifact).

Runs coded / naive-uncoded / greedy-uncoded under the batched engine's
multi-realization mode (`FederatedSimulation.run_multi`) across a set of
heterogeneity profiles, adds an analytic *ideal-no-straggler* baseline, and
writes the ``BENCH_fed_training.json`` artifact so the repo's perf
trajectory is recorded run over run (CI asserts the artifact is written and
well-formed).

The ideal baseline is the deterministic lower bound for the FULL-LOAD
(naive/greedy) schemes: every client processes its full minibatch with no
stochastic compute tail and exactly one transmission per link direction, so
a round costs ``max_j (l / mu_j + tau_j^down + tau_j^up)`` simulated
seconds.  The coded scheme assigns *reduced* per-client loads (the parity
set substitutes for the rest), so it may legitimately finish below this
baseline — ``coded_overhead_vs_ideal`` < 1 means coding beat the full-load
floor, not a measurement error.

Profiles sweep the paper's §V-A geometric decay knobs (k1 = rate_decay for
link rates, k2 = mac_decay for MAC rates): ``uniform`` is a homogeneous
network, ``paper`` the §V-A operating point, ``extreme`` a heavier-tailed
straggler population.

Usage (CLI lives in benchmarks/bench_scheme_compare.py):
  PYTHONPATH=src python -m benchmarks.bench_scheme_compare --smoke \
      --out BENCH_fed_training.json
  PYTHONPATH=src python -m benchmarks.bench_scheme_compare \
      --validate BENCH_fed_training.json
"""
from __future__ import annotations

import datetime
import json
import time
from typing import Optional

import numpy as np

from repro.config import FLConfig, TrainConfig
from repro.core import fed_runtime
from repro.core.delay_model import stack_node_params

SCHEMA_VERSION = 1
ARTIFACT_NAME = "BENCH_fed_training.json"
SCHEMES = ("coded", "naive", "greedy")

# Paper §V-A heterogeneity knobs: effective link rates decay as k1^i and MAC
# rates as k2^i over clients (random permutation), so smaller factors mean a
# heavier straggler tail.
HETEROGENEITY_PROFILES = {
    "uniform": dict(rate_decay=1.0, mac_decay=1.0),
    "paper": dict(rate_decay=0.95, mac_decay=0.8),
    "extreme": dict(rate_decay=0.9, mac_decay=0.6),
}


def ideal_round_time(nodes, l: float) -> float:
    """Deterministic no-straggler round time (seconds).

    One transmission per direction, deterministic compute, full load l on
    every client — the floor for the full-load (naive/greedy) schemes.
    """
    prm = stack_node_params(nodes)
    return float(np.max(l / prm["mu"] + prm["tau_down"] + prm["tau_up"]))


def run_schemes(n_clients: int = 12, l: int = 32, q: int = 64, c: int = 5,
                iters: int = 40, realizations: int = 6, delta: float = 0.2,
                psi: float = 0.2, seed: int = 0,
                profiles: Optional[dict] = None,
                kernel_backend: str = "xla") -> dict:
    """Run the scheme comparison over heterogeneity profiles.

    Returns the artifact dict (see `write_artifact` / `validate_artifact`).
    Simulated wall-clocks come from `run_multi` (mean ± std over independent
    delay realizations); host_seconds is the host-side cost of that one
    compiled multi-realization call.
    """
    profiles = profiles if profiles is not None else HETEROGENEITY_PROFILES
    rng = np.random.default_rng(seed)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.2
    ys = rng.normal(size=(n_clients, l, c)).astype(np.float32)

    out_profiles = {}
    for pname, knobs in profiles.items():
        fl = FLConfig(n_clients=n_clients, delta=delta, psi=psi, seed=seed,
                      **knobs)
        tc = TrainConfig(learning_rate=0.5, l2_reg=1e-5,
                         lr_decay_epochs=(max(1, iters // 2),))
        schemes = {}
        nodes = None
        for scheme in SCHEMES:
            sim = fed_runtime.FederatedSimulation(
                xs, ys, fl, tc, scheme=scheme,
                kernel_backend=kernel_backend)
            if nodes is None:
                # the delay network depends only on fl, not on the scheme
                nodes = sim.nodes
            t0 = time.perf_counter()
            multi = sim.run_multi(iters, realizations)
            host = time.perf_counter() - t0
            mean, std = multi.wall_clock_bands()
            schemes[scheme] = {
                "final_wall_clock_mean": float(mean[-1]),
                "final_wall_clock_std": float(std[-1]),
                "per_round_mean": float(np.diff(
                    mean, prepend=sim.setup_time).mean()),
                "setup_time": float(sim.setup_time),
                "t_star": None if sim.t_star is None else float(sim.t_star),
                "returned_mean": float(np.asarray(multi.returned).mean()),
                "host_seconds": float(host),
            }
            if scheme == "coded":
                schemes[scheme]["total_load"] = float(np.sum(sim.loads))
        ideal_final = ideal_round_time(nodes, float(l)) * iters
        schemes["ideal"] = {
            "final_wall_clock_mean": float(ideal_final),
            "final_wall_clock_std": 0.0,
            "per_round_mean": float(ideal_final / iters),
            "setup_time": 0.0,
            "t_star": None,
            "returned_mean": float(n_clients),
            "host_seconds": 0.0,
        }
        naive_f = schemes["naive"]["final_wall_clock_mean"]
        coded_f = schemes["coded"]["final_wall_clock_mean"]
        out_profiles[pname] = {
            "knobs": dict(knobs),
            "schemes": schemes,
            "coded_speedup_vs_naive": float(naive_f / coded_f),
            "coded_overhead_vs_ideal": float(coded_f / ideal_final),
        }

    return {
        "benchmark": "fed_training_scheme_compare",
        "schema_version": SCHEMA_VERSION,
        "generated": datetime.datetime.now(
            datetime.timezone.utc).isoformat(),
        "config": {
            "n_clients": n_clients, "l": l, "q": q, "c": c, "iters": iters,
            "realizations": realizations, "delta": delta, "psi": psi,
            "seed": seed, "kernel_backend": kernel_backend,
        },
        "profiles": out_profiles,
    }


def write_artifact(result: dict, out_path: str = ARTIFACT_NAME) -> str:
    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return out_path


_SCHEME_FIELDS = ("final_wall_clock_mean", "final_wall_clock_std",
                  "per_round_mean", "setup_time", "returned_mean",
                  "host_seconds")


def validate_artifact(obj) -> list[str]:
    """Structural check of the BENCH_fed_training.json artifact.

    `obj` is a dict or a path.  Returns a list of problems (empty == valid)
    rather than raising, so CI can print every issue at once.
    """
    if isinstance(obj, str):
        try:
            with open(obj) as fh:
                obj = json.load(fh)
        except (OSError, json.JSONDecodeError) as exc:
            return [f"cannot load artifact: {exc}"]
    errs = []
    if not isinstance(obj, dict):
        return [f"artifact must be a JSON object, got {type(obj).__name__}"]
    if obj.get("benchmark") != "fed_training_scheme_compare":
        errs.append(f"bad benchmark id: {obj.get('benchmark')!r}")
    if obj.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"bad schema_version: {obj.get('schema_version')!r}")
    for key in ("generated", "config"):
        if key not in obj:
            errs.append(f"missing top-level key {key!r}")
    profiles = obj.get("profiles")
    if not isinstance(profiles, dict) or not profiles:
        return errs + ["missing/empty 'profiles'"]
    for pname, prof in profiles.items():
        schemes = prof.get("schemes", {})
        for scheme in SCHEMES + ("ideal",):
            entry = schemes.get(scheme)
            if not isinstance(entry, dict):
                errs.append(f"{pname}: missing scheme {scheme!r}")
                continue
            for field in _SCHEME_FIELDS:
                val = entry.get(field)
                if not isinstance(val, (int, float)) or not np.isfinite(val) \
                        or val < 0:
                    errs.append(f"{pname}/{scheme}/{field}: bad value {val!r}")
        if isinstance(schemes.get("coded"), dict) and \
                schemes["coded"].get("t_star") in (None, 0):
            errs.append(f"{pname}/coded: t_star missing")
        for field in ("coded_speedup_vs_naive", "coded_overhead_vs_ideal"):
            val = prof.get(field)
            if not isinstance(val, (int, float)) or not np.isfinite(val) \
                    or val <= 0:
                errs.append(f"{pname}/{field}: bad value {val!r}")
    return errs
