import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on placeholder devices, and extract the roofline inputs.

For each combination this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs abstract params / caches / batches (ShapeDtypeStruct only —
     nothing is allocated),
  3. jax.jit(step, in_shardings, out_shardings).lower(...).compile(),
  4. prints memory_analysis() and cost_analysis(),
  5. parses the compiled HLO for collective operand bytes,
  6. writes a JSON record consumed by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--policy tp_only]
"""
import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import SHAPES
from repro.configs import ARCH_IDS, decode_window, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.model_zoo import build
from repro.sharding import policy as sh

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def normalize_cost_analysis(cost) -> dict:
    """Flatten `compiled.cost_analysis()` across JAX API drift.

    Depending on the JAX version the call returns a dict, a list with one
    dict per device/program, or None.  Return a single plain dict (empty
    when nothing is available) so callers can `.get()` unconditionally.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: dict = {}
        for entry in cost:
            if isinstance(entry, dict):
                merged.update(entry)
        return merged
    if isinstance(cost, dict):
        return dict(cost)
    return {}


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum per-device result bytes of every collective op in the HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s.startswith("%") and not s.startswith("ROOT"):
            continue
        m = re.search(r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) + r")[\.\(]",
                      s)
        if not m:
            continue
        shapes_part, op = m.group(1), m.group(2)
        if "fusion" in shapes_part:
            continue
        total = 0
        for dt, dims in _SHAPE_RE.findall(shapes_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[op] += total
    return out


def _shardings(mesh, tree_of_pspecs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_pspecs,
        is_leaf=lambda x: isinstance(x, P))


def build_step(arch: str, shape_name: str, policy: str, mesh,
               microbatch: int = 1, pad_vocab: bool = False):
    """Returns (fn, abstract_args, in_shardings, out_shardings).

    microbatch > 1 splits the global batch into that many gradient-
    accumulation steps (lax.scan) — trades one extra f32 grad buffer for a
    ~microbatch-fold cut in activation peak (§Perf)."""
    cfg = get_config(arch)
    if pad_vocab:
        import dataclasses
        cfg = dataclasses.replace(cfg, pad_vocab=True)
    shape = SHAPES[shape_name]
    model = build(cfg)
    multi_pod = "pod" in mesh.axis_names
    win = decode_window(cfg, shape)
    params_abs = model.abstract_params()
    pspecs = sh.param_pspecs(params_abs, policy)
    p_shard = _shardings(mesh, pspecs)

    if shape.kind == "train":
        batch_abs = input_specs(cfg, shape)
        b_shard = _shardings(mesh, sh.batch_pspecs(batch_abs, multi_pod))
        lr = 1e-3

        def train_step(params, batch):
            if microbatch > 1:
                mb = jax.tree_util.tree_map(
                    lambda a: a.reshape((microbatch, a.shape[0] // microbatch)
                                        + a.shape[1:]), batch)

                def acc_step(carry, b):
                    loss_acc, g_acc = carry
                    loss, g = jax.value_and_grad(
                        lambda p: model.loss_fn(p, b, window=win))(params)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                    return (loss_acc + loss, g_acc), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.zeros((), jnp.float32), g0), mb)
                loss = loss / microbatch
                grads = jax.tree_util.tree_map(
                    lambda g: g / microbatch, grads)
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss_fn(p, batch, window=win))(params)
            new = jax.tree_util.tree_map(
                lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return loss, new

        return (train_step, (params_abs, batch_abs),
                (p_shard, b_shard),
                (NamedSharding(mesh, P()), p_shard))

    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape)
        b_shard = _shardings(mesh, sh.batch_pspecs(batch_abs, multi_pod))
        cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len,
                                         win)
        c_shard = _shardings(mesh, sh.cache_pspecs(cache_abs, False,
                                                   multi_pod))

        def prefill_step(params, batch):
            return model.prefill(params, batch, window=win)

        return (prefill_step, (params_abs, batch_abs),
                (p_shard, b_shard),
                (NamedSharding(mesh, P()), c_shard))

    # decode
    long_ctx = shape.seq_len * shape.global_batch >= 2 ** 19
    cache_abs = model.abstract_cache(shape.global_batch, shape.seq_len, win)
    c_shard = _shardings(mesh, sh.cache_pspecs(cache_abs, long_ctx,
                                               multi_pod))
    tok_abs = input_specs(cfg, shape)["tokens"]
    t_shard = _shardings(mesh, sh.batch_pspecs({"t": tok_abs},
                                               multi_pod))["t"]
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, window=win)

    return (serve_step, (params_abs, cache_abs, tok_abs, pos_abs),
            (p_shard, c_shard, t_shard, NamedSharding(mesh, P())),
            (NamedSharding(mesh, P()), c_shard))


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            policy: str = "fsdp_tp", out_dir: str = "artifacts/dryrun",
            verbose: bool = True, microbatch: int = 1,
            pad_vocab: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args, in_sh, out_sh = build_step(arch, shape_name, policy, mesh,
                                         microbatch, pad_vocab)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    coll = collective_bytes(compiled.as_text())
    elapsed = time.time() - t0
    record = {
        "arch": arch, "shape": shape_name, "policy": policy,
        "microbatch": microbatch,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "flops_per_device": float(cost.get("flops", 0.0)) if cost else None,
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)) if cost else None,
        "collective_bytes_per_device": coll,
        "compile_seconds": elapsed,
        "memory_analysis": {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if mem is not None and hasattr(mem, k)
        },
    }
    if verbose:
        print(f"== {arch} x {shape_name} x {record['mesh']} ({policy}) "
              f"compile={elapsed:.1f}s")
        print("   memory_analysis:", record["memory_analysis"])
        if cost:
            print(f"   cost_analysis: flops/dev={record['flops_per_device']:.3e} "
                  f"bytes/dev={record['bytes_per_device']:.3e}")
        print("   collectives/dev:", coll)
    os.makedirs(out_dir, exist_ok=True)
    mb = f"_mb{microbatch}" if microbatch > 1 else ""
    pv = "_padvocab" if pad_vocab else ""
    record["pad_vocab"] = pad_vocab
    fname = f"{arch}_{shape_name}_{record['mesh']}_{policy}{mb}{pv}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="fsdp_tp",
                    choices=["fsdp_tp", "tp_only", "dp_only",
                             "fsdp_tp_ep", "tp_only_ep"])
    ap.add_argument("--out-dir", default="artifacts/dryrun")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--pad-vocab", action="store_true")
    args = ap.parse_args()
    combos = ([(a, s) for a in ARCH_IDS for s in SHAPES]
              if args.all else [(args.arch, args.shape)])
    failures = []
    for arch, shape in combos:
        try:
            run_one(arch, shape, multi_pod=args.multi_pod,
                    policy=args.policy, out_dir=args.out_dir,
                    microbatch=args.microbatch, pad_vocab=args.pad_vocab)
        except Exception as e:  # noqa: BLE001 - report and continue
            print(f"== {arch} x {shape} FAILED: {type(e).__name__}: {e}")
            failures.append((arch, shape, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_[0], f_[1], f_[2][:200])
        raise SystemExit(1)
    print("\nall dry-runs compiled OK")


if __name__ == "__main__":
    main()
