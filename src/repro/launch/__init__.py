"""Launchers: make_production_mesh, multi-pod dryrun, train, serve, and the
scheme-comparison benchmark harness (bench)."""
