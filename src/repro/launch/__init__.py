"""Launchers: make_production_mesh, multi-pod dryrun, train, serve."""
