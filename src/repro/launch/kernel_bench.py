"""Per-kernel microbenchmark harness (the ``kernels`` artifact section).

Times every kernel the federated round path is built from — `rff_embed`,
`linreg_grad_masked`, `parity_encode_batched`, and the fused
embed->gradient `rff_linreg_grad_masked` against its two-pass equivalent —
and emits the required ``kernels`` section of ``BENCH_fed_training.json``
(schema v6).  The headline number is ``fused_vs_two_pass_ratio``: the
fused kernel's time over the two-pass (embed, then gradient) time at the
same shapes, i.e. the measured payoff of never materializing the
``(n, l, q)`` embedded tensor per round.

What is timed is the jit'd path of the selected ``kernel_backend``:
``"xla"`` (the CI default) times the plain-jnp reference compositions —
Pallas interpret-mode wall time on CPU measures the interpreter, not the
TPU target, so CI gates regressions on the XLA path and TPU runs pass
``kernel_backend="pallas"`` with ``interpret=False`` for device numbers.

CI gate: `compare_kernels(fresh, committed, threshold)` flags any kernel
whose us_per_call regressed past ``threshold`` x the committed artifact's
(host-noise tolerant: only slowdowns fail, never speedups), and
`validate_kernels` is wired into `repro.launch.bench.validate_artifact`
so an artifact without the section (or with non-finite timings) is
malformed.  CLI front-end: ``benchmarks/bench_kernels_micro.py``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

#: kernel names every ``kernels`` section must time
KERNEL_NAMES = ("rff_embed", "linreg_grad_masked", "parity_encode_batched",
                "rff_linreg_grad_fused", "two_pass_embed_grad")

#: default CI regression threshold: fresh us_per_call may not exceed
#: threshold x committed us_per_call (generous — CI hosts are noisy; the
#: gate exists to catch order-of-magnitude kernel/wrappers regressions,
#: not scheduler jitter)
DEFAULT_THRESHOLD = 3.0

# (n_clients, l, d, q, c, u) per scale; "smoke" is CI-sized (well under a
# second per kernel on a shared runner), "full" the paper's §V-A operating
# point (784-dim MNIST, q = 2000)
SCALES = {
    "smoke": dict(n_clients=4, l=64, d=16, q=128, c=4, u=32),
    "default": dict(n_clients=12, l=128, d=64, q=512, c=8, u=128),
    "full": dict(n_clients=30, l=400, d=784, q=2000, c=10, u=1200),
}


def _time(fn, *args, iters: int, warmup: int = 2) -> float:
    """Mean us/call of ``fn(*args)`` after ``warmup`` compile+cache calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run_kernel_bench(n_clients: int = 12, l: int = 128, d: int = 64,
                     q: int = 512, c: int = 8, u: int = 128,
                     iters: int = 10, seed: int = 0,
                     kernel_backend: str = "xla",
                     interpret: bool = True) -> dict:
    """Time the round path's kernels at one shape; return the section dict.

    Shapes mirror the runtime's layouts: embedding flattens the client
    axis into (n*l, d) rows; the gradient/parity kernels run over the
    dense (n, l, ·) client tensor.  The fused and two-pass timings share
    identical inputs, so their ratio isolates the fusion itself.
    """
    if kernel_backend not in ("xla", "pallas"):
        raise ValueError(f"unknown kernel_backend {kernel_backend!r}")
    use_pallas = kernel_backend == "pallas"
    rng = np.random.default_rng(seed)
    x_raw = jnp.asarray(rng.normal(size=(n_clients, l, d)), jnp.float32)
    omega = jnp.asarray(rng.normal(size=(d, q)) / 5.0, jnp.float32)
    delta = jnp.asarray(rng.uniform(0, 2 * np.pi, size=(q,)), jnp.float32)
    theta = jnp.asarray(rng.normal(size=(q, c)) * 0.1, jnp.float32)
    y = jnp.asarray(rng.normal(size=(n_clients, l, c)), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=(n_clients, l)) < 0.8,
                       jnp.float32)
    g = jnp.asarray(rng.normal(size=(n_clients, u, l)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.0, size=(n_clients, l)), jnp.float32)

    kw = dict(use_pallas=use_pallas, interpret=interpret)
    embed = jax.jit(lambda x2d: ops.rff_embed(x2d, omega, delta, **kw))
    phi = embed(x_raw.reshape(n_clients * l, d)).reshape(n_clients, l, q)
    grad = jax.jit(lambda p, th, yy, mm: ops.linreg_grad_masked(
        p, th, yy, mm, **kw))
    parity = jax.jit(lambda gg, ww, pp: ops.parity_encode_batched(
        gg, ww, pp, **kw))
    fused = jax.jit(lambda x, th: ops.rff_linreg_grad_masked(
        x, omega, delta, th, y, mask, **kw))
    two_pass = jax.jit(lambda x, th: ops.linreg_grad_masked(
        ops.rff_embed_batched(x, omega, delta, **kw), th, y, mask, **kw))

    entries = {
        "rff_embed": _time(embed, x_raw.reshape(n_clients * l, d),
                           iters=iters),
        "linreg_grad_masked": _time(grad, phi, theta, y, mask, iters=iters),
        "parity_encode_batched": _time(parity, g, w, phi, iters=iters),
        "rff_linreg_grad_fused": _time(fused, x_raw, theta, iters=iters),
        "two_pass_embed_grad": _time(two_pass, x_raw, theta, iters=iters),
    }
    return {
        "backend": kernel_backend,
        "interpret": bool(interpret),
        "iters": int(iters),
        "shapes": {"n_clients": n_clients, "l": l, "d": d, "q": q, "c": c,
                   "u": u},
        "entries": {k: {"us_per_call": float(v)}
                    for k, v in entries.items()},
        "fused_vs_two_pass_ratio": float(
            entries["rff_linreg_grad_fused"]
            / entries["two_pass_embed_grad"]),
    }


def validate_kernels(section) -> list[str]:
    """Problems with a ``kernels`` artifact section (empty == valid)."""
    errs = []
    if not isinstance(section, dict):
        return [f"kernels: must be an object, got {type(section).__name__}"]
    if section.get("backend") not in ("xla", "pallas"):
        errs.append(f"kernels/backend: bad value {section.get('backend')!r}")
    entries = section.get("entries")
    if not isinstance(entries, dict):
        return errs + ["kernels/entries: missing"]
    for name in KERNEL_NAMES:
        entry = entries.get(name)
        us = entry.get("us_per_call") if isinstance(entry, dict) else None
        if not _is_pos(us):
            errs.append(f"kernels/entries/{name}/us_per_call: "
                        f"bad value {us!r}")
    ratio = section.get("fused_vs_two_pass_ratio")
    if not _is_pos(ratio):
        errs.append(f"kernels/fused_vs_two_pass_ratio: bad value {ratio!r}")
    shapes = section.get("shapes")
    if not isinstance(shapes, dict) or not all(
            isinstance(shapes.get(k), int) and shapes.get(k) > 0
            for k in ("n_clients", "l", "d", "q", "c", "u")):
        errs.append(f"kernels/shapes: bad value {shapes!r}")
    return errs


def compare_kernels(fresh: dict, committed: dict,
                    threshold: float = DEFAULT_THRESHOLD) -> list[str]:
    """Regression gate: fresh timings vs a committed ``kernels`` section.

    Returns a problem string per kernel whose fresh us_per_call exceeds
    ``threshold`` x the committed one (one-sided: speedups always pass),
    plus one if the fused-vs-two-pass ratio regressed past the same
    factor.  Both sections must validate first; structural problems are
    reported instead of timings nonsense.
    """
    errs = [f"fresh artifact: {e}" for e in validate_kernels(fresh)]
    errs += [f"committed artifact: {e}" for e in validate_kernels(committed)]
    if errs:
        return errs
    if threshold <= 1.0:
        return [f"threshold must exceed 1.0, got {threshold}"]
    for name in KERNEL_NAMES:
        new = fresh["entries"][name]["us_per_call"]
        old = committed["entries"][name]["us_per_call"]
        if new > threshold * old:
            errs.append(
                f"{name}: {new:.1f} us/call vs committed {old:.1f} "
                f"(> {threshold:.2f}x regression threshold)")
    new_r = fresh["fused_vs_two_pass_ratio"]
    old_r = committed["fused_vs_two_pass_ratio"]
    if new_r > threshold * old_r:
        errs.append(
            f"fused_vs_two_pass_ratio: {new_r:.3f} vs committed "
            f"{old_r:.3f} (> {threshold:.2f}x regression threshold)")
    return errs


def _is_pos(val) -> bool:
    return isinstance(val, (int, float)) and np.isfinite(val) and val > 0
