"""Serving driver: batched prefill + decode loop (reduced configs on CPU).

Demonstrates the full request lifecycle the decode dry-run shapes lower:
prefill a batch of prompts, then step the decode loop, greedy-sampling one
token per request per step against the (rolling or full) KV/state cache.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.launch.train import make_batch
from repro.models.model_zoo import build


def serve(cfg, batch: int = 4, prompt_len: int = 32, gen_len: int = 16,
          window: int = 0, seed: int = 0, verbose: bool = True):
    model = build(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    b = make_batch(cfg, batch, prompt_len, seed)
    b.pop("labels", None)
    max_seq = prompt_len + gen_len

    # re-build a cache wide enough for generation, then prefill into it
    prefill = jax.jit(lambda p, bb: model.prefill(p, bb, window=window))
    logits, cache = prefill(params, b)
    # grow cache seq dim to max_seq (prefill sized it to the prompt)
    prompt_slots = b["tokens"].shape[1] + (cfg.n_prefix_patches or 0)

    def grow(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "pos":
            pad = [(0, 0)] * leaf.ndim
            pad[-1] = (0, max_seq - leaf.shape[-1])
            return jnp.pad(leaf, pad, constant_values=-1)
        if name in ("xk", "xv"):           # whisper cross-attn: fixed T_enc
            return leaf
        if leaf.ndim >= 3 and leaf.shape[2] == prompt_slots:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, max_seq - leaf.shape[2])
            return jnp.pad(leaf, pad, constant_values=0)
        return leaf

    if window == 0 and not cfg.attention_free:
        cache = jax.tree_util.tree_map_with_path(grow, cache)

    decode = jax.jit(lambda p, c, t, pos: model.decode_step(
        p, c, t, pos, window=window))
    tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tokens]
    start = b["tokens"].shape[1] + (cfg.n_prefix_patches or 0)
    t0 = time.time()
    for i in range(gen_len - 1):
        logits, cache = decode(params, cache, tokens,
                               jnp.int32(start + i))
        tokens = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tokens)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    if verbose:
        print(f"generated {gen.shape} tokens, "
              f"{gen_len * batch / max(dt, 1e-9):.1f} tok/s (CPU, reduced)")
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    cfg = smoke_variant(get_config(args.arch))
    serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
          gen_len=args.gen_len)


if __name__ == "__main__":
    main()
