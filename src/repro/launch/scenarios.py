"""Drift-scenario benchmark: static vs adaptive allocation under dynamics.

The profile-grid benchmark (`repro.launch.bench`) compares schemes on a
*stationary* network.  This runner benches what the `repro.net` subsystem
adds: the same CodedFedL deployment run under a drifting channel profile
twice — once with the paper's static round-0 allocation, once with the
adaptive controller re-solving the allocation every ``adapt_every``
rounds — and records the **wall-clock time to a common target loss**.
The target is the worse of the two final losses, so both runs provably
reach it; ``adaptive_speedup`` is static's time-to-target over
adaptive's.

Both runs share the data, seed, spec knobs, and channel profile; the
trace generator is seeded per run index, so the static and adaptive runs
face the *same* realized network.  Results land in the ``scenarios``
section of ``BENCH_fed_training.json`` (schema v4) and in the standalone
``BENCH_drift_scenarios.json`` the CI smoke step uploads.

  PYTHONPATH=src python -m benchmarks.bench_drift_scenarios --smoke
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.api import build_experiment
from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.net.channel import CHANNEL_PROFILES

#: default scenario grid: the two directional-drift profiles where a
#: round-0 allocation predictably goes stale (links+compute speeding up
#: -> wasted deadline slack; degrading -> bleeding return mass)
DEFAULT_SCENARIOS = ("speedup_drift", "degrade_drift")


def _tt(history, target: float) -> Optional[float]:
    """First simulated wall-clock at which the loss reaches `target`."""
    for h in history:
        if h.loss <= target:
            return float(h.wall_clock)
    return None


def run_scenarios(n_clients: int = 10, l: int = 24, q: int = 32, c: int = 3,
                  iters: int = 60, adapt_every: int = 5, delta: float = 0.25,
                  psi: float = 0.2, seed: int = 0,
                  scenarios=DEFAULT_SCENARIOS,
                  kernel_backend: str = "xla") -> dict:
    """Static-vs-adaptive comparison over the drift scenarios.

    Returns the ``scenarios`` artifact section: config + one case per
    scenario with per-variant (final_loss, time_to_target, wall-clock)
    and the headline ``adaptive_speedup``.  Data is a synthetic linear
    problem (known ground truth + noise) so the loss trajectory is a
    meaningful convergence signal, not a random-label plateau.
    """
    rng = np.random.default_rng(seed)
    theta_true = rng.normal(size=(q, c)).astype(np.float32)
    xs = rng.normal(size=(n_clients, l, q)).astype(np.float32) * 0.3
    # low noise floor so the loss keeps falling across the whole run —
    # the time-to-target window then spans the drift, not just the first
    # few rounds
    ys = (np.einsum("nlq,qc->nlc", xs, theta_true)
          + 0.005 * rng.normal(size=(n_clients, l, c)).astype(np.float32))
    fl = FLConfig(n_clients=n_clients, delta=delta, psi=psi, seed=seed)
    tc = TrainConfig(learning_rate=1.0, l2_reg=0.0)

    def eval_fn(theta):
        pred = np.einsum("nlq,qc->nlc", xs, np.asarray(theta))
        return float(np.mean((pred - ys) ** 2)), 0.0

    cases = {}
    for prof in scenarios:
        if prof not in CHANNEL_PROFILES:
            raise ValueError(f"unknown channel profile {prof!r} (known: "
                             f"{tuple(CHANNEL_PROFILES)})")
        base = dict(fl=fl, train=tc, channel_profile=prof,
                    kernel_backend=kernel_backend)
        t0 = time.perf_counter()
        static = build_experiment(
            ExperimentSpec(**base, scheme="coded"), xs, ys).run(
                iters, eval_fn=eval_fn, eval_every=1)
        adaptive_exp = build_experiment(
            ExperimentSpec(**base, scheme="adaptive_coded",
                           adapt_every=adapt_every), xs, ys)
        adaptive = adaptive_exp.run(iters, eval_fn=eval_fn, eval_every=1)
        host = time.perf_counter() - t0

        f_s = static.history[-1].loss
        f_a = adaptive.history[-1].loss
        target = max(f_s, f_a)
        tt_s = _tt(static.history, target)
        tt_a = _tt(adaptive.history, target)
        sched = adaptive_exp.last_schedule
        cases[prof] = {
            "channel_profile": prof,
            "adapt_every": adapt_every,
            "target_loss": float(target),
            "static": {
                "final_loss": float(f_s),
                "time_to_target": tt_s,
                "final_wall_clock": float(static.history[-1].wall_clock),
                "t_star": float(static.t_star),
            },
            "adaptive": {
                "final_loss": float(f_a),
                "time_to_target": tt_a,
                "final_wall_clock": float(adaptive.history[-1].wall_clock),
                "t_star_first": float(sched.t_star[0]),
                "t_star_last": float(sched.t_star[-1]),
                "n_blocks": int(sched.n_blocks),
            },
            "adaptive_speedup": (None if not tt_s or not tt_a
                                 else float(tt_s / tt_a)),
            "host_seconds": float(host),
        }
    return {
        "config": {
            "n_clients": n_clients, "l": l, "q": q, "c": c, "iters": iters,
            "adapt_every": adapt_every, "delta": delta, "psi": psi,
            "seed": seed, "kernel_backend": kernel_backend,
            "scenarios": list(scenarios),
        },
        "cases": cases,
    }


def validate_scenarios(section) -> list[str]:
    """Structural check of a ``scenarios`` section (list of problems)."""
    errs = []
    if not isinstance(section, dict):
        return [f"scenarios section must be an object, "
                f"got {type(section).__name__}"]
    config = section.get("config")
    if not isinstance(config, dict) or not config.get("scenarios"):
        errs.append("scenarios/config: missing or empty scenario list")
    cases = section.get("cases")
    if not isinstance(cases, dict) or not cases:
        return errs + ["scenarios/cases: missing or empty"]
    for name, case in cases.items():
        if not isinstance(case, dict):
            errs.append(f"scenarios/{name}: not an object")
            continue
        for field in ("channel_profile", "target_loss", "adaptive_speedup"):
            if case.get(field) is None:
                errs.append(f"scenarios/{name}/{field}: missing")
        for variant in ("static", "adaptive"):
            entry = case.get(variant)
            if not isinstance(entry, dict):
                errs.append(f"scenarios/{name}/{variant}: missing")
                continue
            for field in ("final_loss", "time_to_target",
                          "final_wall_clock"):
                val = entry.get(field)
                if not isinstance(val, (int, float)) \
                        or not np.isfinite(val) or val < 0:
                    errs.append(f"scenarios/{name}/{variant}/{field}: "
                                f"bad value {val!r}")
        spd = case.get("adaptive_speedup")
        if spd is not None and (not isinstance(spd, (int, float))
                                or not np.isfinite(spd) or spd <= 0):
            errs.append(f"scenarios/{name}/adaptive_speedup: "
                        f"bad value {spd!r}")
    return errs
