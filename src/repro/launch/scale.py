"""Population-scaling benchmark: the schema-v8 ``scale`` artifact section.

Runs the hierarchical tier (`repro.hier.HierExperiment`) at a ladder of
population sizes — n = 1e3, 1e4, 1e5 by default, 1e6 in ``--full`` runs —
and records the wall-clock/memory scaling curve: per-n setup and round
timings, the chunked-solver and chunked-trace costs, and the two memory
numbers that certify the O(active cohort) contract (peak transient
client-tensor bytes vs the dense (n, l, q) tensor a flat run would
materialize).  Client data is streamed per block through a deterministic
synthetic `data_fn`, so even the 1e6-client rung never holds a dense
population tensor.

The section also pins the routing identity at the smallest rung:
``build_experiment`` with the identity configuration (``hier_shards=1,
sample_fraction=1.0``) must return the flat engine and reproduce a
directly-built flat `Experiment`'s trajectory bit-exactly.

CLI: ``benchmarks/bench_hier_scale.py --smoke/--full``; the section is
embedded in ``BENCH_fed_training.json`` by `repro.launch.bench` and
enforced by its validator via `validate_scale`.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

#: population rungs every committed artifact must cover (the 1e6 rung is
#: optional --full territory; extra rungs are welcome)
REQUIRED_NS = (1_000, 10_000, 100_000)

#: target clients per edge-aggregator shard — hier_shards ~= n / cohort,
#: so the peak client tensor stays O(cohort) as n grows
DEFAULT_COHORT = 1_000


def synthetic_block(lo: int, hi: int, l: int, q: int, c: int):
    """Deterministic synthetic client block for clients [lo, hi).

    Pointwise function of (client, point, feature) indices — no RNG
    state — so any block pattern (setup's encode blocks, each round's
    shard blocks) sees consistent per-client data, and nothing O(n) is
    ever materialized.
    """
    j = np.arange(lo, hi, dtype=np.float64)[:, None, None]
    i = np.arange(l, dtype=np.float64)[None, :, None]
    kq = np.arange(q, dtype=np.float64)[None, None, :]
    kc = np.arange(c, dtype=np.float64)[None, None, :]
    x = (0.2 * np.sin(0.7 * j + 1.3 * i + 2.1 * kq)).astype(np.float32)
    y = np.cos(0.3 * j + 0.9 * i + 1.7 * kc).astype(np.float32)
    return x, y


def _identity_check(l: int, q: int, c: int, rounds: int,
                    seed: int) -> dict:
    """Pin the routing identity: the identity configuration takes the
    flat engine and reproduces a directly-built flat run bit-exactly."""
    from repro.api import build_experiment
    from repro.config import ExperimentSpec, FLConfig, TrainConfig
    from repro.core.fed_runtime import Experiment

    n = 16
    x, y = synthetic_block(0, n, l, q, c)
    spec = ExperimentSpec(
        fl=FLConfig(n_clients=n, delta=0.2, seed=seed),
        train=TrainConfig(learning_rate=0.5, l2_reg=1e-5),
        scheme="coded", hier_shards=1, sample_fraction=1.0)
    routed = build_experiment(spec, x, y)
    flat = Experiment(spec, x, y)
    th_r = np.asarray(routed.run(rounds).theta)
    th_f = np.asarray(flat.run(rounds).theta)
    return {
        "routes_flat_engine": type(routed).__name__ == "Experiment",
        "bit_identical": bool(np.array_equal(th_r, th_f)),
    }


def run_scale(ns: Sequence[int] = REQUIRED_NS, l: int = 4, q: int = 8,
              c: int = 2, rounds: int = 3, cohort: int = DEFAULT_COHORT,
              sample_fraction: float = 0.25, seed: int = 0,
              solver_block: Optional[int] = None,
              solver_kwargs: Optional[dict] = None,
              trace_rounds: int = 2,
              trace_block: int = 4_096) -> dict:
    """The ``scale`` section: hierarchical sampled runs across the n
    ladder.

    Every rung builds a `HierExperiment` with ``hier_shards = max(2,
    n // cohort)`` (so per-shard transients stay O(cohort)) and a
    sampled cohort, streams its data through `synthetic_block`, runs
    ``rounds`` federated rounds, and times the chunked trace generator
    over the same population.  Tensor shapes (l, q, c) are tunable for
    smoke runs; the n ladder is what the validator pins.

    `solver_kwargs` defaults to a shallower bisection than the solver's
    full-precision defaults (the per-shard deadline search dominates
    setup on a single CPU core at n >= 1e5); results stay deterministic
    per setting.
    """
    if solver_kwargs is None:
        solver_kwargs = dict(n_golden_search=16, n_bisect=28)
    from repro.config import ExperimentSpec, FLConfig, TrainConfig
    from repro.hier import HierExperiment, generate_trace_chunked
    from repro.hier.population import DEFAULT_BLOCK, population_delay_arrays
    from repro.net.channel import CHANNEL_PROFILES

    tc = TrainConfig(learning_rate=0.5, l2_reg=1e-5)
    # a dynamic profile so the trace timing exercises real per-round
    # dynamics; "static" would shortcut most of the generator
    trace_profile = CHANNEL_PROFILES.get(
        "drift_churn") or next(iter(CHANNEL_PROFILES.values()))
    entries = []
    for n in ns:
        n = int(n)
        shards = max(2, n // int(cohort))
        # the paper's k1/k2 decay knobs are per-client geometric,
        # calibrated for n ~ 12; raised to n=1e5 they underflow link rates
        # to zero (tau overflows).  Re-exponentiate so the population
        # spans the SAME heterogeneity range [k^12, 1] at every n.
        k1 = 0.95 ** (12.0 / n)
        k2 = 0.8 ** (12.0 / n)
        spec = ExperimentSpec(
            fl=FLConfig(n_clients=n, delta=0.2, seed=seed,
                        rate_decay=k1, mac_decay=k2), train=tc,
            scheme="coded", hier_shards=shards,
            sample_fraction=float(sample_fraction))
        t0 = time.perf_counter()
        exp = HierExperiment(
            spec, data_fn=lambda lo, hi: synthetic_block(lo, hi, l, q, c),
            solver_block=solver_block or min(DEFAULT_BLOCK, n),
            solver_kwargs=dict(solver_kwargs))
        setup_seconds = time.perf_counter() - t0
        t0 = time.perf_counter()
        result = exp.run(rounds)
        round_seconds = time.perf_counter() - t0
        t0 = time.perf_counter()
        prm = population_delay_arrays(exp.fl, q * c)
        tr = generate_trace_chunked(prm, trace_profile, trace_rounds,
                                    seed=seed + 9973,
                                    block_size=min(trace_block, n))
        trace_seconds = time.perf_counter() - t0
        assert tr.mu_mult.shape == (trace_rounds, n)
        entries.append({
            "n": n,
            "shards": shards,
            "sample_fraction": float(sample_fraction),
            "rounds": int(rounds),
            "setup_seconds": float(setup_seconds),
            "round_seconds": float(round_seconds),
            "wall_seconds": float(setup_seconds + round_seconds),
            "trace_seconds": float(trace_seconds),
            "trace_rounds": int(trace_rounds),
            "peak_client_tensor_bytes": int(exp.peak_client_tensor_bytes()),
            "dense_client_tensor_bytes": int(4 * n * l * (q + c)),
            "population_tensor_bytes": int(exp.population_tensor_bytes()),
            "t_round": float(result.t_round),
            "mean_returned": float(np.mean(result.n_ret)),
        })
    return {
        "shapes": {"l": int(l), "q": int(q), "c": int(c)},
        "ns": [int(n) for n in ns],
        "entries": entries,
        "identity": _identity_check(l, q, c, rounds=3, seed=seed),
    }


def validate_scale(section, *,
                   required_ns: Sequence[int] = REQUIRED_NS) -> list[str]:
    """Structural check of the ``scale`` section (empty list == valid).

    Enforces: the n ladder covers ``required_ns``; every entry's timings
    are positive finite; the memory contract holds (peak transient
    client-tensor bytes no larger than the dense tensor, and strictly
    sub-dense from the 1e4 rung up); and the routing identity flags are
    True.
    """
    errs: list[str] = []
    if not isinstance(section, dict):
        return [f"scale: must be an object, got {type(section).__name__}"]
    entries = section.get("entries")
    if not isinstance(entries, list) or not entries:
        return ["scale: missing/empty 'entries'"]
    by_n = {}
    for i, entry in enumerate(entries):
        if not isinstance(entry, dict) or not isinstance(
                entry.get("n"), int):
            errs.append(f"scale/entries[{i}]: malformed entry")
            continue
        by_n[entry["n"]] = entry
    missing = [n for n in required_ns if n not in by_n]
    if missing:
        errs.append(f"scale: required population rung(s) absent {missing} "
                    f"(have {sorted(by_n)})")
    for n, entry in sorted(by_n.items()):
        for field in ("setup_seconds", "round_seconds", "wall_seconds",
                      "trace_seconds"):
            val = entry.get(field)
            if not isinstance(val, (int, float)) or not np.isfinite(val) \
                    or val <= 0:
                errs.append(f"scale/n={n}/{field}: bad value {val!r}")
        for field in ("shards", "rounds", "peak_client_tensor_bytes",
                      "dense_client_tensor_bytes",
                      "population_tensor_bytes"):
            val = entry.get(field)
            if not isinstance(val, int) or val < 1:
                errs.append(f"scale/n={n}/{field}: bad value {val!r}")
        peak = entry.get("peak_client_tensor_bytes")
        dense = entry.get("dense_client_tensor_bytes")
        if isinstance(peak, int) and isinstance(dense, int):
            if peak > dense:
                errs.append(f"scale/n={n}: peak client tensor {peak} "
                            f"exceeds the dense tensor {dense}")
            if n >= 10_000 and peak * 2 > dense:
                errs.append(
                    f"scale/n={n}: peak client tensor {peak} is not "
                    f"sub-dense (dense {dense}) — the O(active cohort) "
                    "memory contract is broken")
        frac = entry.get("sample_fraction")
        if not isinstance(frac, (int, float)) or not 0.0 < frac <= 1.0:
            errs.append(f"scale/n={n}/sample_fraction: bad value {frac!r}")
    identity = section.get("identity")
    if not isinstance(identity, dict):
        errs.append("scale: missing 'identity' routing check")
    else:
        for flag in ("routes_flat_engine", "bit_identical"):
            if identity.get(flag) is not True:
                errs.append(f"scale/identity/{flag}: expected True, got "
                            f"{identity.get(flag)!r}")
    return errs
