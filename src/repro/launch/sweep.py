"""Compiled multi-deployment sweep engine (the Fig. 4/5 profile grid).

The scheme-comparison benchmark sweeps deployments over heterogeneity
profiles (paper §V-A's k1/k2 decay knobs).  Pre-PR that was a Python loop
of independent `FederatedSimulation.run_multi` calls — one XLA compilation
per (scheme, profile) even though every deployment shares shapes.  This
module stacks the per-deployment step constants built by
`FederatedSimulation.build_consts` along a profile axis and vmaps the SAME
scan step (`fed_runtime.build_step`) over the (profile x realization) grid:
one compiled call per scheme covers the whole grid.

Deployments must share shapes: same (n, l, q, c), iterations, realizations,
psi, and training config.  Coded deployments may have different per-client
load allocations — their dense client tensors are padded to the grid-wide
point-axis maximum (`l_target`), which contributes exactly zero through the
validity mask.

    sweep = run_sweep(xs, ys, profiles=PROFILES, train_cfg=tc,
                      iterations=40, realizations=6)
    sweep.results["coded"]["paper"].wall_clock_bands()

Equivalence to the looped path is locked down by
tests/test_sweep_engine.py; `repro.launch.bench` records the measured
speedup in BENCH_fed_training.json.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.core import fed_runtime
from repro.core import schemes as schemes_registry
from repro.core.fed_runtime import Experiment, MultiFedResult

#: import-time snapshot of the grid-eligible registry, in registration
#: order; the run_sweep default re-reads the LIVE registry at call time,
#: so schemes registered later are swept too.  Adaptive schemes
#: (Scheme.grid = False) are excluded — they need a channel trace and a
#: per-run control schedule (see repro.launch.scenarios).
SCHEMES = schemes_registry.grid_names()


@dataclasses.dataclass
class SweepResult:
    """One compiled sweep: results[scheme][profile] is a MultiFedResult.

    host_seconds[scheme] is the host-side cost of that scheme's ONE
    compiled (profile x realization) call, including compilation; sims
    holds the per-(scheme, profile) deployments for metadata (t_star,
    loads, setup_time).
    """
    results: dict
    sims: dict
    host_seconds: dict


def _build_sims(x_stack, y_stack, profiles: dict, train_cfg: TrainConfig,
                scheme: str, fl_kwargs: dict, kernel_backend: str,
                base_spec: Optional[ExperimentSpec] = None) -> dict:
    """One spec-built Experiment per profile (the per-deployment setup)."""
    sims = {}
    for pname, knobs in profiles.items():
        if base_spec is not None:
            spec = dataclasses.replace(
                base_spec, scheme=scheme, delay_profile=None,
                fl=dataclasses.replace(base_spec.resolved_fl(), **knobs))
        else:
            spec = ExperimentSpec(fl=FLConfig(**{**fl_kwargs, **knobs}),
                                  train=train_cfg, scheme=scheme,
                                  kernel_backend=kernel_backend)
        sims[pname] = Experiment(spec, x_stack, y_stack)
    return sims


def run_sweep(x_stack, y_stack, *, profiles: dict,
              train_cfg: TrainConfig, iterations: int, realizations: int,
              schemes: Optional[Sequence[str]] = None,
              fl_kwargs: Optional[dict] = None,
              kernel_backend: str = "xla",
              sims: Optional[dict] = None,
              base_spec: Optional[ExperimentSpec] = None) -> SweepResult:
    """Run every (scheme, profile) deployment in one compiled call per scheme.

    profiles: {name: FLConfig-override dict} (e.g. rate_decay/mac_decay
    heterogeneity knobs); fl_kwargs: shared FLConfig fields (n_clients,
    delta, psi, seed, ...).  `base_spec` replaces fl_kwargs/kernel_backend
    with a full `ExperimentSpec` to replay across the grid (its `fl` is the
    base each profile's knobs override).  `schemes` defaults to the LIVE
    scheme registry at call time.  Setup (load allocation, parity encoding, delay
    pre-sampling) runs per deployment on the host exactly as the looped
    path would, so equal seeds reproduce looped `run_multi` results.
    Callers that already built the deployments (e.g. the benchmark
    launcher, which times setup separately from the grid execution) pass
    them via `sims` ({scheme: {profile: Experiment}}).
    """
    if schemes is None:
        schemes = schemes_registry.grid_names()
    for scheme in schemes:
        if not schemes_registry.get_scheme(scheme).grid:
            raise ValueError(
                f"scheme {scheme!r} is not grid-sweepable (adaptive "
                "schemes need a channel trace; bench them with "
                "repro.launch.scenarios)")
    if base_spec is not None and (base_spec.channel_profile is not None
                                  or base_spec.channel_params):
        raise ValueError(
            "run_sweep replays one compiled step across the grid and has "
            "no traced-channel path; drop channel_profile/channel_params "
            "from base_spec (drift scenarios: repro.launch.scenarios)")
    if base_spec is not None and base_spec.fused_embed:
        raise ValueError(
            "run_sweep derives q from the embedded x_stack and has no "
            "raw-feature path; drop fused_embed from base_spec (run "
            "fused-embed deployments through Experiment.run/run_multi)")
    if base_spec is not None and base_spec.hier_active:
        raise ValueError(
            "run_sweep replays one flat compiled step across the grid and "
            "has no edge-aggregator path; drop hier_shards/sample_fraction "
            "from base_spec (population-scale runs go through "
            "repro.hier.HierExperiment / repro.launch.scale)")
    if base_spec is not None:
        base_faults = base_spec.resolved_faults()
        if base_faults is not None and base_faults.has_return_faults:
            raise ValueError(
                "run_sweep has no fault-injection path; drop "
                "fault_profile/fault_params from base_spec (fault runs go "
                "through Experiment.run/run_multi or the resilience bench)")
    fl_kwargs = dict(fl_kwargs or {})
    fl_kwargs.setdefault("n_clients", int(x_stack.shape[0]))
    R = int(realizations)
    n = int(x_stack.shape[0])
    q, c = int(x_stack.shape[2]), int(y_stack.shape[2])
    theta0 = jnp.zeros((q, c), jnp.float32)

    results: dict = {}
    all_sims: dict = dict(sims or {})
    host_seconds: dict = {}
    for scheme in schemes:
        scheme_sims = all_sims.get(scheme)
        if scheme_sims is None:
            scheme_sims = _build_sims(
                x_stack, y_stack, profiles, train_cfg, scheme, fl_kwargs,
                kernel_backend, base_spec)
        elif set(scheme_sims) != set(profiles):
            raise ValueError(
                f"prebuilt sims for scheme {scheme!r} cover profiles "
                f"{sorted(scheme_sims)} but the sweep grid expects "
                f"{sorted(profiles)}")
        all_sims[scheme] = scheme_sims
        names = list(scheme_sims)
        # one step serves every profile, so everything Python-static must
        # agree across the grid — a psi (n_wait) or l2 override would
        # otherwise silently diverge from the looped run_multi results
        statics = {p: scheme_sims[p].step_static(collect_theta=False)
                   for p in names}
        ref_static = statics[names[0]]
        for p, st in statics.items():
            bad = [k for k in st if st[k] != ref_static[k]]
            if bad:
                raise ValueError(
                    f"profile {p!r} differs from {names[0]!r} in "
                    f"step-static field(s) {bad}; sweep profiles may only "
                    "vary array-level deployment constants (delay knobs, "
                    "loads, parity), not scheme statics like psi/l2")
        lr_schedules = {p: scheme_sims[p]._lr_schedule(iterations)
                        for p in names}
        for p, sched in lr_schedules.items():
            if not np.array_equal(sched, lr_schedules[names[0]]):
                raise ValueError(
                    f"profile {p!r} has a different learning-rate schedule "
                    f"than {names[0]!r}; all sweep deployments must share "
                    "one TrainConfig")
        # common point-axis length so coded tensors stack across profiles
        l_target = max(scheme_sims[p].consts_point_len() for p in names)
        consts = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves),
            *[scheme_sims[p].build_consts(l_target=l_target) for p in names])
        times = np.stack([
            scheme_sims[p]._sample_round_times(R * iterations)
                          .reshape(R, iterations, n)
            for p in names])
        lrs = jnp.asarray(lr_schedules[names[0]])
        step = fed_runtime.build_step(ref_static)

        carry0 = (theta0, jnp.float32(1.0))

        def profile_run(consts_p, times_p, lrs_r):
            def one(tj):
                return jax.lax.scan(
                    lambda c, inp: step(consts_p, c, inp),
                    carry0, (tj, lrs_r))
            return jax.vmap(one)(times_p)

        sweep_fn = jax.jit(jax.vmap(profile_run, in_axes=(0, 0, None)))
        t0 = time.perf_counter()
        carry_out, (t_rounds, n_ret, _n_masked, _skipped) = \
            jax.block_until_ready(
                sweep_fn(consts, jnp.asarray(times, jnp.float32), lrs))
        theta = carry_out[0]
        host_seconds[scheme] = time.perf_counter() - t0

        per_profile = {}
        t_rounds = np.asarray(t_rounds, np.float64)    # (P, R, iters)
        n_ret = np.asarray(n_ret)
        for i, pname in enumerate(names):
            sim = scheme_sims[pname]
            wall = sim.setup_time + np.cumsum(t_rounds[i], axis=1)
            per_profile[pname] = MultiFedResult(
                theta=theta[i], wall_clock=wall, returned=n_ret[i],
                t_star=sim.t_star, loads=sim.loads,
                setup_time=sim.setup_time, privacy_eps=sim.privacy_eps)
        results[scheme] = per_profile
    return SweepResult(results=results, sims=all_sims,
                       host_seconds=host_seconds)
