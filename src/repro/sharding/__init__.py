"""Multi-pod sharding policies (fsdp_tp / tp_only / dp_only)."""
from repro.sharding import policy

__all__ = ["policy"]
