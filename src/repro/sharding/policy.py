"""Sharding policies: map every param / batch / cache leaf to a PartitionSpec.

Policies (DESIGN.md §5):
  fsdp_tp  — params 2-D sharded: tensor-parallel dim over `model`, FSDP dim
             over `data`; batch over (`pod`, `data`).  Train default.
  tp_only  — params sharded over `model` only (replicated over `data`);
             removes per-step FSDP all-gathers.  Serving-optimized (§Perf).
  dp_only  — pure data parallel (small models).

Divisibility is checked per leaf: a dim is only sharded when its size is a
multiple of the axis size (e.g. internvl2's 14 heads / whisper's 8 heads
fall back to replicated attention; 151655-entry vocabs shard d_model
instead — DESIGN.md §5).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

DATA, MODEL, POD = "data", "model", "pod"
AXIS_SIZE = {DATA: 16, MODEL: 16, POD: 2}


def _div(n: int, axis: str | None) -> bool:
    return axis is not None and n % AXIS_SIZE[axis] == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _rule(name: str, shape: tuple[int, ...], fsdp, tp,
          expert_parallel: bool = False) -> tuple:
    """Spec for one *logical* (unstacked) param leaf."""
    nd = len(shape)
    leaf = name.rsplit("/", 1)[-1]

    def fs(dim):                        # fsdp if divisible
        return fsdp if _div(shape[dim], fsdp) else None

    def mp(dim):                        # tensor-parallel if divisible
        return tp if _div(shape[dim], tp) else None

    # ---------- embeddings / heads
    # NOTE: never FSDP-shard a contraction dim that shares the axis with the
    # batch sharding — XLA then replicates the full-batch activations
    # (measured: 2x37 GB f32 logits collectives on internvl2 train;
    # EXPERIMENTS.md §Perf iteration 3).  Vocab-dim (output) sharding only.
    if leaf == "embed":
        if _div(shape[0], tp):
            return (tp, None)
        return (None, mp(1))
    if leaf == "lm_head":
        if _div(shape[1], tp):
            return (None, tp)
        return (mp(0), None)
    if leaf in ("patch_proj", "enc_in_proj"):
        return (fs(0), mp(1))

    # ---------- attention (wq/wk/wv (D,H,hd), wo (H,hd,D))
    if leaf in ("wq", "wk", "wv") and nd == 3:
        if _div(shape[1], tp):
            return (fs(0), tp, None)
        return (None, None, None)       # tiny heads: replicate (see NOTE)
    if leaf == "wo" and nd == 3:
        if _div(shape[0], tp):
            return (tp, None, fs(2))
        return (None, None, None)

    # ---------- MLA
    if leaf == "w_dkv":
        return (fs(0), None)
    if leaf in ("w_uk", "w_uv"):
        return (None, mp(1), None)

    # ---------- MoE
    if leaf == "router":
        return (fs(0), None)
    if leaf in ("w1", "w3") and nd == 3:        # (E, D, F)
        if expert_parallel and _div(shape[0], tp):
            # experts over `model`, expert-FFN dim over `data` (2-D EP:
            # keeps per-device expert bytes bounded for 348B-expert jamba)
            return (tp, None, fs(2))
        return (None, fs(1), mp(2))             # TP within each expert
    if leaf == "w2" and nd == 3:                # (E, F, D)
        if expert_parallel and _div(shape[0], tp):
            return (tp, fs(1), None)
        return (None, mp(1), fs(2))
    if leaf in ("ws1", "ws3"):
        return (fs(0), mp(1))
    if leaf == "ws2":
        return (mp(0), fs(1))

    # ---------- dense FFN (w1/w3 (D,F), w2 (F,D)) & generic 2-D matmuls
    if leaf in ("w1", "w3", "wk_ffn") and nd == 2:
        return (fs(0), mp(1))
    if leaf == "w2" and nd == 2:
        return (mp(0), fs(1))

    # ---------- RWKV
    if leaf in ("wr", "wg") and nd == 2:
        return (fs(0), mp(1))
    if leaf == "wv" and nd == 2:                 # rwkv ffn (F, D)
        return (mp(0), fs(1))
    if leaf == "wk" and nd == 2:                 # rwkv (D, D) / ffn (D, F)
        return (fs(0), mp(1))
    if leaf == "wo" and nd == 2:
        return (mp(0), fs(1))
    if leaf == "wA":
        return (fs(0), None)
    if leaf == "wB":
        return (None, mp(1))
    if leaf == "u" and nd == 2:
        return (mp(0), None)

    # ---------- Mamba
    if leaf == "in_proj":
        return (fs(0), mp(1))
    if leaf == "conv_w":
        return (None, mp(1))
    if leaf in ("conv_b", "dt_proj_b", "D"):
        return (mp(0),)
    if leaf == "x_proj":
        return (mp(0), None)
    if leaf == "dt_proj_w":
        return (None, mp(1))
    if leaf == "A_log":
        return (mp(0), None)
    if leaf == "out_proj":
        return (mp(0), fs(1))

    # ---------- norms / scalars / small vectors: replicated
    return (None,) * nd


def _is_stacked(path_s: str) -> bool:
    return path_s.startswith("stage") or path_s.startswith("enc/") \
        or path_s.startswith("dec/")


def param_pspecs(abstract_params, policy: str = "fsdp_tp"):
    """PartitionSpec tree matching an abstract param tree.

    Policies: fsdp_tp | tp_only | dp_only, each with an optional `_ep`
    suffix (e.g. fsdp_tp_ep) that shards MoE experts over `model`
    (expert parallelism) instead of tensor-parallel within each expert —
    requires num_experts % 16 == 0 (deepseek 64e, jamba 16e).
    """
    ep = policy.endswith("_ep")
    base = policy[:-3] if ep else policy
    fsdp = DATA if base == "fsdp_tp" else None
    tp = MODEL if base in ("fsdp_tp", "tp_only") else None

    def spec(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if _is_stacked(ps):
            logical = shape[1:]
            return P(*((None,) + _rule(ps, logical, fsdp, tp, ep)))
        return P(*_rule(ps, shape, fsdp, tp, ep))

    return jax.tree_util.tree_map_with_path(spec, abstract_params)


def batch_pspecs(batch_specs, multi_pod: bool):
    """Batch dims over (pod, data); everything else replicated."""
    baxes = (POD, DATA) if multi_pod else (DATA,)

    def spec(path, leaf):
        if len(leaf.shape) == 0:
            return P()
        b = leaf.shape[0]
        n = 1
        for ax in baxes:
            n *= AXIS_SIZE[ax]
        first = baxes if b % n == 0 else None
        return P(first, *((None,) * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch_specs)


def cache_pspecs(abstract_cache, long_context: bool, multi_pod: bool):
    """KV/state cache sharding for decode.

    decode_32k: batch over `data`, cache seq over `model`.
    long_500k (batch=1): cache seq over (`data`,`model`); states over `model`.
    Leading stacked-layer dims are replicated.
    """
    seq_axes = (DATA, MODEL) if long_context else (MODEL,)

    def spec(path, leaf):
        ps = _path_str(path)
        leaf_name = ps.rsplit("/", 1)[-1]
        shape = leaf.shape
        # strip leading stacked layer dim(s): caches built by init_cache have
        # one leading (count,) axis for scanned stages / enc-dec layers.
        lead = 1
        logical = shape[lead:]
        nd = len(logical)
        if leaf_name == "pos":
            return P(*((None,) * len(shape)))
        batch = logical[0] if nd else 1
        b_axis = DATA if (not long_context and batch % AXIS_SIZE[DATA] == 0) \
            else None
        if leaf_name in ("k", "v", "xk", "xv"):          # (B, S, K, hd)
            seq = logical[1]
            s_ax = seq_axes if all(seq % AXIS_SIZE[a] == 0 for a in seq_axes) \
                and _prod(seq_axes) <= seq else None
            if s_ax is None and seq % AXIS_SIZE[MODEL] == 0:
                s_ax = (MODEL,)
            heads = logical[2]
            h_ax = MODEL if (s_ax is None and heads % AXIS_SIZE[MODEL] == 0) \
                else None
            return P(None, b_axis, s_ax, h_ax, None)
        if leaf_name in ("c", "kpe"):                    # MLA latent (B,S,r)
            seq = logical[1]
            s_ax = seq_axes if all(seq % AXIS_SIZE[a] == 0 for a in seq_axes) \
                else ((MODEL,) if seq % AXIS_SIZE[MODEL] == 0 else None)
            return P(None, b_axis, s_ax, None)
        if leaf_name == "wkv":                           # (B, H, hs, hs)
            h_ax = MODEL if logical[1] % AXIS_SIZE[MODEL] == 0 else None
            return P(None, b_axis, h_ax, None, None)
        if leaf_name in ("x_prev_mix", "x_prev_ffn"):    # (B, D)
            d_ax = MODEL if logical[1] % AXIS_SIZE[MODEL] == 0 else None
            return P(None, b_axis, d_ax)
        if leaf_name == "conv":                          # (B, K-1, din)
            d_ax = MODEL if logical[2] % AXIS_SIZE[MODEL] == 0 else None
            return P(None, b_axis, None, d_ax)
        if leaf_name == "h":                             # (B, din, N)
            d_ax = MODEL if logical[1] % AXIS_SIZE[MODEL] == 0 else None
            return P(None, b_axis, d_ax, None)
        return P(*((None,) * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, abstract_cache)


def _prod(axes):
    n = 1
    for a in axes:
        n *= AXIS_SIZE[a]
    return n
