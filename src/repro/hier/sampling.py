"""Per-round client sampling with coded compensation (hierarchical tier).

Sampling gets its OWN seeded RNG stream, exactly like the fault stream
(`repro.faults.inject`, ``seed + 7717``) and the trace stream
(``seed + 9973``): the cohort draws live at ``fl.seed + SAMPLE_SEED_OFFSET``
and consume a fixed layout — one uniform block of shape ``(rounds, n)``
per block of rounds, drawn whether or not ``sample_fraction < 1.0``.
Two invariants follow (the same contract PRs 5/8 pinned for traces and
faults, enforced by tests/test_hier.py):

  * toggling ``sample_fraction`` never shifts the delay, channel-trace,
    or fault realizations — those streams are never touched;
  * the stream position checkpoints/resumes bit-identically through
    `RunState.sample_rng_state` — uniform blocks are drawn row-major over
    rounds, so any block partition of a run consumes the same draws.

Coded compensation: under Bernoulli(f) sampling only ~f of the client
mass participates, so the expected returned client mass shrinks from
``R = sum_j l_j P(T_j <= t*)`` to ``f * R``.  The global parity gradient
was built (paper §III-D) to stand in for the *expected missing mass*
``m - R``; `parity_reweight` scales it by ``(m - f R) / (m - R)`` so it
stands in for the larger sampled-round miss ``m - f R`` instead, keeping
``E[g_round] ~= m * grad`` — an unbiased SGD step at every f, with the
reweight exactly 1.0 at f = 1 (the flat engine's update, bit-identical).
"""
from __future__ import annotations

import numpy as np

#: dedicated sampling-stream seed offset (delay draws live at +17, the
#: subset permutation at +99, secure-agg at +1234, faults at +7717,
#: traces at +9973 — all disjoint by construction)
SAMPLE_SEED_OFFSET = 5557


def sampling_rng(fl_seed: int) -> np.random.Generator:
    """Fresh generator at the start of the dedicated sampling stream."""
    return np.random.default_rng((fl_seed + SAMPLE_SEED_OFFSET,))


def sample_cohort_rows(rng: np.random.Generator, rounds: int, n: int,
                       sample_fraction: float) -> np.ndarray:
    """Per-round Bernoulli(f) cohort masks, (rounds, n) bool.

    Fixed layout: ONE uniform block of shape (rounds, n) is drawn per
    call regardless of ``sample_fraction`` (f = 1.0 draws too, and every
    client is then in-cohort with certainty), so toggling f re-interprets
    the same uniforms rather than consuming a different stream prefix.
    """
    u = rng.random((rounds, n))
    return u < float(sample_fraction)


def parity_reweight(m: float, expected_return_mass: float,
                    sample_fraction: float) -> float:
    """Coded-compensation scale on the parity gradient (module docstring).

        w(f) = (m - f * R) / (m - R),   R = sum_j l_j P(T_j <= t*)

    w(1.0) == 1.0 exactly; w grows as f shrinks (the parity set covers
    the unsampled mass on top of the usual straggled mass).  R is clipped
    a hair below m so a deployment whose clients return almost surely
    degrades to a finite reweight instead of dividing by zero.
    """
    m = float(m)
    r = min(float(expected_return_mass), m * (1.0 - 1e-9))
    f = float(sample_fraction)
    if not 0.0 < f <= 1.0:
        raise ValueError(f"sample_fraction={f} must lie in (0, 1]")
    if f == 1.0:
        return 1.0
    return (m - f * r) / (m - r)
