"""Chunked/streamed population state for the hierarchical tier.

Three facilities, all O(block) memory so a population of n = 1e5-1e6
clients never materializes an O(n) dense intermediate:

  * `population_delay_arrays` — the `delay_model.mec_network` +
    `scale_tau` deployment as stacked ``(n,)`` float64 arrays (the
    `stack_node_params` layout), value-identical to building the n
    Python `NodeDelayParams` objects but a handful of vectorized numpy
    ops; `nodes_for_range` materializes node objects only for the
    shard/stripe actually being processed.
  * `two_step_allocate_chunked` — scan-over-blocks variant of
    `load_allocation.two_step_allocate_vectorized`: step 1 runs the same
    fixed-iteration golden-section program (`_vec_optimal_loads`) one
    node-block at a time inside a `lax.scan`, and the step-2
    bracket/bisection totals are accumulated through a fixed-stripe
    sequential fold (strict left-fold down each global `SUM_STRIPE`-wide
    stripe, stripe sums folded in global stripe order).
  * `generate_trace_chunked` / `iter_trace_chunks` — client-chunked
    channel-trace generation: clients are keyed in fixed-width stripes,
    each stripe an independent `(seed, stripe_index)`-keyed stream, so
    any block partition of the client axis reproduces the same trace.

Bit-equality contract (the PR 7 padding-edge idiom, extended to the
client axis): the chunked solver and the chunked trace generator return
BIT-IDENTICAL results for every block size, including the single-block
call that *is* the dense one-shot path — exactly the contract
`net/trace.generate_trace` already has with `generate_trace_block` over
the rounds axis.  Two deliberate design points make that possible:

  * The solver's total expected return is accumulated through a fixed
    global-stripe association, never with `jnp.sum` over the whole
    population: XLA's dense reduction is SIMD/pairwise-associated, so
    its bit pattern depends on the array length — a partition-dependent
    total would flip knife-edge bisection decisions.  Each absolute
    `SUM_STRIPE`-wide stripe of the node axis is summed by a strict
    left fold (vectorized ACROSS stripes, serial only down the stripe),
    and stripe sums are folded into the carried total in global stripe
    order.  Because block boundaries are rounded up to stripe multiples,
    every stripe lives inside one block with its elements at fixed
    stripe-local slots, so every block partition computes bit-identical
    stripe sums and folds them in the same order; dead padding
    contributes an exact +0.0 at each fold step.  Agreement with the
    dense `two_step_allocate_vectorized` holds to the solver's bisection
    tolerance.
  * The trace generator cannot stride a single flat RNG stream across
    client columns — the normal draws are ziggurat rejection-sampled, so
    per-client consumption is data-dependent.  Instead randomness is
    keyed per fixed-width client stripe; blocks materialize only the
    stripes they overlap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_allocation
from repro.core.delay_model import (NodeDelayParams, mec_network,
                                    packet_bits, scale_tau)
from repro.net.trace import NetworkTrace, generate_trace

#: default node-block width of the chunked solver (solver memory is
#: O(block * pieces * transmission-grid columns), not O(n))
DEFAULT_BLOCK = 2048

#: fixed client-stripe width of the chunked trace generator; part of the
#: RNG layout, so changing it changes trace realizations (block sizes
#: never do)
TRACE_STRIPE = 1024

#: fixed stripe width of the solver's sequential total fold; part of the
#: floating-point association, so changing it perturbs totals at the
#: rounding level (block sizes, which are rounded up to a multiple of
#: this, never do)
SUM_STRIPE = 128


# --------------------------------------------------------------- deployment
def population_delay_arrays(fl_cfg, d_scalars_per_point: int,
                            payload_scalars: int | None = None) -> dict:
    """The `mec_network` deployment as stacked ``(n,)`` float64 arrays.

    Value-identical to ``stack_node_params([scale_tau(nd, payload) for nd
    in mec_network(fl_cfg, d_scalars_per_point)])`` — same RNG
    (``default_rng(fl_cfg.seed)``), same shuffle order, same per-element
    arithmetic — without constructing n Python node objects.
    `payload_scalars` is the per-round model/gradient packet size in
    scalars (defaults to `d_scalars_per_point`, the flat engine's q*c).
    """
    rng = np.random.default_rng(fl_cfg.seed)
    n = fl_cfg.n_clients
    rate_factors = fl_cfg.rate_decay ** np.arange(n)
    mac_factors = fl_cfg.mac_decay ** np.arange(n)
    rng.shuffle(rate_factors)
    rng.shuffle(mac_factors)
    rates = fl_cfg.max_rate_bps * rate_factors
    macs = fl_cfg.max_mac_rate * mac_factors
    payload = packet_bits(
        fl_cfg, d_scalars_per_point if payload_scalars is None
        else payload_scalars)
    tau = (1.0 / rates) * payload
    full = lambda v: np.full(n, v, np.float64)
    return {
        "mu": (macs / float(d_scalars_per_point)).astype(np.float64),
        "alpha": full(fl_cfg.alpha),
        "tau_down": tau.astype(np.float64),
        "tau_up": tau.astype(np.float64),
        "p_down": full(fl_cfg.p_erasure),
        "p_up": full(fl_cfg.p_erasure),
    }


def nodes_for_range(prm: dict, lo: int, hi: int) -> list[NodeDelayParams]:
    """Materialize `NodeDelayParams` objects for clients [lo, hi) only.

    Symmetric entries (tau_up == tau_down, p_up == p_down) come back as
    reciprocal-link nodes (tau_up/p_up left None), matching what
    `mec_network` builds, so downstream symmetric fast paths still fire.
    """
    out = []
    for j in range(lo, hi):
        sym = (prm["tau_up"][j] == prm["tau_down"][j]
               and prm["p_up"][j] == prm["p_down"][j])
        out.append(NodeDelayParams(
            mu=float(prm["mu"][j]), alpha=float(prm["alpha"][j]),
            tau=float(prm["tau_down"][j]), p=float(prm["p_down"][j]),
            tau_up=None if sym else float(prm["tau_up"][j]),
            p_up=None if sym else float(prm["p_up"][j])))
    return out


def population_nodes(fl_cfg, d_scalars_per_point: int, lo: int,
                     hi: int) -> list[NodeDelayParams]:
    """Nodes [lo, hi) of the scaled `mec_network` deployment.

    Convenience composition of `population_delay_arrays` +
    `nodes_for_range`; node-for-node equal to slicing
    ``[scale_tau(nd, payload) for nd in mec_network(...)]``.
    """
    return nodes_for_range(
        population_delay_arrays(fl_cfg, d_scalars_per_point), lo, hi)


def _oracle_nodes(fl_cfg, d_scalars_per_point: int) -> list[NodeDelayParams]:
    """The flat engine's node list (test oracle for the array path)."""
    payload = packet_bits(fl_cfg, d_scalars_per_point)
    return [scale_tau(nd, payload)
            for nd in mec_network(fl_cfg, d_scalars_per_point)]


def return_prob(prm: dict, lo: int, hi: int, t: float,
                loads) -> np.ndarray:
    """Vectorized P(T_j <= t) at per-client loads, clients [lo, hi).

    The symmetric-link `NodeDelayParams.cdf` (paper eq. 42 / Theorem 1)
    over stacked arrays: one shared transmission grid up to the
    population's largest per-node cap, masked per node — O(shard) memory
    instead of a Python object + grid per client.  Values agree with the
    per-node scalar cdf to float tolerance (the row-wise reduction is not
    the scalar path's 1-D `np.sum`); clients with load <= 0 report the
    pure-communication probability, callers zero them out when mirroring
    the flat engine's ``loads > 0`` gate.
    """
    tau = prm["tau_down"][lo:hi]
    p = prm["p_down"][lo:hi]
    mu = prm["mu"][lo:hi]
    al = prm["alpha"][lo:hi]
    if not (np.array_equal(prm["p_down"][lo:hi], prm["p_up"][lo:hi])
            and np.array_equal(prm["tau_down"][lo:hi],
                               prm["tau_up"][lo:hi])):
        raise NotImplementedError(
            "return_prob covers the paper's reciprocal links only; "
            "asymmetric populations go through NodeDelayParams.cdf")
    ld = np.asarray(loads, np.float64)
    v_m = np.floor(t / tau - 1e-12).astype(np.int64)
    tail = np.where(
        p > 0.0,
        2 + np.ceil(-14.0 / np.log10(np.maximum(p, 1e-300))) + 10,
        2.0).astype(np.int64)
    cap = np.minimum(v_m, tail)
    v_hi = int(max(2, cap.max())) if cap.size else 2
    v = np.arange(2, v_hi + 1, dtype=np.float64)              # (V,)
    h = (v - 1.0) * (1.0 - p[:, None]) ** 2 * p[:, None] ** (v - 2.0)
    det = np.where(ld > 0.0, ld / mu, 0.0)
    slack = t - det[:, None] - tau[:, None] * v
    ok = (v[None, :] <= cap[:, None]) & (slack > 0.0)
    rate = np.where(ld > 0.0, al * mu / np.maximum(ld, 1e-300), 0.0)
    inner = np.where(ld[:, None] > 0.0,
                     1.0 - np.exp(-rate[:, None] * np.maximum(slack, 0.0)),
                     1.0)
    out = np.minimum(np.sum(np.where(ok, h * inner, 0.0), axis=1), 1.0)
    return np.where((cap >= 2) & (t > 2.0 * tau), out, 0.0)


# ----------------------------------------------------------- chunked solver
def _block_grids(p_d, p_u, tau_d, tau_u, *, sym: bool, v_cap: int,
                 vd_cap: int, vu_cap: int):
    """In-jit `_transmission_grids` for one node block, (B, K) each.

    Grid widths are STATIC population-wide caps (computed from the whole
    population's largest erasure probabilities), so every block — and
    every block *partition* — runs the same per-node arithmetic.
    """
    if sym:
        v = jnp.arange(2, v_cap + 1, dtype=p_d.dtype)
        h = (v - 1.0) * (1.0 - p_d[:, None]) ** 2 * p_d[:, None] ** (v - 2.0)
        return h, tau_d[:, None] * v
    vd = jnp.arange(1, vd_cap + 1, dtype=p_d.dtype)
    vu = jnp.arange(1, vu_cap + 1, dtype=p_u.dtype)
    b = p_d.shape[0]
    h_d = (1.0 - p_d[:, None]) * p_d[:, None] ** (vd - 1.0)
    h_u = (1.0 - p_u[:, None]) * p_u[:, None] ** (vu - 1.0)
    h = (h_d[:, :, None] * h_u[:, None, :]).reshape(b, -1)
    comm = ((tau_d[:, None] * vd)[:, :, None]
            + (tau_u[:, None] * vu)[:, None, :]).reshape(b, -1)
    return h, comm


@functools.partial(jax.jit, static_argnames=("v_cap", "n_golden", "sym",
                                             "vd_cap", "vu_cap"))
def _chunk_total(mu, alpha, tau_d, tau_u, p_d, p_u, caps, t, *, v_cap: int,
                 n_golden: int, sym: bool, vd_cap: int, vu_cap: int):
    """Maximized total expected return at deadline t, scanned over blocks.

    All array args are (n_blocks, B) with B a multiple of `SUM_STRIPE`.
    The per-node optimum is the SAME fixed-iteration program as the
    dense solver (`load_allocation._vec_optimal_loads`); the total is
    the fixed-stripe sequential fold, bit-identical for every
    stripe-aligned block partition of the same node order (see module
    docstring).
    """
    def body(carry, blk):
        mu_b, al_b, td_b, tu_b, pd_b, pu_b, cap_b = blk
        h, comm = _block_grids(pd_b, pu_b, td_b, tu_b, sym=sym,
                               v_cap=v_cap, vd_cap=vd_cap, vu_cap=vu_cap)
        _, rets = load_allocation._vec_optimal_loads(
            mu_b, al_b, td_b, h, comm, cap_b, t,
            v_cap=v_cap, n_golden=n_golden)
        # strict left fold down each global stripe (rows), vectorized
        # across the block's stripes, then stripe sums folded in order
        rows = rets.reshape(-1, SUM_STRIPE)
        stripe_sums = jax.lax.fori_loop(
            0, SUM_STRIPE, lambda j, acc: acc + rows[:, j],
            jnp.zeros(rows.shape[0], rets.dtype))
        carry = jax.lax.fori_loop(
            0, stripe_sums.shape[0], lambda i, c: c + stripe_sums[i],
            carry)
        return carry, None
    tot, _ = jax.lax.scan(body, jnp.zeros((), mu.dtype),
                          (mu, alpha, tau_d, tau_u, p_d, p_u, caps))
    return tot


@functools.partial(jax.jit, static_argnames=("v_cap", "n_golden", "sym",
                                             "vd_cap", "vu_cap"))
def _chunk_extract(mu, alpha, tau_d, tau_u, p_d, p_u, caps, t, *,
                   v_cap: int, n_golden: int, sym: bool, vd_cap: int,
                   vu_cap: int):
    """Final per-node (loads, returns) at t*, scanned over blocks."""
    def body(_, blk):
        mu_b, al_b, td_b, tu_b, pd_b, pu_b, cap_b = blk
        h, comm = _block_grids(pd_b, pu_b, td_b, tu_b, sym=sym,
                               v_cap=v_cap, vd_cap=vd_cap, vu_cap=vu_cap)
        loads, rets = load_allocation._vec_optimal_loads(
            mu_b, al_b, td_b, h, comm, cap_b, t,
            v_cap=v_cap, n_golden=n_golden)
        return 0, (loads, rets)
    _, (loads, rets) = jax.lax.scan(
        body, 0, (mu, alpha, tau_d, tau_u, p_d, p_u, caps))
    return loads.reshape(-1), rets.reshape(-1)


def _stack_blocks(prm: dict, caps: np.ndarray, block_size: int):
    """Pad the population with dead tail nodes and reshape to blocks.

    Dead nodes (cap 0, erasure 0) contribute an exact +0.0 to the
    sequential total, so trailing padding never changes a single bit of
    any partition's result.
    """
    n = caps.shape[0]
    n_blocks = -(-n // block_size)
    pad = n_blocks * block_size - n
    def padded(arr, fill):
        return np.concatenate(
            [np.asarray(arr, np.float64), np.full(pad, fill, np.float64)]
        ).reshape(n_blocks, block_size)
    return (padded(prm["mu"], 1.0), padded(prm["alpha"], 1.0),
            padded(prm["tau_down"], 1.0), padded(prm["tau_up"], 1.0),
            padded(prm["p_down"], 0.0), padded(prm["p_up"], 0.0),
            padded(caps, 0.0))


def two_step_allocate_chunked(clients=None, client_caps=None,
                              server: NodeDelayParams | None = None,
                              u_max: float = 0.0, m: float = 0.0,
                              tol: float = 1e-6,
                              t_hi: float | None = None,
                              *, prm: dict | None = None,
                              block_size: int = DEFAULT_BLOCK,
                              n_golden: int = 52,
                              n_golden_search: int = 28,
                              n_bracket: int = 60,
                              n_bisect: int = 48
                              ) -> load_allocation.Allocation:
    """Scan-over-blocks two-step load allocation (paper eq. 23-27).

    Same contract as `two_step_allocate_vectorized` — same per-node step-1
    program, same bracket-doubling + fixed-iteration bisection over t —
    but step-1 intermediates are materialized one `block_size` node block
    at a time, so solver memory is O(block), not O(n).  Clients come in
    either as a `NodeDelayParams` list + `client_caps` (the flat call
    shape) or pre-stacked via ``prm`` (a `stack_node_params`-layout dict;
    ``client_caps`` then may be a scalar cap).  ``server=None`` models the
    paper's reliable-MEC assumption (u_max always returns).

    Bit-equality: results are identical for EVERY ``block_size``
    (internally rounded up to a `SUM_STRIPE` multiple so the total
    fold's stripes stay block-aligned), including the single-block call
    that is the dense one-shot path of this tier; agreement with
    `two_step_allocate_vectorized` holds to the solver tolerance (see
    module docstring for why the dense `jnp.sum` association cannot be
    chunked bit-exactly).
    """
    from jax.experimental import enable_x64
    if prm is None:
        prm = load_allocation.stack_node_params(list(clients))
    n = prm["mu"].shape[0]
    caps = np.asarray(client_caps, np.float64)
    if caps.ndim == 0:
        caps = np.full(n, float(caps), np.float64)
    if caps.shape != (n,):
        raise ValueError(f"caps shape {caps.shape} != ({n},)")
    if float(np.sum(caps)) + float(u_max) < float(m) - 1e-9:
        raise ValueError("infeasible: sum of caps + u_max < m")
    target = float(m)
    if server is not None:
        sprm = load_allocation.stack_node_params([server])
        prm = {k: np.concatenate([prm[k], sprm[k]]) for k in prm}
        caps = np.concatenate([caps, [float(u_max)]])
    else:
        target -= float(u_max)      # P(T_C <= t) = 1: u_max always returns
    if block_size < 1:
        raise ValueError(f"block_size={block_size} must be >= 1")
    # stripe-aligned blocks: the partition-independence of the total fold
    # needs every SUM_STRIPE-wide absolute stripe inside one block
    block_size = -(-min(block_size, prm["mu"].shape[0])
                   // SUM_STRIPE) * SUM_STRIPE
    sym = (np.array_equal(prm["p_down"], prm["p_up"])
           and np.array_equal(prm["tau_down"], prm["tau_up"]))
    v_cap = load_allocation._tail_v_cap(float(prm["p_down"].max()))
    vd_cap = load_allocation._geo_tail_cap(float(prm["p_down"].max()))
    vu_cap = load_allocation._geo_tail_cap(float(prm["p_up"].max()))
    blocks = _stack_blocks(prm, caps, block_size)
    static = dict(v_cap=v_cap, sym=sym, vd_cap=vd_cap, vu_cap=vu_cap)
    with enable_x64():
        args = tuple(jnp.asarray(b) for b in blocks)

        def total(t: float) -> float:
            return float(_chunk_total(*args, t,
                                      n_golden=n_golden_search, **static))

        # bracket + bisection replicate `_vec_two_step`'s float arithmetic
        # exactly (doubling, 0.5*(lo+hi) midpoints, >= target decisions)
        hi = float(t_hi if t_hi is not None else 1.0)
        k = 0
        while total(hi) < target and k < n_bracket:
            hi *= 2.0
            k += 1
        lo = 0.0
        for _ in range(n_bisect):
            mid = 0.5 * (lo + hi)
            if total(mid) >= target:
                hi = mid
            else:
                lo = mid
        t_star = hi
        loads, rets = _chunk_extract(*args, t_star,
                                     n_golden=n_golden, **static)
        loads = np.asarray(loads)[:n + (server is not None)]
        rets = np.asarray(rets)[:n + (server is not None)]
    if server is None:
        u_star, coded_ret = float(u_max), float(u_max)
    else:
        loads, u_star = loads[:-1], float(loads[-1])
        rets, coded_ret = rets[:-1], float(rets[-1])
    return load_allocation.Allocation(
        t_star=t_star, loads=loads, u_star=u_star, returns=rets,
        coded_return=coded_ret)


# ------------------------------------------------------------ chunked trace
def _trace_stripe(nodes_or_prm, profile, rounds: int, seed: int,
                  stripe_idx: int, lo: int, hi: int) -> NetworkTrace:
    """One full stripe's trace from its (seed, stripe_index)-keyed stream."""
    if isinstance(nodes_or_prm, dict):
        sub = nodes_for_range(nodes_or_prm, lo, hi)
    else:
        sub = list(nodes_or_prm[lo:hi])
    rng = np.random.default_rng((seed, stripe_idx))
    return generate_trace(sub, profile, rounds, rng)


def iter_trace_chunks(nodes_or_prm, profile, rounds: int, *, seed: int,
                      block_size: int, stripe: int = TRACE_STRIPE):
    """Yield ``(lo, hi, NetworkTrace)`` client blocks of a population trace.

    Clients are keyed in fixed-width stripes: stripe s draws from
    ``default_rng((seed, s))`` with the standard fixed per-dynamic layout
    of `generate_trace`.  A block materializes only the stripes it
    overlaps (memory O(rounds * (block_size + stripe))), and any block
    partition yields bit-identical values — the stripe width is part of
    the RNG layout, the block size never is.  `nodes_or_prm` is either a
    `NodeDelayParams` list or a `population_delay_arrays` dict.
    """
    if block_size < 1:
        raise ValueError(f"block_size={block_size} must be >= 1")
    if stripe < 1:
        raise ValueError(f"stripe={stripe} must be >= 1")
    n = (nodes_or_prm["mu"].shape[0] if isinstance(nodes_or_prm, dict)
         else len(nodes_or_prm))
    for lo in range(0, n, block_size):
        hi = min(lo + block_size, n)
        parts = []
        for s in range(lo // stripe, (hi - 1) // stripe + 1):
            s_lo, s_hi = s * stripe, min((s + 1) * stripe, n)
            tr = _trace_stripe(nodes_or_prm, profile, rounds, seed,
                               s, s_lo, s_hi)
            a, b = max(lo, s_lo) - s_lo, min(hi, s_hi) - s_lo
            parts.append((tr, a, b))
        yield lo, hi, NetworkTrace(
            mu_mult=np.concatenate([t.mu_mult[:, a:b]
                                    for t, a, b in parts], axis=1),
            tau_mult=np.concatenate([t.tau_mult[:, a:b]
                                     for t, a, b in parts], axis=1),
            p_down=np.concatenate([t.p_down[:, a:b]
                                   for t, a, b in parts], axis=1),
            p_up=np.concatenate([t.p_up[:, a:b]
                                 for t, a, b in parts], axis=1),
            active=np.concatenate([t.active[:, a:b]
                                   for t, a, b in parts], axis=1),
            profile=profile)


def generate_trace_chunked(nodes_or_prm, profile, rounds: int, *,
                           seed: int, block_size: int | None = None,
                           stripe: int = TRACE_STRIPE) -> NetworkTrace:
    """Assembled population trace (the dense one-shot of this tier).

    ``block_size=None`` (or >= n) generates in one block; smaller blocks
    stream through `iter_trace_chunks` and concatenate — bit-identical
    either way.  For n <= ``stripe`` the result is also bit-identical to
    the flat ``generate_trace(nodes, profile, rounds,
    default_rng((seed, 0)))`` (a single stripe IS that call).
    """
    n = (nodes_or_prm["mu"].shape[0] if isinstance(nodes_or_prm, dict)
         else len(nodes_or_prm))
    if block_size is None:
        block_size = max(1, n)
    chunks = [tr for _, _, tr in iter_trace_chunks(
        nodes_or_prm, profile, rounds, seed=seed, block_size=block_size,
        stripe=stripe)]
    return NetworkTrace(
        mu_mult=np.concatenate([t.mu_mult for t in chunks], axis=1),
        tau_mult=np.concatenate([t.tau_mult for t in chunks], axis=1),
        p_down=np.concatenate([t.p_down for t in chunks], axis=1),
        p_up=np.concatenate([t.p_up for t in chunks], axis=1),
        active=np.concatenate([t.active for t in chunks], axis=1),
        profile=profile)
