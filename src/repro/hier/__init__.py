"""Hierarchical population-scale tier (edge aggregators over client shards).

Scales the CodedFedL round from one MEC cell (n ~ 1e3) to a population of
n = 1e5-1e6 clients:

  * `repro.hier.population` — chunked/streamed population state: stacked
    delay-parameter arrays instead of n Python node objects, a
    scan-over-blocks two-step load-allocation solver, and a client-chunked
    channel-trace generator, all O(block) memory.
  * `repro.hier.sampling` — per-round client sampling on its own
    fixed-layout RNG stream plus the coded-compensation parity reweight
    that keeps the sampled update an unbiased SGD step.
  * `repro.hier.topology` — the two-level topology: edge-aggregator
    shards each run a coded round over their cohort and contribute one
    aggregate row to the server-level combine (`HierExperiment`).

`repro.api.build_experiment` routes specs with ``hier_shards > 1`` or
``sample_fraction < 1.0`` here; the identity configuration
(``hier_shards=1, sample_fraction=1.0``) stays on the flat engine, so its
trajectory is bit-identical to the pre-hier runtime.
"""
from repro.hier.population import (generate_trace_chunked,  # noqa: F401
                                   iter_trace_chunks,
                                   nodes_for_range,
                                   population_delay_arrays,
                                   two_step_allocate_chunked)
from repro.hier.sampling import (SAMPLE_SEED_OFFSET,  # noqa: F401
                                 parity_reweight, sample_cohort_rows,
                                 sampling_rng)
from repro.hier.topology import (HierExperiment, HierResult,  # noqa: F401
                                 ShardPlan, shard_ranges)

__all__ = [
    "HierExperiment", "HierResult", "ShardPlan", "shard_ranges",
    "SAMPLE_SEED_OFFSET", "parity_reweight", "sample_cohort_rows",
    "sampling_rng", "generate_trace_chunked", "iter_trace_chunks",
    "nodes_for_range", "population_delay_arrays",
    "two_step_allocate_chunked",
]
