"""Two-level hierarchical topology: edge aggregators over client shards.

`HierExperiment` scales the CodedFedL round from one MEC cell to a
population of n = 1e5-1e6 clients by partitioning the population into
``spec.hier_shards`` contiguous edge-aggregator shards (Das et al.,
arXiv 2302.12305 style).  Each shard runs the paper's static coded round
over its own cohort — its own two-step load allocation (the chunked
solver, `repro.hier.population`), its own deadline t*_s, its own global
parity set encoded from its clients — and contributes ONE aggregate
gradient row to the server-level combine.  The server round completes
when the slowest edge aggregator does (``t_round = max_s t*_s``) and
applies the flat engine's update rule

    theta <- theta - lr * (g_sum / m + l2 * theta),    m = n * l,

with ``g_sum`` the sum of the shard rows.

Per-round client sampling (``spec.sample_fraction`` < 1, Bernoulli(f)
cohorts from the dedicated `repro.hier.sampling` stream) drops clients
from a round without touching the delay stream; every shard's parity
gradient is scaled by the coded-compensation reweight
`sampling.parity_reweight` so the update stays an unbiased SGD step
(arXiv 2201.10092's stochastic-coded reading: the unsampled mass is
noise the parity set stands in for).

Memory contract: nothing O(n * l * q) is ever materialized.  Client
tensors exist one shard at a time — the peak transient is the largest
shard's ``(n_s, l, q)`` feature block plus its ``(n_s, q, c)`` gradient
stack (`peak_client_tensor_bytes`), so choosing ``hier_shards ~ n /
cohort`` makes peak client-tensor memory O(active cohort).  Population
state is O(n) scalars only (stacked delay arrays, loads, per-round delay
and cohort rows).

Two deliberate divergences from the flat engine (the identity
configuration ``hier_shards=1, sample_fraction=1.0`` never sees them —
`repro.api.build_experiment` routes it to the flat `Experiment`, so its
trajectory is bit-identical to the pre-hier runtime by construction):

  * processed subsets are load-PREFIXES of each client's local set (the
    adaptive family's re-masking idiom) instead of the flat engine's
    O(n * l) permuted subsets — local points are i.i.d. so prefixes are
    statistically equivalent and need no per-client permutation state;
  * per-client return probabilities come from the vectorized
    `population.return_prob` (same Theorem-1 cdf, float-tolerance equal
    to the per-node scalar path).

Resumability: `RunState` (mode ``"hier"``) carries the delay-stream AND
the sampling-stream RNG positions; both streams are consumed row-major
over rounds, so any block partition of a run — and any kill/resume at a
block boundary — replays bit-identically.
"""
from __future__ import annotations

import dataclasses
import os
import types
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.config import ExperimentSpec
from repro.core import aggregation, encoding
from repro.core import schemes as schemes_registry
from repro.core.delay_model import packet_bits, sample_round_times_stacked
from repro.core.run_state import RunState, pack_state, unpack_state
from repro.hier import population, sampling
from repro.obs import spans as obs_spans

#: default client block width of the streamed parity encode (encode
#: memory is O(encode_block * u * l), never O(n_s * u * l))
DEFAULT_ENCODE_BLOCK = 1024


def shard_ranges(n: int, shards: int) -> list[tuple[int, int]]:
    """Balanced contiguous client ranges [(lo, hi), ...] for the shards.

    The first ``n % shards`` shards take one extra client, so shard sizes
    differ by at most one (at most two distinct per-shard tensor shapes
    to compile).
    """
    if not isinstance(shards, int) or isinstance(shards, bool) or shards < 1:
        raise ValueError(f"hier_shards must be an int >= 1, got {shards!r}")
    if shards > n:
        raise ValueError(
            f"hier_shards={shards} exceeds the population n_clients={n}")
    base, rem = divmod(n, shards)
    out, lo = [], 0
    for s in range(shards):
        hi = lo + base + (1 if s < rem else 0)
        out.append((lo, hi))
        lo = hi
    return out


@dataclasses.dataclass
class ShardPlan:
    """One edge aggregator's frozen deployment (host-side setup output)."""
    lo: int                      # client range [lo, hi)
    hi: int
    t_star: float                # shard deadline (chunked two-step solve)
    u: int                       # shard parity rows
    loads: np.ndarray            # (n_s,) int optimal per-client loads
    p_return: np.ndarray         # (n_s,) P(T_j <= t*_s) at its load
    gmask: jnp.ndarray           # (n_s, l) f32 prefix-validity mask
    parity_x: jnp.ndarray        # (u, q) shard-global parity features
    parity_y: jnp.ndarray        # (u, c) shard-global parity targets
    parity_weight: float         # coded-compensation reweight w(f)
    expected_return_mass: float  # R_s = sum_j l_j P(T_j <= t*_s)
    setup_time: float            # one-time parity-upload overhead (s)

    @property
    def n_clients(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass
class HierResult:
    """Completed hierarchical run (the tier's `FedResult` analogue)."""
    theta: jnp.ndarray           # (q, c) final iterate
    t_rounds: np.ndarray         # (iterations,) simulated round times
    n_ret: np.ndarray            # (iterations,) in-cohort returns by t*
    wall_clock: np.ndarray       # setup_time + cumsum(t_rounds)
    setup_time: float            # max over shards
    t_round: float               # max_s t*_s (server combine deadline)
    shards: int
    sample_fraction: float
    plans: list                  # per-shard `ShardPlan` provenance


def _coded_static_names() -> tuple[str, ...]:
    """Registered coded-family schemes with the STATIC coded step."""
    return tuple(n for n in schemes_registry.coded_names()
                 if schemes_registry.get_scheme(n).step_kind == "coded")


class HierExperiment:
    """One runnable hierarchical deployment (module docstring).

    Data comes in either dense — ``x_stack (n, l, q)``, ``y_stack
    (n, l, c)``, sliced per shard — or streamed via ``data_fn(lo, hi) ->
    (x, y)`` returning the block for clients [lo, hi), so a population
    whose dense tensors would not fit in host memory never materializes
    them (the scale benchmark's path).  ``solver_block`` is the chunked
    allocation solver's node-block width (never changes results — the
    solver is bit-identical across block sizes); ``encode_block`` bounds
    the streamed parity encode's transient.

    The driving surface mirrors the flat engine: `init_state` /
    `run_block` / `finish` over an explicit `RunState` (mode "hier"),
    `save_state` / `restore_state` checkpoints with spec provenance, and
    `run` chaining them block by block.
    """

    def __init__(self, spec: ExperimentSpec, x_stack=None, y_stack=None, *,
                 data_fn: Optional[Callable] = None,
                 rng: Optional[np.random.Generator] = None,
                 solver_block: Optional[int] = None,
                 encode_block: int = DEFAULT_ENCODE_BLOCK,
                 solver_kwargs: Optional[dict] = None):
        if not isinstance(spec, ExperimentSpec):
            raise TypeError(
                f"spec must be an ExperimentSpec, got {type(spec).__name__}")
        if spec.engine != "batched":
            raise ValueError(
                "the hierarchical tier requires the batched engine "
                f"(spec.engine={spec.engine!r})")
        self.spec = spec
        self.scheme = spec.resolved_scheme
        self.scheme_obj = schemes_registry.get_scheme(self.scheme)
        if self.scheme_obj.step_kind != "coded":
            raise ValueError(
                f"scheme {self.scheme!r} (step_kind="
                f"{self.scheme_obj.step_kind!r}) cannot drive the "
                "hierarchical tier: edge aggregators run the static coded "
                "round — expected one of the registered coded-family "
                f"schemes {_coded_static_names()}")
        self.scheme_params = spec.scheme_params_dict
        fl = spec.resolved_fl()
        self.fl = fl
        self.train = spec.train
        self.n = fl.n_clients
        self.sample_fraction = float(spec.sample_fraction)
        self.steps_per_epoch = spec.steps_per_epoch
        self.checkpoint_every = spec.checkpoint_every
        self._use_pallas = spec.kernel_backend == "pallas"
        self._interpret = jax.default_backend() != "tpu"
        # --- data plumbing: dense slices or a streaming block callable
        if data_fn is not None:
            if x_stack is not None or y_stack is not None:
                raise ValueError(
                    "pass dense x_stack/y_stack OR a data_fn, not both")
            probe_x, probe_y = data_fn(0, 1)
            probe_x, probe_y = np.asarray(probe_x), np.asarray(probe_y)
            if probe_x.ndim != 3 or probe_y.ndim != 3 \
                    or probe_x.shape[0] != 1 or probe_y.shape[0] != 1 \
                    or probe_x.shape[1] != probe_y.shape[1]:
                raise ValueError(
                    "data_fn(0, 1) must return ((1, l, q), (1, l, c)) "
                    f"blocks, got {probe_x.shape} / {probe_y.shape}")
            self.l, self.q = int(probe_x.shape[1]), int(probe_x.shape[2])
            self.c = int(probe_y.shape[2])
            self._data = data_fn
        else:
            if x_stack is None or y_stack is None:
                raise ValueError("HierExperiment needs x_stack/y_stack "
                                 "or a data_fn")
            x = np.asarray(x_stack)
            y = np.asarray(y_stack)
            if x.shape[0] != self.n:
                raise ValueError(
                    f"x_stack covers {x.shape[0]} clients but "
                    f"fl.n_clients={self.n}")
            self.l, self.q = int(x.shape[1]), int(x.shape[2])
            self.c = int(y.shape[2])
            self._x_np, self._y_np = x, y
            self._data = lambda lo, hi: (self._x_np[lo:hi],
                                         self._y_np[lo:hi])
        self.m = self.n * self.l
        if encode_block < 1:
            raise ValueError(f"encode_block={encode_block} must be >= 1")
        self._encode_block = int(encode_block)
        self._solver_block = int(solver_block or population.DEFAULT_BLOCK)
        # chunked-solver iteration knobs (n_bisect/n_golden_search/...):
        # deterministic per value — the scale benchmark trades bisection
        # depth for wall-clock on its largest rungs
        self._solver_kwargs = dict(solver_kwargs or {})
        # --- population delay state: O(n) scalars, zero node objects
        self._prm = population.population_delay_arrays(fl, self.q * self.c)
        self._ranges = shard_ranges(self.n, spec.hier_shards)
        self._shard_fn = self._make_shard_fn()
        # telemetry capture (repro.obs): per-block delay/cohort references
        # kept only while spans are enabled, feeding `attribution()`
        self._attr_blocks: "list[dict]" = []
        with obs_spans.span("setup/experiment"):
            self.plans = [self._setup_shard(s, lo, hi)
                          for s, (lo, hi) in enumerate(self._ranges)]
        self.setup_time = max(p.setup_time for p in self.plans)
        self.t_round = max(p.t_star for p in self.plans)
        self._pop_loads = np.concatenate(
            [p.loads for p in self.plans]).astype(np.float64)
        self.rng = rng or np.random.default_rng(fl.seed + 17)
        self._sample_rng = sampling.sampling_rng(fl.seed)

    # -------------------------------------------------------------- setup
    def _setup_shard(self, s: int, lo: int, hi: int) -> ShardPlan:
        """One edge aggregator's coded deployment over clients [lo, hi)."""
        with obs_spans.span("hier/shard_setup"):
            return self._setup_shard_inner(s, lo, hi)

    def _setup_shard_inner(self, s: int, lo: int, hi: int) -> ShardPlan:
        fl = self.fl
        n_s = hi - lo
        m_s = n_s * self.l
        # redundancy rule via the registered scheme's own u_budget (the
        # shard IS the scheme's deployment, so partial_coded's u_fraction
        # etc. apply per shard)
        shim = types.SimpleNamespace(fl=fl, m=m_s,
                                     scheme_params=self.scheme_params)
        u_s = int(self.scheme_obj.u_budget(shim))
        sub = {k: v[lo:hi] for k, v in self._prm.items()}
        with obs_spans.span("solver/two_step"):
            alloc = population.two_step_allocate_chunked(
                prm=sub, client_caps=float(self.l), server=None,
                u_max=float(u_s), m=float(m_s),
                block_size=min(self._solver_block, n_s),
                **self._solver_kwargs)
        loads = np.minimum(np.floor(alloc.loads).astype(int), self.l)
        p_ret = population.return_prob(self._prm, lo, hi, alloc.t_star,
                                       loads)
        p_ret = np.where(loads > 0, p_ret, 0.0)
        # prefix processed subsets (module docstring): the first l*_j
        # points of each client's local set
        prefix = np.arange(self.l)[None, :] < loads[:, None]      # (n_s, l)
        w_stack = np.where(prefix, np.sqrt(1.0 - p_ret)[:, None],
                           1.0).astype(np.float32)
        # shard parity set, streamed encode_block clients at a time; the
        # key chain is the flat engine's seed+99 split chain folded per
        # shard, so shards draw disjoint private generators
        def _chain(key, _):
            key, subkey = jax.random.split(key)
            return key, subkey
        key = jax.random.fold_in(jax.random.PRNGKey(fl.seed + 99), s)
        px = jnp.zeros((u_s, self.q), jnp.float32)
        py = jnp.zeros((u_s, self.c), jnp.float32)
        with obs_spans.span("encode/parity"):
            for a in range(0, n_s, self._encode_block):
                b = min(a + self._encode_block, n_s)
                key, keys = jax.lax.scan(_chain, key, None, length=b - a)
                xb, yb = self._data(lo + a, lo + b)
                stacked = encoding.encode_local_batched(
                    keys, jnp.asarray(xb), jnp.asarray(yb),
                    jnp.asarray(w_stack[a:b]), u_s,
                    use_pallas=self._use_pallas, interpret=self._interpret)
                agg = encoding.aggregate_parity_stacked(stacked)
                px = px + agg.x
                py = py + agg.y
        r_mass = float(np.sum(loads * p_ret))
        w_f = sampling.parity_reweight(m_s, r_mass, self.sample_fraction)
        # one-time parity upload overhead (flat CodedScheme formula over
        # the stacked arrays)
        bits = packet_bits(fl, u_s * (self.q + self.c))
        unit = packet_bits(fl, self.q * self.c)
        setup = float(np.max(sub["tau_down"] / unit * bits
                             / (1.0 - sub["p_down"])))
        return ShardPlan(
            lo=lo, hi=hi, t_star=float(alloc.t_star), u=u_s, loads=loads,
            p_return=p_ret, gmask=jnp.asarray(prefix, jnp.float32),
            parity_x=px, parity_y=py, parity_weight=float(w_f),
            expected_return_mass=r_mass, setup_time=setup)

    def _make_shard_fn(self):
        """One edge aggregator's round: masked client sum + reweighted
        coded gradient, jitted once per distinct shard shape."""
        use_pallas, interpret = self._use_pallas, self._interpret

        @jax.jit
        def shard_round(x, y, gmask, ret, theta, par_x, par_y, w_par):
            grads = aggregation.batched_client_gradients(
                x, y, theta, mask=gmask,
                use_pallas=use_pallas, interpret=interpret)
            g = aggregation.masked_gradient_sum(grads, ret)
            return g + w_par * aggregation.coded_gradient(
                par_x, par_y, theta,
                use_pallas=use_pallas, interpret=interpret)
        return shard_round

    # ------------------------------------------------------------ schedule
    def _lr(self, epoch: int) -> float:
        lr = self.train.learning_rate
        for e in self.train.lr_decay_epochs:
            if epoch >= e:
                lr *= self.train.lr_decay
        return lr

    def _lr_schedule_range(self, r0: int, r1: int) -> np.ndarray:
        return np.array([self._lr(it // self.steps_per_epoch)
                         for it in range(r0, r1)], np.float32)

    # ------------------------------------------------------------- memory
    def peak_client_tensor_bytes(self) -> int:
        """Peak transient client-tensor footprint of one round (bytes):
        the largest shard's f32 feature/target block plus its gradient
        stack — the O(active cohort) quantity the scale artifact records."""
        n_s = max(hi - lo for lo, hi in self._ranges)
        return 4 * n_s * (self.l * (self.q + self.c) + self.q * self.c)

    def population_tensor_bytes(self) -> int:
        """Resident O(n)-scalar population state (bytes): stacked delay
        arrays + per-client loads (all float64)."""
        return 8 * self.n * (len(self._prm) + 1)

    # ------------------------------------------------------------- running
    def init_state(self, iterations: int) -> RunState:
        """Fresh mode-"hier" `RunState`, seeded from this experiment's
        live delay and sampling streams (back-to-back runs consume
        disjoint randomness, like the flat engine)."""
        iterations = int(iterations)
        if iterations < 1:
            raise ValueError(f"iterations={iterations} must be >= 1")
        self._attr_blocks = []   # attribution covers the new run only
        return RunState(
            mode="hier", iterations=iterations, rounds_done=0,
            realizations_done=0, n_realizations=None, collect=False,
            theta=jnp.zeros((self.q, self.c), jnp.float32),
            rng_state=self.rng.bit_generator.state,
            trace_call=-1, trace=None, est=None, controls=None,
            t_rounds=np.zeros(0, np.float64),
            n_ret=np.zeros(0, np.int32),
            losses=None, accs=None, sched=None,
            sample_rng_state=self._sample_rng.bit_generator.state)

    def run_block(self, state: RunState,
                  n_rounds: Optional[int] = None) -> RunState:
        """Advance a hierarchical run by one block (new state returned,
        input never mutated).  ``n_rounds`` defaults to
        ``spec.checkpoint_every``, or the remaining horizon when 0.

        Both RNG streams draw row-major (rounds, n) blocks, element
        order fixed, so every block partition consumes identical draws —
        kill/resume at any boundary is bit-identical.
        """
        if state.mode != "hier":
            raise ValueError(f"run_block(hier) got a {state.mode!r} state")
        if state.done:
            raise ValueError(
                "run is already complete "
                f"({state.rounds_done}/{state.iterations} rounds)")
        rng = np.random.default_rng()
        rng.bit_generator.state = state.rng_state
        srng = np.random.default_rng()
        srng.bit_generator.state = state.sample_rng_state
        r0 = state.rounds_done
        K = int(n_rounds) if n_rounds is not None else (
            self.checkpoint_every or state.iterations)
        if K < 1:
            raise ValueError(f"n_rounds={K} must be >= 1")
        K = min(K, state.iterations - r0)
        # both streams consume a FIXED per-round layout (delay: one
        # 3-draw row per round; sampling: one uniform row per round), so
        # the stream position depends only on the global round cursor —
        # every block partition of a run, and every kill/resume point,
        # replays bit-identically (stronger than the flat engine's
        # per-block draw layout)
        times = np.concatenate(
            [sample_round_times_stacked(self._prm, self._pop_loads, rng, 1)
             for _ in range(K)], axis=0)
        cohort = sampling.sample_cohort_rows(srng, K, self.n,
                                             self.sample_fraction)
        if obs_spans.enabled():
            self._attr_blocks.append({"times": times, "active": cohort})
        lrs = self._lr_schedule_range(r0, r0 + K)
        l2 = jnp.float32(self.train.l2_reg)
        m = jnp.float32(self.m)
        theta = state.theta
        n_ret_blk = np.zeros(K, np.int32)
        with obs_spans.span("hier/round_block"):
            for k in range(K):
                g = jnp.zeros((self.q, self.c), jnp.float32)
                returned = 0
                for plan in self.plans:
                    row = times[k, plan.lo:plan.hi]
                    ret = (row <= plan.t_star) & cohort[k, plan.lo:plan.hi]
                    returned += int(np.sum(ret))
                    xb, yb = self._data(plan.lo, plan.hi)
                    g = g + self._shard_fn(
                        jnp.asarray(xb, jnp.float32),
                        jnp.asarray(yb, jnp.float32),
                        plan.gmask, jnp.asarray(ret, jnp.float32), theta,
                        plan.parity_x, plan.parity_y,
                        jnp.float32(plan.parity_weight))
                theta = theta - jnp.float32(lrs[k]) * (g / m + l2 * theta)
                n_ret_blk[k] = returned
        return dataclasses.replace(
            state, rounds_done=r0 + K, theta=theta,
            rng_state=rng.bit_generator.state,
            sample_rng_state=srng.bit_generator.state,
            t_rounds=np.concatenate(
                [state.t_rounds, np.full(K, self.t_round, np.float64)]),
            n_ret=np.concatenate([state.n_ret, n_ret_blk]))

    # ------------------------------------------------------------ telemetry
    def attribution(self, k: int = 3) -> dict:
        """Per-shard straggler attribution (`repro.obs.attribution`) over
        the delay/cohort blocks captured while telemetry was enabled:
        ``{shard_index: Attribution}``, each shard attributed against its
        own deadline t*_s, loads, and data mass.  Covers rounds computed
        in this process since the last `init_state`/`restore_state`.
        Raises `RuntimeError` when nothing was captured."""
        from repro.obs.attribution import compute_attribution
        if not self._attr_blocks:
            raise RuntimeError(
                "no telemetry captured for this run: call "
                "repro.obs.spans.enable() before running, then "
                "attribution()")
        times = np.concatenate([b["times"] for b in self._attr_blocks])
        cohort = np.concatenate([b["active"] for b in self._attr_blocks])
        out = {}
        for s, plan in enumerate(self.plans):
            T = times.shape[0]
            deadline = np.full(T, float(plan.t_star), np.float64)
            out[s] = compute_attribution(
                times[:, plan.lo:plan.hi], cohort[:, plan.lo:plan.hi],
                deadline, loads=plan.loads,
                m=plan.n_clients * self.l, coded=True, k=k)
        return out

    # --------------------------------------------------------- checkpoints
    def save_state(self, path: str, state: RunState) -> str:
        """Checkpoint `state` atomically with spec provenance."""
        arrays, meta = pack_state(state)
        meta["spec"] = self.spec.to_dict()
        with obs_spans.span("checkpoint/save"):
            return ckpt_io.save_state(path, arrays, meta)

    def restore_state(self, path: str) -> RunState:
        """Load a checkpoint, verifying its spec matches this deployment."""
        self._attr_blocks = []   # attribution covers post-restore rounds
        with obs_spans.span("checkpoint/restore"):
            arrays, meta = ckpt_io.restore_state(path)
        spec_dict = meta.get("spec")
        if spec_dict is not None:
            saved = ExperimentSpec.from_dict(spec_dict)
            if saved != self.spec:
                raise ValueError(
                    f"checkpoint provenance mismatch: {path!r} was saved "
                    "by a run of a different ExperimentSpec than this "
                    "experiment's — refusing to resume across specs")
        return unpack_state(arrays, meta)

    # ----------------------------------------------------------- finishing
    def finish(self, state: RunState) -> HierResult:
        """Completed state -> `HierResult`; syncs both stream positions
        so back-to-back runs stay disjoint."""
        if not state.done:
            raise ValueError(
                f"run is not complete ({state.rounds_done}/"
                f"{state.iterations} rounds); call run_block until "
                "state.done")
        if state.mode != "hier":
            raise ValueError(f"finish(hier) got a {state.mode!r} state")
        self.rng.bit_generator.state = state.rng_state
        self._sample_rng.bit_generator.state = state.sample_rng_state
        return HierResult(
            theta=state.theta, t_rounds=np.asarray(state.t_rounds),
            n_ret=np.asarray(state.n_ret),
            wall_clock=self.setup_time + np.cumsum(state.t_rounds),
            setup_time=self.setup_time, t_round=self.t_round,
            shards=len(self.plans),
            sample_fraction=self.sample_fraction, plans=self.plans)

    def run(self, iterations: int, *,
            checkpoint_dir: Optional[str] = None, resume: bool = False,
            n_rounds: Optional[int] = None,
            journal_dir: Optional[str] = None) -> HierResult:
        """Run `iterations` rounds block by block (flat-engine driving
        contract: checkpoint every block boundary when a directory is
        given, ``resume=True`` restores the latest checkpoint there,
        ``journal_dir`` appends one `repro.obs` event per round — with
        the per-shard deadlines ``t_star_s`` — at the same boundaries)."""
        state = None
        if resume:
            if checkpoint_dir is None:
                raise ValueError("resume=True requires checkpoint_dir")
            latest = ckpt_io.latest_checkpoint(checkpoint_dir,
                                               valid_only=True)
            if latest is not None:
                state = self.restore_state(latest)
                if state.mode != "hier":
                    raise ValueError(
                        f"checkpoint {latest!r} holds a {state.mode!r} "
                        "run; resume it with the flat engine")
                if state.iterations != int(iterations):
                    raise ValueError(
                        f"checkpoint {latest!r} is a {state.iterations}-"
                        f"round run; this run asked for {iterations}")
        if state is None:
            state = self.init_state(iterations)
        journal = None
        if journal_dir is not None:
            from repro.obs.events import RunJournal
            journal = RunJournal(journal_dir)
            journal.reset_to(state.rounds_done)
            journal.sync(self, state)
        while not state.done:
            state = self.run_block(state, n_rounds)
            if checkpoint_dir is not None:
                self.save_state(
                    os.path.join(
                        checkpoint_dir,
                        f"{ckpt_io.CKPT_PREFIX}"
                        f"{state.rounds_done:06d}.npz"),
                    state)
            if journal is not None:
                journal.sync(self, state)
        return self.finish(state)
