"""Wireless network dynamics: time-varying channels, churn, adaptation.

The paper's delay model (`repro.core.delay_model`) is *stationary*: one
`NodeDelayParams` per node, frozen for the whole run, with the load
allocation solved exactly once at setup.  This package models what the
stationary view misses — links and compute that drift over a training run:

  channel.py    declarative `ChannelProfile` (Gilbert–Elliott erasure
                states, log-normal shadowing with an LTE MCS-style rate
                mapping, bounded compute-speed drift, dropout/rejoin
                churn) plus the named `CHANNEL_PROFILES` registry that
                `ExperimentSpec.channel_profile` addresses.
  trace.py      vectorized, deterministic-per-seed generation of
                `(rounds, n)` network-state trace tensors, and the traced
                delay sampler that extends
                `delay_model.sample_round_times` — bit-exactly equal to
                it under the static profile.
  estimator.py  online estimation of `(mu, tau, p)` from observed round
                telemetry (EWMA or windowed means) and the
                `AdaptiveController` that re-solves the load allocation
                every `adapt_every` rounds, emitting a per-round schedule
                the compiled scan engine consumes in ONE call.

Everything here is host-side NumPy: the network simulation never depends
on model state, so the whole control loop runs *before* the training scan
and the engine stays a single compiled program.
"""
from repro.net.channel import CHANNEL_PROFILES, ChannelProfile  # noqa: F401
from repro.net.trace import (NetworkTrace, TraceState,  # noqa: F401
                             generate_trace, generate_trace_block,
                             sample_round_observations,
                             sample_round_times_traced)
from repro.net.estimator import (AdaptiveController,  # noqa: F401
                                 AdaptiveSchedule, SegmentPlan,
                                 OnlineChannelEstimator, plan_segment)
