"""Online channel estimation and adaptive load re-allocation.

`OnlineChannelEstimator` turns the per-round telemetry the MEC
orchestrator collects (`trace.RoundObservations`) into running estimates
of every node's delay parameters ``(mu, tau, p)`` plus an availability
score.  It smooths the *sufficient statistics* — EWMAs by default,
windowed means otherwise (the exact MLE for the model's exponential /
geometric families over the window) — and inverts them only at readout,
so the estimates stay free of the Jensen bias that smoothing per-round
ratios would pick up:

  s_tau  <- (t_down + t_up) / N, N = n_down + n_up  (= tau exactly)
  s_ntr  <- N                      =>  p_hat  = 1 - 2 / s_ntr
  s_comp <- t_comp / load          =>  mu_hat = (1 + 1/alpha) / s_comp

`AdaptiveController` is the host-side control loop of the adaptive
schemes: it walks the training run in blocks of ``adapt_every`` rounds,
samples each block's delays through the network trace (consuming the
experiment's RNG exactly like the static pre-sampling path), feeds the
telemetry to the estimator, and asks the scheme to re-plan — re-solving
the paper's two-step load allocation on the *estimated* network for the
coded family, re-tuning the wait count for the greedy family.  The result
is an `AdaptiveSchedule` of dense per-round arrays (delays, availability,
deadlines, block-indexed load masks) that the compiled scan engine
consumes in ONE call: shapes never change across blocks, so adaptation
costs zero recompiles.

The network simulation never depends on model state, which is what lets
this whole loop run *before* the training scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.delay_model import NodeDelayParams
from repro.net.trace import (NetworkTrace, RoundObservations,
                             sample_round_observations)

# floors keeping estimated NodeDelayParams constructible under heavy noise
_MU_FLOOR = 1e-9
_TAU_FLOOR = 1e-12
_P_CEIL = 0.95


class OnlineChannelEstimator:
    """EWMA / windowed estimates of per-node (mu, tau, p, availability).

    Estimates warm-start from the *nominal* node parameters, so a
    controller that re-plans before any telemetry arrives reproduces the
    static allocation.  Telemetry from churned-out rounds never updates a
    node's link/compute estimates (no upload was seen), only its
    availability score.
    """

    def __init__(self, nodes: "list[NodeDelayParams]", *, beta: float = 0.25,
                 window: Optional[int] = None):
        if not (0.0 < beta <= 1.0):
            raise ValueError(f"beta={beta} must lie in (0, 1]")
        if window is not None and window < 1:
            raise ValueError(f"window={window} must be >= 1")
        self.n = len(nodes)
        self.alpha = np.array([nd.alpha for nd in nodes], np.float64)
        self.beta = float(beta)
        self.window = window
        # sufficient statistics, warm-started at their nominal expectations
        self._s_tau = np.array(
            [(nd.tau + nd._tau_up) / 2.0 for nd in nodes], np.float64)
        p0 = np.array([(nd.p + nd._p_up) / 2.0 for nd in nodes], np.float64)
        self._s_ntr = 2.0 / (1.0 - p0)
        mu0 = np.array([nd.mu for nd in nodes], np.float64)
        self._s_comp = (1.0 + 1.0 / self.alpha) / mu0
        self.avail_hat = np.ones(self.n, np.float64)
        self.rounds_seen = 0
        # ring buffers for the windowed mode (one (n,) row per round,
        # NaN = unobserved)
        self._win: dict[str, list[np.ndarray]] = {
            "comp": [], "tau": [], "ntr": [], "avail": []}

    # ------------------------------------------------------------- updates
    def update(self, obs: RoundObservations) -> None:
        """Fold a block of round observations in, one round at a time."""
        R = obs.total.shape[0]
        for r in range(R):
            seen = np.asarray(obs.active[r], bool)
            ntr = (obs.n_down[r] + obs.n_up[r]).astype(np.float64)
            tau_obs = np.where(seen, (obs.t_down[r] + obs.t_up[r])
                               / np.maximum(ntr, 1.0), np.nan)
            ntr_obs = np.where(seen, ntr, np.nan)
            loaded = seen & (obs.loads[r] > 0.0)
            comp_obs = np.where(
                loaded, obs.t_comp[r] / np.maximum(obs.loads[r], 1e-30),
                np.nan)
            if self.window is None:
                self._ewma("_s_tau", tau_obs)
                self._ewma("_s_ntr", ntr_obs)
                self._ewma("_s_comp", comp_obs)
                self.avail_hat = ((1.0 - self.beta) * self.avail_hat
                                  + self.beta * seen.astype(np.float64))
            else:
                self._push("tau", tau_obs)
                self._push("ntr", ntr_obs)
                self._push("comp", comp_obs)
                self._push("avail", seen.astype(np.float64))
            self.rounds_seen += 1
        if self.window is not None:
            self._refresh_windowed()

    def _ewma(self, attr: str, obs: np.ndarray) -> None:
        cur = getattr(self, attr)
        upd = (1.0 - self.beta) * cur + self.beta * obs
        setattr(self, attr, np.where(np.isnan(obs), cur, upd))

    def _push(self, key: str, row: np.ndarray) -> None:
        buf = self._win[key]
        buf.append(row)
        if len(buf) > self.window:
            del buf[: len(buf) - self.window]

    def _refresh_windowed(self) -> None:
        # explicit NaN-masked mean: an all-NaN column (a node unseen for
        # the whole window) keeps its previous estimate, without the
        # RuntimeWarning np.nanmean emits on empty slices
        for key, attr in (("comp", "_s_comp"), ("tau", "_s_tau"),
                          ("ntr", "_s_ntr"), ("avail", "avail_hat")):
            if not self._win[key]:
                continue
            stacked = np.stack(self._win[key])
            seen = ~np.isnan(stacked)
            count = seen.sum(axis=0)
            total = np.where(seen, stacked, 0.0).sum(axis=0)
            mean = total / np.maximum(count, 1)
            cur = getattr(self, attr)
            setattr(self, attr, np.where(count > 0, mean, cur))

    # ------------------------------------------------------------ readouts
    @property
    def mu_hat(self) -> np.ndarray:
        return (1.0 + 1.0 / self.alpha) / np.maximum(self._s_comp, 1e-30)

    @property
    def tau_hat(self) -> np.ndarray:
        return self._s_tau.copy()

    @property
    def p_hat(self) -> np.ndarray:
        return np.clip(1.0 - 2.0 / np.maximum(self._s_ntr, 2.0), 0.0,
                       _P_CEIL)

    def estimated_nodes(self) -> "list[NodeDelayParams]":
        """The estimated network, ready for the load-allocation solver."""
        mu = np.maximum(self.mu_hat, _MU_FLOOR)
        tau = np.maximum(self.tau_hat, _TAU_FLOOR)
        p = np.clip(self.p_hat, 0.0, _P_CEIL)
        return [NodeDelayParams(mu=float(mu[j]), alpha=float(self.alpha[j]),
                                tau=float(tau[j]), p=float(p[j]))
                for j in range(self.n)]

    def snapshot(self) -> dict:
        return {"mu": self.mu_hat.copy(), "tau": self.tau_hat.copy(),
                "p": self.p_hat.copy(), "avail": self.avail_hat.copy(),
                "rounds_seen": self.rounds_seen}

    # ------------------------------------------------------- checkpointing
    def state_dict(self) -> dict:
        """Everything needed to continue estimation bit-exactly: the
        sufficient statistics, availability score, round counter, and the
        windowed mode's ring buffers (stacked to (k, n) arrays)."""
        return {
            "beta": self.beta, "window": self.window,
            "rounds_seen": int(self.rounds_seen),
            "s_tau": self._s_tau.copy(), "s_ntr": self._s_ntr.copy(),
            "s_comp": self._s_comp.copy(),
            "avail_hat": self.avail_hat.copy(),
            "win": {key: (np.stack(buf) if buf
                          else np.zeros((0, self.n), np.float64))
                    for key, buf in self._win.items()},
        }

    def load_state_dict(self, state: dict) -> None:
        """Inverse of `state_dict`; the estimator must have been built
        with the same smoothing configuration (beta/window)."""
        if (float(state["beta"]) != self.beta
                or state["window"] != self.window):
            raise ValueError(
                f"estimator state was produced with beta={state['beta']}, "
                f"window={state['window']}; this estimator has "
                f"beta={self.beta}, window={self.window}")
        for attr, key in (("_s_tau", "s_tau"), ("_s_ntr", "s_ntr"),
                          ("_s_comp", "s_comp"), ("avail_hat", "avail_hat")):
            arr = np.asarray(state[key], np.float64)
            if arr.shape != (self.n,):
                raise ValueError(f"estimator state {key!r} has shape "
                                 f"{arr.shape}, expected ({self.n},)")
            setattr(self, attr, arr.copy())
        self.rounds_seen = int(state["rounds_seen"])
        self._win = {key: [np.asarray(row, np.float64).copy()
                           for row in np.asarray(state["win"][key])]
                     for key in self._win}


@dataclasses.dataclass
class AdaptiveSchedule:
    """Dense per-round control arrays for one adaptive run.

    ``times``/``active`` drive the round outcomes; ``block_idx`` maps each
    round to its allocation block; the coded family carries per-round
    deadlines (``t_star``) plus per-block load masks (``gmask_blocks``,
    shape (B, rows, L) — same row/point layout as the fused step tensors,
    so re-allocation is pure mask re-weighting); the greedy family carries
    per-round wait counts (``n_wait``).  ``loads_blocks`` and
    ``estimates`` record the controller's trajectory for inspection.
    """
    times: np.ndarray                       # (R, n) float64 delays
    active: np.ndarray                      # (R, n) float32 churn mask
    block_idx: np.ndarray                   # (R,) int32
    loads_blocks: np.ndarray                # (B, n) float64
    t_star: Optional[np.ndarray] = None     # (R,) float32 (coded family)
    n_wait: Optional[np.ndarray] = None     # (R,) int32  (greedy family)
    gmask_blocks: Optional[object] = None   # (B, rows, L) jnp.float32
    estimates: list = dataclasses.field(default_factory=list)

    @property
    def n_blocks(self) -> int:
        return self.loads_blocks.shape[0]


@dataclasses.dataclass
class SegmentPlan:
    """One contiguous segment of an adaptive run's control plan.

    Produced by `plan_segment` for global rounds ``[r0, r1)``; the
    per-round arrays are segment-local, ``block_idx`` indexes into this
    segment's ``loads_blocks``/``gmask_blocks``, and ``controls`` carries
    the live control values forward so the next segment continues exactly
    where this one stopped.
    """
    times: np.ndarray                       # (r1-r0, n) float64 delays
    active: np.ndarray                      # (r1-r0, n) float32 churn mask
    block_idx: np.ndarray                   # (r1-r0,) int32, segment-local
    t_star_r: np.ndarray                    # (r1-r0,) float32
    n_wait_r: np.ndarray                    # (r1-r0,) int32
    loads_blocks: np.ndarray                # (B_seg, n) float64
    gmask_blocks: Optional[object]          # (B_seg, rows, L) jnp (coded)
    estimates: list                         # one snapshot per sub-block
    controls: dict                          # {"loads","t_star","n_wait"}


def plan_segment(exp, estimator: OnlineChannelEstimator,
                 trace_seg: NetworkTrace, r0: int, r1: int,
                 controls: dict, rng: np.random.Generator) -> SegmentPlan:
    """Plan global rounds ``[r0, r1)`` of an adaptive run incrementally.

    `trace_seg` covers exactly this segment (local round 0 = global
    ``r0``); `controls` holds the loads/deadline/wait-count in effect at
    ``r0`` and `estimator` the telemetry folded in so far — together they
    are the full control-plane state, so chaining segments reproduces the
    one-shot plan bit-exactly as long as every segment boundary lands on
    an ``adapt_every`` multiple (the runtime validates that).  Re-planning
    happens at every global round that is a positive multiple of
    ``adapt_every``, including ``r0`` itself for a resumed segment.
    """
    K = exp.adapt_every
    n = exp.n
    R_seg = int(r1) - int(r0)
    if R_seg < 1:
        raise ValueError(f"empty segment [{r0}, {r1})")
    if trace_seg.rounds < R_seg:
        raise ValueError(f"trace segment covers {trace_seg.rounds} rounds, "
                         f"need {R_seg}")
    coded = exp.step_kind == "adaptive_coded"

    loads = np.asarray(controls["loads"], np.float64).copy()
    t_star = controls.get("t_star")
    n_wait = controls.get("n_wait")

    times = np.zeros((R_seg, n))
    active = np.zeros((R_seg, n), np.float32)
    block_idx = np.zeros(R_seg, np.int32)
    t_star_r = np.zeros(R_seg, np.float32)
    n_wait_r = np.zeros(R_seg, np.int32)
    loads_list, gmasks, estimates = [], [], []

    b_local = -1
    r = int(r0)
    while r < r1:
        if r > 0 and r % K == 0:
            plan_b = exp.scheme_obj.replan(exp, estimator)
            loads = np.asarray(plan_b.get("loads", loads), np.float64)
            t_star = plan_b.get("t_star", t_star)
            n_wait = plan_b.get("n_wait", n_wait)
        b_local += 1
        r_end = min(int(r1), (r // K + 1) * K)
        if coded:
            gmasks.append(exp.scheme_obj.gmask_for_loads(exp, loads))
        # block delays consume the run's RNG sequentially, exactly like
        # the static engine's one-shot pre-sampling
        obs = sample_round_observations(
            exp.nodes, loads, rng, trace_seg.slice(r - r0, r_end - r0))
        estimator.update(obs)
        lo, hi = r - r0, r_end - r0
        times[lo:hi] = obs.total
        active[lo:hi] = obs.active.astype(np.float32)
        block_idx[lo:hi] = b_local
        if t_star is not None:
            t_star_r[lo:hi] = t_star
        n_wait_r[lo:hi] = n_wait
        loads_list.append(loads.copy())
        estimates.append(estimator.snapshot())
        r = r_end

    gmask_blocks = None
    if coded:
        import jax.numpy as jnp
        gmask_blocks = jnp.stack(gmasks)
    return SegmentPlan(
        times=times, active=active, block_idx=block_idx,
        t_star_r=t_star_r, n_wait_r=n_wait_r,
        loads_blocks=np.stack(loads_list), gmask_blocks=gmask_blocks,
        estimates=estimates,
        controls={"loads": loads.copy(), "t_star": t_star,
                  "n_wait": n_wait})


class AdaptiveController:
    """Blockwise re-estimation + re-allocation ahead of the compiled scan."""

    def __init__(self, exp, trace: NetworkTrace, *,
                 estimator: Optional[OnlineChannelEstimator] = None):
        if exp.adapt_every < 1:
            raise ValueError(
                "adaptive schemes need ExperimentSpec.adapt_every >= 1 "
                f"(got {exp.adapt_every})")
        self.exp = exp
        self.trace = trace
        self.estimator = estimator or OnlineChannelEstimator(
            exp.nodes, **exp.scheme_params_estimator_kwargs())

    def plan(self, iterations: int) -> AdaptiveSchedule:
        """One-shot plan for a whole run: a single segment from round 0
        seeded with the scheme's setup-time controls."""
        exp = self.exp
        R = int(iterations)
        if self.trace.rounds < R:
            raise ValueError(f"trace covers {self.trace.rounds} rounds, "
                             f"need {R}")
        seg = plan_segment(exp, self.estimator, self.trace, 0, R,
                           exp.scheme_obj.initial_controls(exp), exp.rng)
        sched = AdaptiveSchedule(
            times=seg.times, active=seg.active, block_idx=seg.block_idx,
            loads_blocks=seg.loads_blocks, estimates=seg.estimates)
        if exp.step_kind == "adaptive_coded":
            sched.t_star = seg.t_star_r
            sched.gmask_blocks = seg.gmask_blocks
        else:
            sched.n_wait = seg.n_wait_r
        return sched
