"""Deterministic per-seed network-state traces and traced delay sampling.

`generate_trace` rolls a `ChannelProfile` forward for a whole training
run, producing dense ``(rounds, n)`` state tensors (erasure probabilities,
tau/mu multipliers, availability).  Under the hood it is a single-block
call of `generate_trace_block`, which advances an explicit resumable
`TraceState` (RNG bit-generator state + one recurrence vector per
dynamic) so the block-structured runtime can checkpoint a trace mid-run
and continue it bit-exactly.  `sample_round_observations` then draws
the per-round delays *through* that trace with the same three-draw layout
as `delay_model.sample_round_times` — one geometric draw per link
direction plus one exponential compute tail — so the batched engine keeps
pre-sampling an entire run in a handful of vectorized RNG calls.

Two contracts the tests pin down:

  * **Determinism** — equal (nodes, profile, rounds, seed) reproduce the
    trace array-for-array; the trace generator always consumes the same
    RNG layout (one uniform/normal block per dynamic, drawn whether or
    not that dynamic is enabled), so switching one knob on never changes
    another's realization at equal seed.
  * **Static exactness** — under a static profile the sampler's delays
    are BIT-IDENTICAL to `sample_round_times` given the same generator
    state: multipliers are exactly 1.0 (multiplying by them is an IEEE
    no-op), erasure probabilities are the unmodified per-node values, and
    the arithmetic evaluates in the same order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delay_model import NodeDelayParams, stack_node_params
from repro.net.channel import ChannelProfile, mcs_efficiency


@dataclasses.dataclass
class NetworkTrace:
    """Realized network state, one row per round: all arrays (rounds, n)."""
    mu_mult: np.ndarray     # compute-speed multiplier (exactly 1.0 if off)
    tau_mult: np.ndarray    # per-transmission-time multiplier (both dirs)
    p_down: np.ndarray      # absolute downlink erasure prob per round
    p_up: np.ndarray
    active: np.ndarray      # bool availability (churn) mask
    profile: ChannelProfile

    @property
    def rounds(self) -> int:
        return self.mu_mult.shape[0]

    @property
    def n(self) -> int:
        return self.mu_mult.shape[1]

    def slice(self, r0: int, r1: int) -> "NetworkTrace":
        """Rounds [r0, r1) as a view-trace (the controller's block window)."""
        return NetworkTrace(
            mu_mult=self.mu_mult[r0:r1], tau_mult=self.tau_mult[r0:r1],
            p_down=self.p_down[r0:r1], p_up=self.p_up[r0:r1],
            active=self.active[r0:r1], profile=self.profile)


@dataclasses.dataclass
class TraceState:
    """Resumable cursor of a rolling channel trace.

    Every dynamic `generate_trace` rolls forward is a first-order
    recurrence over the rounds axis, so one ``(n,)`` vector per dynamic —
    plus the RNG bit-generator state and the global round cursor — is
    sufficient to continue the trace from any round boundary.  Chaining
    `generate_trace_block` calls through this state yields, for a fixed
    block partition, exactly the trajectory of the per-block draws; a
    single block covering the whole horizon is bit-identical to the
    one-shot `generate_trace`.
    """
    rng_state: dict         # numpy BitGenerator state (JSON-serializable)
    rounds_done: int        # global rounds already generated
    ge_bad: np.ndarray      # (n,) bool Gilbert–Elliott bad-state flags
    shadow_x: np.ndarray    # (n,) raw AR(1) shadowing in dB (pre-trend)
    drift_g: np.ndarray     # (n,) log-domain compute-drift walk position
    churn_active: np.ndarray  # (n,) bool availability flags

    @classmethod
    def init(cls, n: int, rng: np.random.Generator) -> "TraceState":
        """Fresh state at round 0 (good links, nominal speed, all present),
        consuming `rng`'s current position as the stream start."""
        return cls(rng_state=rng.bit_generator.state, rounds_done=0,
                   ge_bad=np.zeros(n, bool), shadow_x=np.zeros(n),
                   drift_g=np.zeros(n), churn_active=np.ones(n, bool))


def generate_trace_block(nodes: "list[NodeDelayParams]",
                         profile: ChannelProfile, rounds: int,
                         state: TraceState
                         ) -> "tuple[NetworkTrace, TraceState]":
    """Roll the profile forward `rounds` more rounds from `state`.

    Vectorized over nodes; the only Python-level loop is the O(rounds)
    recurrence each dynamic needs (Markov states, AR(1), random walk).
    The RNG layout is fixed — four (rounds, n) blocks drawn in one order
    — so the realization of one dynamic is invariant to the others being
    toggled (controlled comparisons at equal seed).  Round 0 of the whole
    run (``state.rounds_done == 0``) gets the stationary/nominal initial
    conditions; later blocks continue their recurrences seamlessly.

    Returns the block's trace and the advanced state; `state` itself is
    not mutated (checkpointing keeps the pre-block snapshot valid).
    """
    prm = stack_node_params(nodes)
    n = len(nodes)
    R = int(rounds)
    if R < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    r0 = int(state.rounds_done)
    rng = np.random.default_rng()
    rng.bit_generator.state = state.rng_state
    # fixed draw layout (see docstring): GE uniforms, shadowing normals,
    # drift normals, churn uniforms
    ge_u = rng.random((R, n))
    shadow_eps = rng.standard_normal((R, n))
    drift_eps = rng.standard_normal((R, n))
    churn_u = rng.random((R, n))

    # --- Gilbert–Elliott erasure states -> absolute per-round erasure probs
    ge_bad = state.ge_bad
    if profile.has_erasure_dynamics:
        bad = np.zeros((R, n), bool)
        prev = state.ge_bad.copy()            # round 0 starts in good state
        for t in range(R):
            prev = np.where(prev, ge_u[t] >= profile.ge_p_bg,
                            ge_u[t] < profile.ge_p_gb)
            bad[t] = prev
        scale = np.where(bad, profile.ge_bad_scale, 1.0)
        p_down = np.clip(prm["p_down"] * scale, 0.0, profile.p_cap)
        p_up = np.clip(prm["p_up"] * scale, 0.0, profile.p_cap)
        ge_bad = prev
    else:
        p_down = np.broadcast_to(prm["p_down"], (R, n)).copy()
        p_up = np.broadcast_to(prm["p_up"], (R, n)).copy()

    # --- log-normal shadowing (AR(1) in dB) + deterministic trend,
    # optionally MCS-quantized.  The dB process is *attenuation*: positive
    # values slow the link in both the continuous and the MCS mapping.
    shadow_x = state.shadow_x
    if profile.has_shadowing:
        sigma, rho = profile.shadow_sigma_db, profile.shadow_rho
        x = np.zeros((R, n))
        innov = np.sqrt(max(0.0, 1.0 - rho * rho)) * sigma
        prev = state.shadow_x
        for t in range(R):
            if r0 + t == 0:
                x[t] = sigma * shadow_eps[t]  # start at the stationary law
            else:
                x[t] = rho * prev + innov * shadow_eps[t]
            prev = x[t]
        shadow_x = x[-1].copy()               # raw (pre-trend) carry
        x = x + profile.tau_trend_db * np.arange(r0, r0 + R)[:, None]
        if profile.mcs:
            # attenuation lowers SNR; rate hops along the CQI ladder
            eff0 = mcs_efficiency(profile.mcs_snr0_db)
            tau_mult = eff0 / mcs_efficiency(profile.mcs_snr0_db - x)
        else:
            tau_mult = 10.0 ** (x / 10.0)
    else:
        tau_mult = np.ones((R, n))

    # --- bounded compute-speed random walk (log domain)
    drift_g = state.drift_g
    if profile.has_compute_drift:
        lo, hi = np.log(profile.mu_min), np.log(profile.mu_max)
        step = np.log1p(profile.mu_drift_rate)
        g = np.zeros((R, n))
        prev = state.drift_g
        for t in range(R):
            if r0 + t == 0:
                g[t] = 0.0                    # round 0 at nominal speed
            else:
                g[t] = np.clip(
                    prev + step + profile.mu_drift_sigma * drift_eps[t],
                    lo, hi)
            prev = g[t]
        mu_mult = np.exp(g)
        drift_g = g[-1].copy()
    else:
        mu_mult = np.ones((R, n))

    # --- dropout/rejoin churn
    churn_active = state.churn_active
    if profile.has_churn:
        active = np.ones((R, n), bool)
        prev = state.churn_active.copy()      # round 0 everyone present
        for t in range(R):
            if r0 + t > 0:
                prev = np.where(prev, churn_u[t] >= profile.dropout_prob,
                                churn_u[t] < profile.rejoin_prob)
            active[t] = prev
        churn_active = prev
    else:
        active = np.ones((R, n), bool)

    trace = NetworkTrace(mu_mult=mu_mult, tau_mult=tau_mult, p_down=p_down,
                         p_up=p_up, active=active, profile=profile)
    new_state = TraceState(rng_state=rng.bit_generator.state,
                           rounds_done=r0 + R, ge_bad=ge_bad,
                           shadow_x=shadow_x, drift_g=drift_g,
                           churn_active=churn_active)
    return trace, new_state


def generate_trace(nodes: "list[NodeDelayParams]", profile: ChannelProfile,
                   rounds: int, rng: np.random.Generator) -> NetworkTrace:
    """Roll the channel profile forward `rounds` rounds for all nodes.

    One-shot wrapper over `generate_trace_block`: a fresh `TraceState` at
    round 0 plus a single block covering the whole horizon.  The caller's
    generator is advanced past the consumed draws, exactly as if the
    draws had been made on it directly.
    """
    trace, end = generate_trace_block(nodes, profile, rounds,
                                      TraceState.init(len(nodes), rng))
    rng.bit_generator.state = end.rng_state
    return trace


@dataclasses.dataclass
class RoundObservations:
    """Per-round, per-node timing telemetry the MEC orchestrator collects.

    The simulator grants full per-phase observability — download time,
    compute time, upload time, and per-direction transmission counts (the
    link layer counts its own retransmissions) — which is what the online
    estimator (`repro.net.estimator`) consumes.  ``total`` is the scalar
    round-trip delay the engine's deadline logic sees.
    """
    total: np.ndarray       # (R, n) seconds
    t_down: np.ndarray      # (R, n) downlink communication seconds
    t_up: np.ndarray        # (R, n) uplink communication seconds
    t_comp: np.ndarray      # (R, n) compute seconds (deterministic + tail)
    n_down: np.ndarray      # (R, n) downlink transmission counts
    n_up: np.ndarray        # (R, n) uplink transmission counts
    active: np.ndarray      # (R, n) availability (copied from the trace)
    loads: np.ndarray       # (R, n) loads in effect when sampled


def sample_round_observations(nodes: "list[NodeDelayParams]", loads,
                              rng: np.random.Generator,
                              trace: NetworkTrace) -> RoundObservations:
    """Sample every round's delays through the trace, with telemetry.

    Mirrors `delay_model.sample_round_times`'s three-draw layout exactly
    (geometric per direction, then one unit exponential), with the trace's
    per-round parameters substituted elementwise.  `loads` is (n,) for a
    fixed allocation or (rounds, n) for a per-round (adaptive) schedule.
    """
    prm = stack_node_params(nodes)
    n = len(nodes)
    R = trace.rounds
    loads = np.asarray(loads, np.float64)
    if loads.shape == (n,):
        loads_rn = np.broadcast_to(loads, (R, n))
    elif loads.shape == (R, n):
        loads_rn = loads
    else:
        raise ValueError(f"loads shape {loads.shape} must be ({n},) "
                         f"or ({R}, {n})")
    if trace.n != n:
        raise ValueError(f"trace covers {trace.n} nodes, got {n}")

    n_down = rng.geometric(1.0 - trace.p_down)
    n_up = rng.geometric(1.0 - trace.p_up)
    t_down = (prm["tau_down"] * trace.tau_mult) * n_down
    t_up = (prm["tau_up"] * trace.tau_mult) * n_up
    active_load = loads_rn > 0.0
    mu_eff = prm["mu"] * trace.mu_mult
    scale = np.where(active_load, loads_rn / (prm["alpha"] * mu_eff), 0.0)
    t_stoch = rng.exponential(1.0, size=(R, n)) * scale
    t_det = np.where(active_load, loads_rn / mu_eff, 0.0)
    # same association order as sample_round_times: (comm + det) + tail
    total = (t_down + t_up) + t_det + t_stoch
    return RoundObservations(total=total, t_down=t_down, t_up=t_up,
                             t_comp=t_det + t_stoch, n_down=n_down,
                             n_up=n_up, active=trace.active.copy(),
                             loads=np.asarray(loads_rn, np.float64).copy())


def sample_round_times_traced(nodes: "list[NodeDelayParams]", loads,
                              rng: np.random.Generator,
                              trace: NetworkTrace) -> np.ndarray:
    """(rounds, n) round-trip delays through the trace.

    Drop-in extension of `delay_model.sample_round_times`: under a static
    profile (all multipliers exactly 1.0, erasure probs untouched) the
    output is bit-identical to it for the same generator state, because
    the RNG draws see elementwise-equal parameters and the arithmetic
    keeps the same evaluation order.
    """
    return sample_round_observations(nodes, loads, rng, trace).total
