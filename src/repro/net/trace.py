"""Deterministic per-seed network-state traces and traced delay sampling.

`generate_trace` rolls a `ChannelProfile` forward for a whole training
run, producing dense ``(rounds, n)`` state tensors (erasure probabilities,
tau/mu multipliers, availability).  `sample_round_observations` then draws
the per-round delays *through* that trace with the same three-draw layout
as `delay_model.sample_round_times` — one geometric draw per link
direction plus one exponential compute tail — so the batched engine keeps
pre-sampling an entire run in a handful of vectorized RNG calls.

Two contracts the tests pin down:

  * **Determinism** — equal (nodes, profile, rounds, seed) reproduce the
    trace array-for-array; the trace generator always consumes the same
    RNG layout (one uniform/normal block per dynamic, drawn whether or
    not that dynamic is enabled), so switching one knob on never changes
    another's realization at equal seed.
  * **Static exactness** — under a static profile the sampler's delays
    are BIT-IDENTICAL to `sample_round_times` given the same generator
    state: multipliers are exactly 1.0 (multiplying by them is an IEEE
    no-op), erasure probabilities are the unmodified per-node values, and
    the arithmetic evaluates in the same order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.delay_model import NodeDelayParams, stack_node_params
from repro.net.channel import ChannelProfile, mcs_efficiency


@dataclasses.dataclass
class NetworkTrace:
    """Realized network state, one row per round: all arrays (rounds, n)."""
    mu_mult: np.ndarray     # compute-speed multiplier (exactly 1.0 if off)
    tau_mult: np.ndarray    # per-transmission-time multiplier (both dirs)
    p_down: np.ndarray      # absolute downlink erasure prob per round
    p_up: np.ndarray
    active: np.ndarray      # bool availability (churn) mask
    profile: ChannelProfile

    @property
    def rounds(self) -> int:
        return self.mu_mult.shape[0]

    @property
    def n(self) -> int:
        return self.mu_mult.shape[1]

    def slice(self, r0: int, r1: int) -> "NetworkTrace":
        """Rounds [r0, r1) as a view-trace (the controller's block window)."""
        return NetworkTrace(
            mu_mult=self.mu_mult[r0:r1], tau_mult=self.tau_mult[r0:r1],
            p_down=self.p_down[r0:r1], p_up=self.p_up[r0:r1],
            active=self.active[r0:r1], profile=self.profile)


def generate_trace(nodes: "list[NodeDelayParams]", profile: ChannelProfile,
                   rounds: int, rng: np.random.Generator) -> NetworkTrace:
    """Roll the channel profile forward `rounds` rounds for all nodes.

    Vectorized over nodes; the only Python-level loop is the O(rounds)
    recurrence each dynamic needs (Markov states, AR(1), random walk).
    The RNG layout is fixed — four (rounds, n) blocks drawn uniformly in
    one order — so the realization of one dynamic is invariant to the
    others being toggled (controlled comparisons at equal seed).
    """
    prm = stack_node_params(nodes)
    n = len(nodes)
    R = int(rounds)
    # fixed draw layout (see docstring): GE uniforms, shadowing normals,
    # drift normals, churn uniforms
    ge_u = rng.random((R, n))
    shadow_eps = rng.standard_normal((R, n))
    drift_eps = rng.standard_normal((R, n))
    churn_u = rng.random((R, n))

    # --- Gilbert–Elliott erasure states -> absolute per-round erasure probs
    if profile.has_erasure_dynamics:
        bad = np.zeros((R, n), bool)          # round 0 starts in good state
        prev = np.zeros(n, bool)
        for t in range(R):
            prev = np.where(prev, ge_u[t] >= profile.ge_p_bg,
                            ge_u[t] < profile.ge_p_gb)
            bad[t] = prev
        scale = np.where(bad, profile.ge_bad_scale, 1.0)
        p_down = np.clip(prm["p_down"] * scale, 0.0, profile.p_cap)
        p_up = np.clip(prm["p_up"] * scale, 0.0, profile.p_cap)
    else:
        p_down = np.broadcast_to(prm["p_down"], (R, n)).copy()
        p_up = np.broadcast_to(prm["p_up"], (R, n)).copy()

    # --- log-normal shadowing (AR(1) in dB) + deterministic trend,
    # optionally MCS-quantized.  The dB process is *attenuation*: positive
    # values slow the link in both the continuous and the MCS mapping.
    if profile.has_shadowing:
        sigma, rho = profile.shadow_sigma_db, profile.shadow_rho
        x = np.zeros((R, n))
        x[0] = sigma * shadow_eps[0]          # start at the stationary law
        innov = np.sqrt(max(0.0, 1.0 - rho * rho)) * sigma
        for t in range(1, R):
            x[t] = rho * x[t - 1] + innov * shadow_eps[t]
        x = x + profile.tau_trend_db * np.arange(R)[:, None]
        if profile.mcs:
            # attenuation lowers SNR; rate hops along the CQI ladder
            eff0 = mcs_efficiency(profile.mcs_snr0_db)
            tau_mult = eff0 / mcs_efficiency(profile.mcs_snr0_db - x)
        else:
            tau_mult = 10.0 ** (x / 10.0)
    else:
        tau_mult = np.ones((R, n))

    # --- bounded compute-speed random walk (log domain)
    if profile.has_compute_drift:
        lo, hi = np.log(profile.mu_min), np.log(profile.mu_max)
        step = np.log1p(profile.mu_drift_rate)
        g = np.zeros((R, n))                  # round 0 at nominal speed
        for t in range(1, R):
            g[t] = np.clip(
                g[t - 1] + step + profile.mu_drift_sigma * drift_eps[t],
                lo, hi)
        mu_mult = np.exp(g)
    else:
        mu_mult = np.ones((R, n))

    # --- dropout/rejoin churn
    if profile.has_churn:
        active = np.ones((R, n), bool)        # round 0 everyone present
        prev = np.ones(n, bool)
        for t in range(1, R):
            prev = np.where(prev, churn_u[t] >= profile.dropout_prob,
                            churn_u[t] < profile.rejoin_prob)
            active[t] = prev
    else:
        active = np.ones((R, n), bool)

    return NetworkTrace(mu_mult=mu_mult, tau_mult=tau_mult, p_down=p_down,
                        p_up=p_up, active=active, profile=profile)


@dataclasses.dataclass
class RoundObservations:
    """Per-round, per-node timing telemetry the MEC orchestrator collects.

    The simulator grants full per-phase observability — download time,
    compute time, upload time, and per-direction transmission counts (the
    link layer counts its own retransmissions) — which is what the online
    estimator (`repro.net.estimator`) consumes.  ``total`` is the scalar
    round-trip delay the engine's deadline logic sees.
    """
    total: np.ndarray       # (R, n) seconds
    t_down: np.ndarray      # (R, n) downlink communication seconds
    t_up: np.ndarray        # (R, n) uplink communication seconds
    t_comp: np.ndarray      # (R, n) compute seconds (deterministic + tail)
    n_down: np.ndarray      # (R, n) downlink transmission counts
    n_up: np.ndarray        # (R, n) uplink transmission counts
    active: np.ndarray      # (R, n) availability (copied from the trace)
    loads: np.ndarray       # (R, n) loads in effect when sampled


def sample_round_observations(nodes: "list[NodeDelayParams]", loads,
                              rng: np.random.Generator,
                              trace: NetworkTrace) -> RoundObservations:
    """Sample every round's delays through the trace, with telemetry.

    Mirrors `delay_model.sample_round_times`'s three-draw layout exactly
    (geometric per direction, then one unit exponential), with the trace's
    per-round parameters substituted elementwise.  `loads` is (n,) for a
    fixed allocation or (rounds, n) for a per-round (adaptive) schedule.
    """
    prm = stack_node_params(nodes)
    n = len(nodes)
    R = trace.rounds
    loads = np.asarray(loads, np.float64)
    if loads.shape == (n,):
        loads_rn = np.broadcast_to(loads, (R, n))
    elif loads.shape == (R, n):
        loads_rn = loads
    else:
        raise ValueError(f"loads shape {loads.shape} must be ({n},) "
                         f"or ({R}, {n})")
    if trace.n != n:
        raise ValueError(f"trace covers {trace.n} nodes, got {n}")

    n_down = rng.geometric(1.0 - trace.p_down)
    n_up = rng.geometric(1.0 - trace.p_up)
    t_down = (prm["tau_down"] * trace.tau_mult) * n_down
    t_up = (prm["tau_up"] * trace.tau_mult) * n_up
    active_load = loads_rn > 0.0
    mu_eff = prm["mu"] * trace.mu_mult
    scale = np.where(active_load, loads_rn / (prm["alpha"] * mu_eff), 0.0)
    t_stoch = rng.exponential(1.0, size=(R, n)) * scale
    t_det = np.where(active_load, loads_rn / mu_eff, 0.0)
    # same association order as sample_round_times: (comm + det) + tail
    total = (t_down + t_up) + t_det + t_stoch
    return RoundObservations(total=total, t_down=t_down, t_up=t_up,
                             t_comp=t_det + t_stoch, n_down=n_down,
                             n_up=n_up, active=trace.active.copy(),
                             loads=np.asarray(loads_rn, np.float64).copy())


def sample_round_times_traced(nodes: "list[NodeDelayParams]", loads,
                              rng: np.random.Generator,
                              trace: NetworkTrace) -> np.ndarray:
    """(rounds, n) round-trip delays through the trace.

    Drop-in extension of `delay_model.sample_round_times`: under a static
    profile (all multipliers exactly 1.0, erasure probs untouched) the
    output is bit-identical to it for the same generator state, because
    the RNG draws see elementwise-equal parameters and the arithmetic
    keeps the same evaluation order.
    """
    return sample_round_observations(nodes, loads, rng, trace).total
