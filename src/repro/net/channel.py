"""Time-varying wireless channel profiles.

A `ChannelProfile` declares how the MEC network drifts over a training
run, on top of the per-node stationary parameters (`NodeDelayParams`):

  * **Gilbert–Elliott erasure states** — each node's link hops between a
    good and a bad state with a 2-state Markov chain; the bad state
    multiplies the node's base erasure probability by ``ge_bad_scale``
    (Gilbert 1960 / Elliott 1963 burst-loss model).
  * **Log-normal shadowing on tau** — an AR(1) process in dB perturbs the
    per-transmission time.  With ``mcs=True`` the dB process is read as an
    SNR offset and quantized through an LTE CQI table (TS 36.213
    Table 7.2.3-1 spectral efficiencies), so the realized rate hops
    between discrete MCS levels the way an LTE link adapter would.
  * **Compute-speed drift** — a bounded random walk (plus an optional
    deterministic trend) on each node's processing rate ``mu``, modeling
    thermal throttling, background load, or hardware upgrades.
  * **Churn** — a 2-state availability chain: an active client drops out
    with ``dropout_prob`` per round and rejoins with ``rejoin_prob``.

All knobs default OFF, so ``ChannelProfile()`` (the ``"static"`` profile)
reproduces the stationary paper model *bit-exactly* through the traced
sampler (`repro.net.trace`).  Named profiles in `CHANNEL_PROFILES` are
addressable from ``ExperimentSpec.channel_profile``; scenario-specific
overrides ride in ``ExperimentSpec.channel_params``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# LTE CQI table (TS 36.213 Table 7.2.3-1): spectral efficiency per CQI
# index, with the customary AWGN SNR switching thresholds (dB).  The rate
# mapping picks the highest CQI whose threshold the instantaneous SNR
# clears; per-transmission time scales inversely with efficiency.
MCS_SNR_DB = np.array([-6.7, -4.7, -2.3, 0.2, 2.4, 4.3, 5.9, 8.1,
                       10.3, 11.7, 14.1, 16.3, 18.7, 21.0, 22.7])
MCS_EFFICIENCY = np.array([0.1523, 0.2344, 0.3770, 0.6016, 0.8770,
                           1.1758, 1.4766, 1.9141, 2.4063, 2.7305,
                           3.3223, 3.9023, 4.5234, 5.1152, 5.5547])


def mcs_efficiency(snr_db) -> np.ndarray:
    """Spectral efficiency at `snr_db` through the CQI switching table.

    Below the lowest threshold the link stays at the most robust MCS
    (CQI 1) — outage is the erasure process's job, not the rate mapping's.
    """
    idx = np.searchsorted(MCS_SNR_DB, np.asarray(snr_db, np.float64),
                          side="right") - 1
    return MCS_EFFICIENCY[np.clip(idx, 0, len(MCS_EFFICIENCY) - 1)]


@dataclasses.dataclass(frozen=True)
class ChannelProfile:
    """Declarative network-dynamics knobs (all OFF by default = static)."""
    # Gilbert–Elliott erasure chain (per node, shared by both directions)
    ge_p_gb: float = 0.0        # P(good -> bad) per round; 0 = never bad
    ge_p_bg: float = 1.0        # P(bad -> good) per round
    ge_bad_scale: float = 1.0   # erasure-prob multiplier in the bad state
    # log-normal shadowing (AR(1) in dB) on per-transmission time tau,
    # plus an optional deterministic dB-per-round trend (negative = links
    # improve over the run, positive = degrade)
    shadow_sigma_db: float = 0.0
    shadow_rho: float = 0.9     # round-to-round correlation in [0, 1]
    tau_trend_db: float = 0.0
    mcs: bool = False           # quantize through the LTE CQI table
    mcs_snr0_db: float = 10.3   # nominal operating SNR (CQI 9)
    # bounded random walk (+ trend) on compute speed mu, in log domain
    mu_drift_sigma: float = 0.0     # per-round log-step std
    mu_drift_rate: float = 0.0      # per-round multiplicative trend - 1
    mu_min: float = 0.25            # multiplier clip range
    mu_max: float = 4.0
    # client dropout/rejoin churn
    dropout_prob: float = 0.0
    rejoin_prob: float = 1.0
    # time-varying erasure probabilities are clipped here (p = 1 would
    # make a link permanently dead — see NodeDelayParams validation)
    p_cap: float = 0.95

    def __post_init__(self):
        for name in ("ge_p_gb", "ge_p_bg", "dropout_prob", "rejoin_prob",
                     "shadow_rho"):
            val = getattr(self, name)
            if not (0.0 <= val <= 1.0):
                raise ValueError(f"{name}={val} must lie in [0, 1]")
        if self.ge_bad_scale < 0.0:
            raise ValueError(f"ge_bad_scale={self.ge_bad_scale} must be >= 0")
        if self.shadow_sigma_db < 0.0:
            raise ValueError(
                f"shadow_sigma_db={self.shadow_sigma_db} must be >= 0")
        if self.mu_drift_sigma < 0.0:
            raise ValueError(
                f"mu_drift_sigma={self.mu_drift_sigma} must be >= 0")
        if self.mu_drift_rate <= -1.0:
            raise ValueError(
                f"mu_drift_rate={self.mu_drift_rate} must be > -1")
        if not (0.0 < self.mu_min <= 1.0 <= self.mu_max):
            raise ValueError(
                f"need 0 < mu_min <= 1 <= mu_max, got "
                f"[{self.mu_min}, {self.mu_max}]")
        if not (0.0 < self.p_cap < 1.0):
            raise ValueError(f"p_cap={self.p_cap} must lie in (0, 1)")

    # ------------------------------------------------------------ properties
    @property
    def has_erasure_dynamics(self) -> bool:
        return self.ge_p_gb > 0.0 and self.ge_bad_scale != 1.0

    @property
    def has_shadowing(self) -> bool:
        return self.shadow_sigma_db > 0.0 or self.tau_trend_db != 0.0

    @property
    def has_compute_drift(self) -> bool:
        return self.mu_drift_sigma > 0.0 or self.mu_drift_rate != 0.0

    @property
    def has_churn(self) -> bool:
        return self.dropout_prob > 0.0

    @property
    def is_static(self) -> bool:
        """True iff the trace is guaranteed exactly neutral (multipliers
        exactly 1.0, erasure probs untouched, everyone always active)."""
        return not (self.has_erasure_dynamics or self.has_shadowing
                    or self.has_compute_drift or self.has_churn)


#: Named profiles addressable from ``ExperimentSpec.channel_profile``.
#: "static" is the exact stationary paper model; the rest are the drift
#: scenarios the bench (`repro.launch.scenarios`) compares static vs
#: adaptive allocation on.
CHANNEL_PROFILES: dict[str, ChannelProfile] = {
    # no dynamics: bit-exact with the stationary engine
    "static": ChannelProfile(),
    # bursty erasures: ~19% of rounds in a 6x-loss bad state
    "markov_loss": ChannelProfile(ge_p_gb=0.08, ge_p_bg=0.35,
                                  ge_bad_scale=6.0),
    # slow log-normal fading quantized through the LTE CQI ladder
    "slow_fade": ChannelProfile(shadow_sigma_db=4.0, shadow_rho=0.95,
                                mcs=True),
    # undirected compute wander (thermal throttling / background load)
    "compute_drift": ChannelProfile(mu_drift_sigma=0.06),
    # network steadily speeds up (compute AND links): a round-0
    # allocation grows stale fast, wasting deadline slack every round
    "speedup_drift": ChannelProfile(mu_drift_rate=0.05,
                                    mu_drift_sigma=0.01, mu_max=8.0,
                                    tau_trend_db=-0.3, mcs=True),
    # network steadily degrades: fixed deadline loses more return mass
    # every round
    "degrade_drift": ChannelProfile(mu_drift_rate=-0.04,
                                    mu_drift_sigma=0.01, mu_min=0.15,
                                    tau_trend_db=0.15, mcs=True),
    # clients drop out and rejoin (5%/round out, 25%/round back)
    "churn": ChannelProfile(dropout_prob=0.05, rejoin_prob=0.25),
    # the stress scenario: fading + MCS hopping + degrading compute +
    # churn, all at once
    "drift_churn": ChannelProfile(shadow_sigma_db=3.0, shadow_rho=0.9,
                                  mcs=True, mu_drift_rate=-0.03,
                                  mu_drift_sigma=0.03, mu_min=0.15,
                                  dropout_prob=0.03, rejoin_prob=0.3),
}
