"""repro: CodedFedL (IEEE JSAC 2020) as a production-grade JAX framework.

Layers: core/ (the paper), models/ + configs/ (assigned architecture zoo),
kernels/ (Pallas), sharding/ + launch/ (multi-pod pjit), data/, optim/,
checkpoint/, plus the FL runtime in core.fed_runtime.
"""
__version__ = "1.0.0"
