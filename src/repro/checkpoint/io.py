"""Checkpointing: flat .npz snapshots of arbitrary param pytrees.

Shard-aware in the sense that leaves are gathered to host before writing
(fine at the model sizes this container trains) and restored with the same
treedef; keys encode the tree path.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:       # npz has no bf16: store as f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save(path: str, tree, step: int | None = None):
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, like_tree):
    """Restore into the structure of `like_tree` (shapes must match)."""
    data = np.load(path)
    flat_like = _flatten(like_tree)
    restored = {}
    for key, ref in flat_like.items():
        arr = data[key]
        assert arr.shape == ref.shape, (key, arr.shape, ref.shape)
        restored[key] = arr
    leaves_paths = jax.tree_util.tree_flatten_with_path(like_tree)
    paths_leaves = [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in p), leaf)
                    for p, leaf in leaves_paths[0]]
    new_leaves = [jnp.asarray(restored[p]).astype(ref.dtype)
                  for p, ref in paths_leaves]
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)


def restore_step(path: str) -> int | None:
    data = np.load(path)
    return int(data["__step__"]) if "__step__" in data else None
