"""Checkpointing: flat .npz snapshots of param pytrees and run state.

Shard-aware in the sense that leaves are gathered to host before writing
(fine at the model sizes this container trains) and restored with the same
treedef; keys encode the tree path.

Two payload families share the format:

  * `save`/`restore` — pure param pytrees (arrays only), keyed by tree
    path.  `restore` validates against a `like_tree`: a shape mismatch or
    a file key absent from the reference tree is a `ValueError`, never a
    silent drop.
  * `save_state`/`restore_state` — mixed payloads for the resumable
    runtime (`repro.core.run_state.RunState`): named arrays plus one JSON
    metadata blob under the reserved ``__meta__`` key (RNG bit-generator
    states, cursors, and the originating `ExperimentSpec` for
    provenance).

Keys starting with ``__`` are reserved for format metadata (``__step__``,
``__meta__``) and never validated against user trees.

Writes are atomic (tmp file + ``os.replace``), so a run killed mid-save
leaves the previous checkpoint intact — `latest_checkpoint` then resumes
from the newest complete snapshot.  Stale ``*.tmp`` leftovers from a
mid-save kill are swept on the next successful save and never considered
resume candidates.

Run-state snapshots carry a sha256 content digest inside ``__meta__``
(over canonical array bytes + metadata JSON, not raw npz bytes — zip
headers embed timestamps).  `restore_state` verifies it and raises
`CheckpointCorruptError` on truncation, bit rot, or a digest mismatch;
``latest_checkpoint(..., valid_only=True)`` then falls back to the newest
checkpoint that still verifies.
"""
from __future__ import annotations

import hashlib
import json
import os
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

#: key prefix reserved for format metadata, exempt from like_tree checks
RESERVED_PREFIX = "__"
#: filename prefix the runtime uses for block-boundary snapshots
CKPT_PREFIX = "ckpt_"

#: key carrying the sha256 content digest inside the ``__meta__`` blob
DIGEST_KEY = "__digest__"


class CheckpointCorruptError(ValueError):
    """A checkpoint file is unreadable or fails digest verification."""


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:       # npz has no bf16: store as f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _sweep_stale_tmp(directory: str) -> None:
    """Remove ``*.tmp`` / ``*.tmp.npz`` leftovers of mid-save kills."""
    try:
        names = os.listdir(directory)
    except OSError:
        return
    for name in names:
        if name.endswith(".tmp") or name.endswith(".tmp.npz"):
            try:
                os.remove(os.path.join(directory, name))
            except OSError:
                pass


def _atomic_savez(path: str, flat: dict) -> None:
    directory = os.path.dirname(path) or "."
    os.makedirs(directory, exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)
    # a previous save killed between np.savez and os.replace leaves its
    # tmp file behind forever — sweep those now that this save landed
    _sweep_stale_tmp(directory)


def save(path: str, tree, step: int | None = None):
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    _atomic_savez(path, flat)


def restore(path: str, like_tree):
    """Restore into the structure of `like_tree`.

    Every non-reserved key in the file must exist in `like_tree` and every
    reference leaf must exist in the file with a matching shape — any
    divergence raises `ValueError` naming the offending key (a checkpoint
    from a different run shape should fail loudly, not load partially).
    """
    flat_like = _flatten(like_tree)
    restored = {}
    with np.load(path) as data:
        extra = sorted(k for k in data.files
                       if not k.startswith(RESERVED_PREFIX)
                       and k not in flat_like)
        if extra:
            raise ValueError(
                f"checkpoint {path!r} holds key(s) {extra} absent from "
                f"like_tree — refusing to silently drop them")
        for key, ref in flat_like.items():
            if key not in data.files:
                raise ValueError(
                    f"checkpoint {path!r} is missing key {key!r} "
                    f"required by like_tree")
            arr = data[key]
            if arr.shape != ref.shape:
                raise ValueError(
                    f"checkpoint key {key!r}: stored shape {arr.shape} "
                    f"does not match like_tree shape {ref.shape}")
            restored[key] = arr
    leaves_paths = jax.tree_util.tree_flatten_with_path(like_tree)
    paths_leaves = [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in p), leaf)
                    for p, leaf in leaves_paths[0]]
    new_leaves = [jnp.asarray(restored[p]).astype(ref.dtype)
                  for p, ref in paths_leaves]
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)


def restore_step(path: str) -> int | None:
    with np.load(path) as data:
        return int(data["__step__"]) if "__step__" in data.files else None


# ---------------------------------------------------------------------------
# Run-state payloads: named arrays + one JSON metadata blob
# ---------------------------------------------------------------------------

def _state_digest(arrays: dict, meta: dict) -> str:
    """sha256 over canonical array bytes + metadata JSON.

    Deliberately NOT a hash of the npz file: zip member headers embed
    timestamps, so byte-identical payloads produce different files.
    Hashing (key, dtype, shape, bytes) per array plus the sorted-key
    metadata JSON makes the digest a pure function of the checkpoint
    *content*.
    """
    h = hashlib.sha256()
    for key in sorted(arrays):
        arr = np.ascontiguousarray(arrays[key])
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(repr(arr.shape).encode())
        h.update(arr.tobytes())
    h.update(json.dumps(meta, sort_keys=True).encode())
    return h.hexdigest()


def save_state(path: str, arrays: dict, meta: dict) -> str:
    """Atomically write a mixed arrays + JSON-metadata snapshot.

    `arrays` maps names to array-likes (names must not use the reserved
    ``__`` prefix); `meta` is any JSON-serializable dict — RNG
    bit-generator states round-trip because PCG64 state words are plain
    (big) Python ints, which JSON handles exactly.  A sha256 content
    digest is embedded under ``__digest__`` inside the ``__meta__`` blob
    and verified by `restore_state`.
    """
    bad = sorted(k for k in arrays if k.startswith(RESERVED_PREFIX))
    if bad:
        raise ValueError(f"array key(s) {bad} use the reserved "
                         f"{RESERVED_PREFIX!r} prefix")
    if DIGEST_KEY in meta:
        raise ValueError(f"meta key {DIGEST_KEY!r} is reserved")
    flat = {k: np.asarray(v) for k, v in arrays.items()}
    meta_full = dict(meta)
    meta_full[DIGEST_KEY] = _state_digest(flat, meta)
    flat["__meta__"] = np.asarray(json.dumps(meta_full))
    _atomic_savez(path, flat)
    return path


def restore_state(path: str, verify: bool = True) -> tuple[dict, dict]:
    """Load a `save_state` snapshot -> (arrays, meta).

    Unreadable files (truncation, zip damage) and digest mismatches (bit
    rot) raise `CheckpointCorruptError`.  Snapshots written before the
    digest existed load without verification.  ``verify=False`` skips
    the digest check (forensics on a known-bad file).
    """
    try:
        with np.load(path) as data:
            raw = {k: data[k] for k in data.files}
    except (OSError, EOFError, ValueError, KeyError,
            zipfile.BadZipFile) as exc:
        raise CheckpointCorruptError(
            f"checkpoint {path!r} is unreadable "
            f"(truncated or damaged): {exc}") from exc
    if "__meta__" not in raw:
        raise ValueError(
            f"{path!r} is not a run-state checkpoint (no __meta__ "
            "payload; param-tree snapshots restore via `restore`)")
    try:
        meta = json.loads(str(raw["__meta__"][()]))
    except json.JSONDecodeError as exc:
        raise CheckpointCorruptError(
            f"checkpoint {path!r} holds an unparseable __meta__ "
            f"blob: {exc}") from exc
    arrays = {k: v for k, v in raw.items()
              if not k.startswith(RESERVED_PREFIX)}
    digest = meta.pop(DIGEST_KEY, None)
    if verify and digest is not None:
        actual = _state_digest(arrays, meta)
        if actual != digest:
            raise CheckpointCorruptError(
                f"checkpoint {path!r} failed digest verification "
                f"(stored {digest[:12]}…, recomputed {actual[:12]}…) — "
                "the file was corrupted after writing")
    return arrays, meta


def latest_checkpoint(directory: str, prefix: str = CKPT_PREFIX,
                      valid_only: bool = False) -> str | None:
    """Newest ``<prefix><number>.npz`` in `directory`, or None.

    "Newest" orders by the numeric suffix (the rounds-done cursor the
    runtime embeds in the filename), not by mtime, so a clock-skewed
    filesystem cannot resume from a stale block.  Half-written
    ``*.tmp`` leftovers are never candidates.

    With ``valid_only=True`` candidates are tried newest-first and the
    first one that passes `restore_state`'s digest verification wins —
    a corrupted latest checkpoint falls back to the newest intact one
    instead of poisoning the resume.
    """
    if not os.path.isdir(directory):
        return None
    candidates = []
    for name in os.listdir(directory):
        if not (name.startswith(prefix) and name.endswith(".npz")):
            continue
        if ".tmp" in name:
            continue
        try:
            key = int(name[len(prefix):-len(".npz")])
        except ValueError:
            continue
        candidates.append((key, name))
    for _, name in sorted(candidates, reverse=True):
        path = os.path.join(directory, name)
        if not valid_only:
            return path
        try:
            restore_state(path)
        except CheckpointCorruptError:
            continue
        return path
    return None
