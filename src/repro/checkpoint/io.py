"""Checkpointing: flat .npz snapshots of param pytrees and run state.

Shard-aware in the sense that leaves are gathered to host before writing
(fine at the model sizes this container trains) and restored with the same
treedef; keys encode the tree path.

Two payload families share the format:

  * `save`/`restore` — pure param pytrees (arrays only), keyed by tree
    path.  `restore` validates against a `like_tree`: a shape mismatch or
    a file key absent from the reference tree is a `ValueError`, never a
    silent drop.
  * `save_state`/`restore_state` — mixed payloads for the resumable
    runtime (`repro.core.run_state.RunState`): named arrays plus one JSON
    metadata blob under the reserved ``__meta__`` key (RNG bit-generator
    states, cursors, and the originating `ExperimentSpec` for
    provenance).

Keys starting with ``__`` are reserved for format metadata (``__step__``,
``__meta__``) and never validated against user trees.

Writes are atomic (tmp file + ``os.replace``), so a run killed mid-save
leaves the previous checkpoint intact — `latest_checkpoint` then resumes
from the newest complete snapshot.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

#: key prefix reserved for format metadata, exempt from like_tree checks
RESERVED_PREFIX = "__"
#: filename prefix the runtime uses for block-boundary snapshots
CKPT_PREFIX = "ckpt_"


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:       # npz has no bf16: store as f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _atomic_savez(path: str, flat: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def save(path: str, tree, step: int | None = None):
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    _atomic_savez(path, flat)


def restore(path: str, like_tree):
    """Restore into the structure of `like_tree`.

    Every non-reserved key in the file must exist in `like_tree` and every
    reference leaf must exist in the file with a matching shape — any
    divergence raises `ValueError` naming the offending key (a checkpoint
    from a different run shape should fail loudly, not load partially).
    """
    flat_like = _flatten(like_tree)
    restored = {}
    with np.load(path) as data:
        extra = sorted(k for k in data.files
                       if not k.startswith(RESERVED_PREFIX)
                       and k not in flat_like)
        if extra:
            raise ValueError(
                f"checkpoint {path!r} holds key(s) {extra} absent from "
                f"like_tree — refusing to silently drop them")
        for key, ref in flat_like.items():
            if key not in data.files:
                raise ValueError(
                    f"checkpoint {path!r} is missing key {key!r} "
                    f"required by like_tree")
            arr = data[key]
            if arr.shape != ref.shape:
                raise ValueError(
                    f"checkpoint key {key!r}: stored shape {arr.shape} "
                    f"does not match like_tree shape {ref.shape}")
            restored[key] = arr
    leaves_paths = jax.tree_util.tree_flatten_with_path(like_tree)
    paths_leaves = [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in p), leaf)
                    for p, leaf in leaves_paths[0]]
    new_leaves = [jnp.asarray(restored[p]).astype(ref.dtype)
                  for p, ref in paths_leaves]
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)


def restore_step(path: str) -> int | None:
    with np.load(path) as data:
        return int(data["__step__"]) if "__step__" in data.files else None


# ---------------------------------------------------------------------------
# Run-state payloads: named arrays + one JSON metadata blob
# ---------------------------------------------------------------------------

def save_state(path: str, arrays: dict, meta: dict) -> str:
    """Atomically write a mixed arrays + JSON-metadata snapshot.

    `arrays` maps names to array-likes (names must not use the reserved
    ``__`` prefix); `meta` is any JSON-serializable dict — RNG
    bit-generator states round-trip because PCG64 state words are plain
    (big) Python ints, which JSON handles exactly.
    """
    bad = sorted(k for k in arrays if k.startswith(RESERVED_PREFIX))
    if bad:
        raise ValueError(f"array key(s) {bad} use the reserved "
                         f"{RESERVED_PREFIX!r} prefix")
    flat = {k: np.asarray(v) for k, v in arrays.items()}
    flat["__meta__"] = np.asarray(json.dumps(meta))
    _atomic_savez(path, flat)
    return path


def restore_state(path: str) -> tuple[dict, dict]:
    """Load a `save_state` snapshot -> (arrays, meta)."""
    with np.load(path) as data:
        if "__meta__" not in data.files:
            raise ValueError(
                f"{path!r} is not a run-state checkpoint (no __meta__ "
                "payload; param-tree snapshots restore via `restore`)")
        meta = json.loads(str(data["__meta__"][()]))
        arrays = {k: data[k] for k in data.files
                  if not k.startswith(RESERVED_PREFIX)}
    return arrays, meta


def latest_checkpoint(directory: str,
                      prefix: str = CKPT_PREFIX) -> str | None:
    """Newest ``<prefix><number>.npz`` in `directory`, or None.

    "Newest" orders by the numeric suffix (the rounds-done cursor the
    runtime embeds in the filename), not by mtime, so a clock-skewed
    filesystem cannot resume from a stale block.
    """
    if not os.path.isdir(directory):
        return None
    best, best_key = None, None
    for name in os.listdir(directory):
        if not (name.startswith(prefix) and name.endswith(".npz")):
            continue
        try:
            key = int(name[len(prefix):-len(".npz")])
        except ValueError:
            continue
        if best_key is None or key > best_key:
            best, best_key = name, key
    return None if best is None else os.path.join(directory, best)
