"""Checkpoint substrate (npz, path-keyed, tree-structured)."""
from repro.checkpoint import io

__all__ = ["io"]
