"""Coded federated aggregation (paper §III-E).

Per round r+1:
  - client j (if it returns by t*) contributes the unnormalized partial
    gradient over its l*_j processed points:  X~_j^T (X~_j theta - Y~_j)
  - the MEC compute unit contributes the coded gradient over the global
    parity set, weighted by 1/(1 - pnr_C):
        g_C = 1/(1-pnr_C) * Xv^T (Xv theta - Yv)           (eq. 28)
  - the server aggregates  g_M = (g_C + g_U) / m            (eq. 30)

E[g_M] ~= g, the full gradient over the entire decentralized dataset
(eq. 31/32), because the W_j weighting built the parity data to carry
exactly the *expected missing mass* of each data point.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ops


def batched_client_gradients(x_stack, y_stack, theta, *, mask=None,
                             use_pallas: bool = False,
                             interpret: bool = True):
    """All-client unnormalized gradients in one call.

    x_stack: (n, l, q), y_stack: (n, l, c), theta: (q, c) -> (n, q, c).
    Rows padded with zeros contribute exactly zero (x_k = 0 makes the
    per-point gradient x_k (x_k theta - y_k)^T vanish), so callers may pass
    dense mask-padded subsets.  Passing the (n, l) validity `mask` instead
    routes through the fused masked kernel, which also tolerates un-zeroed
    padding; with `use_pallas` the whole stack is one tiled Pallas call
    (interpret mode on CPU, compiled on TPU).
    """
    if mask is not None:
        return ops.linreg_grad_masked(x_stack, theta, y_stack, mask,
                                      use_pallas=use_pallas,
                                      interpret=interpret)
    return ops.linreg_grad_batched(x_stack, theta, y_stack,
                                   use_pallas=use_pallas,
                                   interpret=interpret)


def masked_gradient_sum(client_grads, returned_mask):
    """sum_j 1{T_j<=t*} g_j over a dense (n, q, c) gradient stack.

    returned_mask: (n,) bool/float — fused multiply-add, no Python loop.
    """
    mask = jnp.asarray(returned_mask, client_grads.dtype)[:, None, None]
    return jnp.sum(client_grads * mask, axis=0)


def fused_client_parity_tensors(sub_x, sub_y, mask, parity_x, parity_y, *,
                                pnr_c: float = 0.0,
                                l_target: int | None = None):
    """Append the global parity set as an (n+1)-th pseudo-client row.

    sub_x: (n, l_max, q), sub_y: (n, l_max, c), mask: (n, l_max) validity;
    parity_x: (u, q), parity_y: (u, c).  Returns (fx, fy, fmask) of shapes
    ((n+1, L, q), (n+1, L, c), (n+1, L)) with L = max(l_max, u, l_target).

    The coded-gradient scale 1/(u (1-pnr_C)) (eq. 28, incl. the G^T G / u
    concentration of eq. 31) is folded into the parity row's mask entries:
    `linreg_grad_masked` multiplies the residual by the mask, so the parity
    row yields  Xv^T ((Xv theta - Yv) / (u (1-pnr_C)))  — exactly the coded
    gradient — from the SAME kernel call that produces the n client
    gradients.  Zero-mask padding contributes exactly nothing, so rows of
    different true lengths tile together.  `l_target` pads L further so
    deployments with different loads stack along a sweep axis.
    """
    n, l_max, q = sub_x.shape
    c = sub_y.shape[-1]
    u = parity_x.shape[0]
    L = max(l_max, u, l_target or 1)
    fx = jnp.zeros((n + 1, L, q), sub_x.dtype)
    fy = jnp.zeros((n + 1, L, c), sub_y.dtype)
    # the mask must be floating so the fractional parity scale survives —
    # a bool/int validity mask would truncate 1/u to 1 or 0
    mask = jnp.asarray(mask, sub_x.dtype)
    fmask = jnp.zeros((n + 1, L), mask.dtype)
    fx = fx.at[:n, :l_max].set(sub_x).at[n, :u].set(parity_x)
    fy = fy.at[:n, :l_max].set(sub_y).at[n, :u].set(parity_y)
    scale = 1.0 / (u * (1.0 - pnr_c))
    fmask = fmask.at[:n, :l_max].set(mask).at[n, :u].set(scale)
    return fx, fy, fmask


def fused_embed_client_gradients(x_raw, y_stack, omega, delta, theta, *,
                                 mask, parity_phi=None,
                                 use_pallas: bool = False,
                                 interpret: bool = True):
    """All-client gradients straight from RAW features in one fused call.

    x_raw: (n, l, d) raw features, y_stack: (rows, l, c), mask: (rows, l)
    -> (rows, q, c): the RFF embedding phi(X) = sqrt(2/q) cos(X Omega +
    delta) is computed inside the gradient kernel, so the (n, l, q)
    embedded tensor is never materialized.  With `parity_phi` (l, q) the
    coded parity pseudo-client (already in embedded q-space) rides along
    as row n (rows = n + 1); its mask entries must carry the coded
    1/(u (1-pnr_C)) scale, exactly like `fused_client_parity_tensors`.
    """
    return ops.rff_linreg_grad_masked(
        x_raw, omega, delta, theta, y_stack, mask, parity_phi=parity_phi,
        use_pallas=use_pallas, interpret=interpret)


def fused_embed_client_parity_tensors(sub_x_raw, sub_y, mask, parity_x,
                                      parity_y, *, pnr_c: float = 0.0,
                                      l_target: int | None = None):
    """Raw-space analogue of `fused_client_parity_tensors`.

    sub_x_raw: (n, l_max, d) RAW features, sub_y: (n, l_max, c), mask:
    (n, l_max); parity_x: (u, q) EMBEDDED parity rows, parity_y: (u, c).
    Returns (fx, fy, fmask, pphi) with fx: (n, L, d) raw client rows only
    (the fused kernel appends the parity grid row itself), fy/fmask:
    (n+1, L, ·) carrying the parity labels and its 1/(u (1-pnr_C))-scaled
    mask row, and pphi: (L, q) the pre-embedded parity block the kernel
    substitutes for the in-kernel embed on the parity row.
    L = max(l_max, u, l_target).
    """
    n, l_max, d = sub_x_raw.shape
    c = sub_y.shape[-1]
    u, q = parity_x.shape
    L = max(l_max, u, l_target or 1)
    fx = jnp.zeros((n, L, d), sub_x_raw.dtype).at[:, :l_max].set(sub_x_raw)
    fy = jnp.zeros((n + 1, L, c), sub_y.dtype)
    mask = jnp.asarray(mask, fy.dtype)
    fmask = jnp.zeros((n + 1, L), mask.dtype)
    fy = fy.at[:n, :l_max].set(sub_y).at[n, :u].set(parity_y)
    scale = 1.0 / (u * (1.0 - pnr_c))
    fmask = fmask.at[:n, :l_max].set(mask).at[n, :u].set(scale)
    pphi = jnp.zeros((L, q), parity_x.dtype).at[:u].set(parity_x)
    return fx, fy, fmask, pphi


def client_gradient(x, y, theta, *, use_pallas: bool = False):
    """Unnormalized partial gradient X^T (X theta - Y) over processed points."""
    return ops.linreg_grad(x, theta, y, use_pallas=use_pallas)


def coded_gradient(parity_x, parity_y, theta, pnr_c: float = 0.0,
                   *, use_pallas: bool = False, interpret: bool = True):
    """g_C over the global parity set (eq. 28).

        g_C = 1/(1-pnr_C) * (1/u) * Xv^T (Xv theta - Yv)

    The 1/u factor realizes the G^T G / u -> I concentration (eq. 31):
    E[(1/u) Xv^T(Xv theta - Yv)] = X^^T W^T W (X^ theta - Y), i.e. the SUM
    over data points of the expected-missing-mass-weighted per-point
    gradients — commensurate with the clients' unnormalized sums.
    """
    u = parity_x.shape[0]
    g = ops.linreg_grad(parity_x, theta, parity_y, use_pallas=use_pallas,
                        interpret=interpret)
    return g / (u * (1.0 - pnr_c))


def federated_gradient(coded_g, client_grads, returned_mask, m: int,
                       l2_reg: float = 0.0, theta=None):
    """g_M = (g_C + sum_j 1{T_j<=t*} g_j) / m  (+ optional L2 term).

    coded_g: (q, c) or None (coded unit straggled this round / uncoded run)
    client_grads: list of (q, c) unnormalized client gradients
    returned_mask: bool per client — whether it arrived by the deadline
    """
    total = jnp.zeros_like(client_grads[0] if client_grads else coded_g)
    for g, ret in zip(client_grads, returned_mask):
        total = total + jnp.where(ret, g, jnp.zeros_like(g))
    if coded_g is not None:
        total = total + coded_g
    g_m = total / m
    if l2_reg and theta is not None:
        g_m = g_m + l2_reg * theta
    return g_m
