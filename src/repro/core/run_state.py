"""Explicit, serializable state of a block-structured federated run.

`RunState` is everything `Experiment.run_block` needs to advance a run by
one block — and therefore everything a checkpoint needs to resume it
bit-identically after a kill:

  * the model carry ``theta`` and the global round cursor (the
    lr-schedule position is derived from the cursor, never stored),
  * the run RNG's bit-generator state (delay draws continue mid-stream),
  * the trace-stream index and live `repro.net.trace.TraceState` of the
    channel trace (the former hidden ``Experiment._next_trace_rng``
    counter, folded in here so replays are hermetic),
  * the `OnlineChannelEstimator` sufficient statistics and the adaptive
    control values (loads / deadline / wait count) in effect,
  * the per-round accumulators that become the final `FedResult` history
    (round times, returned counts, eval losses) and the adaptive
    schedule record,
  * the degradation state of the self-healing runtime: the divergence
    guard's lr backoff scale, per-round masked-return / skipped-round
    accumulators (surfaced as `FedResult.health`), the previous-round
    iterate when stale-update faults are enabled, and the dedicated
    fault-stream RNG state (`repro.faults`).

Four run modes share the structure: ``"single"`` (one trajectory,
blocks advance the round cursor), ``"multi"`` (stationary `run_multi`,
blocks advance all realizations' round cursors together),
``"multi_channel"`` (traced `run_multi`, blocks advance one full
realization at a time — each realization is an independent trace), and
``"hier"`` (the hierarchical population tier, `repro.hier.topology` —
which additionally carries the dedicated client-sampling stream's RNG
position ``sample_rng_state`` so sampled cohorts replay bit-identically
across kill/resume).

`pack_state`/`unpack_state` convert to/from the (arrays, JSON-meta)
payload of `repro.checkpoint.io.save_state`; numpy PCG64 states are
plain-int dicts, so the RNG round-trips exactly through JSON.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.net.trace import TraceState

FORMAT_VERSION = 2

_MODES = ("single", "multi", "multi_channel", "hier")

#: per-sub-block adaptive schedule record arrays, (B, n) unless noted
_SCHED_KEYS = ("times", "active", "block_idx", "t_star_r", "n_wait_r",
               "loads_blocks", "est_mu", "est_tau", "est_p", "est_avail",
               "est_rounds_seen")

_WIN_KEYS = ("comp", "tau", "ntr", "avail")


@dataclasses.dataclass
class RunState:
    """One resumable run, between block boundaries.  See module docstring.

    Accumulator shapes by mode (r = rounds_done, R = n_realizations,
    T = iterations):

      single        t_rounds (r,)    n_ret (r,)    theta (q, c)
      multi         t_rounds (R, r)  n_ret (R, r)  theta (R, q, c)
      multi_channel t_rounds (realizations_done, T), theta (R, q, c)
                    with rows past ``realizations_done`` still zero
    """
    mode: str
    iterations: int
    rounds_done: int
    realizations_done: int
    n_realizations: Optional[int]
    collect: bool                     # eval thetas collected per block
    theta: Any                        # jnp.ndarray
    rng_state: dict                   # run RNG (delay draws)
    trace_call: int                   # base trace-stream index (-1 = none)
    trace: Optional[TraceState]
    est: Optional[dict]               # OnlineChannelEstimator.state_dict()
    controls: Optional[dict]          # {"loads", "t_star", "n_wait"}
    t_rounds: np.ndarray
    n_ret: np.ndarray
    losses: Optional[np.ndarray]      # (r,) NaN where not evaluated
    accs: Optional[np.ndarray]
    sched: Optional[dict]             # adaptive record, keys _SCHED_KEYS
    # --- self-healing runtime state (format >= 2) --------------------
    lr_scale: Any = None              # divergence-backoff lr multiplier,
                                      # () for single / (R,) for multi
    n_masked: Optional[np.ndarray] = None  # per-round masked returns,
                                           # shaped like n_ret
    skipped: Optional[np.ndarray] = None   # per-round 0/1 divergence
                                           # skips, shaped like n_ret
    theta_prev: Any = None            # previous-round iterate (present
                                      # only when stale faults are on)
    fault_rng_state: Optional[dict] = None  # fault-stream RNG (PCG64)
    # --- hierarchical tier state (mode "hier") -----------------------
    sample_rng_state: Optional[dict] = None  # client-sampling-stream RNG

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown run mode {self.mode!r} "
                             f"(expected one of {_MODES})")

    @property
    def done(self) -> bool:
        if self.mode == "multi_channel":
            return self.realizations_done >= int(self.n_realizations)
        return self.rounds_done >= self.iterations


def _scalar(val):
    """None-preserving plain-Python scalar for JSON metadata."""
    if val is None:
        return None
    return val.item() if isinstance(val, np.generic) else val


def pack_state(state: RunState) -> "tuple[dict, dict]":
    """RunState -> (arrays, meta) for `checkpoint.io.save_state`."""
    arrays = {
        "theta": np.asarray(state.theta),
        "t_rounds": np.asarray(state.t_rounds),
        "n_ret": np.asarray(state.n_ret),
    }
    meta = {
        "format": FORMAT_VERSION,
        "mode": state.mode,
        "iterations": int(state.iterations),
        "rounds_done": int(state.rounds_done),
        "realizations_done": int(state.realizations_done),
        "n_realizations": _scalar(state.n_realizations),
        "collect": bool(state.collect),
        "rng_state": state.rng_state,
        "trace_call": int(state.trace_call),
        "has_eval": state.losses is not None,
        "trace": None,
        "est": None,
        "controls": None,
        "has_sched": state.sched is not None,
        "fault_rng_state": state.fault_rng_state,
        "sample_rng_state": state.sample_rng_state,
    }
    if state.lr_scale is not None:
        arrays["lr_scale"] = np.asarray(state.lr_scale, np.float64)
    if state.n_masked is not None:
        arrays["n_masked"] = np.asarray(state.n_masked)
        arrays["skipped"] = np.asarray(state.skipped)
    if state.theta_prev is not None:
        arrays["theta_prev"] = np.asarray(state.theta_prev)
    if state.losses is not None:
        arrays["losses"] = np.asarray(state.losses)
        arrays["accs"] = np.asarray(state.accs)
    if state.trace is not None:
        meta["trace"] = {"rng_state": state.trace.rng_state,
                         "rounds_done": int(state.trace.rounds_done)}
        arrays["trace/ge_bad"] = state.trace.ge_bad
        arrays["trace/shadow_x"] = state.trace.shadow_x
        arrays["trace/drift_g"] = state.trace.drift_g
        arrays["trace/churn_active"] = state.trace.churn_active
    if state.est is not None:
        est = state.est
        meta["est"] = {"beta": float(est["beta"]),
                       "window": _scalar(est["window"]),
                       "rounds_seen": int(est["rounds_seen"])}
        for key in ("s_tau", "s_ntr", "s_comp", "avail_hat"):
            arrays[f"est/{key}"] = np.asarray(est[key])
        for key in _WIN_KEYS:
            arrays[f"est/win_{key}"] = np.asarray(est["win"][key])
    if state.controls is not None:
        meta["controls"] = {
            "t_star": _scalar(state.controls.get("t_star")),
            "n_wait": _scalar(state.controls.get("n_wait"))}
        arrays["controls/loads"] = np.asarray(state.controls["loads"],
                                              np.float64)
    if state.sched is not None:
        for key in _SCHED_KEYS:
            arrays[f"sched/{key}"] = np.asarray(state.sched[key])
    return arrays, meta


def unpack_state(arrays: dict, meta: dict) -> RunState:
    """(arrays, meta) -> RunState; inverse of `pack_state`."""
    if meta.get("format") != FORMAT_VERSION:
        raise ValueError(f"run-state format {meta.get('format')!r} not "
                         f"supported (this build reads {FORMAT_VERSION})")
    trace = None
    if meta["trace"] is not None:
        trace = TraceState(
            rng_state=meta["trace"]["rng_state"],
            rounds_done=int(meta["trace"]["rounds_done"]),
            ge_bad=np.asarray(arrays["trace/ge_bad"], bool),
            shadow_x=np.asarray(arrays["trace/shadow_x"], np.float64),
            drift_g=np.asarray(arrays["trace/drift_g"], np.float64),
            churn_active=np.asarray(arrays["trace/churn_active"], bool))
    est = None
    if meta["est"] is not None:
        est = {"beta": meta["est"]["beta"],
               "window": meta["est"]["window"],
               "rounds_seen": meta["est"]["rounds_seen"],
               "win": {key: np.asarray(arrays[f"est/win_{key}"])
                       for key in _WIN_KEYS}}
        for key in ("s_tau", "s_ntr", "s_comp", "avail_hat"):
            est[key] = np.asarray(arrays[f"est/{key}"])
    controls = None
    if meta["controls"] is not None:
        controls = {"loads": np.asarray(arrays["controls/loads"],
                                        np.float64),
                    "t_star": meta["controls"]["t_star"],
                    "n_wait": meta["controls"]["n_wait"]}
    sched = None
    if meta.get("has_sched"):
        sched = {key: np.asarray(arrays[f"sched/{key}"])
                 for key in _SCHED_KEYS}
    has_eval = bool(meta.get("has_eval"))
    return RunState(
        mode=meta["mode"],
        iterations=int(meta["iterations"]),
        rounds_done=int(meta["rounds_done"]),
        realizations_done=int(meta["realizations_done"]),
        n_realizations=meta["n_realizations"],
        collect=bool(meta["collect"]),
        theta=jnp.asarray(arrays["theta"]),
        rng_state=meta["rng_state"],
        trace_call=int(meta["trace_call"]),
        trace=trace, est=est, controls=controls,
        t_rounds=np.asarray(arrays["t_rounds"]),
        n_ret=np.asarray(arrays["n_ret"]),
        losses=np.asarray(arrays["losses"]) if has_eval else None,
        accs=np.asarray(arrays["accs"]) if has_eval else None,
        sched=sched,
        lr_scale=(np.asarray(arrays["lr_scale"])
                  if "lr_scale" in arrays else None),
        n_masked=(np.asarray(arrays["n_masked"])
                  if "n_masked" in arrays else None),
        skipped=(np.asarray(arrays["skipped"])
                 if "skipped" in arrays else None),
        theta_prev=(jnp.asarray(arrays["theta_prev"])
                    if "theta_prev" in arrays else None),
        fault_rng_state=meta.get("fault_rng_state"),
        sample_rng_state=meta.get("sample_rng_state"))
