"""Secure aggregation of local parity datasets (paper §VI future work).

The paper notes that the server only needs the *global* parity dataset
(the sum of local parity sets), so local sets can be hidden by secure
aggregation [Bonawitz et al. 2016].  This implements the pairwise-mask
construction: every client pair (i, j) derives a shared mask M_ij from a
shared seed; client i adds +M_ij for j > i and -M_ij for j < i, so all
masks cancel exactly in the server-side sum while each individual upload
is marginally uniform noise.

The shared seeds come from a deterministic key-agreement stand-in
(fold_in of both ids into a session key); swapping in a real DH exchange
changes nothing downstream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.encoding import LocalParity


def _pair_key(session_key, i: int, j: int):
    lo, hi = (i, j) if i < j else (j, i)
    return jax.random.fold_in(jax.random.fold_in(session_key, lo), hi)


def _mask_like(key, parity: LocalParity, scale: float):
    kx, ky = jax.random.split(key)
    return LocalParity(
        x=jax.random.normal(kx, parity.x.shape, parity.x.dtype) * scale,
        y=jax.random.normal(ky, parity.y.shape, parity.y.dtype) * scale,
    )


def mask_parity(session_key, client_id: int, n_clients: int,
                parity: LocalParity, scale: float = 1.0) -> LocalParity:
    """Return the client's masked upload (what the server may see)."""
    x, y = parity.x, parity.y
    for other in range(n_clients):
        if other == client_id:
            continue
        m = _mask_like(_pair_key(session_key, client_id, other), parity,
                       scale)
        sign = 1.0 if client_id < other else -1.0
        x = x + sign * m.x
        y = y + sign * m.y
    return LocalParity(x=x, y=y)


def secure_aggregate(masked: list[LocalParity]) -> LocalParity:
    """Server-side sum; pairwise masks cancel, yielding the true global
    parity dataset without revealing any individual local set."""
    x = jnp.sum(jnp.stack([p.x for p in masked]), axis=0)
    y = jnp.sum(jnp.stack([p.y for p in masked]), axis=0)
    return LocalParity(x=x, y=y)
