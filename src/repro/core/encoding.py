"""Distributed encoding of local datasets into parity data (paper §III-B/D).

Client j:
  - draws a PRIVATE generator G_j in R^{u x l_j}, entries iid mean-0 var-1
    (normal or Rademacher);
  - builds the diagonal weight matrix W_j from the no-return probabilities:
      w_{j,k} = sqrt(1 - P(T_j <= t*))  if point k is in the processed subset
      w_{j,k} = 1                        otherwise (never evaluated locally)
    (paper §III-D: pnr_{j,2} = 1 for unprocessed points);
  - ships (X~_j, Y~_j) = (G_j W_j X^_j, G_j W_j Y_j) to the server.

Server: sums the n local parity sets -> global parity dataset (eq. 20/21).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def generator_matrix(key, u: int, l: int, kind: str = "normal"):
    """Private random generator G_j with iid mean-0 var-1 entries."""
    if kind == "normal":
        return jax.random.normal(key, (u, l), jnp.float32)
    if kind == "rademacher":
        return (2.0 * jax.random.bernoulli(key, 0.5, (u, l)) - 1.0).astype(jnp.float32)
    raise ValueError(kind)


def weight_vector(l: int, processed_idx: np.ndarray, p_return: float) -> np.ndarray:
    """Diagonal of W_j (paper §III-D).

    processed_idx: indices of the l*_j points the client will process.
    p_return: P(T_j <= t*) for this client.
    """
    w = np.ones(l, dtype=np.float32)                    # sqrt(pnr=1) = 1
    w[processed_idx] = np.sqrt(1.0 - p_return)          # sqrt(pnr_{j,1})
    return w


@dataclasses.dataclass
class LocalParity:
    x: jnp.ndarray    # (u, q)
    y: jnp.ndarray    # (u, c)


def encode_local(key, x_hat, y, w, u: int, *, kind: str = "normal",
                 use_pallas: bool = False) -> LocalParity:
    """Local parity dataset (X~_j, Y~_j) = (G_j W_j X^_j, G_j W_j Y_j)."""
    l = x_hat.shape[0]
    g = generator_matrix(key, u, l, kind)
    w = jnp.asarray(w)
    px = ops.parity_encode(g, w, x_hat, use_pallas=use_pallas)
    py = ops.parity_encode(g, w, y, use_pallas=use_pallas)
    return LocalParity(x=px, y=py)


def encode_local_batched(keys, x_stack, y_stack, w_stack, u: int, *,
                         kind: str = "normal",
                         use_pallas: bool = False,
                         interpret: bool = True) -> LocalParity:
    """All-clients parity encode in one batched call.

    keys: (n,) stacked PRNG keys (one per client, identical to what a
    sequential `jax.random.split` chain would hand each client, so the
    parity sets match `encode_local` exactly);
    x_stack: (n, l, q); y_stack: (n, l, c); w_stack: (n, l).
    Returns stacked LocalParity with x: (n, u, q), y: (n, u, c).

    The jnp path vmaps the reference encode; `use_pallas` runs the whole
    population through ONE tiled `parity_encode_batched` kernel launch per
    array (client axis = outermost grid dim) — bit-identical to a
    per-client `encode_local` loop, without its n Python-level kernel
    launches and padding rounds.
    """
    l = x_stack.shape[1]
    if use_pallas:
        g_stack = jax.vmap(
            lambda k: generator_matrix(k, u, l, kind))(keys)
        w_stack = jnp.asarray(w_stack)
        px = ops.parity_encode_batched(g_stack, w_stack,
                                       jnp.asarray(x_stack),
                                       use_pallas=True, interpret=interpret)
        py = ops.parity_encode_batched(g_stack, w_stack,
                                       jnp.asarray(y_stack),
                                       use_pallas=True, interpret=interpret)
        return LocalParity(x=px, y=py)

    def one(key, x, y, w):
        g = generator_matrix(key, u, x.shape[0], kind)
        return ref.parity_encode(g, w, x), ref.parity_encode(g, w, y)

    px, py = jax.vmap(one)(keys, jnp.asarray(x_stack), jnp.asarray(y_stack),
                           jnp.asarray(w_stack))
    return LocalParity(x=px, y=py)


def aggregate_parity_stacked(parity: LocalParity) -> LocalParity:
    """Global parity set from a stacked (n, u, ·) LocalParity (eq. 20)."""
    return LocalParity(x=jnp.sum(parity.x, axis=0), y=jnp.sum(parity.y, axis=0))


def aggregate_parity(parities: list[LocalParity]) -> LocalParity:
    """Global parity set = sum over clients (paper eq. 20).

    On a pod this is a psum over the `data` axis; here (host simulation of
    the MEC server) it is a tree-sum.
    """
    x = jnp.sum(jnp.stack([p.x for p in parities]), axis=0)
    y = jnp.sum(jnp.stack([p.y for p in parities]), axis=0)
    return LocalParity(x=x, y=y)
