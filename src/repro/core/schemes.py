"""Straggler-mitigation scheme registry (the paper's §V "Schemes").

Each scheme is a registered object that owns its deployment setup — load
allocation, parity construction, privacy accounting — and its contributions
to the compiled step (`fed_runtime.build_step` consts / gradient tensors)
behind one common interface.  The runtime (`repro.core.fed_runtime`), the
compiled sweep (`repro.launch.sweep`), and the benchmark grid
(`repro.launch.bench`) all enumerate this registry, so registering a new
scheme makes it runnable via ``repro.api.build_experiment`` and puts it in
``BENCH_fed_training.json`` automatically.

Built-in schemes:

  naive          — server waits for ALL n clients (full load).
  greedy         — server waits for the fastest (1-psi)*n clients.
  ideal          — deterministic no-straggler floor: full load, exact
                   compute, one transmission per direction.  Runnable
                   (same gradients as naive, deterministic wall-clock).
  coded          — CodedFedL: optimized loads l*_j + a global parity set
                   with redundancy u = delta * m; round time = t*.
  partial_coded  — coded with a *tunable fraction* of the redundancy
                   budget, u = u_fraction * delta * m (Prakash et al. /
                   Sun et al. style partial coding: less parity shared,
                   smaller privacy budget, weaker straggler cover).  The
                   fraction comes from ``ExperimentSpec.scheme_params``
                   ("u_fraction", default 0.5).

Registering your own::

    from repro.core import schemes

    class MyScheme(schemes.CodedScheme):
        name = "my_scheme"
        def u_budget(self, exp):
            return 7   # any redundancy rule

    schemes.register(MyScheme())
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding, load_allocation, privacy
from repro.core.delay_model import ideal_round_time, packet_bits
from repro.obs import spans as obs_spans


class Scheme:
    """Base scheme: full per-client loads, no parity, no deadline consts.

    Subclasses set ``name`` (registry key) and ``step_kind`` (the static
    branch `fed_runtime.build_step` compiles: one of "naive", "greedy",
    "coded", "ideal", "adaptive_coded", "adaptive_greedy").  ``coded``
    marks schemes that allocate loads and build a parity set (t_star /
    loads / parity / privacy budget).  ``grid`` marks schemes that belong
    to the default profile-grid sweep/benchmark
    (`repro.launch.sweep.run_sweep` / `repro.launch.bench`); adaptive
    schemes opt out — they need a channel trace and a per-run control
    schedule, and are benched by the drift-scenario runner
    (`repro.launch.scenarios`) instead.
    """
    name: str = ""
    step_kind: str = ""
    coded: bool = False
    grid: bool = True

    def setup(self, exp) -> None:
        """Host-side deployment setup; mutates the Experiment in place."""

    def consts_point_len(self, exp) -> int:
        """Point-axis length of `grad_tensors`' gx — shape arithmetic only,
        so sweep callers can compute a grid-wide l_target cheaply."""
        return exp.l

    def grad_tensors(self, exp, l_target=None):
        """(gx, gy, gmask, ret_tail) — the dense client gradient tensors.

        ret_tail lists the returned-mask entries of any pseudo-client rows
        appended past the n real clients (mesh padding is applied by the
        caller on top).
        """
        gx, gy = exp.x, exp.y
        gmask = jnp.ones((exp.n, exp.l), exp.x.dtype)
        return gx, gy, gmask, []

    def extra_consts(self, exp) -> dict:
        """Scheme-specific entries of the step `consts` pytree."""
        return {}

    def privacy_budget(self, exp):
        """Worst-case eps-MI-DP leakage (bits) of what clients share, or
        None when nothing beyond gradients leaves the device."""
        return None

    def replan(self, exp, estimator) -> dict:
        """Adaptive-family hook: new control values from the estimated
        network (called by `repro.net.estimator.AdaptiveController`
        between blocks).  Returns a dict of updated control values
        ({"loads", "t_star"} for the coded family, {"n_wait"} for the
        greedy family); non-adaptive schemes never re-plan."""
        raise NotImplementedError(f"{self.name!r} is not adaptive")

    def initial_controls(self, exp) -> dict:
        """The scheme's control-plane contribution to a fresh `RunState`:
        the values in effect at round 0, updated by `replan` thereafter
        and carried across checkpoint boundaries.  Every scheme has a
        load vector and a wait count; the coded family additionally has
        its setup-time deadline (``t_star`` is None otherwise)."""
        return {"loads": np.asarray(exp.loads, np.float64).copy(),
                "t_star": exp.t_star, "n_wait": exp.n_wait}

    def __repr__(self):
        return f"<Scheme {self.name!r} step_kind={self.step_kind!r}>"


class NaiveScheme(Scheme):
    name = "naive"
    step_kind = "naive"


class GreedyScheme(Scheme):
    name = "greedy"
    step_kind = "greedy"


class IdealScheme(Scheme):
    """Deterministic no-straggler baseline, now runnable end-to-end.

    Gradient-wise identical to naive (every client, full load); the round
    clock is the deterministic floor `delay_model.ideal_round_time` instead
    of the sampled max — so trajectories match naive's all-returned rounds
    while the wall-clock lower-bounds every full-load scheme.
    """
    name = "ideal"
    step_kind = "ideal"

    def setup(self, exp) -> None:
        exp.t_ideal = ideal_round_time(exp.nodes, float(exp.l))

    def extra_consts(self, exp) -> dict:
        return {"t_ideal": jnp.float32(exp.t_ideal)}


class CodedScheme(Scheme):
    """CodedFedL (paper §III): optimized loads + global parity set.

    The parity set is also what makes the coded family *robust*: the
    MDS-style global parity gradient stands in for whatever client mass
    is missing from a round, whether that mass was lost to stragglers
    (the paper's case) or masked out by the runtime's non-finite guard
    (`fed_runtime.build_step` with fault injection, `repro.faults`).  A
    naive average has no such stand-in — masked returns simply shrink
    its effective batch, which is the coded-degrades-gracefully /
    naive-pays contrast the resilience benchmark records
    (`repro.launch.resilience`).
    """
    name = "coded"
    step_kind = "coded"
    coded = True

    # ------------------------------------------------------------ redundancy
    def u_budget(self, exp) -> int:
        """Parity rows u to build — the full paper budget delta * m."""
        return max(1, int(round(exp.fl.delta * exp.m)))

    # ----------------------------------------------------------------- setup
    def setup(self, exp) -> None:
        fl = exp.fl
        u_max = self.u_budget(exp)
        allocate = (load_allocation.two_step_allocate_vectorized
                    if exp._pick_alloc_backend() == "vectorized"
                    else load_allocation.two_step_allocate)
        with obs_spans.span("solver/two_step"):
            alloc = allocate(
                exp.nodes, [float(exp.l)] * exp.n, server=None,
                u_max=float(u_max), m=float(exp.m))
        exp.t_star = alloc.t_star
        exp.u = u_max
        # integer loads (floor, at least 0)
        exp.loads = np.minimum(np.floor(alloc.loads).astype(int), exp.l)
        # probability of return by t* per client at its optimal load
        exp.p_return = np.array([
            nd.cdf(exp.t_star, float(ld)) if ld > 0 else 0.0
            for nd, ld in zip(exp.nodes, exp.loads)])
        # Processed-subset sampling v2 (vectorized): one `rng.permuted` draw
        # over an (n, l) index matrix replaces the per-client
        # `rng.permutation` loop.  This consumes the numpy RNG stream
        # differently from v1 (so subsets differ across versions — pinned by
        # tests/test_batched_engine.py::test_vectorized_subset_sampling_spec)
        # but stays fully deterministic per seed.
        perm = exp.rng.permuted(
            np.tile(np.arange(exp.l), (exp.n, 1)), axis=1)
        # selection-priority order: point perm[j, k] is the k-th point
        # client j would process — the adaptive family re-masks prefixes
        # of this order when it re-allocates loads
        exp._select_perm = perm
        take = np.arange(exp.l)[None, :] < exp.loads[:, None]   # (n, l)
        processed = np.zeros((exp.n, exp.l), dtype=bool)
        row_ids = np.broadcast_to(np.arange(exp.n)[:, None],
                                  (exp.n, exp.l))
        processed[row_ids[take], perm[take]] = True
        exp.processed_idx = [np.nonzero(processed[j])[0]
                             for j in range(exp.n)]
        # weight matrices (paper §III-D) for the whole population at once:
        # sqrt(1 - P(return)) on processed points, 1 elsewhere
        w_stack = np.where(processed,
                           np.sqrt(1.0 - exp.p_return)[:, None],
                           1.0).astype(np.float32)
        # per-client PRNG keys: same sequential split chain the per-client
        # encode would consume, rolled up into one lax.scan
        def _chain(key, _):
            key, sub = jax.random.split(key)
            return key, sub
        _, keys = jax.lax.scan(_chain, jax.random.PRNGKey(fl.seed + 99),
                               None, length=exp.n)
        # all n local parity sets in one batched encode (paper eq. 19) —
        # one vmapped jnp call or one tiled Pallas kernel launch.  In
        # fused_embed mode the clients hold RAW features; parity encoding
        # happens over on-the-fly embeds (a transient (n, l, q) stack that
        # lives only for this setup step — the round path never sees it)
        x_enc = exp.embedded_x() if exp.fused_embed else exp.x
        with obs_spans.span("encode/parity"):
            stacked = encoding.encode_local_batched(
                keys, x_enc, exp.y, w_stack, exp.u,
                use_pallas=exp.kernel_backend == "pallas",
                interpret=exp._interpret)
        if exp.secure_aggregation:
            # paper §VI future work: the server only ever sees masked
            # uploads; pairwise masks cancel in the sum (core/secure_agg.py)
            from repro.core import secure_agg
            skey = jax.random.PRNGKey(fl.seed + 1234)
            masked = [secure_agg.mask_parity(
                skey, j, exp.n,
                encoding.LocalParity(x=stacked.x[j], y=stacked.y[j]))
                for j in range(exp.n)]
            exp.parity = secure_agg.secure_aggregate(masked)
        else:
            exp.parity = encoding.aggregate_parity_stacked(stacked)
        # one-time parity upload overhead: clients upload u*(q+c) scalars in
        # parallel; expected transmissions 1/(1-p) (paper Fig 4a inset).
        # NodeDelayParams validates p < 1 at construction, so the expected
        # transmission count is finite here by contract.
        bits = packet_bits(fl, exp.u * (exp.q + exp.c))
        exp.setup_time = max(
            nd.tau / packet_bits(fl, exp.q * exp.c) * bits / (1.0 - nd.p)
            for nd in exp.nodes)
        # ragged per-client subsets: only the legacy oracle reads them
        if exp.engine == "legacy":
            exp._sub_x = [exp.x[j][exp.processed_idx[j]]
                          for j in range(exp.n)]
            exp._sub_y = [exp.y[j][exp.processed_idx[j]]
                          for j in range(exp.n)]
        # dense mask-padded (n, l_max, ·) view: the chosen indices of each
        # row, sorted ascending, with unchosen slots pushed past the end by
        # an `l` sentinel — vectorized replacement for the per-client
        # pad/gather loop
        l_max = max(1, int(exp.loads.max()))
        sorted_idx = np.sort(np.where(take, perm, exp.l), axis=1)[:, :l_max]
        pad_mask = (sorted_idx < exp.l).astype(np.float32)
        pad_idx = np.where(sorted_idx < exp.l, sorted_idx, 0).astype(np.int32)
        rows = jnp.asarray(pad_idx)
        mask = jnp.asarray(pad_mask)[:, :, None]
        gather = jax.vmap(lambda xj, ij: xj[ij])
        exp._sub_x_pad = gather(exp.x, rows) * mask
        exp._sub_y_pad = gather(exp.y, rows) * mask
        exp._grad_mask = jnp.asarray(pad_mask)       # (n, l_max) row validity

    # ------------------------------------------------------------ step consts
    def consts_point_len(self, exp) -> int:
        l_max = int(exp._sub_x_pad.shape[1])
        return max(l_max, exp.u) if exp.fused_coded else l_max

    def grad_tensors(self, exp, l_target=None):
        from repro.core import aggregation
        if exp.fused_coded:
            if exp.fused_embed:
                # raw-space client rows; the embedded parity block goes in
                # as a separate `pphi` const the fused kernel reads on the
                # parity grid row (stashed here so `extra_consts` — which
                # has no l_target — ships the matching padded view)
                gx, gy, gmask, pphi = \
                    aggregation.fused_embed_client_parity_tensors(
                        exp._sub_x_pad, exp._sub_y_pad, exp._grad_mask,
                        exp.parity.x, exp.parity.y, pnr_c=0.0,
                        l_target=l_target)
                exp._pphi_const = pphi
            else:
                gx, gy, gmask = aggregation.fused_client_parity_tensors(
                    exp._sub_x_pad, exp._sub_y_pad, exp._grad_mask,
                    exp.parity.x, exp.parity.y, pnr_c=0.0,
                    l_target=l_target)
            tail = [1.0]          # the always-active parity pseudo-row
        else:
            gx, gy, gmask = (exp._sub_x_pad, exp._sub_y_pad,
                             exp._grad_mask)
            if l_target is not None and l_target > gx.shape[1]:
                pad = ((0, 0), (0, l_target - gx.shape[1]))
                gx = jnp.pad(gx, pad + ((0, 0),))
                gy = jnp.pad(gy, pad + ((0, 0),))
                gmask = jnp.pad(gmask, pad)
            tail = []
        return gx, gy, gmask, tail

    def extra_consts(self, exp) -> dict:
        consts = {
            "t_star": jnp.float32(exp.t_star),
            "active": jnp.asarray(exp.loads > 0, jnp.float32),
        }
        if exp.fused_coded and exp.fused_embed:
            consts["pphi"] = exp._pphi_const
        if not exp.fused_coded:
            consts["par_x"] = exp.parity.x
            consts["par_y"] = exp.parity.y
        return consts

    # --------------------------------------------------------------- privacy
    def privacy_budget(self, exp) -> float:
        """Worst-client eps-MI-DP budget (bits) of sharing u parity rows
        (paper Appendix F, eq. 62).  What leaks is the EMBEDDED data the
        parity rows are built from, so fused_embed runs account over the
        same transient embeds the parity encode consumed."""
        x_src = exp.embedded_x() if exp.fused_embed else exp.x
        return float(max(
            privacy.mi_dp_budget(np.asarray(x_src[j]), exp.u)
            for j in range(exp.n)))


class PartialCodedScheme(CodedScheme):
    """Coded with a tunable fraction of the redundancy budget.

    u = u_fraction * delta * m, u_fraction in (0, 1] — the partial/
    stochastic-coding regime of Prakash et al. (*Coded Computing for
    Federated Learning at the Edge*) and Sun et al. (*Stochastic Coded
    Federated Learning*): smaller parity uploads (cheaper setup, smaller
    eps-MI-DP leakage) against a later optimal deadline t*.
    """
    name = "partial_coded"
    default_u_fraction = 0.5

    def u_fraction(self, exp) -> float:
        frac = float(exp.scheme_params.get("u_fraction",
                                           self.default_u_fraction))
        if not 0.0 < frac <= 1.0:
            raise ValueError(
                f"u_fraction must lie in (0, 1], got {frac}")
        return frac

    def u_budget(self, exp) -> int:
        return max(1, int(round(self.u_fraction(exp)
                                * exp.fl.delta * exp.m)))


class AdaptiveCodedScheme(CodedScheme):
    """CodedFedL with blockwise load re-allocation under network drift.

    Static CodedFedL solves the two-step allocation ONCE from the nominal
    (round-0) delay statistics; when the network drifts (Dhakal et al.
    2020, Sun et al. 2022 both flag this), the fixed deadline t* either
    wastes wall-clock on a network that got faster or bleeds return mass
    on one that got slower.  This scheme re-solves the allocation every
    ``ExperimentSpec.adapt_every`` rounds on the *estimated* network
    (`repro.net.estimator`), applying the new loads as prefix-mask
    re-weightings over a full-length fused client tensor — shapes (and
    the compiled step) never change.

    The parity set stays the one built at setup from the initial
    allocation: re-uploading parity every block would re-pay the setup
    cost the coding exists to amortize, so the §III-D expected-miss
    weights are an approximation away from the re-allocated loads (the
    same approximation a deployed system would make).

    ``scheme_params`` knobs: ``est_beta`` (EWMA factor, default 0.25),
    ``est_window`` (switch to windowed-MLE estimation), ``avail_min``
    (availability score below which a client gets no load, default 0.5).
    """
    name = "adaptive_coded"
    step_kind = "adaptive_coded"
    grid = False

    def setup(self, exp) -> None:
        if not exp.fused_coded:
            raise ValueError(
                "adaptive_coded requires fused_coded=True (re-allocation "
                "re-weights the fused client+parity mask)")
        if exp.fused_embed:
            raise NotImplementedError(
                "adaptive_coded does not support fused_embed yet (the "
                "per-block gmask re-weighting assumes embedded tensors)")
        super().setup(exp)
        # full-length priority view: every client's points in selection-
        # priority order, so ANY re-allocated load l_j <= l is a prefix
        # mask of the same (n, l) tensor
        perm = jnp.asarray(exp._select_perm)
        gather = jax.vmap(lambda xj, ij: xj[ij])
        exp._adapt_x = gather(exp.x, perm)
        exp._adapt_y = gather(exp.y, perm)

    # ------------------------------------------------------------ step consts
    def consts_point_len(self, exp) -> int:
        return max(exp.l, exp.u)

    def grad_tensors(self, exp, l_target=None):
        from repro.core import aggregation
        # full-length tensors; the per-block prefix mask (not baked into
        # the data) selects the processed points — linreg_grad_masked
        # tolerates un-zeroed padding by contract
        gx, gy, gmask = aggregation.fused_client_parity_tensors(
            exp._adapt_x, exp._adapt_y,
            jnp.asarray(self._prefix_mask(exp, exp.loads)),
            exp.parity.x, exp.parity.y, pnr_c=0.0, l_target=l_target)
        return gx, gy, gmask, [1.0]

    @staticmethod
    def _prefix_mask(exp, loads) -> np.ndarray:
        """(n, l) float32 prefix mask over the priority order."""
        loads = np.asarray(loads)
        return (np.arange(exp.l)[None, :]
                < loads[:, None]).astype(np.float32)

    def gmask_for_loads(self, exp, loads) -> jnp.ndarray:
        """(n+1, L) fused mask for a load vector: client prefix rows plus
        the 1/u-scaled parity pseudo-row — the mask-re-weighting unit the
        adaptive step indexes per block."""
        L = max(exp.l, exp.u)
        mask = np.zeros((exp.n + 1, L), np.float32)
        mask[:exp.n, :exp.l] = self._prefix_mask(exp, loads)
        mask[exp.n, :exp.u] = 1.0 / exp.u
        return jnp.asarray(mask)

    # ----------------------------------------------------------------- replan
    def replan(self, exp, estimator) -> dict:
        from repro.core import load_allocation
        est_nodes = estimator.estimated_nodes()
        avail_min = float(exp.scheme_params.get("avail_min", 0.5))
        caps = np.where(estimator.avail_hat >= avail_min, float(exp.l), 0.0)
        allocate = (load_allocation.two_step_allocate_vectorized
                    if exp._pick_alloc_backend() == "vectorized"
                    else load_allocation.two_step_allocate)
        with obs_spans.span("solver/two_step"):
            try:
                alloc = allocate(est_nodes, list(caps), server=None,
                                 u_max=float(exp.u), m=float(exp.m))
            except ValueError:
                # too many clients estimated unavailable for feasibility:
                # fall back to full caps rather than keep a stale plan
                alloc = allocate(est_nodes, [float(exp.l)] * exp.n,
                                 server=None, u_max=float(exp.u),
                                 m=float(exp.m))
        loads = np.minimum(np.floor(alloc.loads).astype(int), exp.l)
        return {"loads": loads, "t_star": float(alloc.t_star)}


class AdaptiveGreedyScheme(GreedyScheme):
    """Greedy waiting with an adaptively re-tuned wait count.

    Static greedy always waits for the fastest ``(1 - psi) n`` clients.
    Under drift/churn the right count changes: this scheme re-picks, every
    ``adapt_every`` rounds, the k maximizing expected returned data per
    second — ``argmin_k E[T]_(k) / k`` over the *estimated* per-client
    expected delays, restricted to clients whose availability score
    clears ``avail_min`` (default 0.5).
    """
    name = "adaptive_greedy"
    step_kind = "adaptive_greedy"
    grid = False

    def replan(self, exp, estimator) -> dict:
        est_nodes = estimator.estimated_nodes()
        avail_min = float(exp.scheme_params.get("avail_min", 0.5))
        avail = estimator.avail_hat >= avail_min
        if not np.any(avail):
            return {"n_wait": 1}
        exp_delay = np.array([nd.expected_delay(float(exp.l))
                              for nd in est_nodes])
        srt = np.sort(np.where(avail, exp_delay, np.inf))
        k = np.arange(1, exp.n + 1, dtype=np.float64)
        with np.errstate(invalid="ignore"):
            cost = np.where(np.isfinite(srt), srt / k, np.inf)
        return {"n_wait": int(np.argmin(cost)) + 1}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Scheme] = {}


def register(scheme: Scheme, *, overwrite: bool = False) -> Scheme:
    """Register a Scheme instance under its ``name``.

    Everything downstream — ``repro.api.build_experiment``, the compiled
    sweep, the benchmark grid/artifact — enumerates this registry.
    """
    if not scheme.name:
        raise ValueError(f"{scheme!r} has no name")
    if scheme.step_kind not in ("naive", "greedy", "coded", "ideal",
                                "adaptive_coded", "adaptive_greedy"):
        raise ValueError(
            f"scheme {scheme.name!r} has unknown step_kind "
            f"{scheme.step_kind!r}")
    if scheme.name in _REGISTRY and not overwrite:
        raise ValueError(f"scheme {scheme.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[scheme.name] = scheme
    return scheme


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_scheme(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scheme {name!r} (registered: "
                         f"{registered_names()})") from None


def registered_names() -> tuple[str, ...]:
    """All registered scheme names, in registration order."""
    return tuple(_REGISTRY)


def coded_names() -> tuple[str, ...]:
    """Names of the coded-family schemes (parity + load allocation)."""
    return tuple(n for n, s in _REGISTRY.items() if s.coded)


def grid_names() -> tuple[str, ...]:
    """Schemes belonging to the default profile-grid sweep/benchmark
    (adaptive schemes opt out — see `Scheme.grid`)."""
    return tuple(n for n, s in _REGISTRY.items() if s.grid)


register(CodedScheme())
register(NaiveScheme())
register(GreedyScheme())
register(IdealScheme())
register(PartialCodedScheme())
register(AdaptiveCodedScheme())
register(AdaptiveGreedyScheme())
