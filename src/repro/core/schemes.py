"""Straggler-mitigation scheme registry (the paper's §V "Schemes").

Each scheme is a registered object that owns its deployment setup — load
allocation, parity construction, privacy accounting — and its contributions
to the compiled step (`fed_runtime.build_step` consts / gradient tensors)
behind one common interface.  The runtime (`repro.core.fed_runtime`), the
compiled sweep (`repro.launch.sweep`), and the benchmark grid
(`repro.launch.bench`) all enumerate this registry, so registering a new
scheme makes it runnable via ``repro.api.build_experiment`` and puts it in
``BENCH_fed_training.json`` automatically.

Built-in schemes:

  naive          — server waits for ALL n clients (full load).
  greedy         — server waits for the fastest (1-psi)*n clients.
  ideal          — deterministic no-straggler floor: full load, exact
                   compute, one transmission per direction.  Runnable
                   (same gradients as naive, deterministic wall-clock).
  coded          — CodedFedL: optimized loads l*_j + a global parity set
                   with redundancy u = delta * m; round time = t*.
  partial_coded  — coded with a *tunable fraction* of the redundancy
                   budget, u = u_fraction * delta * m (Prakash et al. /
                   Sun et al. style partial coding: less parity shared,
                   smaller privacy budget, weaker straggler cover).  The
                   fraction comes from ``ExperimentSpec.scheme_params``
                   ("u_fraction", default 0.5).

Registering your own::

    from repro.core import schemes

    class MyScheme(schemes.CodedScheme):
        name = "my_scheme"
        def u_budget(self, exp):
            return 7   # any redundancy rule

    schemes.register(MyScheme())
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding, load_allocation, privacy
from repro.core.delay_model import ideal_round_time, packet_bits


class Scheme:
    """Base scheme: full per-client loads, no parity, no deadline consts.

    Subclasses set ``name`` (registry key) and ``step_kind`` (the static
    branch `fed_runtime.build_step` compiles: one of "naive", "greedy",
    "coded", "ideal").  ``coded`` marks schemes that allocate loads and
    build a parity set (t_star / loads / parity / privacy budget).
    """
    name: str = ""
    step_kind: str = ""
    coded: bool = False

    def setup(self, exp) -> None:
        """Host-side deployment setup; mutates the Experiment in place."""

    def consts_point_len(self, exp) -> int:
        """Point-axis length of `grad_tensors`' gx — shape arithmetic only,
        so sweep callers can compute a grid-wide l_target cheaply."""
        return exp.l

    def grad_tensors(self, exp, l_target=None):
        """(gx, gy, gmask, ret_tail) — the dense client gradient tensors.

        ret_tail lists the returned-mask entries of any pseudo-client rows
        appended past the n real clients (mesh padding is applied by the
        caller on top).
        """
        gx, gy = exp.x, exp.y
        gmask = jnp.ones((exp.n, exp.l), exp.x.dtype)
        return gx, gy, gmask, []

    def extra_consts(self, exp) -> dict:
        """Scheme-specific entries of the step `consts` pytree."""
        return {}

    def privacy_budget(self, exp):
        """Worst-case eps-MI-DP leakage (bits) of what clients share, or
        None when nothing beyond gradients leaves the device."""
        return None

    def __repr__(self):
        return f"<Scheme {self.name!r} step_kind={self.step_kind!r}>"


class NaiveScheme(Scheme):
    name = "naive"
    step_kind = "naive"


class GreedyScheme(Scheme):
    name = "greedy"
    step_kind = "greedy"


class IdealScheme(Scheme):
    """Deterministic no-straggler baseline, now runnable end-to-end.

    Gradient-wise identical to naive (every client, full load); the round
    clock is the deterministic floor `delay_model.ideal_round_time` instead
    of the sampled max — so trajectories match naive's all-returned rounds
    while the wall-clock lower-bounds every full-load scheme.
    """
    name = "ideal"
    step_kind = "ideal"

    def setup(self, exp) -> None:
        exp.t_ideal = ideal_round_time(exp.nodes, float(exp.l))

    def extra_consts(self, exp) -> dict:
        return {"t_ideal": jnp.float32(exp.t_ideal)}


class CodedScheme(Scheme):
    """CodedFedL (paper §III): optimized loads + global parity set."""
    name = "coded"
    step_kind = "coded"
    coded = True

    # ------------------------------------------------------------ redundancy
    def u_budget(self, exp) -> int:
        """Parity rows u to build — the full paper budget delta * m."""
        return max(1, int(round(exp.fl.delta * exp.m)))

    # ----------------------------------------------------------------- setup
    def setup(self, exp) -> None:
        fl = exp.fl
        u_max = self.u_budget(exp)
        allocate = (load_allocation.two_step_allocate_vectorized
                    if exp._pick_alloc_backend() == "vectorized"
                    else load_allocation.two_step_allocate)
        alloc = allocate(
            exp.nodes, [float(exp.l)] * exp.n, server=None,
            u_max=float(u_max), m=float(exp.m))
        exp.t_star = alloc.t_star
        exp.u = u_max
        # integer loads (floor, at least 0)
        exp.loads = np.minimum(np.floor(alloc.loads).astype(int), exp.l)
        # probability of return by t* per client at its optimal load
        exp.p_return = np.array([
            nd.cdf(exp.t_star, float(ld)) if ld > 0 else 0.0
            for nd, ld in zip(exp.nodes, exp.loads)])
        # Processed-subset sampling v2 (vectorized): one `rng.permuted` draw
        # over an (n, l) index matrix replaces the per-client
        # `rng.permutation` loop.  This consumes the numpy RNG stream
        # differently from v1 (so subsets differ across versions — pinned by
        # tests/test_batched_engine.py::test_vectorized_subset_sampling_spec)
        # but stays fully deterministic per seed.
        perm = exp.rng.permuted(
            np.tile(np.arange(exp.l), (exp.n, 1)), axis=1)
        take = np.arange(exp.l)[None, :] < exp.loads[:, None]   # (n, l)
        processed = np.zeros((exp.n, exp.l), dtype=bool)
        row_ids = np.broadcast_to(np.arange(exp.n)[:, None],
                                  (exp.n, exp.l))
        processed[row_ids[take], perm[take]] = True
        exp.processed_idx = [np.nonzero(processed[j])[0]
                             for j in range(exp.n)]
        # weight matrices (paper §III-D) for the whole population at once:
        # sqrt(1 - P(return)) on processed points, 1 elsewhere
        w_stack = np.where(processed,
                           np.sqrt(1.0 - exp.p_return)[:, None],
                           1.0).astype(np.float32)
        # per-client PRNG keys: same sequential split chain the per-client
        # encode would consume, rolled up into one lax.scan
        def _chain(key, _):
            key, sub = jax.random.split(key)
            return key, sub
        _, keys = jax.lax.scan(_chain, jax.random.PRNGKey(fl.seed + 99),
                               None, length=exp.n)
        # all n local parity sets in one batched encode (paper eq. 19) —
        # one vmapped jnp call or one tiled Pallas kernel launch
        stacked = encoding.encode_local_batched(
            keys, exp.x, exp.y, w_stack, exp.u,
            use_pallas=exp.kernel_backend == "pallas",
            interpret=exp._interpret)
        if exp.secure_aggregation:
            # paper §VI future work: the server only ever sees masked
            # uploads; pairwise masks cancel in the sum (core/secure_agg.py)
            from repro.core import secure_agg
            skey = jax.random.PRNGKey(fl.seed + 1234)
            masked = [secure_agg.mask_parity(
                skey, j, exp.n,
                encoding.LocalParity(x=stacked.x[j], y=stacked.y[j]))
                for j in range(exp.n)]
            exp.parity = secure_agg.secure_aggregate(masked)
        else:
            exp.parity = encoding.aggregate_parity_stacked(stacked)
        # one-time parity upload overhead: clients upload u*(q+c) scalars in
        # parallel; expected transmissions 1/(1-p) (paper Fig 4a inset).
        # NodeDelayParams validates p < 1 at construction, so the expected
        # transmission count is finite here by contract.
        bits = packet_bits(fl, exp.u * (exp.q + exp.c))
        exp.setup_time = max(
            nd.tau / packet_bits(fl, exp.q * exp.c) * bits / (1.0 - nd.p)
            for nd in exp.nodes)
        # ragged per-client subsets: only the legacy oracle reads them
        if exp.engine == "legacy":
            exp._sub_x = [exp.x[j][exp.processed_idx[j]]
                          for j in range(exp.n)]
            exp._sub_y = [exp.y[j][exp.processed_idx[j]]
                          for j in range(exp.n)]
        # dense mask-padded (n, l_max, ·) view: the chosen indices of each
        # row, sorted ascending, with unchosen slots pushed past the end by
        # an `l` sentinel — vectorized replacement for the per-client
        # pad/gather loop
        l_max = max(1, int(exp.loads.max()))
        sorted_idx = np.sort(np.where(take, perm, exp.l), axis=1)[:, :l_max]
        pad_mask = (sorted_idx < exp.l).astype(np.float32)
        pad_idx = np.where(sorted_idx < exp.l, sorted_idx, 0).astype(np.int32)
        rows = jnp.asarray(pad_idx)
        mask = jnp.asarray(pad_mask)[:, :, None]
        gather = jax.vmap(lambda xj, ij: xj[ij])
        exp._sub_x_pad = gather(exp.x, rows) * mask
        exp._sub_y_pad = gather(exp.y, rows) * mask
        exp._grad_mask = jnp.asarray(pad_mask)       # (n, l_max) row validity

    # ------------------------------------------------------------ step consts
    def consts_point_len(self, exp) -> int:
        l_max = int(exp._sub_x_pad.shape[1])
        return max(l_max, exp.u) if exp.fused_coded else l_max

    def grad_tensors(self, exp, l_target=None):
        from repro.core import aggregation
        if exp.fused_coded:
            gx, gy, gmask = aggregation.fused_client_parity_tensors(
                exp._sub_x_pad, exp._sub_y_pad, exp._grad_mask,
                exp.parity.x, exp.parity.y, pnr_c=0.0,
                l_target=l_target)
            tail = [1.0]          # the always-active parity pseudo-row
        else:
            gx, gy, gmask = (exp._sub_x_pad, exp._sub_y_pad,
                             exp._grad_mask)
            if l_target is not None and l_target > gx.shape[1]:
                pad = ((0, 0), (0, l_target - gx.shape[1]))
                gx = jnp.pad(gx, pad + ((0, 0),))
                gy = jnp.pad(gy, pad + ((0, 0),))
                gmask = jnp.pad(gmask, pad)
            tail = []
        return gx, gy, gmask, tail

    def extra_consts(self, exp) -> dict:
        consts = {
            "t_star": jnp.float32(exp.t_star),
            "active": jnp.asarray(exp.loads > 0, jnp.float32),
        }
        if not exp.fused_coded:
            consts["par_x"] = exp.parity.x
            consts["par_y"] = exp.parity.y
        return consts

    # --------------------------------------------------------------- privacy
    def privacy_budget(self, exp) -> float:
        """Worst-client eps-MI-DP budget (bits) of sharing u parity rows
        (paper Appendix F, eq. 62)."""
        return float(max(
            privacy.mi_dp_budget(np.asarray(exp.x[j]), exp.u)
            for j in range(exp.n)))


class PartialCodedScheme(CodedScheme):
    """Coded with a tunable fraction of the redundancy budget.

    u = u_fraction * delta * m, u_fraction in (0, 1] — the partial/
    stochastic-coding regime of Prakash et al. (*Coded Computing for
    Federated Learning at the Edge*) and Sun et al. (*Stochastic Coded
    Federated Learning*): smaller parity uploads (cheaper setup, smaller
    eps-MI-DP leakage) against a later optimal deadline t*.
    """
    name = "partial_coded"
    default_u_fraction = 0.5

    def u_fraction(self, exp) -> float:
        frac = float(exp.scheme_params.get("u_fraction",
                                           self.default_u_fraction))
        if not 0.0 < frac <= 1.0:
            raise ValueError(
                f"u_fraction must lie in (0, 1], got {frac}")
        return frac

    def u_budget(self, exp) -> int:
        return max(1, int(round(self.u_fraction(exp)
                                * exp.fl.delta * exp.m)))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Scheme] = {}


def register(scheme: Scheme, *, overwrite: bool = False) -> Scheme:
    """Register a Scheme instance under its ``name``.

    Everything downstream — ``repro.api.build_experiment``, the compiled
    sweep, the benchmark grid/artifact — enumerates this registry.
    """
    if not scheme.name:
        raise ValueError(f"{scheme!r} has no name")
    if scheme.step_kind not in ("naive", "greedy", "coded", "ideal"):
        raise ValueError(
            f"scheme {scheme.name!r} has unknown step_kind "
            f"{scheme.step_kind!r}")
    if scheme.name in _REGISTRY and not overwrite:
        raise ValueError(f"scheme {scheme.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[scheme.name] = scheme
    return scheme


def unregister(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_scheme(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown scheme {name!r} (registered: "
                         f"{registered_names()})") from None


def registered_names() -> tuple[str, ...]:
    """All registered scheme names, in registration order."""
    return tuple(_REGISTRY)


def coded_names() -> tuple[str, ...]:
    """Names of the coded-family schemes (parity + load allocation)."""
    return tuple(n for n, s in _REGISTRY.items() if s.coded)


register(CodedScheme())
register(NaiveScheme())
register(GreedyScheme())
register(IdealScheme())
register(PartialCodedScheme())
