"""Privacy budget of sharing local parity data (paper Appendix F).

For Gaussian G_j, sharing u parity rows leaks at most

    eps_j = 1/2 * log2(1 + u / f^2(X^_j))      bits   (eq. 62)

under eps-mutual-information differential privacy, where

    f(X^) = min_{k2 in [q]} sqrt( sum_{k1} |x_{k1}(k2)|^2
                                  - max_{k3} |x_{k3}(k2)|^2 ).

Intuition: features whose mass concentrates on few points are the most
identifiable; f measures the *least* spread-out feature column.
"""
from __future__ import annotations

import numpy as np


def feature_spread(x_hat: np.ndarray) -> float:
    """f(X^) per eq. 62's definition.  x_hat: (l, q)."""
    x = np.asarray(x_hat, dtype=np.float64)
    col_sq = np.sum(x * x, axis=0)            # (q,)
    col_max = np.max(x * x, axis=0)           # (q,)
    vals = col_sq - col_max
    vals = np.maximum(vals, 0.0)
    return float(np.sqrt(np.min(vals)))


def mi_dp_budget(x_hat: np.ndarray, u: int) -> float:
    """eps_j (bits) for sharing u parity rows of x_hat (eq. 62)."""
    f = feature_spread(x_hat)
    if f == 0.0:
        return float("inf")
    return 0.5 * float(np.log2(1.0 + u / (f * f)))
