"""CodedFedL core: the paper's primary contribution.

Modules:
  delay_model     -- shifted-exponential compute + geometric-link comm delays
  load_allocation -- two-step deadline/load/redundancy optimizer (SIII-C, SIV)
  rff             -- shared-seed random Fourier feature embedding (SIII-A)
  encoding        -- private generators, weight matrices, parity sets (SIII-B/D)
  aggregation     -- coded federated gradient aggregation (SIII-E)
  privacy         -- eps-MI-DP budget of parity sharing (Appendix F)
  schemes         -- pluggable straggler-mitigation scheme registry (SV)
  fed_runtime     -- the FL server loop driving a registered scheme
"""
from repro.core import (aggregation, delay_model, encoding, fed_runtime,
                        load_allocation, privacy, rff, schemes)

__all__ = ["aggregation", "delay_model", "encoding", "fed_runtime",
           "load_allocation", "privacy", "rff", "schemes"]
