"""CodedFedL core: the paper's primary contribution.

Modules:
  delay_model     -- shifted-exponential compute + geometric-link comm delays
  load_allocation -- two-step deadline/load/redundancy optimizer (SIII-C, SIV)
  rff             -- shared-seed random Fourier feature embedding (SIII-A)
  encoding        -- private generators, weight matrices, parity sets (SIII-B/D)
  aggregation     -- coded federated gradient aggregation (SIII-E)
  privacy         -- eps-MI-DP budget of parity sharing (Appendix F)
  fed_runtime     -- the FL server loop: coded / naive / greedy schemes (SV)
"""
from repro.core import (aggregation, delay_model, encoding, fed_runtime,
                        load_allocation, privacy, rff)

__all__ = ["aggregation", "delay_model", "encoding", "fed_runtime",
           "load_allocation", "privacy", "rff"]
