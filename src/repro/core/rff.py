"""Distributed kernel embedding via random Fourier features (paper §III-A).

Every client derives (Omega, delta) from a *shared pseudo-random seed*
(Remark 2) so the server never ships the q frequency vectors: sampling is a
deterministic function of (seed, d, q, sigma).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import RFFConfig
from repro.kernels import ops


def rff_params(cfg: RFFConfig, d: int):
    """Sample (Omega, delta) for the RBF kernel (paper eq. 17/18).

    Omega_s ~ N(0, I_d / sigma^2), delta_s ~ Uniform(0, 2*pi].
    Deterministic in cfg.seed — this is the shared-seed mechanism.
    """
    key = jax.random.PRNGKey(cfg.seed)
    k_omega, k_delta = jax.random.split(key)
    omega = jax.random.normal(k_omega, (d, cfg.q), jnp.float32) / cfg.sigma
    delta = jax.random.uniform(k_delta, (cfg.q,), jnp.float32,
                               minval=0.0, maxval=2.0 * jnp.pi)
    return omega, delta


def rff_transform(x, omega, delta, *, use_pallas: bool = False):
    """phi(X) = sqrt(2/q) cos(X Omega + delta): (m, d) -> (m, q)."""
    return ops.rff_embed(x, omega, delta, use_pallas=use_pallas)


def median_sigma(x, n_pairs: int = 2000, seed: int = 0) -> float:
    """Median-pairwise-distance heuristic for the RBF bandwidth sigma.

    The paper fixes (sigma, q) = (5, 2000) for 784-dim MNIST; for other
    feature scales this heuristic reproduces that operating point.
    """
    import numpy as np
    x = np.asarray(x)
    if x.shape[0] < 2:
        raise ValueError(
            f"median_sigma needs at least 2 points, got {x.shape[0]}")
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, x.shape[0], size=(n_pairs, 2))
    # self-pairs have distance exactly 0 and bias the median low at small
    # n_pairs — redraw the second index until every pair is distinct
    while True:
        self_pairs = idx[:, 0] == idx[:, 1]
        if not self_pairs.any():
            break
        idx[self_pairs, 1] = rng.integers(0, x.shape[0],
                                          size=int(self_pairs.sum()))
    d = np.linalg.norm(x[idx[:, 0]] - x[idx[:, 1]], axis=1)
    return float(np.median(d))


def suggest_lr(x_hat, target: float = 1.8, iters: int = 30, seed: int = 0) -> float:
    """lr ~= target / lambda_max( X^T X / m ) via power iteration."""
    import numpy as np
    x = np.asarray(x_hat)
    m, q = x.shape
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(q,)).astype(np.float64)
    v /= np.linalg.norm(v)
    lam = 1.0
    for _ in range(iters):
        w = x.T @ (x @ v) / m
        lam = float(np.linalg.norm(w))
        v = w / max(lam, 1e-12)
    return target / max(lam, 1e-12)
