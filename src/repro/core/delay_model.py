"""Compute + communication delay model of CodedFedL (paper §II-B).

Per node j (client or MEC compute unit):

  T_j = T_down + T_cmp + T_up
      = tau_j * N_down + ( l_j / mu_j + Exp(alpha_j * mu_j / l_j) ) + tau_j * N_up

with N_down, N_up ~ iid Geometric(1 - p_j) (number of transmissions until
success over an erasure link) so N_down + N_up ~ NegBinomial(r=2, 1-p_j).

The module is pure NumPy — the delay model drives the *simulation* of the
wireless MEC network and the load-allocation optimizer; it never runs on
device.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class NodeDelayParams:
    """Delay parameters for one node (client or server compute unit).

    The paper assumes reciprocal links (footnote 1) — tau_up == tau_down ==
    tau.  The asymmetric generalization the footnote mentions is supported:
    set tau_up (and/or p_up) explicitly; everywhere the symmetric model is
    analyzed, the asymmetric case substitutes tau -> (tau + tau_up)/2 in
    expectation and samples each direction with its own parameters.
    """
    mu: float                 # data points processed per second
    alpha: float              # compute/memory-access ratio (>0)
    tau: float                # seconds per downlink (re)transmission
    p: float                  # downlink erasure probability in [0, 1)
    tau_up: float | None = None   # uplink; None -> reciprocal (= tau)
    p_up: float | None = None

    def __post_init__(self):
        for name, p in (("p", self.p), ("p_up", self.p_up)):
            if p is not None and not (0.0 <= p < 1.0):
                raise ValueError(
                    f"erasure probability {name}={p} must lie in [0, 1): "
                    "p == 1 means the link never delivers a packet, so every "
                    "delay (and the parity upload time) is infinite")
        if self.mu <= 0.0 or self.alpha <= 0.0 or self.tau <= 0.0:
            raise ValueError(
                f"mu={self.mu}, alpha={self.alpha}, tau={self.tau} "
                "must all be positive")
        if self.tau_up is not None and self.tau_up <= 0.0:
            raise ValueError(f"tau_up={self.tau_up} must be positive")

    @property
    def _tau_up(self) -> float:
        return self.tau if self.tau_up is None else self.tau_up

    @property
    def _p_up(self) -> float:
        return self.p if self.p_up is None else self.p_up

    def expected_delay(self, load: float) -> float:
        """E[T_j] for a per-round load of `load` points  (paper eq. 15,
        asymmetric links per footnote 1)."""
        comm = self.tau / (1.0 - self.p) + self._tau_up / (1.0 - self._p_up)
        if load <= 0:
            return comm
        return load / self.mu * (1.0 + 1.0 / self.alpha) + comm

    def _v_cap(self, t: float) -> int:
        """Largest transmission count worth summing over.

        Exact bound is floor(t/tau); we additionally truncate the negative-
        binomial tail where (v-1) p^(v-2) < 1e-14 — beyond that the terms
        cannot move the cdf at double precision.
        """
        v_m = int(np.floor(t / self.tau - 1e-12))
        if self.p <= 0.0:
            return min(v_m, 2)
        v_tail = 2 + int(np.ceil(-14.0 / np.log10(self.p))) + 10
        return min(v_m, v_tail)

    # ------------------------------------------------------------------ cdf
    def cdf(self, t: float, load: float) -> float:
        """P(T_j <= t) for load l  (paper eq. 42 / Theorem 1).

        P = sum_{v=2}^{v_m} (v-1)(1-p)^2 p^(v-2) * (1 - exp(-a*mu/l*(t - l/mu - tau*v)))
        over v with t - l/mu - tau*v > 0.  Asymmetric links use the nested
        two-geometric sum (footnote 1 generalization).
        """
        if self.tau_up is not None or self.p_up is not None:
            return self._cdf_asym(t, load)
        if t <= 2.0 * self.tau:
            return 0.0
        if load <= 0:
            # pure communication: P(N_com * tau <= t), N_com ~ NB(2, 1-p)
            v_m = self._v_cap(t)
            if v_m < 2:
                return 0.0
            v = np.arange(2, v_m + 1)
            return float(min(np.sum(
                (v - 1) * (1 - self.p) ** 2 * self.p ** (v - 2)), 1.0))
        v_m = self._v_cap(t)
        if v_m < 2:
            return 0.0
        v = np.arange(2, v_m + 1, dtype=np.float64)
        slack = t - load / self.mu - self.tau * v
        mask = slack > 0
        if not np.any(mask):
            return 0.0
        rate = self.alpha * self.mu / load
        h = (v - 1) * (1 - self.p) ** 2 * self.p ** (v - 2)
        val = h[mask] * (1.0 - np.exp(-rate * slack[mask]))
        return float(min(np.sum(val), 1.0))

    def _cdf_asym(self, t: float, load: float) -> float:
        """Nested sum over (n_down, n_up) geometric pairs."""
        det = load / self.mu if load > 0 else 0.0
        rate = self.alpha * self.mu / load if load > 0 else None
        tot = 0.0
        nd_cap = self._geo_cap(self.p)
        nu_cap = self._geo_cap(self._p_up)
        for nd in range(1, nd_cap + 1):
            p_nd = self.p ** (nd - 1) * (1.0 - self.p)
            for nu in range(1, nu_cap + 1):
                slack = t - det - self.tau * nd - self._tau_up * nu
                if slack <= 0:
                    break
                p_nu = self._p_up ** (nu - 1) * (1.0 - self._p_up)
                inner = 1.0 if rate is None else 1.0 - np.exp(-rate * slack)
                tot += p_nd * p_nu * inner
        return float(min(tot, 1.0))

    @staticmethod
    def _geo_cap(p: float) -> int:
        if p <= 0.0:
            return 1
        return 1 + int(np.ceil(-14.0 / np.log10(p))) + 10

    # ------------------------------------------------------------- sampling
    def sample(self, rng: np.random.Generator, load: float, size: int = 1) -> np.ndarray:
        """Sample total round-trip delays T_j (seconds)."""
        n_down = rng.geometric(1.0 - self.p, size=size)
        n_up = rng.geometric(1.0 - self._p_up, size=size)
        t_comm = self.tau * n_down + self._tau_up * n_up
        if load <= 0:
            return t_comm
        t_det = load / self.mu
        t_stoch = rng.exponential(load / (self.alpha * self.mu), size=size)
        return t_det + t_stoch + t_comm


def stack_node_params(nodes: "list[NodeDelayParams]") -> dict[str, np.ndarray]:
    """Stack per-node delay parameters into dense arrays.

    Returns {"mu", "alpha", "tau_down", "tau_up", "p_down", "p_up"}, each of
    shape (n,).  Reciprocal links (tau_up/p_up unset) are resolved to their
    downlink values, so consumers never branch on None.
    """
    return {
        "mu": np.array([nd.mu for nd in nodes], np.float64),
        "alpha": np.array([nd.alpha for nd in nodes], np.float64),
        "tau_down": np.array([nd.tau for nd in nodes], np.float64),
        "tau_up": np.array([nd._tau_up for nd in nodes], np.float64),
        "p_down": np.array([nd.p for nd in nodes], np.float64),
        "p_up": np.array([nd._p_up for nd in nodes], np.float64),
    }


def sample_round_times(nodes: "list[NodeDelayParams]", loads,
                       rng: np.random.Generator, rounds: int = 1) -> np.ndarray:
    """Vectorized delay sampling: all nodes x all rounds in 3 RNG draws.

    Replaces `rounds * n` Python-level `NodeDelayParams.sample` calls with one
    vectorized geometric draw per link direction plus one exponential draw —
    the sampling API the batched `FederatedSimulation` engine pre-computes an
    entire training run's delays with.

    loads: (n,) per-node per-round loads (data points).  Nodes with load <= 0
    incur communication delay only, matching `NodeDelayParams.sample`.
    Returns float64 delays of shape (rounds, n).
    """
    return sample_round_times_stacked(stack_node_params(nodes), loads,
                                      rng, rounds)


def sample_round_times_stacked(prm: dict, loads, rng: np.random.Generator,
                               rounds: int = 1) -> np.ndarray:
    """`sample_round_times` over pre-stacked `stack_node_params` arrays.

    Identical draw layout (geometric down, geometric up, exponential) and
    bit-identical output — the population tier (`repro.hier`) works with
    stacked arrays to avoid materializing n Python node objects per draw.
    """
    loads = np.asarray(loads, np.float64)
    n = prm["mu"].shape[0]
    if loads.shape != (n,):
        raise ValueError(f"loads shape {loads.shape} != ({n},)")
    n_down = rng.geometric(1.0 - prm["p_down"], size=(rounds, n))
    n_up = rng.geometric(1.0 - prm["p_up"], size=(rounds, n))
    t = prm["tau_down"] * n_down + prm["tau_up"] * n_up
    active = loads > 0.0
    # exponential compute tail with per-node scale l/(alpha*mu); a single
    # unit-rate draw is rescaled so inactive nodes cost no extra RNG state
    scale = np.where(active, loads / (prm["alpha"] * prm["mu"]), 0.0)
    t_stoch = rng.exponential(1.0, size=(rounds, n)) * scale
    return t + np.where(active, loads / prm["mu"], 0.0) + t_stoch


def mec_network(fl_cfg, d_scalars_per_point: int) -> list[NodeDelayParams]:
    """Build the paper's §V-A heterogeneous 30-client MEC network.

    Effective rates are max_rate * k1^i (random permutation over clients),
    MAC rates max_mac * k2^i.  tau is the time to move one packet of
    b bits = d_scalars_per_point-independent model/gradient packet; the paper
    sends the full model/gradient each round, so b = payload bits with 10%
    overhead.  We parameterize tau per *packet* where the packet carries the
    model (q*c scalars); callers pass the packet size via
    `packet_bits(fl_cfg, n_scalars)` and scale tau accordingly — here we
    return per-client (mu, alpha, tau_unit, p) with tau_unit = seconds per
    bit, to be scaled by the payload.
    """
    rng = np.random.default_rng(fl_cfg.seed)
    n = fl_cfg.n_clients
    rate_factors = fl_cfg.rate_decay ** np.arange(n)
    mac_factors = fl_cfg.mac_decay ** np.arange(n)
    rng.shuffle(rate_factors)
    rng.shuffle(mac_factors)
    rates = fl_cfg.max_rate_bps * rate_factors            # bits/s
    macs = fl_cfg.max_mac_rate * mac_factors              # MAC/s
    # mu: data points per second = MAC rate / MACs per point
    mus = macs / float(d_scalars_per_point)
    nodes = []
    for j in range(n):
        nodes.append(NodeDelayParams(
            mu=float(mus[j]), alpha=fl_cfg.alpha,
            tau=1.0 / float(rates[j]),                     # seconds per bit
            p=fl_cfg.p_erasure))
    return nodes


def scale_tau(node: NodeDelayParams, payload_bits: float) -> NodeDelayParams:
    """Return a copy of `node` with tau scaled to a concrete packet size."""
    return NodeDelayParams(
        mu=node.mu, alpha=node.alpha, tau=node.tau * payload_bits, p=node.p,
        tau_up=None if node.tau_up is None else node.tau_up * payload_bits,
        p_up=node.p_up)


def packet_bits(fl_cfg, n_scalars: int) -> float:
    """Bits to ship `n_scalars` scalars incl. protocol overhead."""
    return n_scalars * fl_cfg.bits_per_scalar * (1.0 + fl_cfg.overhead)


# Paper §V-A heterogeneity knobs: effective link rates decay as k1^i and MAC
# rates as k2^i over clients (random permutation), so smaller factors mean a
# heavier straggler tail.  The grid walks from a homogeneous network through
# the §V-A operating point out to a heavy straggler tail, plus one-knob
# skews isolating link-rate vs MAC-rate heterogeneity.  Named profiles are
# addressable from `ExperimentSpec.delay_profile`; the benchmark launcher
# sweeps the full grid.
HETEROGENEITY_PROFILES = {
    "uniform": dict(rate_decay=1.0, mac_decay=1.0),
    "gentle": dict(rate_decay=0.99, mac_decay=0.95),
    "mild": dict(rate_decay=0.98, mac_decay=0.9),
    "moderate": dict(rate_decay=0.96, mac_decay=0.85),
    "paper": dict(rate_decay=0.95, mac_decay=0.8),
    "rate_skew": dict(rate_decay=0.9, mac_decay=1.0),
    "rate_heavy": dict(rate_decay=0.85, mac_decay=1.0),
    "mac_skew": dict(rate_decay=1.0, mac_decay=0.7),
    "mac_heavy": dict(rate_decay=1.0, mac_decay=0.55),
    "mixed": dict(rate_decay=0.94, mac_decay=0.75),
    "heavy": dict(rate_decay=0.92, mac_decay=0.7),
    "extreme": dict(rate_decay=0.9, mac_decay=0.6),
    "harsh": dict(rate_decay=0.85, mac_decay=0.5),
    "brutal": dict(rate_decay=0.8, mac_decay=0.45),
}


def ideal_round_time(nodes: "list[NodeDelayParams]", l: float) -> float:
    """Deterministic no-straggler round time (seconds).

    One transmission per direction, deterministic compute, full load l on
    every client — the floor for the full-load (naive/greedy) schemes.
    """
    prm = stack_node_params(nodes)
    return float(np.max(l / prm["mu"] + prm["tau_down"] + prm["tau_up"]))
