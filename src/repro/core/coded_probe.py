"""Coded linear-probe head: exact CodedFedL on top of a deep backbone.

The paper's parity-gradient identity is exact only for squared-loss linear
models (DESIGN.md §4).  This module applies it to arbitrary architectures
the way the paper's future-work section suggests: each client runs the
*frozen* backbone over its local tokens, mean-pools the final hidden states,
applies the shared-seed RFF map, and then the full CodedFedL machinery
(private parity encoding, load allocation, deadline aggregation) trains the
linear readout — every theorem in the paper applies verbatim to this head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ExperimentSpec, FLConfig, RFFConfig, TrainConfig
from repro.core import rff
from repro.models import transformer


def extract_features(cfg, params, tokens, batch_size: int = 8):
    """Mean-pooled final hidden states for (N, S) token rows -> (N, D)."""
    feats = []
    fn = jax.jit(lambda b: jnp.mean(
        transformer.hidden_states(cfg, params, {"tokens": b}), axis=1))
    for i in range(0, tokens.shape[0], batch_size):
        feats.append(np.asarray(fn(jnp.asarray(tokens[i:i + batch_size]))))
    return np.concatenate(feats, axis=0).astype(np.float32)


def coded_probe_training(cfg, params, client_tokens, client_labels,
                         n_classes: int, fl_cfg: FLConfig | None = None,
                         rff_q: int = 256, iterations: int = 100,
                         scheme: str = "coded"):
    """Train a CodedFedL linear probe on a frozen backbone.

    client_tokens: (n_clients, l, S) int32; client_labels: (n_clients, l).
    Returns (FedResult, eval_fn-compatible theta).
    """
    n, l, _ = client_tokens.shape
    fl = fl_cfg or FLConfig(n_clients=n)
    # 1. every client extracts features locally (backbone is frozen/shared)
    feats = np.stack([extract_features(cfg, params, client_tokens[j])
                      for j in range(n)])                    # (n, l, D)
    # 2. shared-seed RFF on the pooled features (paper §III-A)
    sigma = rff.median_sigma(feats.reshape(n * l, -1))
    rcfg = RFFConfig(q=rff_q, sigma=max(sigma, 1e-3))
    omega, delta = rff.rff_params(rcfg, feats.shape[-1])
    xh = np.stack([np.asarray(rff.rff_transform(jnp.asarray(feats[j]),
                                                omega, delta))
                   for j in range(n)])                       # (n, l, q)
    y = np.eye(n_classes, dtype=np.float32)[client_labels]   # (n, l, C)
    # 3. exact CodedFedL on the linear head
    lr = rff.suggest_lr(xh.reshape(n * l, -1))
    tcfg = TrainConfig(learning_rate=lr,
                       lr_decay_epochs=(int(iterations * 0.6),
                                        int(iterations * 0.85)))
    from repro.api import build_experiment
    exp = build_experiment(
        ExperimentSpec(fl=fl, train=tcfg, rff=rcfg, scheme=scheme), xh, y)
    res = exp.run(iterations)
    return res, (omega, delta)
