"""CodedFedL load allocation and coding-redundancy optimizer (paper §III-C, §IV).

Two-step scheme:
  Step 1 (fixed deadline t): for every node j in [n+1] (clients + MEC
    compute unit), maximize the expected return
        E[R_j(t; l)] = l * P(T_j <= t)
    over 0 <= l <= cap_j.  By Theorem 1 the objective is piece-wise concave
    in l with concavity-piece boundaries at l = mu_j (t - v tau_j); we run a
    golden-section search per piece (no SciPy dependency).
  Step 2: bisection over t (the maximized total expected return is monotone
    increasing in t, Appendix C) until it equals m.

Special case p_j = 0 (AWGN links): closed form via the Lambert-W minor
branch (paper eq. 34/35, Appendix D), used both as a fast path and as an
oracle in tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.delay_model import NodeDelayParams

_INV_PHI = (math.sqrt(5.0) - 1.0) / 2.0


# --------------------------------------------------------------------------
# Lambert W, minor branch W_{-1}:  w e^w = x  for x in (-1/e, 0), w <= -1.
# --------------------------------------------------------------------------
def lambert_w_minus1(x: float) -> float:
    if not (-1.0 / math.e < x < 0.0):
        raise ValueError(f"W_-1 defined on (-1/e, 0); got {x}")
    # initial guess (Corless et al. 1996 asymptotics)
    l1 = math.log(-x)
    l2 = math.log(-l1)
    w = l1 - l2 + l2 / l1
    for _ in range(100):
        ew = math.exp(w)
        f = w * ew - x
        # Halley's method
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        w_new = w - f / denom
        if abs(w_new - w) < 1e-14 * (1.0 + abs(w_new)):
            return w_new
        w = w_new
    return w


def awgn_slope(node: NodeDelayParams) -> float:
    """s_j = -alpha*mu / (W_{-1}(-e^{-(1+alpha)}) + 1)   (paper eq. 34)."""
    w = lambert_w_minus1(-math.exp(-(1.0 + node.alpha)))
    return -node.alpha * node.mu / (w + 1.0)


def awgn_optimal_load(node: NodeDelayParams, t: float, cap: float) -> float:
    """Closed-form l*_j(t) for p=0 (paper eq. 34)."""
    if t <= 2.0 * node.tau:
        return 0.0
    s = awgn_slope(node)
    return min(s * (t - 2.0 * node.tau), cap)


def awgn_optimal_return(node: NodeDelayParams, t: float, cap: float) -> float:
    """Closed-form E[R_j(t; l*_j(t))] for p=0 (paper eq. 35)."""
    if t <= 2.0 * node.tau:
        return 0.0
    s = awgn_slope(node)
    zeta = cap / s + 2.0 * node.tau
    if t <= zeta:
        s_tilde = s * (1.0 - math.exp(-node.alpha * (node.mu / s - 1.0)))
        return s_tilde * (t - 2.0 * node.tau)
    return cap * (1.0 - math.exp(
        -node.alpha * node.mu / cap * (t - cap / node.mu - 2.0 * node.tau)))


# --------------------------------------------------------------------------
# General case: E[R_j(t; l)] = l * cdf_j(t; l), piece-wise concave in l.
# --------------------------------------------------------------------------
def expected_return(node: NodeDelayParams, t: float, load: float) -> float:
    if load <= 0:
        return 0.0
    return load * node.cdf(t, load)


def _golden_max(f, lo: float, hi: float, tol: float = 1e-9):
    """Golden-section maximization of unimodal f on [lo, hi]."""
    a, b = lo, hi
    c = b - _INV_PHI * (b - a)
    d = a + _INV_PHI * (b - a)
    fc, fd = f(c), f(d)
    while (b - a) > tol * (1.0 + abs(a) + abs(b)):
        if fc >= fd:
            b, d, fd = d, c, fc
            c = b - _INV_PHI * (b - a)
            fc = f(c)
        else:
            a, c, fc = c, d, fd
            d = a + _INV_PHI * (b - a)
            fd = f(d)
    x = (a + b) / 2.0
    return x, f(x)


def optimal_load(node: NodeDelayParams, t: float, cap: float) -> tuple[float, float]:
    """Maximize E[R_j(t; l)] over 0 <= l <= cap.

    Returns (l*, E[R_j(t; l*)]).  Handles the general p>0 case by searching
    each concavity piece; for p==0 uses the closed form.
    """
    symmetric = node.tau_up is None and node.p_up is None
    if cap <= 0 or (symmetric and t <= 2.0 * node.tau) or \
            (not symmetric and t <= node.tau + node._tau_up):
        return 0.0, 0.0
    if node.p == 0.0 and symmetric:
        l = awgn_optimal_load(node, t, cap)
        return l, expected_return(node, t, l)
    # piece boundaries: l = mu (t - v tau) for v = 2..v_m, clipped to (0, cap]
    # (v capped where the NB tail is numerically zero — see NodeDelayParams)
    v_m = node._v_cap(t)
    if v_m < 2:
        return 0.0, 0.0
    bounds = sorted({min(max(node.mu * (t - v * node.tau), 0.0), cap)
                     for v in range(2, v_m + 1)} | {0.0, cap})
    best_l, best_r = 0.0, 0.0
    f = lambda l: expected_return(node, t, l)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi - lo < 1e-15:
            continue
        x, fx = _golden_max(f, lo + 1e-12, hi)
        # also test the piece endpoints
        for cand, fc in ((x, fx), (hi, f(hi))):
            if fc > best_r:
                best_l, best_r = cand, fc
    return best_l, best_r


@dataclasses.dataclass(frozen=True)
class Allocation:
    t_star: float                 # optimal epoch deadline (seconds)
    loads: np.ndarray             # l*_j for clients j in [n]
    u_star: float                 # coded redundancy processed at the server
    returns: np.ndarray           # E[R_j(t*; l*_j)] per client
    coded_return: float           # E[R_C(t*; u*)]

    @property
    def total_return(self) -> float:
        return float(np.sum(self.returns) + self.coded_return)


def max_total_return(nodes: Sequence[NodeDelayParams], caps: Sequence[float],
                     t: float) -> tuple[np.ndarray, np.ndarray]:
    loads = np.zeros(len(nodes))
    rets = np.zeros(len(nodes))
    for j, (node, cap) in enumerate(zip(nodes, caps)):
        loads[j], rets[j] = optimal_load(node, t, cap)
    return loads, rets


def two_step_allocate(clients: Sequence[NodeDelayParams],
                      client_caps: Sequence[float],
                      server: NodeDelayParams | None,
                      u_max: float,
                      m: float,
                      tol: float = 1e-6,
                      t_hi: float | None = None) -> Allocation:
    """Solve paper eq. (23) via the two-step approach (eq. 24-27).

    `server=None` models the paper's §V assumption P(T_C <= t) = 1 (dedicated
    reliable MEC resources => u* = u_max contributes fully for any t>0).
    """
    nodes = list(clients)
    caps = list(client_caps)

    def total(t: float) -> float:
        _, rets = max_total_return(nodes, caps, t)
        tot = float(np.sum(rets))
        if server is None:
            tot += u_max
        else:
            _, r = optimal_load(server, t, u_max)
            tot += r
        return tot

    target = float(m)
    # the maximal possible return is sum(caps) + u_max; demand feasibility
    if sum(caps) + u_max < target - 1e-9:
        raise ValueError("infeasible: sum of caps + u_max < m")
    # bracket
    lo = 0.0
    hi = t_hi if t_hi is not None else 1.0
    for _ in range(200):
        if total(hi) >= target:
            break
        hi *= 2.0
    else:
        raise RuntimeError("could not bracket deadline time")
    # bisection (total return monotone increasing in t, Appendix C)
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if total(mid) >= target:
            hi = mid
        else:
            lo = mid
        if hi - lo < tol * (1.0 + hi):
            break
    t_star = hi
    loads, rets = max_total_return(nodes, caps, t_star)
    if server is None:
        u_star, coded_ret = float(u_max), float(u_max)
    else:
        u_star, coded_ret = optimal_load(server, t_star, u_max)
    return Allocation(t_star=t_star, loads=loads, u_star=u_star,
                      returns=rets, coded_return=coded_ret)
