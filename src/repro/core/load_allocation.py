"""CodedFedL load allocation and coding-redundancy optimizer (paper §III-C, §IV).

Two-step scheme:
  Step 1 (fixed deadline t): for every node j in [n+1] (clients + MEC
    compute unit), maximize the expected return
        E[R_j(t; l)] = l * P(T_j <= t)
    over 0 <= l <= cap_j.  By Theorem 1 the objective is piece-wise concave
    in l with concavity-piece boundaries at l = mu_j (t - v tau_j); we run a
    golden-section search per piece (no SciPy dependency).
  Step 2: bisection over t (the maximized total expected return is monotone
    increasing in t, Appendix C) until it equals m.

Two solver backends share this structure:

  * the original scalar NumPy path (``two_step_allocate``) — a Python loop
    over nodes and concavity pieces; exact, but O(n) Python-level work per
    bisection step, so it cannot scale past a few hundred clients;
  * ``two_step_allocate_vectorized`` — the same two-step scheme as ONE
    fixed-iteration jitted JAX program: golden-section over every
    (node, concavity-piece) pair simultaneously, bracketing + bisection as
    ``lax.fori_loop``s.  All n clients (plus the optional MEC server node,
    i.e. the paper's n+1 nodes) are solved in a single call; n >= 1000 is a
    single device program.  Asymmetric tau_up/p_up links (footnote 1) ride
    the same program through a flattened per-direction transmission grid.
    The scalar path stays as the numerical oracle (tests assert
    node-for-node agreement).

Special case p_j = 0 (AWGN links): closed form via the Lambert-W minor
branch (paper eq. 34/35, Appendix D), used both as a fast path and as an
oracle in tests (the vectorized solver must reproduce it at p = 0).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.delay_model import NodeDelayParams, stack_node_params

_INV_PHI = (math.sqrt(5.0) - 1.0) / 2.0


# --------------------------------------------------------------------------
# Lambert W, minor branch W_{-1}:  w e^w = x  for x in (-1/e, 0), w <= -1.
# --------------------------------------------------------------------------
def lambert_w_minus1(x: float) -> float:
    if not (-1.0 / math.e < x < 0.0):
        raise ValueError(f"W_-1 defined on (-1/e, 0); got {x}")
    # initial guess (Corless et al. 1996 asymptotics)
    l1 = math.log(-x)
    l2 = math.log(-l1)
    w = l1 - l2 + l2 / l1
    for _ in range(100):
        ew = math.exp(w)
        f = w * ew - x
        # Halley's method
        denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0)
        w_new = w - f / denom
        if abs(w_new - w) < 1e-14 * (1.0 + abs(w_new)):
            return w_new
        w = w_new
    return w


def awgn_slope(node: NodeDelayParams) -> float:
    """s_j = -alpha*mu / (W_{-1}(-e^{-(1+alpha)}) + 1)   (paper eq. 34)."""
    w = lambert_w_minus1(-math.exp(-(1.0 + node.alpha)))
    return -node.alpha * node.mu / (w + 1.0)


def awgn_optimal_load(node: NodeDelayParams, t: float, cap: float) -> float:
    """Closed-form l*_j(t) for p=0 (paper eq. 34)."""
    if t <= 2.0 * node.tau:
        return 0.0
    s = awgn_slope(node)
    return min(s * (t - 2.0 * node.tau), cap)


def awgn_optimal_return(node: NodeDelayParams, t: float, cap: float) -> float:
    """Closed-form E[R_j(t; l*_j(t))] for p=0 (paper eq. 35)."""
    if t <= 2.0 * node.tau:
        return 0.0
    s = awgn_slope(node)
    zeta = cap / s + 2.0 * node.tau
    if t <= zeta:
        s_tilde = s * (1.0 - math.exp(-node.alpha * (node.mu / s - 1.0)))
        return s_tilde * (t - 2.0 * node.tau)
    return cap * (1.0 - math.exp(
        -node.alpha * node.mu / cap * (t - cap / node.mu - 2.0 * node.tau)))


# --------------------------------------------------------------------------
# General case: E[R_j(t; l)] = l * cdf_j(t; l), piece-wise concave in l.
# --------------------------------------------------------------------------
def expected_return(node: NodeDelayParams, t: float, load: float) -> float:
    if load <= 0:
        return 0.0
    return load * node.cdf(t, load)


def _golden_max(f, lo: float, hi: float, tol: float = 1e-9):
    """Golden-section maximization of unimodal f on [lo, hi]."""
    a, b = lo, hi
    c = b - _INV_PHI * (b - a)
    d = a + _INV_PHI * (b - a)
    fc, fd = f(c), f(d)
    while (b - a) > tol * (1.0 + abs(a) + abs(b)):
        if fc >= fd:
            b, d, fd = d, c, fc
            c = b - _INV_PHI * (b - a)
            fc = f(c)
        else:
            a, c, fc = c, d, fd
            d = a + _INV_PHI * (b - a)
            fd = f(d)
    x = (a + b) / 2.0
    return x, f(x)


def optimal_load(node: NodeDelayParams, t: float, cap: float) -> tuple[float, float]:
    """Maximize E[R_j(t; l)] over 0 <= l <= cap.

    Returns (l*, E[R_j(t; l*)]).  Handles the general p>0 case by searching
    each concavity piece; for p==0 uses the closed form.
    """
    symmetric = node.tau_up is None and node.p_up is None
    if cap <= 0 or (symmetric and t <= 2.0 * node.tau) or \
            (not symmetric and t <= node.tau + node._tau_up):
        return 0.0, 0.0
    if node.p == 0.0 and symmetric:
        l = awgn_optimal_load(node, t, cap)
        return l, expected_return(node, t, l)
    # piece boundaries: l = mu (t - v tau) for v = 2..v_m, clipped to (0, cap]
    # (v capped where the NB tail is numerically zero — see NodeDelayParams)
    v_m = node._v_cap(t)
    if v_m < 2:
        return 0.0, 0.0
    bounds = sorted({min(max(node.mu * (t - v * node.tau), 0.0), cap)
                     for v in range(2, v_m + 1)} | {0.0, cap})
    best_l, best_r = 0.0, 0.0
    f = lambda l: expected_return(node, t, l)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi - lo < 1e-15:
            continue
        x, fx = _golden_max(f, lo + 1e-12, hi)
        # also test the piece endpoints
        for cand, fc in ((x, fx), (hi, f(hi))):
            if fc > best_r:
                best_l, best_r = cand, fc
    return best_l, best_r


@dataclasses.dataclass(frozen=True)
class Allocation:
    t_star: float                 # optimal epoch deadline (seconds)
    loads: np.ndarray             # l*_j for clients j in [n]
    u_star: float                 # coded redundancy processed at the server
    returns: np.ndarray           # E[R_j(t*; l*_j)] per client
    coded_return: float           # E[R_C(t*; u*)]

    @property
    def total_return(self) -> float:
        return float(np.sum(self.returns) + self.coded_return)


def max_total_return(nodes: Sequence[NodeDelayParams], caps: Sequence[float],
                     t: float) -> tuple[np.ndarray, np.ndarray]:
    loads = np.zeros(len(nodes))
    rets = np.zeros(len(nodes))
    for j, (node, cap) in enumerate(zip(nodes, caps)):
        loads[j], rets[j] = optimal_load(node, t, cap)
    return loads, rets


def two_step_allocate(clients: Sequence[NodeDelayParams],
                      client_caps: Sequence[float],
                      server: NodeDelayParams | None,
                      u_max: float,
                      m: float,
                      tol: float = 1e-6,
                      t_hi: float | None = None) -> Allocation:
    """Solve paper eq. (23) via the two-step approach (eq. 24-27).

    `server=None` models the paper's §V assumption P(T_C <= t) = 1 (dedicated
    reliable MEC resources => u* = u_max contributes fully for any t>0).
    """
    nodes = list(clients)
    caps = list(client_caps)

    def total(t: float) -> float:
        _, rets = max_total_return(nodes, caps, t)
        tot = float(np.sum(rets))
        if server is None:
            tot += u_max
        else:
            _, r = optimal_load(server, t, u_max)
            tot += r
        return tot

    target = float(m)
    # the maximal possible return is sum(caps) + u_max; demand feasibility
    if sum(caps) + u_max < target - 1e-9:
        raise ValueError("infeasible: sum of caps + u_max < m")
    # bracket
    lo = 0.0
    hi = t_hi if t_hi is not None else 1.0
    for _ in range(200):
        if total(hi) >= target:
            break
        hi *= 2.0
    else:
        raise RuntimeError("could not bracket deadline time")
    # bisection (total return monotone increasing in t, Appendix C)
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if total(mid) >= target:
            hi = mid
        else:
            lo = mid
        if hi - lo < tol * (1.0 + hi):
            break
    t_star = hi
    loads, rets = max_total_return(nodes, caps, t_star)
    if server is None:
        u_star, coded_ret = float(u_max), float(u_max)
    else:
        u_star, coded_ret = optimal_load(server, t_star, u_max)
    return Allocation(t_star=t_star, loads=loads, u_star=u_star,
                      returns=rets, coded_return=coded_ret)


# --------------------------------------------------------------------------
# Vectorized fixed-iteration JAX solver (all n+1 nodes simultaneously).
# --------------------------------------------------------------------------
def _tail_v_cap(p_max: float) -> int:
    """Static truncation of the NB transmission-count tail.

    Mirrors NodeDelayParams._v_cap's tail rule at the population's largest
    erasure probability; the per-t floor(t/tau) part of the scalar cap is
    subsumed by the slack > 0 masking inside the vectorized cdf.  Rounded up
    to a multiple of 8 so nearby populations share one compiled program
    (v_cap is a static jit argument; the extra tail terms are < 1e-13).
    """
    if p_max <= 0.0:
        return 2
    exact = 2 + int(np.ceil(-14.0 / np.log10(p_max))) + 10
    return int(-(-exact // 8) * 8)


def _geo_tail_cap(p_max: float) -> int:
    """Static per-direction geometric tail cap: NodeDelayParams._geo_cap
    (the scalar oracle's truncation rule — one source of truth) at the
    population's largest erasure prob, rounded up to a multiple of 8 so
    nearby populations share one compiled program."""
    return int(-(-NodeDelayParams._geo_cap(p_max) // 8) * 8)


def vectorized_grid_width(nodes: Sequence[NodeDelayParams]) -> int:
    """Transmission-grid columns K the vectorized solver would build.

    Symmetric populations collapse to the NB(2) grid (K = V - 1);
    asymmetric ones pay the per-direction pair grid (K = Vd * Vu), which
    grows as O(log^2 p) toward p -> 1.  The runtime's auto backend pick
    consults this to keep high-erasure asymmetric populations on the
    scalar solver instead of materializing (n, pieces, K) intermediates.
    """
    prm = stack_node_params(nodes)
    if np.array_equal(prm["p_down"], prm["p_up"]) \
            and np.array_equal(prm["tau_down"], prm["tau_up"]):
        return _tail_v_cap(float(prm["p_down"].max())) - 1
    return (_geo_tail_cap(float(prm["p_down"].max()))
            * _geo_tail_cap(float(prm["p_up"].max())))


def _transmission_grids(prm: dict) -> tuple[np.ndarray, np.ndarray]:
    """Per-node transmission-count weights/offsets (h, comm), each (n, K).

    The cdf inside the vectorized objective is a weighted sum over
    transmission counts: P(T <= t) = sum_k h_k (1 - exp(-rate (t - l/mu -
    comm_k))) over terms with positive slack.  Symmetric (reciprocal)
    links collapse the two geometric directions into the NB(2, 1-p) pmf
    over the round-trip count (K = V-1 terms, exactly the pre-asym
    layout); asymmetric links keep the full (n_down, n_up) pair grid with
    per-direction tail caps — mirroring `NodeDelayParams._cdf_asym`'s
    nested sum, flattened so the same jitted program serves both.
    """
    p_d, p_u = prm["p_down"], prm["p_up"]
    tau_d, tau_u = prm["tau_down"], prm["tau_up"]
    if np.array_equal(p_d, p_u) and np.array_equal(tau_d, tau_u):
        v_cap = _tail_v_cap(float(p_d.max()))
        v = np.arange(2, v_cap + 1, dtype=np.float64)
        h = ((v - 1.0) * (1.0 - p_d[:, None]) ** 2
             * p_d[:, None] ** (v - 2.0))
        return h, tau_d[:, None] * v
    vd = np.arange(1, _geo_tail_cap(float(p_d.max())) + 1, dtype=np.float64)
    vu = np.arange(1, _geo_tail_cap(float(p_u.max())) + 1, dtype=np.float64)
    n = p_d.shape[0]
    h_d = (1.0 - p_d[:, None]) * p_d[:, None] ** (vd - 1.0)    # (n, Vd)
    h_u = (1.0 - p_u[:, None]) * p_u[:, None] ** (vu - 1.0)    # (n, Vu)
    h = (h_d[:, :, None] * h_u[:, None, :]).reshape(n, -1)
    comm = ((tau_d[:, None] * vd)[:, :, None]
            + (tau_u[:, None] * vu)[:, None, :]).reshape(n, -1)
    return h, comm


def _vec_expected_return(mu, alpha, h, comm, t, loads):
    """E[R(t; l)] = l * P(T <= t), element-wise (paper eq. 42 / Theorem 1).

    mu/alpha and the precomputed transmission grids `h`/`comm` must
    broadcast against `loads[..., None]`.  Terms with non-positive slack
    are masked, so the result is exact for any t without a data-dependent
    transmission-count cap.
    """
    lo = loads[..., None]
    slack = t - lo / mu[..., None] - comm
    safe = jnp.where(loads > 0, loads, 1.0)
    rate = (alpha * mu / safe)[..., None]
    term = jnp.where(slack > 0, h * (1.0 - jnp.exp(-rate * slack)), 0.0)
    cdf = jnp.minimum(jnp.sum(term, axis=-1), 1.0)
    return jnp.where(loads > 0, loads * cdf, 0.0)


def _vec_optimal_loads(mu, alpha, tau, h, comm, caps, t, *, v_cap: int,
                       n_golden: int):
    """Step 1 for every node at once: argmax_l E[R(t; l)], 0 <= l <= cap.

    Runs a fixed-iteration golden-section search on every (node, concavity
    piece) pair simultaneously — piece boundaries at l = mu (t - v tau)
    (Theorem 1; asymmetric links keep the downlink-tau boundary grid, the
    same heuristic piece placement the scalar solver uses) — then keeps
    the best of the piece interior and piece upper endpoint, mirroring the
    scalar solver's candidate order.
    Returns (loads, returns), each shaped like caps.
    """
    v = jnp.arange(2, v_cap + 1, dtype=caps.dtype)

    def f(l):                                   # l: (n, P) piece-grid loads
        return _vec_expected_return(mu[:, None], alpha[:, None],
                                    h[:, None, :], comm[:, None, :], t, l)

    # sorted piece boundaries: clip(mu (t - v tau), [0, cap]) ∪ {0, cap}
    b = jnp.clip(mu[:, None] * (t - v * tau[:, None]), 0.0, caps[:, None])
    zeros = jnp.zeros_like(caps)[:, None]
    bounds = jnp.sort(jnp.concatenate([zeros, b, caps[:, None]], axis=1),
                      axis=1)                   # (n, V + 1)
    lo, hi = bounds[:, :-1], bounds[:, 1:]      # (n, V) pieces

    # classic golden section with one objective eval per iteration: carry
    # (a, b, c, d, fc, fd) and probe only the one new interior point
    a, bb = lo + 1e-12, hi
    c = bb - _INV_PHI * (bb - a)
    d = a + _INV_PHI * (bb - a)
    fc, fd = f(c), f(d)

    def body(_, st):
        a, bb, c, d, fc, fd = st
        left = fc >= fd
        a2 = jnp.where(left, a, c)
        b2 = jnp.where(left, d, bb)
        probe = jnp.where(left, b2 - _INV_PHI * (b2 - a2),
                          a2 + _INV_PHI * (b2 - a2))
        fp = f(probe)
        c2 = jnp.where(left, probe, d)
        d2 = jnp.where(left, c, probe)
        fc2 = jnp.where(left, fp, fd)
        fd2 = jnp.where(left, fc, fp)
        return (a2, b2, c2, d2, fc2, fd2)

    a, bb, *_ = jax.lax.fori_loop(0, n_golden, body, (a, bb, c, d, fc, fd))
    x = 0.5 * (a + bb)

    # candidate order matches the scalar loop: per piece (ascending), the
    # golden interior point first, then the piece's upper endpoint
    cands = jnp.stack([x, hi], axis=-1).reshape(caps.shape[0], -1)
    rets = jnp.stack([f(x), f(hi)], axis=-1).reshape(caps.shape[0], -1)
    best = jnp.argmax(rets, axis=1)
    best_ret = jnp.take_along_axis(rets, best[:, None], axis=1)[:, 0]
    best_load = jnp.take_along_axis(cands, best[:, None], axis=1)[:, 0]
    ok = best_ret > 0.0
    return jnp.where(ok, best_load, 0.0), jnp.where(ok, best_ret, 0.0)


@functools.partial(jax.jit, static_argnames=("v_cap", "n_golden",
                                             "n_golden_search",
                                             "n_bracket", "n_bisect"))
def _vec_two_step(mu, alpha, tau, h, comm, caps, target, t_hi0, *,
                  v_cap: int, n_golden: int, n_golden_search: int,
                  n_bracket: int, n_bisect: int):
    """Step 2: bracket + bisection over t, entirely on device.

    The bracket doubles t until the maximized total return reaches the
    target (lax.while_loop, capped at n_bracket doublings); the bisection is
    a fixed n_bisect-iteration lax.fori_loop, so one compiled program solves
    the whole population regardless of n.  During the search only the
    objective VALUE matters, and golden-section value error is quadratic in
    the interval width, so a coarser n_golden_search is used inside the
    bracket/bisection and the full n_golden only for the final load
    extraction at t*.  `h`/`comm` are the `_transmission_grids` weights —
    symmetric NB(2) or the asymmetric pair grid, transparently.
    """
    def total(t):
        _, rets = _vec_optimal_loads(mu, alpha, tau, h, comm, caps, t,
                                     v_cap=v_cap, n_golden=n_golden_search)
        return jnp.sum(rets)

    def need_more(state):
        hi, k = state
        return (total(hi) < target) & (k < n_bracket)
    hi, _ = jax.lax.while_loop(need_more, lambda s: (s[0] * 2.0, s[1] + 1),
                               (t_hi0, 0))

    def bisect(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        ge = total(mid) >= target
        return (jnp.where(ge, lo, mid), jnp.where(ge, mid, hi))
    _, t_star = jax.lax.fori_loop(0, n_bisect, bisect,
                                  (jnp.zeros_like(hi), hi))

    loads, rets = _vec_optimal_loads(mu, alpha, tau, h, comm, caps, t_star,
                                     v_cap=v_cap, n_golden=n_golden)
    return t_star, loads, rets


def vectorized_optimal_loads(nodes: Sequence[NodeDelayParams], t: float,
                             caps: Sequence[float], *, n_golden: int = 52
                             ) -> tuple[np.ndarray, np.ndarray]:
    """Step-1 optimal loads for all nodes in one jitted call (float64).

    Node-for-node equivalent of looping `optimal_load` — including
    asymmetric tau_up/p_up links (footnote 1 generalization), which flow
    through the flattened per-direction transmission grid; the scalar
    path is the oracle the property tests compare against.
    """
    from jax.experimental import enable_x64
    prm = stack_node_params(nodes)
    v_cap = _tail_v_cap(float(prm["p_down"].max()))
    h, comm = _transmission_grids(prm)
    with enable_x64():
        loads, rets = jax.jit(_vec_optimal_loads,
                              static_argnames=("v_cap", "n_golden"))(
            jnp.asarray(prm["mu"]), jnp.asarray(prm["alpha"]),
            jnp.asarray(prm["tau_down"]), jnp.asarray(h), jnp.asarray(comm),
            jnp.asarray(np.asarray(caps, np.float64)), float(t),
            v_cap=v_cap, n_golden=n_golden)
        return np.asarray(loads), np.asarray(rets)


def two_step_allocate_vectorized(clients: Sequence[NodeDelayParams],
                                 client_caps: Sequence[float],
                                 server: NodeDelayParams | None,
                                 u_max: float,
                                 m: float,
                                 tol: float = 1e-6,
                                 t_hi: float | None = None,
                                 n_golden: int = 52,
                                 n_golden_search: int = 28,
                                 n_bracket: int = 60,
                                 n_bisect: int = 48) -> Allocation:
    """Vectorized counterpart of `two_step_allocate` (paper eq. 23-27).

    One fixed-iteration jitted JAX program solves step 1 for all n clients
    (plus the MEC server compute node when given — the paper's n+1 nodes)
    and runs the step-2 bracket/bisection on device; n >= 1000 nodes is a
    single call.  Asymmetric tau_up/p_up links are supported through the
    flattened per-direction transmission grid (`_transmission_grids`).
    Matches the scalar solver within its bisection tolerance (`tol` only
    documents that contract — iteration counts are fixed and exceed it).
    Float64 throughout via a local x64 scope.
    """
    from jax.experimental import enable_x64
    nodes = list(clients)
    caps = [float(cp) for cp in client_caps]
    target = float(m)
    if server is not None:
        nodes.append(server)
        caps.append(float(u_max))
    else:
        target -= float(u_max)          # P(T_C <= t) = 1: u_max always returns
    if sum(client_caps) + u_max < m - 1e-9:
        raise ValueError("infeasible: sum of caps + u_max < m")
    prm = stack_node_params(nodes)
    v_cap = _tail_v_cap(float(prm["p_down"].max()))
    h, comm = _transmission_grids(prm)
    with enable_x64():
        t_star, loads, rets = _vec_two_step(
            jnp.asarray(prm["mu"]), jnp.asarray(prm["alpha"]),
            jnp.asarray(prm["tau_down"]), jnp.asarray(h), jnp.asarray(comm),
            jnp.asarray(np.asarray(caps, np.float64)), target,
            float(t_hi if t_hi is not None else 1.0),
            v_cap=v_cap, n_golden=n_golden,
            n_golden_search=n_golden_search, n_bracket=n_bracket,
            n_bisect=n_bisect)
        t_star = float(t_star)
        loads = np.asarray(loads)
        rets = np.asarray(rets)
    if server is None:
        u_star, coded_ret = float(u_max), float(u_max)
    else:
        loads, u_star = loads[:-1], float(loads[-1])
        rets, coded_ret = rets[:-1], float(rets[-1])
    return Allocation(t_star=t_star, loads=loads, u_star=u_star,
                      returns=rets, coded_return=coded_ret)
