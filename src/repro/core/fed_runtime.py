"""Federated-learning runtime: the engine behind `repro.api.Experiment`.

This is the paper's system layer (§III, §V): a server loop over training
rounds in a simulated wireless MEC network.  Compute/communication delays are
*sampled from the paper's stochastic models* each round; the simulated
wall-clock is the quantity all of Fig. 4/5 and Tables II/III are measured in.

The straggler-mitigation scheme is a pluggable registry object
(``repro.core.schemes``: naive / greedy / ideal / coded / partial_coded,
plus anything registered since) that owns the deployment setup and its
contributions to the compiled step; `Experiment` is built from a frozen
`ExperimentSpec` (``repro.api.build_experiment``), and the kwargs-era
`FederatedSimulation` survives as a deprecated shim over it.

Engines
-------
``ExperimentSpec(engine="batched")`` (the default) runs the whole
training loop as one compiled program:

  * per-client processed subsets are padded to a dense ``(n, l_max, q)``
    tensor with a validity mask (rows with mask 0 contribute exactly zero to
    the linear-regression gradient), so all n client gradients come from a
    single call;
  * the coded scheme appends the global parity set as an (n+1)-th
    *pseudo-client row* of that tensor, with the 1/(u (1-pnr_C)) coded-
    gradient scale folded into its mask entries — client gradients AND the
    coded gradient come from ONE masked-kernel call per round
    (``fused_coded=False`` keeps the historical two-call path as the
    numerical oracle);
  * round delays for the *entire run* are pre-sampled with the vectorized
    ``delay_model.sample_round_times`` API (3 RNG draws total instead of
    ``iterations * n`` Python-level calls);
  * the per-round update runs under ``jax.lax.scan`` inside one ``jax.jit``.

``engine="legacy"`` keeps the original per-client Python loop and serves as
the numerical-equivalence oracle: both engines consume the same pre-sampled
delay matrix, so with equal seeds they produce the same ``theta`` trajectory
to fp32 tolerance (see tests/test_batched_engine.py).

Network dynamics (``ExperimentSpec.channel_profile``, ``repro.net``): the
run's delays are pre-sampled *through* a deterministic per-seed channel
trace (Gilbert–Elliott erasure bursts, shadowing/MCS rate hopping, compute
drift, churn) instead of the stationary model — still one compiled scan,
with a per-round availability row joining the scan inputs.  The static
profile reproduces the stationary engine bit-exactly.  Adaptive schemes
(``adaptive_coded``/``adaptive_greedy``) additionally run the
``repro.net.estimator.AdaptiveController`` control loop on the host ahead
of the scan: online (mu, tau, p) estimation from round telemetry,
re-solving the load allocation every ``adapt_every`` rounds, applied as
block-indexed mask re-weighting so shapes (and the compiled step) never
change.

``kernel_backend`` selects how the batched engine computes gradients:
``"xla"`` (default) is the plain-jnp vmapped path; ``"pallas"`` routes every
per-round gradient through the fused Pallas kernels
(``kernels.linreg_grad_masked`` over the dense padded client tensor —
interpret mode off-TPU, compiled on TPU).  Both backends produce the same
trajectory to fp32 tolerance.  ``alloc_backend`` picks the deadline/load
optimizer: the scalar NumPy two-step solver or the vectorized
fixed-iteration JAX solver (``"auto"`` chooses by population size).

Client-mesh mode
----------------
``ExperimentSpec(mesh=k)`` (an int device count; a concrete 1-D
``jax.sharding.Mesh`` with a single ``"clients"`` axis goes through
``build_experiment(..., mesh=...)`` instead) partitions the dense client
tensor, the
per-round returned mask, and the per-shard gradient computation over the
mesh with ``shard_map``; each device computes its local clients' gradients
and the shards are reduced with a ``psum`` — structurally mirroring the MEC
server aggregation in paper §III.  The client axis is zero-row padded up to
a multiple of the mesh size (padded rows carry an all-zero mask, so they
contribute exactly nothing).  CI-testable on CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the sharded engine
reproduces the single-device trajectory to fp32 tolerance at any device
count (tests/test_sharded_engine.py).

Multi-realization mode
----------------------
``run_multi(iterations, n_realizations)`` vmaps the compiled scan over a
stack of independent delay realizations (same deployment, fresh network
draws), producing the Fig. 4/5 wall-clock curves *with confidence bands* in
one compiled call — ``MultiFedResult.wall_clock`` is ``(R, iterations)``.
For sweeps over many deployments sharing shapes, ``repro.launch.sweep``
stacks the per-deployment constants built here and vmaps the same step over
the (profile x realization) grid in one compiled call per scheme.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import ExperimentSpec, FLConfig, TrainConfig
from repro.core import aggregation, schemes
from repro.core.delay_model import (mec_network, packet_bits,
                                    sample_round_times, scale_tau)

#: name of the client-partitioned mesh axis (see `repro.launch.mesh`)
CLIENT_AXIS = "clients"


# jitted once at module level so the legacy oracle keeps the same compiled
# gradient path the pre-batched runtime had (the batched engine compiles its
# whole scan instead)
_batched_client_grads_jit = jax.jit(aggregation.batched_client_gradients)


@dataclasses.dataclass
class RoundLog:
    iteration: int
    wall_clock: float          # cumulative simulated seconds
    returned: int              # clients that made the deadline
    loss: float
    accuracy: float


@dataclasses.dataclass
class FedResult:
    theta: jnp.ndarray
    history: list[RoundLog]
    t_star: float | None = None
    loads: np.ndarray | None = None
    setup_time: float = 0.0    # parity upload overhead (coded only)
    # worst-client eps-MI-DP leakage (bits) of the shared parity rows
    # (core/privacy.py, paper Appendix F); None for schemes that share
    # nothing beyond gradients
    privacy_eps: float | None = None


@dataclasses.dataclass
class MultiFedResult:
    """One deployment, R independent delay realizations (vmapped scan).

    theta: (R, q, c) final iterates; wall_clock / returned: (R, iterations)
    cumulative simulated seconds (incl. setup) and per-round return counts.
    """
    theta: jnp.ndarray
    wall_clock: np.ndarray
    returned: np.ndarray
    t_star: float | None = None
    loads: np.ndarray | None = None
    setup_time: float = 0.0
    accuracy: np.ndarray | None = None   # (R,) if an eval_fn was supplied
    privacy_eps: float | None = None     # see FedResult.privacy_eps

    def wall_clock_bands(self) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) over realizations, each (iterations,) — the Fig. 4/5
        curve with its confidence band."""
        return (self.wall_clock.mean(axis=0), self.wall_clock.std(axis=0))


# ---------------------------------------------------------------------------
# Scheme step: a module-level factory so the single-run scan, run_multi, and
# the compiled sweep engine (repro.launch.sweep) all execute the *same*
# per-round math.  Per-deployment arrays live in a `consts` dict (a pytree
# vmappable over a profile axis); everything Python-static lives in `static`.
# ---------------------------------------------------------------------------

def _make_grad_sum(static: dict):
    """g_sum(gx, gy, gmask, ret, theta) -> (q, c) returned-masked gradient sum.

    Single-device: one masked-kernel call over the whole client tensor.
    Mesh mode: the same call per client shard inside `shard_map`, reduced
    with a psum over the `clients` axis (the MEC server aggregation).
    """
    use_pallas = static["use_pallas"]
    interpret = static["interpret"]
    mesh: Optional[Mesh] = static["mesh"]

    def local(gx, gy, gmask, ret, theta):
        g = aggregation.batched_client_gradients(
            gx, gy, theta, mask=gmask, use_pallas=use_pallas,
            interpret=interpret)
        return aggregation.masked_gradient_sum(g, ret)

    if mesh is None:
        return local

    def shard(gx, gy, gmask, ret, theta):
        return jax.lax.psum(local(gx, gy, gmask, ret, theta), CLIENT_AXIS)

    # check_rep=False: pallas_call has no replication rule; correctness is
    # covered by the psum (out is explicitly replicated by the reduction).
    return shard_map(
        shard, mesh=mesh,
        in_specs=(P(CLIENT_AXIS), P(CLIENT_AXIS), P(CLIENT_AXIS),
                  P(CLIENT_AXIS), P()),
        out_specs=P(), check_rep=False)


def build_step(static: dict):
    """One scan step ``step(consts, theta, inp)``.

    `static` (Python-level, fixed at trace time): scheme, n, n_wait, l2, m,
    l, fused, mesh, use_pallas, interpret, collect_theta, channel.
    `consts` (arrays, vmappable): gx (rows, L, q), gy (rows, L, c), gmask
    (rows, L), ret_tail (rows - n,); coded adds t_star (), active (n,) and —
    when unfused — par_x (u, q) / par_y (u, c); adaptive_coded adds
    gmask_blocks (B, rows, L).

    ``inp`` is ``(t_row, lr)`` on the stationary path.  With
    ``channel=True`` (a network trace drives the run) it grows a per-round
    availability row: ``(t_row, lr, active)`` — churned-out clients never
    count as returned, and the naive/greedy deadlines range over the
    clients actually present.  The adaptive step kinds extend it further
    with their per-round control values: ``(..., t_star_r, block)`` for
    adaptive_coded (the block index selects that block's re-allocated
    fused load mask — pure mask re-weighting, shapes never change) and
    ``(..., n_wait_r)`` for adaptive_greedy.  Under the static channel
    profile `active` is identically 1.0 and every extra operation is an
    IEEE no-op, so trajectories stay bit-identical to the stationary path.

    Scheme dispatch is static, so each scheme compiles to a straight-line
    fused update.
    """
    scheme = static["scheme"]
    n = static["n"]
    n_wait = static["n_wait"]
    l2 = static["l2"]
    m = static["m"]
    l = static["l"]
    fused = static["fused"]
    channel = static.get("channel", False)
    collect_theta = static["collect_theta"]
    use_pallas = static["use_pallas"]
    interpret = static["interpret"]
    grad_sum = _make_grad_sum(static)

    def step(consts, theta, inp):
        gmask = consts["gmask"]
        if scheme == "adaptive_coded":
            t_row, lr, active, t_star_r, block = inp
        elif scheme == "adaptive_greedy":
            t_row, lr, active, n_wait_r = inp
        elif channel:
            t_row, lr, active = inp
        else:
            t_row, lr = inp
        if scheme == "naive":
            if channel:
                ret_real = active
                n_ret = jnp.sum(active).astype(jnp.int32)
                t_round = jnp.max(jnp.where(active > 0, t_row, 0.0))
            else:
                n_ret = jnp.int32(n)
                t_round = jnp.max(t_row)
                ret_real = jnp.ones_like(t_row)
            denom = m
        elif scheme == "greedy":
            if channel:
                # deadline = n_wait-th fastest among the clients present
                srt = jnp.sort(jnp.where(active > 0, t_row, jnp.inf))
                n_act = jnp.sum(active).astype(jnp.int32)
                k_eff = jnp.clip(jnp.minimum(jnp.int32(n_wait), n_act), 1, n)
                t_round = jnp.where(n_act > 0, jnp.take(srt, k_eff - 1), 0.0)
                ret_real = (t_row <= t_round).astype(t_row.dtype) * active
            else:
                t_round = jnp.sort(t_row)[n_wait - 1]
                ret_real = (t_row <= t_round).astype(t_row.dtype)
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            denom = jnp.maximum(n_ret, 1).astype(jnp.float32) * l
        elif scheme == "coded":
            t_star = consts["t_star"]
            t_round = t_star
            by_deadline = (t_row <= t_star).astype(t_row.dtype)
            ret_real = by_deadline * consts["active"]
            if channel:
                by_deadline = by_deadline * active
                ret_real = ret_real * active
            n_ret = jnp.sum(by_deadline).astype(jnp.int32)
            denom = m
        elif scheme == "ideal":
            # deterministic no-straggler floor: all clients, full load,
            # fixed round clock (the sampled t_row is ignored)
            t_round = consts["t_ideal"]
            ret_real = active if channel else jnp.ones_like(t_row)
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            denom = m
        elif scheme == "adaptive_coded":
            t_round = t_star_r
            ret_real = (t_row <= t_star_r).astype(t_row.dtype) * active
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            gmask = consts["gmask_blocks"][block]
            denom = m
        elif scheme == "adaptive_greedy":
            srt = jnp.sort(jnp.where(active > 0, t_row, jnp.inf))
            n_act = jnp.sum(active).astype(jnp.int32)
            k_eff = jnp.clip(jnp.minimum(n_wait_r, n_act), 1, n)
            t_round = jnp.where(n_act > 0, jnp.take(srt, k_eff - 1), 0.0)
            ret_real = (t_row <= t_round).astype(t_row.dtype) * active
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            denom = jnp.maximum(n_ret, 1).astype(jnp.float32) * l
        else:
            raise ValueError(scheme)
        # ret_tail covers the pseudo-client rows: the always-active parity
        # row (fused coded) and any zero-mask mesh padding rows.
        ret = jnp.concatenate([ret_real.astype(jnp.float32),
                               consts["ret_tail"]])
        g_sum = grad_sum(consts["gx"], consts["gy"], gmask, ret, theta)
        if scheme == "coded" and not fused:
            g_sum = g_sum + aggregation.coded_gradient(
                consts["par_x"], consts["par_y"], theta, pnr_c=0.0,
                use_pallas=use_pallas, interpret=interpret)
        theta_new = theta - lr * (g_sum / denom + l2 * theta)
        out = (t_round, n_ret)
        if collect_theta:
            out = out + (theta_new,)
        return theta_new, out

    return step


def _pad_rows(arr: jnp.ndarray, rows: int) -> jnp.ndarray:
    """Zero-pad the leading (client) axis up to `rows`."""
    extra = rows - arr.shape[0]
    if extra == 0:
        return arr
    return jnp.pad(arr, ((0, extra),) + ((0, 0),) * (arr.ndim - 1))


class Experiment:
    """One runnable FL deployment, built from a frozen `ExperimentSpec`.

    Clients hold equally sized local minibatches of RFF-transformed data
    (x_stack: (n, l, q), y_stack: (n, l, c)); the delay network follows
    paper §V-A.  The spec names a registered scheme
    (``repro.core.schemes``) that owns the deployment setup — load
    allocation, parity construction, privacy accounting — and its
    contributions to the compiled step.  ``spec.engine`` selects the
    compiled batched scan loop ("batched", default) or the per-client
    Python oracle ("legacy"); ``spec.mesh`` (a device count) or the
    ``mesh`` override (an int or a concrete 1-D "clients" Mesh) shards the
    batched engine's client axis over devices.

    Prefer the entrypoint ``repro.api.build_experiment(spec, xs, ys)``;
    the kwargs-era ``FederatedSimulation`` front-end survives as a
    deprecated shim over this class.
    """

    def __init__(self, spec: ExperimentSpec, x_stack, y_stack, *,
                 nodes: Optional[list] = None,
                 rng: Optional[np.random.Generator] = None,
                 mesh: "Mesh | int | None" = None):
        if not isinstance(spec, ExperimentSpec):
            raise TypeError(
                f"spec must be an ExperimentSpec, got {type(spec).__name__}"
                " (legacy kwargs callers: use FederatedSimulation)")
        self.spec = spec
        fl_cfg = spec.resolved_fl()      # delay-profile knobs applied
        self.engine = spec.engine
        # "pallas" routes the batched engine's gradient calls through the
        # fused Pallas kernels (interpret mode off-TPU so CI stays green on
        # CPU); "xla" keeps the plain-jnp vmapped path.  The legacy oracle
        # engine always uses the jnp path.
        self.kernel_backend = spec.kernel_backend
        self.alloc_backend = spec.alloc_backend
        self._interpret = jax.default_backend() != "tpu"
        self.mesh = self._resolve_mesh(spec.mesh if mesh is None else mesh)
        self.fused_coded = spec.fused_coded
        self.secure_aggregation = spec.secure_aggregation
        self.scheme = spec.resolved_scheme
        self.scheme_obj = schemes.get_scheme(self.scheme)
        self.step_kind = self.scheme_obj.step_kind
        self.scheme_params = spec.scheme_params_dict
        # --- network dynamics (repro.net): channel trace + adaptation
        self.channel = spec.resolved_channel()
        self.adapt_every = spec.adapt_every
        self.adaptive = self.step_kind.startswith("adaptive")
        if self.adaptive:
            if self.engine == "legacy":
                raise ValueError(
                    f"scheme {self.scheme!r} needs the batched engine "
                    "(the legacy oracle has no adaptive schedule path)")
            if self.mesh is not None:
                raise NotImplementedError(
                    "adaptive schemes do not support client-mesh "
                    "sharding yet")
            if self.adapt_every < 1:
                raise ValueError(
                    f"scheme {self.scheme!r} requires "
                    "ExperimentSpec.adapt_every >= 1 (the re-allocation "
                    "period in rounds)")
            if self.channel is None:
                # adaptation without declared dynamics: run on the exact
                # static profile (estimation converges to the nominal
                # network, allocation stays ~put)
                from repro.net.channel import CHANNEL_PROFILES
                self.channel = CHANNEL_PROFILES["static"]
        self._trace_seed = fl_cfg.seed + 9973
        self._trace_calls = 0
        self.last_schedule = None     # AdaptiveSchedule of the latest run
        self.fl = fl_cfg
        self.train = spec.train
        self.x = jnp.asarray(x_stack)
        self.y = jnp.asarray(y_stack)
        self.n, self.l, self.q = self.x.shape
        self.c = self.y.shape[-1]
        self.m = self.n * self.l
        self.steps_per_epoch = spec.steps_per_epoch
        self.rng = rng or np.random.default_rng(fl_cfg.seed + 17)

        # --- delay network (tau scaled to the actual gradient/model packet)
        base_nodes = nodes or mec_network(fl_cfg, d_scalars_per_point=self.q * self.c)
        payload = packet_bits(fl_cfg, self.q * self.c)    # model == gradient size
        self.nodes = [scale_tau(nd, payload) for nd in base_nodes[:self.n]]

        self.t_star = None
        self.t_ideal = None
        self.loads = np.full(self.n, self.l, dtype=np.float64)
        self.parity = None
        self.setup_time = 0.0
        self.processed_idx = [np.arange(self.l) for _ in range(self.n)]
        self._scan_cache: dict = {}
        self.scheme_obj.setup(self)
        self.privacy_eps = self.scheme_obj.privacy_budget(self)
        self._consts = None     # built lazily on first run/run_multi

    @staticmethod
    def _resolve_mesh(mesh) -> Optional[Mesh]:
        if mesh is None:
            return None
        if isinstance(mesh, int):
            from repro.launch.mesh import make_client_mesh
            mesh = make_client_mesh(mesh)
        if tuple(mesh.axis_names) != (CLIENT_AXIS,):
            raise ValueError(
                f"mesh must have exactly one axis named {CLIENT_AXIS!r}, "
                f"got {mesh.axis_names}")
        return mesh

    @property
    def n_wait(self) -> int:
        """Greedy-family wait count: the fastest (1 - psi) * n clients.
        Single source of truth for the compiled step's static clamp, the
        legacy oracle, and the adaptive controller's block-0 plan."""
        return max(1, int(math.ceil((1.0 - self.fl.psi) * self.n)))

    # -------------------------------------------------------- scheme plumbing
    def _pick_alloc_backend(self) -> str:
        """Resolve alloc_backend="auto": the vectorized jitted solver wins at
        scale, the scalar loop has no compile cost at small n.  Asymmetric
        links ride the vectorized solver's per-direction transmission grid
        since PR 5, so symmetry no longer forces the scalar path — but the
        pair grid is O(Vd*Vu) columns, so auto keeps high-erasure
        asymmetric populations (grid wider than ~4k columns) on the scalar
        loop rather than materializing multi-GB solver intermediates.
        Explicit alloc_backend="vectorized" overrides."""
        if self.alloc_backend != "auto":
            return self.alloc_backend
        from repro.core.load_allocation import vectorized_grid_width
        return "vectorized" if (self.n >= 64 and
                                vectorized_grid_width(self.nodes) <= 4096) \
            else "scalar"

    # ------------------------------------------------------------- step consts
    def consts_point_len(self) -> int:
        """Point-axis length of `build_consts()["gx"]` — shape arithmetic
        only, so sweep callers can compute a grid-wide `l_target` without
        materializing (and discarding) the fused tensors per profile."""
        return self.scheme_obj.consts_point_len(self)

    def build_consts(self, l_target: Optional[int] = None) -> dict:
        """Per-deployment arrays consumed by `build_step`'s step function.

        The registered scheme contributes the gradient tensors and its
        scheme-specific consts (deadlines, parity, activity masks).
        `l_target` pads the point axis up to a common length so deployments
        with different per-client loads stack along a profile axis
        (repro.launch.sweep).  With a mesh, the client axis is additionally
        zero-row padded to a multiple of the mesh size.
        """
        gx, gy, gmask, tail = self.scheme_obj.grad_tensors(self, l_target)
        if self.mesh is not None:
            rows = -(-gx.shape[0] // self.mesh.size) * self.mesh.size
            tail = tail + [0.0] * (rows - gx.shape[0])
            gx, gy, gmask = (_pad_rows(gx, rows), _pad_rows(gy, rows),
                             _pad_rows(gmask, rows))
        consts = {
            "gx": gx, "gy": gy, "gmask": gmask,
            "ret_tail": jnp.asarray(tail, jnp.float32),
        }
        consts.update(self.scheme_obj.extra_consts(self))
        return consts

    def step_static(self, collect_theta: bool = False) -> dict:
        """Python-static step parameters matching `build_consts`."""
        return {
            "scheme": self.step_kind,
            "n": self.n,
            "n_wait": self.n_wait,
            "l2": self.train.l2_reg,
            "m": float(self.m),
            "l": float(self.l),
            "fused": self.fused_coded,
            "mesh": self.mesh,
            "use_pallas": self.kernel_backend == "pallas",
            "interpret": self._interpret,
            "collect_theta": collect_theta,
            "channel": self.channel is not None,
        }

    def scheme_params_estimator_kwargs(self) -> dict:
        """Estimator knobs riding in `scheme_params` (adaptive family)."""
        kw = {}
        if "est_beta" in self.scheme_params:
            kw["beta"] = float(self.scheme_params["est_beta"])
        if "est_window" in self.scheme_params:
            kw["window"] = int(self.scheme_params["est_window"])
        return kw

    # ------------------------------------------------------------------ round
    def _sample_round_times(self, rounds: int = 1) -> np.ndarray:
        """(rounds, n) delay samples — one vectorized draw for the whole run."""
        return sample_round_times(self.nodes, np.asarray(self.loads, float),
                                  self.rng, rounds)

    def _next_trace_rng(self) -> np.random.Generator:
        """Dedicated per-run trace generator: deterministic per (seed, run
        index) and independent of `self.rng`, so turning the channel on
        never shifts the delay-draw stream the static engine consumes."""
        rng = np.random.default_rng((self._trace_seed, self._trace_calls))
        self._trace_calls += 1
        return rng

    def _lr(self, epoch: int) -> float:
        lr = self.train.learning_rate
        for e in self.train.lr_decay_epochs:
            if epoch >= e:
                lr *= self.train.lr_decay
        return lr

    def _lr_schedule(self, iterations: int) -> np.ndarray:
        return np.array([self._lr(it // self.steps_per_epoch)
                         for it in range(iterations)], np.float32)

    # --------------------------------------------------------- batched engine
    def _get_scan(self, collect_theta: bool):
        """jit'd `lax.scan` over a per-round input pytree, cached per
        (scheme, collect).  The xs tuple's structure follows the step's
        static configuration (see `build_step`)."""
        cache_key = (self.scheme, collect_theta)
        fn = self._scan_cache.get(cache_key)
        if fn is None:
            step = build_step(self.step_static(collect_theta))
            fn = jax.jit(lambda consts, theta0, xs:
                         jax.lax.scan(lambda th, inp: step(consts, th, inp),
                                      theta0, xs))
            self._scan_cache[cache_key] = fn
        return fn

    def _get_consts(self) -> dict:
        if self._consts is None:
            self._consts = self.build_consts()
        return self._consts

    def _scan_xs(self, times: np.ndarray, lrs: np.ndarray) -> tuple:
        """Per-round scan inputs for one realization's pre-sampled delays."""
        return (jnp.asarray(times, jnp.float32),
                jnp.asarray(lrs, jnp.float32))

    def _finish_run(self, iterations: int, outs, eval_fn,
                    eval_every: int) -> FedResult:
        """Shared post-processing: scan outputs -> wall-clock + history."""
        collect = eval_fn is not None
        theta, per_round = outs
        t_rounds = np.asarray(per_round[0], np.float64)
        n_ret = np.asarray(per_round[1])
        thetas = per_round[2] if collect else None
        wall = self.setup_time + np.cumsum(t_rounds)
        history: list[RoundLog] = []
        for it in range(iterations):
            if collect and (it % eval_every == 0 or it == iterations - 1):
                loss, acc = eval_fn(thetas[it])
            else:
                loss, acc = float("nan"), float("nan")
            history.append(RoundLog(it, float(wall[it]), int(n_ret[it]),
                                    loss, acc))
        return FedResult(theta=theta, history=history, t_star=self.t_star,
                         loads=self.loads, setup_time=self.setup_time,
                         privacy_eps=self.privacy_eps)

    def _run_batched(self, iterations: int, times: np.ndarray,
                     lrs: np.ndarray, eval_fn, eval_every: int) -> FedResult:
        scan_fn = self._get_scan(eval_fn is not None)
        theta0 = jnp.zeros((self.q, self.c), jnp.float32)
        outs = scan_fn(self._get_consts(), theta0, self._scan_xs(times, lrs))
        return self._finish_run(iterations, outs, eval_fn, eval_every)

    # --------------------------------------------------------- channel engine
    def _channel_outs(self, iterations: int, collect: bool):
        """One realization through the traced-channel (and, for adaptive
        schemes, controller-scheduled) path.  Consumes `self.rng`
        sequentially exactly like the stationary pre-sampling, plus one
        dedicated trace generator per call."""
        from repro.net.estimator import AdaptiveController
        from repro.net.trace import generate_trace, sample_round_times_traced
        trace = generate_trace(self.nodes, self.channel, iterations,
                               self._next_trace_rng())
        lrs = jnp.asarray(self._lr_schedule(iterations))
        consts = dict(self._get_consts())
        if self.adaptive:
            sched = AdaptiveController(self, trace).plan(iterations)
            self.last_schedule = sched
            xs = (jnp.asarray(sched.times, jnp.float32), lrs,
                  jnp.asarray(sched.active))
            if self.step_kind == "adaptive_coded":
                consts["gmask_blocks"] = sched.gmask_blocks
                xs = xs + (jnp.asarray(sched.t_star, jnp.float32),
                           jnp.asarray(sched.block_idx))
            else:
                xs = xs + (jnp.asarray(sched.n_wait),)
        else:
            times = sample_round_times_traced(
                self.nodes, np.asarray(self.loads, float), self.rng, trace)
            xs = (jnp.asarray(times, jnp.float32), lrs,
                  jnp.asarray(trace.active, jnp.float32))
        scan_fn = self._get_scan(collect)
        theta0 = jnp.zeros((self.q, self.c), jnp.float32)
        return scan_fn(consts, theta0, xs)

    def _run_channel(self, iterations: int, eval_fn,
                     eval_every: int) -> FedResult:
        outs = self._channel_outs(iterations, collect=eval_fn is not None)
        return self._finish_run(iterations, outs, eval_fn, eval_every)

    def _run_multi_channel(self, iterations: int, n_realizations: int,
                           eval_fn) -> MultiFedResult:
        """R independent channel realizations (fresh trace + delay draws
        each).  The compiled scan is shared across realizations (equal
        shapes), but the host-side trace/controller loop runs per
        realization — the stationary `run_multi` keeps its one-call vmap."""
        thetas, t_rounds, n_rets = [], [], []
        for _ in range(int(n_realizations)):
            theta, per_round = self._channel_outs(iterations, collect=False)
            thetas.append(theta)
            t_rounds.append(np.asarray(per_round[0], np.float64))
            n_rets.append(np.asarray(per_round[1]))
        theta = jnp.stack(thetas)
        wall = self.setup_time + np.cumsum(np.stack(t_rounds), axis=1)
        acc = None
        if eval_fn is not None:
            acc = np.array([eval_fn(theta[r])[1]
                            for r in range(theta.shape[0])])
        return MultiFedResult(theta=theta, wall_clock=wall,
                              returned=np.stack(n_rets),
                              t_star=self.t_star, loads=self.loads,
                              setup_time=self.setup_time, accuracy=acc,
                              privacy_eps=self.privacy_eps)

    # ---------------------------------------------------------- legacy engine
    def _run_legacy(self, iterations: int, times_all: np.ndarray,
                    lrs: np.ndarray, eval_fn, eval_every: int) -> FedResult:
        """Original per-client Python loop — the numerical oracle the batched
        engine is tested against (same pre-sampled delays, same trajectory)."""
        theta = jnp.zeros((self.q, self.c), jnp.float32)
        wall = self.setup_time
        history: list[RoundLog] = []
        n_wait = self.n_wait

        for it in range(iterations):
            times = times_all[it]
            if self.step_kind == "naive":
                returned = np.ones(self.n, dtype=bool)
                t_round = float(np.max(times))
                denom = self.m
            elif self.step_kind == "greedy":
                order = np.argsort(times)
                returned = np.zeros(self.n, dtype=bool)
                returned[order[:n_wait]] = True
                t_round = float(times[order[n_wait - 1]])
                denom = int(returned.sum()) * self.l
            elif self.step_kind == "coded":
                returned = times <= self.t_star
                t_round = float(self.t_star)
                denom = self.m
            elif self.step_kind == "ideal":
                returned = np.ones(self.n, dtype=bool)
                t_round = float(self.t_ideal)
                denom = self.m
            else:
                raise ValueError(self.step_kind)

            # gradients
            if self.step_kind == "coded":
                grads = []
                for j in range(self.n):
                    if returned[j] and self.loads[j] > 0:
                        grads.append(aggregation.client_gradient(
                            self._sub_x[j], self._sub_y[j], theta))
                coded_g = aggregation.coded_gradient(
                    self.parity.x, self.parity.y, theta, pnr_c=0.0)
                total = coded_g
                for g in grads:
                    total = total + g
                g_m = total / denom + self.train.l2_reg * theta
            else:
                g_all = _batched_client_grads_jit(self.x, self.y, theta)
                g_m = aggregation.masked_gradient_sum(g_all, returned) / denom \
                    + self.train.l2_reg * theta

            theta = theta - float(lrs[it]) * g_m
            wall += t_round

            if eval_fn is not None and (it % eval_every == 0 or it == iterations - 1):
                loss, acc = eval_fn(theta)
            else:
                loss, acc = float("nan"), float("nan")
            history.append(RoundLog(it, wall, int(returned.sum()), loss, acc))

        return FedResult(theta=theta, history=history, t_star=self.t_star,
                         loads=self.loads, setup_time=self.setup_time,
                         privacy_eps=self.privacy_eps)

    # ------------------------------------------------------------------- runs
    def run(self, iterations: int,
            eval_fn: Optional[Callable[[jnp.ndarray], tuple[float, float]]] = None,
            eval_every: int = 10) -> FedResult:
        """Run `iterations` rounds; delays for the whole run are pre-sampled
        once, so both engines consume the identical delay matrix.  With a
        channel profile the delays flow through the network trace (and the
        adaptive controller's schedule) instead — still one compiled scan."""
        if self.channel is not None:
            return self._run_channel(iterations, eval_fn, eval_every)
        times = self._sample_round_times(iterations)
        lrs = self._lr_schedule(iterations)
        if self.engine == "legacy":
            return self._run_legacy(iterations, times, lrs, eval_fn, eval_every)
        return self._run_batched(iterations, times, lrs, eval_fn, eval_every)

    def run_multi(self, iterations: int, n_realizations: int,
                  eval_fn: Optional[Callable[[jnp.ndarray],
                                             tuple[float, float]]] = None
                  ) -> MultiFedResult:
        """R independent delay realizations of the same deployment, vmapped.

        One compiled call produces the full (R, iterations) wall-clock /
        return-count surface — mean ± std over axis 0 is the Fig. 4/5 curve
        with its confidence band (`MultiFedResult.wall_clock_bands`).

        Always runs on the batched scan engine (the legacy oracle has no
        vmappable form); the `engine` constructor argument only selects the
        `run()` path.  The final-iterate eval is vmapped over the
        realization axis when `eval_fn` is jax-traceable, falling back to a
        per-realization Python loop otherwise.  Channel-profile runs loop
        realizations on the host (fresh trace each) over one shared
        compiled scan instead.
        """
        if self.channel is not None:
            return self._run_multi_channel(iterations, n_realizations,
                                           eval_fn)
        R = int(n_realizations)
        times = self._sample_round_times(R * iterations)
        times = times.reshape(R, iterations, self.n)
        lrs = jnp.asarray(self._lr_schedule(iterations))
        theta0 = jnp.zeros((self.q, self.c), jnp.float32)

        cache_key = (self.scheme, "multi")
        multi = self._scan_cache.get(cache_key)
        if multi is None:
            step = build_step(self.step_static(collect_theta=False))

            def multi(consts, times_r, lrs_r):
                def one(tj):
                    return jax.lax.scan(
                        lambda th, inp: step(consts, th, inp),
                        theta0, (tj, lrs_r))
                return jax.vmap(one)(times_r)

            multi = jax.jit(multi)
            self._scan_cache[cache_key] = multi

        theta, (t_rounds, n_ret) = multi(self._get_consts(),
                                         jnp.asarray(times, jnp.float32), lrs)
        wall = self.setup_time + np.cumsum(
            np.asarray(t_rounds, np.float64), axis=1)
        acc = None
        if eval_fn is not None:
            # vmap the eval over the realization axis when eval_fn is
            # jax-traceable (it must then be pure — it sees a batched
            # tracer, not R concrete arrays); numpy/host-side eval_fns
            # raise a tracer-conversion error and fall back to the loop.
            # Genuine eval_fn bugs (bad shapes etc.) propagate normally.
            try:
                acc = np.asarray(jax.vmap(
                    lambda th: jnp.asarray(eval_fn(th)[1]))(theta))
            except jax.errors.JAXTypeError:
                acc = np.array([eval_fn(theta[r])[1] for r in range(R)])
        return MultiFedResult(theta=theta, wall_clock=wall,
                              returned=np.asarray(n_ret),
                              t_star=self.t_star, loads=self.loads,
                              setup_time=self.setup_time, accuracy=acc,
                              privacy_eps=self.privacy_eps)

    # ------------------------------------------------------------------ sweep
    def sweep(self, *, profiles: dict, iterations: int, realizations: int,
              schemes: Optional[tuple] = None):
        """Sweep this experiment's data over heterogeneity profiles.

        Convenience front-end over `repro.launch.sweep.run_sweep` — the
        same spec (scheme, backends, training config) is replayed across
        `profiles` ({name: FLConfig-override dict}) in ONE compiled
        (profile x realization) call per scheme.  `schemes` defaults to
        just this experiment's scheme.
        """
        from repro.launch import sweep as sweep_mod
        return sweep_mod.run_sweep(
            self.x, self.y, profiles=profiles, train_cfg=self.train,
            iterations=iterations, realizations=realizations,
            schemes=schemes or (self.scheme,), base_spec=self.spec)


class FederatedSimulation(Experiment):
    """Deprecated kwargs front-end over `Experiment`.

    Kept as a thin shim for the pre-spec constructor signature: it folds
    the kwargs into a frozen `ExperimentSpec` and defers everything to
    `Experiment`, so both entrypoints share one code path (and therefore
    identical trajectories — locked down by tests/test_experiment_api.py).
    New code should build an `ExperimentSpec` and call
    ``repro.api.build_experiment(spec, x_stack, y_stack)``.
    """

    def __init__(self, x_stack, y_stack, fl_cfg: FLConfig,
                 train_cfg: TrainConfig, *, scheme: Optional[str] = None,
                 steps_per_epoch: int = 1, nodes: Optional[list] = None,
                 rng: Optional[np.random.Generator] = None,
                 secure_aggregation: bool = False,
                 engine: str = "batched",
                 kernel_backend: str = "xla",
                 alloc_backend: str = "auto",
                 mesh: "Mesh | int | None" = None,
                 fused_coded: bool = True):
        warnings.warn(
            "FederatedSimulation is deprecated; build a frozen "
            "ExperimentSpec and call "
            "repro.api.build_experiment(spec, x_stack, y_stack) instead",
            DeprecationWarning, stacklevel=2)
        # a concrete Mesh object is not spec-serializable — pass it through
        # as the Experiment-level override instead
        mesh_obj = None
        spec_mesh = None
        if mesh is None or isinstance(mesh, int):
            spec_mesh = mesh
        else:
            mesh_obj = mesh
        spec = ExperimentSpec(
            fl=fl_cfg, train=train_cfg, scheme=scheme,
            engine=engine, kernel_backend=kernel_backend,
            alloc_backend=alloc_backend, mesh=spec_mesh,
            fused_coded=fused_coded,
            secure_aggregation=secure_aggregation,
            steps_per_epoch=steps_per_epoch)
        super().__init__(spec, x_stack, y_stack, nodes=nodes, rng=rng,
                         mesh=mesh_obj)
