"""Federated-learning runtime: the engine behind `repro.api.Experiment`.

This is the paper's system layer (§III, §V): a server loop over training
rounds in a simulated wireless MEC network.  Compute/communication delays are
*sampled from the paper's stochastic models* each round; the simulated
wall-clock is the quantity all of Fig. 4/5 and Tables II/III are measured in.

The straggler-mitigation scheme is a pluggable registry object
(``repro.core.schemes``: naive / greedy / ideal / coded / partial_coded,
plus anything registered since) that owns the deployment setup and its
contributions to the compiled step; `Experiment` is built from a frozen
`ExperimentSpec` (``repro.api.build_experiment``).  The kwargs-era
`FederatedSimulation` front-end has been removed (a stub raising a
pointed error remains).

Block-structured resumable runs
-------------------------------
Every batched-engine run is threaded through an explicit
`repro.core.run_state.RunState`: ``run(iterations)`` is a loop of
``run_block(state, n_rounds) -> state`` calls over the same cached
compiled scan, where a block is ``spec.checkpoint_every`` rounds (0 = the
whole horizon in one block, reproducing the historical one-shot
trajectories bit-exactly).  The state carries the model iterate, round
cursor, RNG bit-generator state, channel-trace state, estimator
sufficient statistics, adaptive control values, and the round-log
accumulators — so ``save_state``/``restore_state``
(`repro.checkpoint.io`) give kill/resume at any block boundary that is
bit-identical to the uninterrupted blocked run, including the loss curve
and the adaptive schedule.  `repro.launch.service.ExperimentService`
multiplexes many such runs' blocks over one process.

Engines
-------
``ExperimentSpec(engine="batched")`` (the default) runs the whole
training loop as one compiled program:

  * per-client processed subsets are padded to a dense ``(n, l_max, q)``
    tensor with a validity mask (rows with mask 0 contribute exactly zero to
    the linear-regression gradient), so all n client gradients come from a
    single call;
  * the coded scheme appends the global parity set as an (n+1)-th
    *pseudo-client row* of that tensor, with the 1/(u (1-pnr_C)) coded-
    gradient scale folded into its mask entries — client gradients AND the
    coded gradient come from ONE masked-kernel call per round
    (``fused_coded=False`` keeps the historical two-call path as the
    numerical oracle);
  * round delays for the *entire run* are pre-sampled with the vectorized
    ``delay_model.sample_round_times`` API (3 RNG draws total instead of
    ``iterations * n`` Python-level calls);
  * the per-round update runs under ``jax.lax.scan`` inside one ``jax.jit``.

``engine="legacy"`` keeps the original per-client Python loop and serves as
the numerical-equivalence oracle: both engines consume the same pre-sampled
delay matrix, so with equal seeds they produce the same ``theta`` trajectory
to fp32 tolerance (see tests/test_batched_engine.py).

Network dynamics (``ExperimentSpec.channel_profile``, ``repro.net``): the
run's delays are pre-sampled *through* a deterministic per-seed channel
trace (Gilbert–Elliott erasure bursts, shadowing/MCS rate hopping, compute
drift, churn) instead of the stationary model — still one compiled scan,
with a per-round availability row joining the scan inputs.  The static
profile reproduces the stationary engine bit-exactly.  Adaptive schemes
(``adaptive_coded``/``adaptive_greedy``) additionally run the
``repro.net.estimator.AdaptiveController`` control loop on the host ahead
of the scan: online (mu, tau, p) estimation from round telemetry,
re-solving the load allocation every ``adapt_every`` rounds, applied as
block-indexed mask re-weighting so shapes (and the compiled step) never
change.

Robustness (``ExperimentSpec.fault_profile``, ``repro.faults``): the
compiled step carries two guards.  The non-finite guard
(``spec.nonfinite_guard``, default on) zeroes non-finite client/parity
gradient rows out of the weighted sum and counts them — for coded
schemes the parity gradient compensates the masked mass exactly as it
covers stragglers.  The always-on divergence guard never commits a
non-finite iterate: the round is skipped (model held) and the effective
lr backs off by `LR_BACKOFF` per skip.  Both are IEEE no-ops on clean
rounds, so guarded fault-free runs stay bit-identical to history.
Injected return faults (NaN/inf uploads, stale-update replay, corrupted
parity) ride the scan inputs from a dedicated RNG stream; degradation
counters thread through `RunState` and surface as `FedResult.health`.

``kernel_backend`` selects how the batched engine computes gradients:
``"xla"`` (default) is the plain-jnp vmapped path; ``"pallas"`` routes every
per-round gradient through the fused Pallas kernels
(``kernels.linreg_grad_masked`` over the dense padded client tensor —
interpret mode off-TPU, compiled on TPU).  Both backends produce the same
trajectory to fp32 tolerance.  ``alloc_backend`` picks the deadline/load
optimizer: the scalar NumPy two-step solver or the vectorized
fixed-iteration JAX solver (``"auto"`` chooses by population size).

Client-mesh mode
----------------
``ExperimentSpec(mesh=k)`` (an int device count; a concrete 1-D
``jax.sharding.Mesh`` with a single ``"clients"`` axis goes through
``build_experiment(..., mesh=...)`` instead) partitions the dense client
tensor, the
per-round returned mask, and the per-shard gradient computation over the
mesh with ``shard_map``; each device computes its local clients' gradients
and the shards are reduced with a ``psum`` — structurally mirroring the MEC
server aggregation in paper §III.  The client axis is zero-row padded up to
a multiple of the mesh size (padded rows carry an all-zero mask, so they
contribute exactly nothing).  CI-testable on CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the sharded engine
reproduces the single-device trajectory to fp32 tolerance at any device
count (tests/test_sharded_engine.py).

Multi-realization mode
----------------------
``run_multi(iterations, n_realizations)`` vmaps the compiled scan over a
stack of independent delay realizations (same deployment, fresh network
draws), producing the Fig. 4/5 wall-clock curves *with confidence bands* in
one compiled call — ``MultiFedResult.wall_clock`` is ``(R, iterations)``.
For sweeps over many deployments sharing shapes, ``repro.launch.sweep``
stacks the per-deployment constants built here and vmaps the same step over
the (profile x realization) grid in one compiled call per scheme.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import io as ckpt_io
from repro.config import ExperimentSpec
from repro.core import aggregation, rff as rff_mod, schemes
from repro.kernels import ops as kernel_ops
from repro.core.delay_model import (mec_network, packet_bits,
                                    sample_round_times, scale_tau)
from repro.core.run_state import RunState, pack_state, unpack_state
from repro.net.estimator import (AdaptiveSchedule, OnlineChannelEstimator,
                                 plan_segment)
from repro.faults import inject as finject
from repro.net.trace import (TraceState, generate_trace_block,
                             sample_round_times_traced)
from repro.obs import spans as obs_spans

#: name of the client-partitioned mesh axis (see `repro.launch.mesh`)
CLIENT_AXIS = "clients"

#: divergence-guard learning-rate backoff per skipped round
LR_BACKOFF = 0.5


# jitted once at module level so the legacy oracle keeps the same compiled
# gradient path the pre-batched runtime had (the batched engine compiles its
# whole scan instead)
_batched_client_grads_jit = jax.jit(aggregation.batched_client_gradients)


@dataclasses.dataclass
class RoundLog:
    iteration: int
    wall_clock: float          # cumulative simulated seconds
    returned: int              # clients that made the deadline
    loss: float
    accuracy: float
    # per-round degradation counters (batched engine; the legacy oracle
    # has no guards and leaves the zero defaults)
    n_masked: int = 0          # contributions masked by the finite guard
    skipped: int = 0           # 1 if the divergence guard skipped the round


@dataclasses.dataclass
class RunHealth:
    """Degradation counters of a completed batched-engine run.

    ``rounds_degraded`` counts rounds where the non-finite guard masked
    at least one contribution (client upload or parity row);
    ``returns_masked`` is the total masked contributions over the run;
    ``rounds_skipped`` counts divergence-guard skips (iterate kept, lr
    backed off by `LR_BACKOFF`); ``lr_scale`` is the final backoff
    multiplier — 1.0 means the divergence guard never fired (for multi
    runs: the worst realization's).
    """
    rounds_degraded: int
    returns_masked: int
    rounds_skipped: int
    lr_scale: float


@dataclasses.dataclass
class FedResult:
    theta: jnp.ndarray
    history: list[RoundLog]
    t_star: float | None = None
    loads: np.ndarray | None = None
    setup_time: float = 0.0    # parity upload overhead (coded only)
    # worst-client eps-MI-DP leakage (bits) of the shared parity rows
    # (core/privacy.py, paper Appendix F); None for schemes that share
    # nothing beyond gradients
    privacy_eps: float | None = None
    # degradation counters (batched engine only; the legacy oracle has
    # no guards and reports None)
    health: RunHealth | None = None


@dataclasses.dataclass
class MultiFedResult:
    """One deployment, R independent delay realizations (vmapped scan).

    theta: (R, q, c) final iterates; wall_clock / returned: (R, iterations)
    cumulative simulated seconds (incl. setup) and per-round return counts.
    """
    theta: jnp.ndarray
    wall_clock: np.ndarray
    returned: np.ndarray
    t_star: float | None = None
    loads: np.ndarray | None = None
    setup_time: float = 0.0
    accuracy: np.ndarray | None = None   # (R,) if an eval_fn was supplied
    privacy_eps: float | None = None     # see FedResult.privacy_eps
    health: RunHealth | None = None      # aggregated over realizations

    def wall_clock_bands(self) -> tuple[np.ndarray, np.ndarray]:
        """(mean, std) over realizations, each (iterations,) — the Fig. 4/5
        curve with its confidence band."""
        return (self.wall_clock.mean(axis=0), self.wall_clock.std(axis=0))


# ---------------------------------------------------------------------------
# Scheme step: a module-level factory so the single-run scan, run_multi, and
# the compiled sweep engine (repro.launch.sweep) all execute the *same*
# per-round math.  Per-deployment arrays live in a `consts` dict (a pytree
# vmappable over a profile axis); everything Python-static lives in `static`.
# ---------------------------------------------------------------------------

def _guard_and_sum(g, ret, bad, guard):
    """Inject per-row corruption, guard non-finite rows, and reduce.

    Returns ``(g_sum, n_masked)``.  `bad` (rows,) carries injected fault
    values: a non-finite entry replaces the whole gradient row of a
    client that RETURNED this round (a client past the deadline uploads
    nothing, corrupt or not); finite entries leave rows bit-untouched
    (the replacement is a `where`, never an add, so -0.0 entries
    survive).  With `guard` every non-finite row — injected or organic —
    is zeroed out of the weighted sum and counted; without it, poison
    flows into the iterate and the always-on divergence guard skips the
    round instead.  On an all-finite run the guard is an IEEE no-op
    (``where(True, g, 0) == g``), so guard-on clean trajectories stay
    bit-identical to historical ones.
    """
    if bad is not None:
        live_bad = jnp.where(ret > 0.0, bad, 0.0)
        g = jnp.where(jnp.isfinite(live_bad)[:, None, None], g,
                      live_bad[:, None, None])
    if not guard:
        return aggregation.masked_gradient_sum(g, ret), jnp.int32(0)
    finite = jnp.all(jnp.isfinite(g), axis=(1, 2))
    n_masked = jnp.sum((ret > 0.0) & ~finite).astype(jnp.int32)
    g = jnp.where(finite[:, None, None], g, 0.0)
    return aggregation.masked_gradient_sum(g, ret), n_masked


def _make_grad_sum(static: dict):
    """g_sum(gx, gy, gmask, ret, theta[, bad]) ->
    ((q, c) returned-masked gradient sum, n_masked int32).

    Single-device: one masked-kernel call over the whole client tensor.
    Mesh mode: the same call per client shard inside `shard_map`, the
    (sum, count) pair reduced with a psum over the `clients` axis (the
    MEC server aggregation).  With ``fused_embed`` the call signature
    becomes ``g_sum(consts, gmask, ret, theta[, bad])`` — the fused
    embed->gradient kernel needs the omega/delta (and coded pphi) consts
    alongside the raw client tensor, and never runs under a mesh.  `bad`
    (fault injection, see `_guard_and_sum`) is only ever passed on the
    non-mesh paths — return-fault injection under a mesh is rejected at
    construction.
    """
    use_pallas = static["use_pallas"]
    interpret = static["interpret"]
    mesh: Optional[Mesh] = static["mesh"]
    guard = static.get("guard", True)

    if static.get("fused_embed", False):
        def local_fused(consts, gmask, ret, theta, bad=None):
            g = aggregation.fused_embed_client_gradients(
                consts["gx"], consts["gy"], consts["omega"],
                consts["delta"], theta, mask=gmask,
                parity_phi=consts.get("pphi"), use_pallas=use_pallas,
                interpret=interpret)
            return _guard_and_sum(g, ret, bad, guard)
        return local_fused

    def local(gx, gy, gmask, ret, theta, bad=None):
        g = aggregation.batched_client_gradients(
            gx, gy, theta, mask=gmask, use_pallas=use_pallas,
            interpret=interpret)
        return _guard_and_sum(g, ret, bad, guard)

    if mesh is None:
        return local

    def shard(gx, gy, gmask, ret, theta):
        return jax.lax.psum(local(gx, gy, gmask, ret, theta), CLIENT_AXIS)

    # check_rep=False: pallas_call has no replication rule; correctness is
    # covered by the psum (out is explicitly replicated by the reduction).
    return shard_map(
        shard, mesh=mesh,
        in_specs=(P(CLIENT_AXIS), P(CLIENT_AXIS), P(CLIENT_AXIS),
                  P(CLIENT_AXIS), P()),
        out_specs=(P(), P()), check_rep=False)


def build_step(static: dict):
    """One scan step ``step(consts, carry, inp)``.

    `static` (Python-level, fixed at trace time): scheme, n, n_wait, l2, m,
    l, fused, mesh, use_pallas, interpret, collect_theta, channel, guard,
    faults, stale.
    `consts` (arrays, vmappable): gx (rows, L, q), gy (rows, L, c), gmask
    (rows, L), ret_tail (rows - n,); coded adds t_star (), active (n,) and —
    when unfused — par_x (u, q) / par_y (u, c); adaptive_coded adds
    gmask_blocks (B, rows, L).

    ``carry`` is ``(theta, lr_scale)`` — lr_scale is the divergence
    guard's backoff multiplier, 1.0 until a non-finite iterate is
    produced, halved (`LR_BACKOFF`) on every skipped round thereafter;
    with ``stale=True`` (stale-update fault injection) it grows the
    previous round's iterate: ``(theta, lr_scale, theta_prev)``.

    ``inp`` is ``(t_row, lr)`` on the stationary path.  With
    ``channel=True`` (a network trace drives the run) it grows a per-round
    availability row: ``(t_row, lr, active)`` — churned-out clients never
    count as returned, and the naive/greedy deadlines range over the
    clients actually present.  The adaptive step kinds extend it further
    with their per-round control values: ``(..., t_star_r, block)`` for
    adaptive_coded (the block index selects that block's re-allocated
    fused load mask — pure mask re-weighting, shapes never change) and
    ``(..., n_wait_r)`` for adaptive_greedy.  With ``faults=True``
    (`repro.faults`) two fault inputs ride at the very END of the tuple:
    ``(..., fcode, fpar)`` — per-client fault codes (n,) int32 and the
    round's corrupted-parity flag () f32.  Under the static channel
    profile `active` is identically 1.0 and every extra operation is an
    IEEE no-op, so trajectories stay bit-identical to the stationary
    path; likewise guard-on fault-free steps compile to bit-identical
    trajectories (see `_guard_and_sum`).

    Scheme dispatch is static, so each scheme compiles to a straight-line
    fused update.
    """
    scheme = static["scheme"]
    n = static["n"]
    n_wait = static["n_wait"]
    l2 = static["l2"]
    m = static["m"]
    l = static["l"]
    fused = static["fused"]
    fused_embed = static.get("fused_embed", False)
    channel = static.get("channel", False)
    guard = static.get("guard", True)
    faults = static.get("faults", False)
    stale = static.get("stale", False)
    collect_theta = static["collect_theta"]
    use_pallas = static["use_pallas"]
    interpret = static["interpret"]
    grad_sum = _make_grad_sum(static)

    def step(consts, carry, inp):
        if stale:
            theta, lr_scale, theta_prev = carry
        else:
            theta, lr_scale = carry
        if faults:
            *inp, fcode, fpar = inp
            inp = tuple(inp)
        gmask = consts["gmask"]
        if scheme == "adaptive_coded":
            t_row, lr, active, t_star_r, block = inp
        elif scheme == "adaptive_greedy":
            t_row, lr, active, n_wait_r = inp
        elif channel:
            t_row, lr, active = inp
        else:
            t_row, lr = inp
        if scheme == "naive":
            if channel:
                ret_real = active
                n_ret = jnp.sum(active).astype(jnp.int32)
                t_round = jnp.max(jnp.where(active > 0, t_row, 0.0))
            else:
                n_ret = jnp.int32(n)
                t_round = jnp.max(t_row)
                ret_real = jnp.ones_like(t_row)
            denom = m
        elif scheme == "greedy":
            if channel:
                # deadline = n_wait-th fastest among the clients present
                srt = jnp.sort(jnp.where(active > 0, t_row, jnp.inf))
                n_act = jnp.sum(active).astype(jnp.int32)
                k_eff = jnp.clip(jnp.minimum(jnp.int32(n_wait), n_act), 1, n)
                t_round = jnp.where(n_act > 0, jnp.take(srt, k_eff - 1), 0.0)
                ret_real = (t_row <= t_round).astype(t_row.dtype) * active
            else:
                t_round = jnp.sort(t_row)[n_wait - 1]
                ret_real = (t_row <= t_round).astype(t_row.dtype)
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            denom = jnp.maximum(n_ret, 1).astype(jnp.float32) * l
        elif scheme == "coded":
            t_star = consts["t_star"]
            t_round = t_star
            by_deadline = (t_row <= t_star).astype(t_row.dtype)
            ret_real = by_deadline * consts["active"]
            if channel:
                by_deadline = by_deadline * active
                ret_real = ret_real * active
            n_ret = jnp.sum(by_deadline).astype(jnp.int32)
            denom = m
        elif scheme == "ideal":
            # deterministic no-straggler floor: all clients, full load,
            # fixed round clock (the sampled t_row is ignored)
            t_round = consts["t_ideal"]
            ret_real = active if channel else jnp.ones_like(t_row)
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            denom = m
        elif scheme == "adaptive_coded":
            t_round = t_star_r
            ret_real = (t_row <= t_star_r).astype(t_row.dtype) * active
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            gmask = consts["gmask_blocks"][block]
            denom = m
        elif scheme == "adaptive_greedy":
            srt = jnp.sort(jnp.where(active > 0, t_row, jnp.inf))
            n_act = jnp.sum(active).astype(jnp.int32)
            k_eff = jnp.clip(jnp.minimum(n_wait_r, n_act), 1, n)
            t_round = jnp.where(n_act > 0, jnp.take(srt, k_eff - 1), 0.0)
            ret_real = (t_row <= t_round).astype(t_row.dtype) * active
            n_ret = jnp.sum(ret_real).astype(jnp.int32)
            denom = jnp.maximum(n_ret, 1).astype(jnp.float32) * l
        else:
            raise ValueError(scheme)
        # ret_tail covers the pseudo-client rows: the always-active parity
        # row (fused coded) and any zero-mask mesh padding rows.
        ret = jnp.concatenate([ret_real.astype(jnp.float32),
                               consts["ret_tail"]])
        bad = None
        if faults:
            # per-row injected fault values: NaN/inf garbage where the
            # fault code says so, 0.0 (= leave the row untouched) where
            # clean; the parity pseudo-row (tail[0] of the fused coded
            # tensors) corrupts on the round's fpar flag
            bad_client = jnp.where(
                fcode == finject.CODE_NAN, jnp.float32(jnp.nan),
                jnp.where(fcode == finject.CODE_INF, jnp.float32(jnp.inf),
                          jnp.float32(0.0)))
            tail_n = consts["ret_tail"].shape[0]
            tail_bad = jnp.zeros((tail_n,), jnp.float32)
            if fused and scheme in ("coded", "adaptive_coded") and tail_n:
                tail_bad = tail_bad.at[0].set(
                    jnp.where(fpar > 0, jnp.float32(jnp.nan),
                              jnp.float32(0.0)))
            bad = jnp.concatenate([bad_client, tail_bad])

        def sum_at(th, ret_v):
            args = ((consts, gmask, ret_v, th) if fused_embed
                    else (consts["gx"], consts["gy"], gmask, ret_v, th))
            if faults:
                args = args + (bad,)
            return grad_sum(*args)

        if stale:
            # stale-replay clients contribute their gradient at the
            # PREVIOUS iterate: partition the returned mask into fresh
            # and stale rows and take a second masked sum at theta_prev
            # (the parity row is server-side and always fresh)
            stale_f = (fcode == finject.CODE_STALE).astype(jnp.float32)
            stale_full = jnp.concatenate(
                [stale_f, jnp.zeros_like(consts["ret_tail"])])
            g_fresh, m_fresh = sum_at(theta, ret * (1.0 - stale_full))
            g_stale, m_stale = sum_at(theta_prev, ret * stale_full)
            g_sum = g_fresh + g_stale
            n_masked = m_fresh + m_stale
        else:
            g_sum, n_masked = sum_at(theta, ret)
        if scheme == "coded" and not fused:
            g_par = aggregation.coded_gradient(
                consts["par_x"], consts["par_y"], theta, pnr_c=0.0,
                use_pallas=use_pallas, interpret=interpret)
            if faults:
                par_bad = jnp.where(fpar > 0, jnp.float32(jnp.nan),
                                    jnp.float32(0.0))
                g_par = jnp.where(jnp.isfinite(par_bad), g_par, par_bad)
            if guard:
                par_ok = jnp.all(jnp.isfinite(g_par))
                n_masked = n_masked + (~par_ok).astype(jnp.int32)
                g_par = jnp.where(par_ok, g_par, 0.0)
            g_sum = g_sum + g_par
        theta_upd = theta - (lr * lr_scale) * (g_sum / denom + l2 * theta)
        # always-on divergence guard: a non-finite iterate is never
        # committed — the round is skipped (model held) and the lr backs
        # off.  `lr * lr_scale` with lr_scale == 1.0 is bit-identical to
        # the unguarded update, so clean runs reproduce history exactly.
        ok = jnp.all(jnp.isfinite(theta_upd))
        theta_new = jnp.where(ok, theta_upd, theta)
        lr_scale_new = jnp.where(ok, lr_scale,
                                 lr_scale * jnp.float32(LR_BACKOFF))
        skipped = (~ok).astype(jnp.int32)
        out = (t_round, n_ret, n_masked, skipped)
        if collect_theta:
            out = out + (theta_new,)
        carry_new = ((theta_new, lr_scale_new, theta) if stale
                     else (theta_new, lr_scale_new))
        return carry_new, out

    return step


def _pad_rows(arr: jnp.ndarray, rows: int) -> jnp.ndarray:
    """Zero-pad the leading (client) axis up to `rows`."""
    extra = rows - arr.shape[0]
    if extra == 0:
        return arr
    return jnp.pad(arr, ((0, extra),) + ((0, 0),) * (arr.ndim - 1))


def _empty_sched(n: int) -> dict:
    """Zero-length adaptive-schedule record (keys per
    `repro.core.run_state._SCHED_KEYS`); blocks append to it via
    `_append_sched`, `Experiment._assemble_schedule` turns the finished
    record back into an `AdaptiveSchedule`."""
    return {
        "times": np.zeros((0, n), np.float64),
        "active": np.zeros((0, n), np.float32),
        "block_idx": np.zeros(0, np.int32),
        "t_star_r": np.zeros(0, np.float32),
        "n_wait_r": np.zeros(0, np.int32),
        "loads_blocks": np.zeros((0, n), np.float64),
        "est_mu": np.zeros((0, n), np.float64),
        "est_tau": np.zeros((0, n), np.float64),
        "est_p": np.zeros((0, n), np.float64),
        "est_avail": np.zeros((0, n), np.float64),
        "est_rounds_seen": np.zeros(0, np.int64),
    }


def _append_sched(sched: dict, seg) -> dict:
    """Append one `SegmentPlan`'s record to a schedule dict, offsetting
    the segment-local block indices onto the run-global block axis."""
    b0 = sched["loads_blocks"].shape[0]
    est = seg.estimates
    return {
        "times": np.concatenate([sched["times"], seg.times]),
        "active": np.concatenate([sched["active"], seg.active]),
        "block_idx": np.concatenate(
            [sched["block_idx"], (seg.block_idx + b0).astype(np.int32)]),
        "t_star_r": np.concatenate([sched["t_star_r"], seg.t_star_r]),
        "n_wait_r": np.concatenate([sched["n_wait_r"], seg.n_wait_r]),
        "loads_blocks": np.concatenate([sched["loads_blocks"],
                                        seg.loads_blocks]),
        "est_mu": np.concatenate(
            [sched["est_mu"], np.stack([e["mu"] for e in est])]),
        "est_tau": np.concatenate(
            [sched["est_tau"], np.stack([e["tau"] for e in est])]),
        "est_p": np.concatenate(
            [sched["est_p"], np.stack([e["p"] for e in est])]),
        "est_avail": np.concatenate(
            [sched["est_avail"], np.stack([e["avail"] for e in est])]),
        "est_rounds_seen": np.concatenate(
            [sched["est_rounds_seen"],
             np.array([e["rounds_seen"] for e in est], np.int64)]),
    }


class Experiment:
    """One runnable FL deployment, built from a frozen `ExperimentSpec`.

    Clients hold equally sized local minibatches of RFF-transformed data
    (x_stack: (n, l, q), y_stack: (n, l, c)); the delay network follows
    paper §V-A.  The spec names a registered scheme
    (``repro.core.schemes``) that owns the deployment setup — load
    allocation, parity construction, privacy accounting — and its
    contributions to the compiled step.  ``spec.engine`` selects the
    compiled batched scan loop ("batched", default) or the per-client
    Python oracle ("legacy"); ``spec.mesh`` (a device count) or the
    ``mesh`` override (an int or a concrete 1-D "clients" Mesh) shards the
    batched engine's client axis over devices.

    Prefer the entrypoint ``repro.api.build_experiment(spec, xs, ys)``.

    Batched-engine runs are block-structured: ``run``/``run_multi`` drive
    `init_state` / `run_block` / `finish` over an explicit `RunState`,
    checkpointable at every block boundary via `save_state` /
    `restore_state` (see the module docstring).
    """

    def __init__(self, spec: ExperimentSpec, x_stack, y_stack, *,
                 nodes: Optional[list] = None,
                 rng: Optional[np.random.Generator] = None,
                 mesh: "Mesh | int | None" = None):
        if not isinstance(spec, ExperimentSpec):
            raise TypeError(
                f"spec must be an ExperimentSpec, got {type(spec).__name__}"
                " (build one with repro.config.ExperimentSpec and pass it"
                " to repro.api.build_experiment)")
        if spec.hier_active:
            raise ValueError(
                f"spec requests the hierarchical tier (hier_shards="
                f"{spec.hier_shards}, sample_fraction="
                f"{spec.sample_fraction}) but was passed to the flat "
                "engine; build it with repro.api.build_experiment, which "
                "routes hier-active specs to repro.hier.HierExperiment")
        self.spec = spec
        fl_cfg = spec.resolved_fl()      # delay-profile knobs applied
        self.engine = spec.engine
        # "pallas" routes the batched engine's gradient calls through the
        # fused Pallas kernels (interpret mode off-TPU so CI stays green on
        # CPU); "xla" keeps the plain-jnp vmapped path.  The legacy oracle
        # engine always uses the jnp path.
        self.kernel_backend = spec.kernel_backend
        self.alloc_backend = spec.alloc_backend
        self._interpret = jax.default_backend() != "tpu"
        self.mesh = self._resolve_mesh(spec.mesh if mesh is None else mesh)
        self.fused_coded = spec.fused_coded
        self.secure_aggregation = spec.secure_aggregation
        self.scheme = spec.resolved_scheme
        self.scheme_obj = schemes.get_scheme(self.scheme)
        self.step_kind = self.scheme_obj.step_kind
        self.scheme_params = spec.scheme_params_dict
        # --- network dynamics (repro.net): channel trace + adaptation
        self.channel = spec.resolved_channel()
        self.adapt_every = spec.adapt_every
        self.adaptive = self.step_kind.startswith("adaptive")
        if self.adaptive:
            if self.engine == "legacy":
                raise ValueError(
                    f"scheme {self.scheme!r} needs the batched engine "
                    "(the legacy oracle has no adaptive schedule path)")
            if self.mesh is not None:
                raise NotImplementedError(
                    "adaptive schemes do not support client-mesh "
                    "sharding yet")
            if self.adapt_every < 1:
                raise ValueError(
                    f"scheme {self.scheme!r} requires "
                    "ExperimentSpec.adapt_every >= 1 (the re-allocation "
                    "period in rounds)")
            if self.channel is None:
                # adaptation without declared dynamics: run on the exact
                # static profile (estimation converges to the nominal
                # network, allocation stays ~put)
                from repro.net.channel import CHANNEL_PROFILES
                self.channel = CHANNEL_PROFILES["static"]
        # --- fault injection (repro.faults): return faults compile into
        # the step via a dedicated RNG stream; service-level faults
        # (crashes, checkpoint corruption) are read by ExperimentService
        self.faults = spec.resolved_faults()
        self.nonfinite_guard = bool(spec.nonfinite_guard)
        self.return_faults = (self.faults is not None
                              and self.faults.has_return_faults)
        self.stale_faults = (self.faults is not None
                             and self.faults.stale_prob > 0.0)
        if self.return_faults and self.mesh is not None:
            # the config layer rejects spec.mesh; this catches the
            # build_experiment(..., mesh=...) override path too
            raise NotImplementedError(
                "return-fault injection does not support client-mesh "
                "sharding yet (crash/checkpoint faults are fine)")
        self._fault_seed = fl_cfg.seed + 7717
        self.checkpoint_every = spec.checkpoint_every
        if (self.checkpoint_every > 0 and self.adaptive
                and self.checkpoint_every % self.adapt_every != 0):
            raise ValueError(
                f"checkpoint_every={self.checkpoint_every} must be a "
                f"multiple of adapt_every={self.adapt_every} so checkpoint "
                "boundaries align with re-allocation blocks")
        self.run_id = spec.run_id
        self._trace_seed = fl_cfg.seed + 9973
        # trace-stream reservation cursor: each single run reserves one
        # stream index, each traced run_multi one per realization.  The
        # reserved index lives in the run's RunState (not here), so
        # replaying a restored state is hermetic — this counter only
        # hands out fresh streams to NEW runs on this instance.
        self._trace_calls = 0
        self.last_schedule = None     # AdaptiveSchedule of the latest run
        self.fl = fl_cfg
        self.train = spec.train
        self.x = jnp.asarray(x_stack)
        self.y = jnp.asarray(y_stack)
        # fused_embed: x_stack is RAW (n, l, d); q comes from the RFF
        # config and the shared-seed (Omega, delta) are derived here so
        # the in-kernel embed matches rff.rff_transform exactly
        self.fused_embed = spec.fused_embed
        if self.fused_embed:
            if self.adaptive:
                raise NotImplementedError(
                    f"scheme {self.scheme!r} does not support "
                    "fused_embed yet (adaptive re-allocation assumes "
                    "embedded tensors)")
            if self.mesh is not None:
                raise NotImplementedError(
                    "fused_embed does not support client-mesh sharding "
                    "yet")
            self.n, self.l, self.d = self.x.shape
            self.q = spec.rff.q
            self.omega, self.delta = rff_mod.rff_params(spec.rff, self.d)
        else:
            self.n, self.l, self.q = self.x.shape
            self.d = None
            self.omega = self.delta = None
        self.c = self.y.shape[-1]
        self.m = self.n * self.l
        self.steps_per_epoch = spec.steps_per_epoch
        self.rng = rng or np.random.default_rng(fl_cfg.seed + 17)

        # --- delay network (tau scaled to the actual gradient/model packet)
        base_nodes = nodes or mec_network(fl_cfg, d_scalars_per_point=self.q * self.c)
        payload = packet_bits(fl_cfg, self.q * self.c)    # model == gradient size
        self.nodes = [scale_tau(nd, payload) for nd in base_nodes[:self.n]]

        self.t_star = None
        self.t_ideal = None
        self.loads = np.full(self.n, self.l, dtype=np.float64)
        self.parity = None
        self.setup_time = 0.0
        self.processed_idx = [np.arange(self.l) for _ in range(self.n)]
        self._scan_cache: dict = {}
        # telemetry capture (repro.obs): per-block delay/plan references
        # kept only while spans are enabled, feeding `attribution()`
        self._attr_blocks: "list[dict]" = []
        with obs_spans.span("setup/experiment"):
            self.scheme_obj.setup(self)
        self.privacy_eps = self.scheme_obj.privacy_budget(self)
        self._consts = None     # built lazily on first run/run_multi

    @staticmethod
    def _resolve_mesh(mesh) -> Optional[Mesh]:
        if mesh is None:
            return None
        if isinstance(mesh, int):
            from repro.launch.mesh import make_client_mesh
            mesh = make_client_mesh(mesh)
        if tuple(mesh.axis_names) != (CLIENT_AXIS,):
            raise ValueError(
                f"mesh must have exactly one axis named {CLIENT_AXIS!r}, "
                f"got {mesh.axis_names}")
        return mesh

    @property
    def n_wait(self) -> int:
        """Greedy-family wait count: the fastest (1 - psi) * n clients.
        Single source of truth for the compiled step's static clamp, the
        legacy oracle, and the adaptive controller's block-0 plan."""
        return max(1, int(math.ceil((1.0 - self.fl.psi) * self.n)))

    def embedded_x(self) -> jnp.ndarray:
        """Transient (n, l, q) embedded stack for HOST-SIDE setup only
        (parity encoding, privacy accounting).  The fused_embed round
        path never materializes this — phi is computed tile-by-tile
        inside the gradient kernel each round."""
        if not self.fused_embed:
            raise ValueError("embedded_x() is only meaningful with "
                             "fused_embed=True (x is already embedded)")
        return kernel_ops.rff_embed_batched(
            self.x, self.omega, self.delta,
            use_pallas=self.kernel_backend == "pallas",
            interpret=self._interpret)

    # -------------------------------------------------------- scheme plumbing
    def _pick_alloc_backend(self) -> str:
        """Resolve alloc_backend="auto": the vectorized jitted solver wins at
        scale, the scalar loop has no compile cost at small n.  Asymmetric
        links ride the vectorized solver's per-direction transmission grid
        since PR 5, so symmetry no longer forces the scalar path — but the
        pair grid is O(Vd*Vu) columns, so auto keeps high-erasure
        asymmetric populations (grid wider than ~4k columns) on the scalar
        loop rather than materializing multi-GB solver intermediates.
        Explicit alloc_backend="vectorized" overrides."""
        if self.alloc_backend != "auto":
            return self.alloc_backend
        from repro.core.load_allocation import vectorized_grid_width
        return "vectorized" if (self.n >= 64 and
                                vectorized_grid_width(self.nodes) <= 4096) \
            else "scalar"

    # ------------------------------------------------------------- step consts
    def consts_point_len(self) -> int:
        """Point-axis length of `build_consts()["gx"]` — shape arithmetic
        only, so sweep callers can compute a grid-wide `l_target` without
        materializing (and discarding) the fused tensors per profile."""
        return self.scheme_obj.consts_point_len(self)

    def build_consts(self, l_target: Optional[int] = None) -> dict:
        """Per-deployment arrays consumed by `build_step`'s step function.

        The registered scheme contributes the gradient tensors and its
        scheme-specific consts (deadlines, parity, activity masks).
        `l_target` pads the point axis up to a common length so deployments
        with different per-client loads stack along a profile axis
        (repro.launch.sweep).  With a mesh, the client axis is additionally
        zero-row padded to a multiple of the mesh size.
        """
        gx, gy, gmask, tail = self.scheme_obj.grad_tensors(self, l_target)
        if self.mesh is not None:
            rows = -(-gx.shape[0] // self.mesh.size) * self.mesh.size
            tail = tail + [0.0] * (rows - gx.shape[0])
            gx, gy, gmask = (_pad_rows(gx, rows), _pad_rows(gy, rows),
                             _pad_rows(gmask, rows))
        consts = {
            "gx": gx, "gy": gy, "gmask": gmask,
            "ret_tail": jnp.asarray(tail, jnp.float32),
        }
        if self.fused_embed:
            consts["omega"] = self.omega
            consts["delta"] = self.delta
        consts.update(self.scheme_obj.extra_consts(self))
        return consts

    def step_static(self, collect_theta: bool = False) -> dict:
        """Python-static step parameters matching `build_consts`."""
        return {
            "scheme": self.step_kind,
            "n": self.n,
            "n_wait": self.n_wait,
            "l2": self.train.l2_reg,
            "m": float(self.m),
            "l": float(self.l),
            "fused": self.fused_coded,
            "fused_embed": self.fused_embed,
            "mesh": self.mesh,
            "use_pallas": self.kernel_backend == "pallas",
            "interpret": self._interpret,
            "collect_theta": collect_theta,
            "channel": self.channel is not None,
            "guard": self.nonfinite_guard,
            "faults": self.return_faults,
            "stale": self.stale_faults,
        }

    def scheme_params_estimator_kwargs(self) -> dict:
        """Estimator knobs riding in `scheme_params` (adaptive family)."""
        kw = {}
        if "est_beta" in self.scheme_params:
            kw["beta"] = float(self.scheme_params["est_beta"])
        if "est_window" in self.scheme_params:
            kw["window"] = int(self.scheme_params["est_window"])
        return kw

    # ------------------------------------------------------------------ round
    def _sample_round_times(self, rounds: int = 1) -> np.ndarray:
        """(rounds, n) delay samples — one vectorized draw for the whole run."""
        return sample_round_times(self.nodes, np.asarray(self.loads, float),
                                  self.rng, rounds)

    def _reserve_trace_streams(self, k: int) -> int:
        """Reserve `k` consecutive trace-stream indices for a new run and
        return the base index.  The base lives in the run's `RunState`
        (``trace_call``), so restored states replay hermetically no
        matter how many runs this instance has since started."""
        base = self._trace_calls
        self._trace_calls += k
        return base

    def _trace_rng(self, index: int) -> np.random.Generator:
        """Dedicated per-run trace generator: deterministic per (seed,
        stream index) and independent of `self.rng`, so turning the
        channel on never shifts the delay-draw stream the static engine
        consumes."""
        return np.random.default_rng((self._trace_seed, int(index)))

    def _lr(self, epoch: int) -> float:
        lr = self.train.learning_rate
        for e in self.train.lr_decay_epochs:
            if epoch >= e:
                lr *= self.train.lr_decay
        return lr

    def _lr_schedule_range(self, r0: int, r1: int) -> np.ndarray:
        """Per-round learning rates for global rounds [r0, r1) — blocks
        read their position from the global cursor, so the schedule is
        invariant to how the run is partitioned into blocks."""
        return np.array([self._lr(it // self.steps_per_epoch)
                         for it in range(r0, r1)], np.float32)

    def _lr_schedule(self, iterations: int) -> np.ndarray:
        return self._lr_schedule_range(0, iterations)

    # --------------------------------------------------------- batched engine
    @staticmethod
    def _timed_scan(fn):
        """Telemetry shim over a cached jitted scan: the first (compiling)
        call lands in span ``scan/compile``, warm calls in
        ``scan/execute``.  Disabled spans delegate straight through — no
        sync, no clock read; enabled spans block on the output inside the
        span (same values, the device sync is just forced before the
        clock stops)."""
        state = {"warm": False}

        def call(*args):
            if not obs_spans.enabled():
                state["warm"] = True
                return fn(*args)
            name = "scan/execute" if state["warm"] else "scan/compile"
            with obs_spans.span(name):
                out = jax.block_until_ready(fn(*args))
            state["warm"] = True
            return out

        return call

    def _get_scan(self, collect_theta: bool):
        """jit'd `lax.scan` over a per-round input pytree, cached per
        (scheme, collect).  The xs tuple's structure follows the step's
        static configuration (see `build_step`)."""
        cache_key = (self.scheme, collect_theta)
        fn = self._scan_cache.get(cache_key)
        if fn is None:
            step = build_step(self.step_static(collect_theta))
            fn = self._timed_scan(
                jax.jit(lambda consts, carry0, xs:
                        jax.lax.scan(lambda c, inp: step(consts, c, inp),
                                     carry0, xs)))
            self._scan_cache[cache_key] = fn
        return fn

    def _get_consts(self) -> dict:
        if self._consts is None:
            self._consts = self.build_consts()
        return self._consts

    def _scan_xs(self, times: np.ndarray, lrs: np.ndarray) -> tuple:
        """Per-round scan inputs for one realization's pre-sampled delays."""
        return (jnp.asarray(times, jnp.float32),
                jnp.asarray(lrs, jnp.float32))

    def _get_multi_scan(self):
        """jit'd vmapped scan for the stationary multi-realization mode,
        cached once per scheme.  Takes the per-realization carry
        explicitly so blocks chain across calls.  With return faults
        enabled the per-realization fault inputs join the vmapped xs."""
        cache_key = (self.scheme, "multi")
        fn = self._scan_cache.get(cache_key)
        if fn is None:
            step = build_step(self.step_static(collect_theta=False))
            if self.return_faults:
                def multi(consts, carry0_r, times_r, lrs_r, fc_r, fp_r):
                    def one(c0, tj, fc, fp):
                        return jax.lax.scan(
                            lambda c, inp: step(consts, c, inp), c0,
                            (tj, lrs_r, fc, fp))
                    return jax.vmap(one)(carry0_r, times_r, fc_r, fp_r)
            else:
                def multi(consts, carry0_r, times_r, lrs_r):
                    def one(c0, tj):
                        return jax.lax.scan(
                            lambda c, inp: step(consts, c, inp), c0,
                            (tj, lrs_r))
                    return jax.vmap(one)(carry0_r, times_r)

            fn = self._timed_scan(jax.jit(multi))
            self._scan_cache[cache_key] = fn
        return fn

    # ------------------------------------------------------- fault plumbing
    def _fault_rows(self, state: RunState, rounds: int):
        """Draw `rounds` rows of fault inputs from the state's dedicated
        fault stream; returns ``(xs_extra, new_rng_state)`` — ``((), old
        state)`` when return faults are off.  The stream is seeded off
        ``fl.seed + 7717``, independent of both the delay-draw RNG and
        the channel-trace streams, so toggling faults never shifts the
        network realization a run faces."""
        if not self.return_faults:
            return (), state.fault_rng_state
        frng = np.random.default_rng()
        frng.bit_generator.state = state.fault_rng_state
        fcodes, fpar = finject.sample_fault_rows(
            self.faults, frng, rounds, self.n)
        return ((jnp.asarray(fcodes), jnp.asarray(fpar, jnp.float32)),
                frng.bit_generator.state)

    def _carry0(self, theta, lr_scale, theta_prev=None):
        """Scan carry matching `build_step`'s static configuration."""
        carry = (jnp.asarray(theta),
                 jnp.asarray(np.asarray(lr_scale), jnp.float32))
        if self.stale_faults:
            carry = carry + (jnp.asarray(
                theta if theta_prev is None else theta_prev),)
        return carry

    # ------------------------------------------------- block-structured runs
    def init_state(self, iterations: int, *,
                   n_realizations: Optional[int] = None,
                   collect: bool = False) -> RunState:
        """Fresh `RunState` for a run of `iterations` rounds.

        ``n_realizations=None`` starts a "single" run; otherwise a
        "multi" run (stationary — blocks advance all realizations'
        cursors together through one vmapped scan call) or a
        "multi_channel" run (traced — blocks advance one full
        realization at a time, each with its own trace stream).  The
        state is seeded from this experiment's live RNG and the run's
        trace streams are reserved here, so runs launched back to back
        consume disjoint randomness exactly like the pre-RunState
        engine.
        """
        iterations = int(iterations)
        if iterations < 1:
            raise ValueError(f"iterations={iterations} must be >= 1")
        self._attr_blocks = []   # attribution covers the new run only
        if n_realizations is None:
            mode = "single"
            R = None
        else:
            R = int(n_realizations)
            if R < 1:
                raise ValueError(f"n_realizations={R} must be >= 1")
            mode = "multi_channel" if self.channel is not None else "multi"
            collect = False
        trace_call = -1
        trace = est = controls = sched = None
        if self.channel is not None:
            if mode == "single":
                trace_call = self._reserve_trace_streams(1)
                trace = TraceState.init(self.n, self._trace_rng(trace_call))
                if self.adaptive:
                    est = OnlineChannelEstimator(
                        self.nodes,
                        **self.scheme_params_estimator_kwargs()).state_dict()
                    controls = self.scheme_obj.initial_controls(self)
                    sched = _empty_sched(self.n)
            else:
                # one stream per realization; the per-realization
                # estimator/controls are block-local (a block IS one
                # whole realization), so they never live in the state
                trace_call = self._reserve_trace_streams(R)
        if mode == "single":
            theta = jnp.zeros((self.q, self.c), jnp.float32)
            t_rounds = np.zeros(0, np.float64)
            n_ret = np.zeros(0, np.int32)
            lr_scale = 1.0
            n_masked = np.zeros(0, np.int64)
            skipped = np.zeros(0, np.int64)
        elif mode == "multi":
            theta = jnp.zeros((R, self.q, self.c), jnp.float32)
            t_rounds = np.zeros((R, 0), np.float64)
            n_ret = np.zeros((R, 0), np.int32)
            lr_scale = np.ones(R, np.float64)
            n_masked = np.zeros((R, 0), np.int64)
            skipped = np.zeros((R, 0), np.int64)
        else:
            theta = jnp.zeros((R, self.q, self.c), jnp.float32)
            t_rounds = np.zeros((0, iterations), np.float64)
            n_ret = np.zeros((0, iterations), np.int32)
            lr_scale = np.ones(R, np.float64)
            n_masked = np.zeros((0, iterations), np.int64)
            skipped = np.zeros((0, iterations), np.int64)
        losses = accs = None
        if mode == "single" and collect:
            losses = np.zeros(0, np.float64)
            accs = np.zeros(0, np.float64)
        # stale-fault replay needs the previous iterate in the carry;
        # multi_channel blocks are whole realizations, so theirs is
        # block-local and never lives in the state
        theta_prev = (theta if self.stale_faults
                      and mode != "multi_channel" else None)
        fault_rng_state = None
        if self.return_faults:
            fault_rng_state = np.random.default_rng(
                (self._fault_seed,)).bit_generator.state
        return RunState(
            mode=mode, iterations=iterations, rounds_done=0,
            realizations_done=0, n_realizations=R, collect=bool(collect),
            theta=theta, rng_state=self.rng.bit_generator.state,
            trace_call=trace_call, trace=trace, est=est, controls=controls,
            t_rounds=t_rounds, n_ret=n_ret, losses=losses, accs=accs,
            sched=sched, lr_scale=lr_scale, n_masked=n_masked,
            skipped=skipped, theta_prev=theta_prev,
            fault_rng_state=fault_rng_state)

    def run_block(self, state: RunState, n_rounds: Optional[int] = None, *,
                  eval_fn: Optional[Callable] = None,
                  eval_every: int = 10) -> RunState:
        """Advance a run by one block and return the NEW `RunState` (the
        input is never mutated, so replaying a block from a saved state
        is always safe).

        ``n_rounds`` defaults to ``spec.checkpoint_every``, or the whole
        remaining horizon when that is 0.  "multi_channel" runs advance
        exactly one full realization per block regardless of
        ``n_rounds``.  A "single" run initialized with ``collect=True``
        must be given its ``eval_fn`` on every block — losses are
        evaluated block-locally so resumed runs rebuild the identical
        loss curve.
        """
        if state.done:
            raise ValueError(
                "run is already complete "
                f"({state.rounds_done}/{state.iterations} rounds)")
        if state.mode == "single":
            if state.collect and eval_fn is None:
                raise ValueError("state was initialized with collect=True; "
                                 "run_block needs its eval_fn")
            if not state.collect and eval_fn is not None:
                raise ValueError(
                    "state was initialized with collect=False; re-init "
                    "with collect=True to evaluate during the run")
        # detached generator: the stream position lives in the state, not
        # in this Experiment, so replaying a restored block is hermetic
        rng = np.random.default_rng()
        rng.bit_generator.state = state.rng_state
        if state.mode == "multi_channel":
            return self._block_multi_channel(state, rng)
        r0 = state.rounds_done
        K = int(n_rounds) if n_rounds is not None else (
            self.checkpoint_every or state.iterations)
        if K < 1:
            raise ValueError(f"n_rounds={K} must be >= 1")
        K = min(K, state.iterations - r0)
        lrs = self._lr_schedule_range(r0, r0 + K)
        if state.mode == "multi":
            return self._block_multi(state, rng, K, lrs)
        return self._block_single(state, rng, K, lrs, eval_fn, eval_every)

    def _block_single(self, state: RunState, rng, K: int, lrs, eval_fn,
                      eval_every: int) -> RunState:
        """K rounds of a single trajectory: stationary pre-sampling, or
        the traced-channel (and adaptive-controller) path chained through
        the state's `TraceState` / estimator stats / control values."""
        r0 = state.rounds_done
        consts = self._get_consts()
        trace_new = state.trace
        est_new, controls_new = state.est, state.controls
        sched_new = state.sched
        if self.channel is None:
            times = sample_round_times(
                self.nodes, np.asarray(self.loads, float), rng, K)
            xs = self._scan_xs(times, lrs)
            if obs_spans.enabled():
                self._attr_blocks.append({"times": times, "active": None})
        else:
            with obs_spans.span("trace/generate"):
                trace_block, trace_new = generate_trace_block(
                    self.nodes, self.channel, K, state.trace)
            if self.adaptive:
                est = OnlineChannelEstimator(
                    self.nodes, **self.scheme_params_estimator_kwargs())
                est.load_state_dict(state.est)
                seg = plan_segment(self, est, trace_block, r0, r0 + K,
                                   state.controls, rng)
                xs = (jnp.asarray(seg.times, jnp.float32),
                      jnp.asarray(lrs), jnp.asarray(seg.active))
                if self.step_kind == "adaptive_coded":
                    consts = dict(consts)
                    consts["gmask_blocks"] = seg.gmask_blocks
                    xs = xs + (jnp.asarray(seg.t_star_r, jnp.float32),
                               jnp.asarray(seg.block_idx))
                else:
                    xs = xs + (jnp.asarray(seg.n_wait_r),)
                est_new = est.state_dict()
                controls_new = seg.controls
                sched_new = _append_sched(state.sched, seg)
                if obs_spans.enabled():
                    self._attr_blocks.append({
                        "times": np.asarray(seg.times),
                        "active": np.asarray(seg.active),
                        "t_star_r": (np.asarray(seg.t_star_r)
                                     if self.step_kind == "adaptive_coded"
                                     else None),
                        "n_wait_r": (np.asarray(seg.n_wait_r)
                                     if self.step_kind != "adaptive_coded"
                                     else None)})
            else:
                times = sample_round_times_traced(
                    self.nodes, np.asarray(self.loads, float), rng,
                    trace_block)
                xs = (jnp.asarray(times, jnp.float32), jnp.asarray(lrs),
                      jnp.asarray(trace_block.active, jnp.float32))
                if obs_spans.enabled():
                    self._attr_blocks.append({
                        "times": times,
                        "active": np.asarray(trace_block.active)})
        fault_xs, fault_rng_new = self._fault_rows(state, K)
        xs = xs + fault_xs
        scan_fn = self._get_scan(state.collect)
        carry_out, per_round = scan_fn(
            consts, self._carry0(state.theta, state.lr_scale,
                                 state.theta_prev), xs)
        theta = carry_out[0]
        losses_new, accs_new = state.losses, state.accs
        if state.collect:
            thetas = per_round[4]
            loss_b = np.full(K, np.nan)
            acc_b = np.full(K, np.nan)
            for k in range(K):
                it = r0 + k
                if it % eval_every == 0 or it == state.iterations - 1:
                    loss, acc = eval_fn(thetas[k])
                    loss_b[k] = float(loss)
                    acc_b[k] = float(acc)
            losses_new = np.concatenate([state.losses, loss_b])
            accs_new = np.concatenate([state.accs, acc_b])
        return dataclasses.replace(
            state, rounds_done=r0 + K, theta=theta,
            rng_state=rng.bit_generator.state, trace=trace_new,
            est=est_new, controls=controls_new,
            t_rounds=np.concatenate(
                [state.t_rounds, np.asarray(per_round[0], np.float64)]),
            n_ret=np.concatenate(
                [state.n_ret, np.asarray(per_round[1])]),
            losses=losses_new, accs=accs_new, sched=sched_new,
            lr_scale=float(carry_out[1]),
            n_masked=np.concatenate(
                [state.n_masked, np.asarray(per_round[2], np.int64)]),
            skipped=np.concatenate(
                [state.skipped, np.asarray(per_round[3], np.int64)]),
            theta_prev=(carry_out[2] if self.stale_faults else None),
            fault_rng_state=fault_rng_new)

    def _block_multi(self, state: RunState, rng, K: int, lrs) -> RunState:
        """K rounds of ALL stationary realizations in one vmapped scan
        call; per-realization theta carries chain across blocks."""
        R = int(state.n_realizations)
        times = sample_round_times(
            self.nodes, np.asarray(self.loads, float), rng, R * K)
        times = times.reshape(R, K, self.n)
        multi = self._get_multi_scan()
        args = (self._get_consts(),
                self._carry0(state.theta, state.lr_scale,
                             state.theta_prev),
                jnp.asarray(times, jnp.float32), jnp.asarray(lrs))
        fault_rng_new = state.fault_rng_state
        if self.return_faults:
            frng = np.random.default_rng()
            frng.bit_generator.state = state.fault_rng_state
            fcodes, fpar = finject.sample_fault_rows(
                self.faults, frng, R * K, self.n)
            args = args + (
                jnp.asarray(fcodes.reshape(R, K, self.n)),
                jnp.asarray(fpar.reshape(R, K), jnp.float32))
            fault_rng_new = frng.bit_generator.state
        carry_out, (t_rounds, n_ret, n_masked, skipped) = multi(*args)
        return dataclasses.replace(
            state, rounds_done=state.rounds_done + K, theta=carry_out[0],
            rng_state=rng.bit_generator.state,
            t_rounds=np.concatenate(
                [state.t_rounds, np.asarray(t_rounds, np.float64)], axis=1),
            n_ret=np.concatenate(
                [state.n_ret, np.asarray(n_ret)], axis=1),
            lr_scale=np.asarray(carry_out[1], np.float64),
            n_masked=np.concatenate(
                [state.n_masked, np.asarray(n_masked, np.int64)], axis=1),
            skipped=np.concatenate(
                [state.skipped, np.asarray(skipped, np.int64)], axis=1),
            theta_prev=(carry_out[2] if self.stale_faults else None),
            fault_rng_state=fault_rng_new)

    def _block_multi_channel(self, state: RunState, rng) -> RunState:
        """One full traced realization per block: a fresh trace stream at
        index ``trace_call + r`` and (adaptive family) a fresh controller,
        exactly like the per-realization host loop of the pre-RunState
        engine."""
        r = state.realizations_done
        tstate = TraceState.init(self.n,
                                 self._trace_rng(state.trace_call + r))
        with obs_spans.span("trace/generate"):
            trace, _ = generate_trace_block(self.nodes, self.channel,
                                            state.iterations, tstate)
        consts = self._get_consts()
        lrs = jnp.asarray(self._lr_schedule(state.iterations))
        sched_new = state.sched
        if self.adaptive:
            est = OnlineChannelEstimator(
                self.nodes, **self.scheme_params_estimator_kwargs())
            seg = plan_segment(self, est, trace, 0, state.iterations,
                               self.scheme_obj.initial_controls(self), rng)
            xs = (jnp.asarray(seg.times, jnp.float32), lrs,
                  jnp.asarray(seg.active))
            if self.step_kind == "adaptive_coded":
                consts = dict(consts)
                consts["gmask_blocks"] = seg.gmask_blocks
                xs = xs + (jnp.asarray(seg.t_star_r, jnp.float32),
                           jnp.asarray(seg.block_idx))
            else:
                xs = xs + (jnp.asarray(seg.n_wait_r),)
            # the record kept is the LAST realization's plan, matching the
            # pre-RunState engine's `last_schedule` semantics
            sched_new = _append_sched(_empty_sched(self.n), seg)
        else:
            times = sample_round_times_traced(
                self.nodes, np.asarray(self.loads, float), rng, trace)
            xs = (jnp.asarray(times, jnp.float32), lrs,
                  jnp.asarray(trace.active, jnp.float32))
        fault_xs, fault_rng_new = self._fault_rows(state,
                                                   state.iterations)
        xs = xs + fault_xs
        scan_fn = self._get_scan(False)
        theta0 = jnp.zeros((self.q, self.c), jnp.float32)
        carry_out, per_round = scan_fn(
            consts, self._carry0(theta0, 1.0), xs)
        theta_r = carry_out[0]
        lr_scale_new = np.asarray(state.lr_scale, np.float64).copy()
        lr_scale_new[r] = float(carry_out[1])
        return dataclasses.replace(
            state, realizations_done=r + 1,
            rounds_done=(r + 1) * state.iterations,
            theta=state.theta.at[r].set(theta_r),
            rng_state=rng.bit_generator.state, sched=sched_new,
            t_rounds=np.concatenate(
                [state.t_rounds,
                 np.asarray(per_round[0], np.float64)[None]]),
            n_ret=np.concatenate(
                [state.n_ret, np.asarray(per_round[1])[None]]),
            lr_scale=lr_scale_new,
            n_masked=np.concatenate(
                [state.n_masked,
                 np.asarray(per_round[2], np.int64)[None]]),
            skipped=np.concatenate(
                [state.skipped,
                 np.asarray(per_round[3], np.int64)[None]]),
            fault_rng_state=fault_rng_new)

    # ---------------------------------------------------- checkpoint/restore
    def save_state(self, path: str, state: RunState) -> str:
        """Checkpoint `state` atomically (`repro.checkpoint.io`),
        embedding this experiment's `ExperimentSpec` as JSON provenance."""
        arrays, meta = pack_state(state)
        meta["spec"] = self.spec.to_dict()
        with obs_spans.span("checkpoint/save"):
            return ckpt_io.save_state(path, arrays, meta)

    def restore_state(self, path: str) -> RunState:
        """Load a `RunState` checkpoint, verify its spec provenance
        against this experiment, and bump the trace-stream cursor past
        the restored run's reservation so new runs stay disjoint."""
        self._attr_blocks = []   # attribution covers post-restore rounds
        with obs_spans.span("checkpoint/restore"):
            arrays, meta = ckpt_io.restore_state(path)
        spec_dict = meta.get("spec")
        if spec_dict is not None:
            saved = ExperimentSpec.from_dict(spec_dict)
            if saved != self.spec:
                raise ValueError(
                    f"checkpoint provenance mismatch: {path!r} was saved "
                    "by a run of a different ExperimentSpec than this "
                    "experiment's — refusing to resume across specs")
        state = unpack_state(arrays, meta)
        if state.trace_call >= 0:
            reserved = (int(state.n_realizations)
                        if state.mode == "multi_channel" else 1)
            self._trace_calls = max(self._trace_calls,
                                    state.trace_call + reserved)
        return state

    # ------------------------------------------------------------ finalizing
    def finish(self, state: RunState,
               eval_fn: Optional[Callable] = None):
        """Turn a completed `RunState` into `FedResult` /
        `MultiFedResult` and sync this experiment's RNG to the run-end
        stream position (so back-to-back runs consume disjoint draws,
        exactly like the pre-RunState engine)."""
        if not state.done:
            raise ValueError(
                f"run is not complete ({state.rounds_done}/"
                f"{state.iterations} rounds); call run_block until "
                "state.done")
        self.rng.bit_generator.state = state.rng_state
        if state.sched is not None:
            self.last_schedule = self._assemble_schedule(state.sched)
        if state.mode == "single":
            return self._finish_single(state)
        return self._finish_multi(state, eval_fn)

    @staticmethod
    def _run_health(state: RunState) -> "RunHealth | None":
        if state.n_masked is None:
            return None
        ls = np.asarray(state.lr_scale, np.float64)
        return RunHealth(
            rounds_degraded=int(np.sum(np.asarray(state.n_masked) > 0)),
            returns_masked=int(np.sum(state.n_masked)),
            rounds_skipped=int(np.sum(state.skipped)),
            lr_scale=float(ls.min() if ls.ndim else ls))

    def _finish_single(self, state: RunState) -> FedResult:
        wall = self.setup_time + np.cumsum(state.t_rounds)
        history: list[RoundLog] = []
        # a restored format-1 checkpoint has no guard counters
        have_guards = state.n_masked is not None
        for it in range(state.iterations):
            loss = float(state.losses[it]) if state.collect else float("nan")
            acc = float(state.accs[it]) if state.collect else float("nan")
            history.append(RoundLog(
                it, float(wall[it]), int(state.n_ret[it]), loss, acc,
                n_masked=int(state.n_masked[it]) if have_guards else 0,
                skipped=int(state.skipped[it]) if have_guards else 0))
        return FedResult(theta=state.theta, history=history,
                         t_star=self.t_star, loads=self.loads,
                         setup_time=self.setup_time,
                         privacy_eps=self.privacy_eps,
                         health=self._run_health(state))

    def _finish_multi(self, state: RunState, eval_fn) -> MultiFedResult:
        wall = self.setup_time + np.cumsum(state.t_rounds, axis=1)
        theta = state.theta
        acc = None
        if eval_fn is not None:
            if state.mode == "multi_channel":
                acc = np.array([eval_fn(theta[r])[1]
                                for r in range(theta.shape[0])])
            else:
                # vmap the eval over the realization axis when eval_fn is
                # jax-traceable (it must then be pure — it sees a batched
                # tracer, not R concrete arrays); numpy/host-side eval_fns
                # raise a tracer-conversion error and fall back to the
                # loop.  Genuine eval_fn bugs (bad shapes) propagate.
                try:
                    acc = np.asarray(jax.vmap(
                        lambda th: jnp.asarray(eval_fn(th)[1]))(theta))
                except jax.errors.JAXTypeError:
                    acc = np.array([eval_fn(theta[r])[1]
                                    for r in range(theta.shape[0])])
        return MultiFedResult(theta=theta, wall_clock=wall,
                              returned=np.asarray(state.n_ret),
                              t_star=self.t_star, loads=self.loads,
                              setup_time=self.setup_time, accuracy=acc,
                              privacy_eps=self.privacy_eps,
                              health=self._run_health(state))

    def _assemble_schedule(self, sched: dict) -> AdaptiveSchedule:
        """Rebuild the run's `AdaptiveSchedule` from the state's
        serialized record (gmasks are re-derived from the per-block
        loads — `gmask_for_loads` is a pure function of them)."""
        estimates = [
            {"mu": sched["est_mu"][b], "tau": sched["est_tau"][b],
             "p": sched["est_p"][b], "avail": sched["est_avail"][b],
             "rounds_seen": int(sched["est_rounds_seen"][b])}
            for b in range(sched["loads_blocks"].shape[0])]
        out = AdaptiveSchedule(
            times=sched["times"], active=sched["active"],
            block_idx=sched["block_idx"],
            loads_blocks=sched["loads_blocks"], estimates=estimates)
        if self.step_kind == "adaptive_coded":
            out.t_star = sched["t_star_r"]
            out.gmask_blocks = jnp.stack(
                [self.scheme_obj.gmask_for_loads(self, loads)
                 for loads in sched["loads_blocks"]])
        else:
            out.n_wait = sched["n_wait_r"]
        return out

    # ------------------------------------------------------------ telemetry
    def attribution(self, k: int = 3):
        """Post-hoc straggler attribution (`repro.obs.attribution`) over
        the delay blocks this experiment materialized while telemetry was
        enabled (`repro.obs.spans.enable`): per-client deadline-miss
        rate, slowest-`k` contribution counts, and the coded-compensation
        data share per round.  Covers single-trajectory rounds computed
        in this process since the last `init_state`/`restore_state`.
        Raises `RuntimeError` when nothing was captured."""
        from repro.obs.attribution import attribution_from_blocks
        return attribution_from_blocks(
            self._attr_blocks, self.step_kind, t_star=self.t_star,
            t_ideal=self.t_ideal, n_wait=self.n_wait,
            loads=self.loads, m=self.m, k=k)

    def _drive(self, state: RunState, checkpoint_dir: Optional[str],
               eval_fn=None, eval_every: int = 10,
               journal=None) -> RunState:
        """Advance `state` to completion block by block, checkpointing
        each block boundary when a directory is given and journaling each
        block's rounds when a `RunJournal` is given (after the
        checkpoint, so the journal never runs ahead of durable state)."""
        while not state.done:
            state = self.run_block(state, eval_fn=eval_fn,
                                   eval_every=eval_every)
            if checkpoint_dir is not None:
                self.save_state(
                    os.path.join(
                        checkpoint_dir,
                        f"{ckpt_io.CKPT_PREFIX}{state.rounds_done:06d}.npz"),
                    state)
            if journal is not None:
                journal.sync(self, state)
        return state

    # ---------------------------------------------------------- legacy engine
    def _run_legacy(self, iterations: int, times_all: np.ndarray,
                    lrs: np.ndarray, eval_fn, eval_every: int) -> FedResult:
        """Original per-client Python loop — the numerical oracle the batched
        engine is tested against (same pre-sampled delays, same trajectory)."""
        theta = jnp.zeros((self.q, self.c), jnp.float32)
        wall = self.setup_time
        history: list[RoundLog] = []
        n_wait = self.n_wait

        for it in range(iterations):
            times = times_all[it]
            if self.step_kind == "naive":
                returned = np.ones(self.n, dtype=bool)
                t_round = float(np.max(times))
                denom = self.m
            elif self.step_kind == "greedy":
                order = np.argsort(times)
                returned = np.zeros(self.n, dtype=bool)
                returned[order[:n_wait]] = True
                t_round = float(times[order[n_wait - 1]])
                denom = int(returned.sum()) * self.l
            elif self.step_kind == "coded":
                returned = times <= self.t_star
                t_round = float(self.t_star)
                denom = self.m
            elif self.step_kind == "ideal":
                returned = np.ones(self.n, dtype=bool)
                t_round = float(self.t_ideal)
                denom = self.m
            else:
                raise ValueError(self.step_kind)

            # gradients
            if self.step_kind == "coded":
                grads = []
                for j in range(self.n):
                    if returned[j] and self.loads[j] > 0:
                        grads.append(aggregation.client_gradient(
                            self._sub_x[j], self._sub_y[j], theta))
                coded_g = aggregation.coded_gradient(
                    self.parity.x, self.parity.y, theta, pnr_c=0.0)
                total = coded_g
                for g in grads:
                    total = total + g
                g_m = total / denom + self.train.l2_reg * theta
            else:
                g_all = _batched_client_grads_jit(self.x, self.y, theta)
                g_m = aggregation.masked_gradient_sum(g_all, returned) / denom \
                    + self.train.l2_reg * theta

            theta = theta - float(lrs[it]) * g_m
            wall += t_round

            if eval_fn is not None and (it % eval_every == 0 or it == iterations - 1):
                loss, acc = eval_fn(theta)
            else:
                loss, acc = float("nan"), float("nan")
            history.append(RoundLog(it, wall, int(returned.sum()), loss, acc))

        return FedResult(theta=theta, history=history, t_star=self.t_star,
                         loads=self.loads, setup_time=self.setup_time,
                         privacy_eps=self.privacy_eps)

    # ------------------------------------------------------------------- runs
    def run(self, iterations: int,
            eval_fn: Optional[Callable[[jnp.ndarray], tuple[float, float]]] = None,
            eval_every: int = 10, *, checkpoint_dir: Optional[str] = None,
            resume: bool = False,
            journal_dir: Optional[str] = None) -> FedResult:
        """Run `iterations` rounds as a chain of `run_block` calls over
        the cached compiled scan: block size = ``spec.checkpoint_every``
        rounds, or the whole horizon when 0 (which reproduces the
        historical one-shot trajectories bit-for-bit).  With a channel
        profile the delays flow through the network trace (and the
        adaptive controller's schedule) instead — still one compiled
        scan per block.

        ``checkpoint_dir`` writes an atomic `RunState` checkpoint at
        every block boundary; ``resume=True`` restores the latest one
        there (if any) and continues, bit-identical to the uninterrupted
        blocked run.  ``journal_dir`` appends one `repro.obs` event per
        round to ``<journal_dir>/events.jsonl`` at the same boundaries —
        on resume the journal is trimmed/regrown to match the restored
        state, so an interrupted run's journal is always extended, never
        corrupted.
        """
        if self.engine == "legacy" and self.channel is None:
            if checkpoint_dir is not None or resume:
                raise ValueError(
                    "checkpointing requires the batched engine; the legacy "
                    "per-client oracle has no block-structured run state")
            if journal_dir is not None:
                raise ValueError(
                    "journal_dir requires the batched engine; the legacy "
                    "per-client oracle has no RunState to journal from")
            times = self._sample_round_times(iterations)
            lrs = self._lr_schedule(iterations)
            return self._run_legacy(iterations, times, lrs, eval_fn,
                                    eval_every)
        state = None
        if resume:
            if checkpoint_dir is None:
                raise ValueError("resume=True requires checkpoint_dir")
            latest = ckpt_io.latest_checkpoint(checkpoint_dir,
                                               valid_only=True)
            if latest is not None:
                state = self.restore_state(latest)
                if state.mode != "single":
                    raise ValueError(
                        f"checkpoint {latest!r} holds a {state.mode!r} "
                        "run; resume it with run_multi")
                if state.iterations != int(iterations):
                    raise ValueError(
                        f"checkpoint {latest!r} is a {state.iterations}-"
                        f"round run; this run asked for {iterations}")
                if state.collect != (eval_fn is not None):
                    raise ValueError(
                        f"checkpoint {latest!r} was saved with collect="
                        f"{state.collect}; pass a matching eval_fn")
        if state is None:
            state = self.init_state(iterations,
                                    collect=eval_fn is not None)
        journal = None
        if journal_dir is not None:
            from repro.obs.events import RunJournal
            journal = RunJournal(journal_dir)
            # trim past the restored state (a journal ahead of a rolled-
            # back checkpoint replays from authoritative state), then
            # regrow whatever prefix the state already carries
            journal.reset_to(state.rounds_done)
            journal.sync(self, state)
        state = self._drive(state, checkpoint_dir, eval_fn, eval_every,
                            journal=journal)
        return self.finish(state)

    def run_multi(self, iterations: int, n_realizations: int,
                  eval_fn: Optional[Callable[[jnp.ndarray],
                                             tuple[float, float]]] = None,
                  *, checkpoint_dir: Optional[str] = None,
                  resume: bool = False) -> MultiFedResult:
        """R independent delay realizations of the same deployment.

        One vmapped scan call per block produces the full
        (R, iterations) wall-clock / return-count surface — mean ± std
        over axis 0 is the Fig. 4/5 curve with its confidence band
        (`MultiFedResult.wall_clock_bands`).  With
        ``spec.checkpoint_every == 0`` the whole run is one block, i.e.
        one compiled call, exactly as before.

        Always runs on the batched scan engine (the legacy oracle has no
        vmappable form); the `engine` constructor argument only selects
        the `run()` path.  The final-iterate eval is vmapped over the
        realization axis when `eval_fn` is jax-traceable, falling back
        to a per-realization Python loop otherwise.  Channel-profile
        runs advance one full realization (fresh trace stream) per block
        over one shared compiled scan instead — checkpoints then land at
        realization, not round, granularity.

        ``checkpoint_dir``/``resume`` checkpoint and restore the run at
        block boundaries exactly like `run`.
        """
        state = None
        if resume:
            if checkpoint_dir is None:
                raise ValueError("resume=True requires checkpoint_dir")
            latest = ckpt_io.latest_checkpoint(checkpoint_dir,
                                               valid_only=True)
            if latest is not None:
                state = self.restore_state(latest)
                if state.mode == "single":
                    raise ValueError(
                        f"checkpoint {latest!r} holds a single run; "
                        "resume it with run()")
                if (state.iterations != int(iterations)
                        or int(state.n_realizations)
                        != int(n_realizations)):
                    raise ValueError(
                        f"checkpoint {latest!r} is a {state.iterations}-"
                        f"round x {state.n_realizations}-realization run; "
                        f"this run asked for {iterations} x "
                        f"{n_realizations}")
        if state is None:
            state = self.init_state(iterations,
                                    n_realizations=n_realizations)
        state = self._drive(state, checkpoint_dir)
        return self.finish(state, eval_fn)

    # ------------------------------------------------------------------ sweep
    def sweep(self, *, profiles: dict, iterations: int, realizations: int,
              schemes: Optional[tuple] = None):
        """Sweep this experiment's data over heterogeneity profiles.

        Convenience front-end over `repro.launch.sweep.run_sweep` — the
        same spec (scheme, backends, training config) is replayed across
        `profiles` ({name: FLConfig-override dict}) in ONE compiled
        (profile x realization) call per scheme.  `schemes` defaults to
        just this experiment's scheme.
        """
        from repro.launch import sweep as sweep_mod
        return sweep_mod.run_sweep(
            self.x, self.y, profiles=profiles, train_cfg=self.train,
            iterations=iterations, realizations=realizations,
            schemes=schemes or (self.scheme,), base_spec=self.spec)


class FederatedSimulation:
    """Removed.  The deprecated kwargs front-end over `Experiment` was a
    shim folding its arguments into a frozen `ExperimentSpec`; the two
    entrypoints shared one code path, so nothing is lost by migrating.
    The stub survives only to point stragglers at the replacement."""

    def __init__(self, *args, **kwargs):
        raise TypeError(
            "FederatedSimulation has been removed; build a frozen "
            "repro.config.ExperimentSpec and call "
            "repro.api.build_experiment(spec, x_stack, y_stack) instead")
