"""Federated-learning runtime: CodedFedL / naive-uncoded / greedy-uncoded.

This is the paper's system layer (§III, §V): a server loop over training
rounds in a simulated wireless MEC network.  Compute/communication delays are
*sampled from the paper's stochastic models* each round; the simulated
wall-clock is the quantity all of Fig. 4/5 and Tables II/III are measured in.

Schemes (paper §V "Schemes"):
  naive  — server waits for ALL n clients; round time = max_j T_j.
  greedy — server waits for the fastest (1-psi)*n clients.
  coded  — CodedFedL: clients process l*_j points, server adds the coded
           gradient over the global parity set, round time = t*.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig, RFFConfig, TrainConfig
from repro.core import aggregation, encoding, load_allocation
from repro.core.delay_model import NodeDelayParams, mec_network, packet_bits, scale_tau


@dataclasses.dataclass
class RoundLog:
    iteration: int
    wall_clock: float          # cumulative simulated seconds
    returned: int              # clients that made the deadline
    loss: float
    accuracy: float


@dataclasses.dataclass
class FedResult:
    theta: jnp.ndarray
    history: list[RoundLog]
    t_star: float | None = None
    loads: np.ndarray | None = None
    setup_time: float = 0.0    # parity upload overhead (coded only)


def _batched_client_grads(x_stack, y_stack, theta):
    """Per-client unnormalized gradients, vmapped over the client axis.

    x_stack: (n, l, q), y_stack: (n, l, c), theta: (q, c) -> (n, q, c)
    """
    def one(x, y):
        return x.T @ (x @ theta - y)
    return jax.vmap(one)(x_stack, y_stack)


_batched_client_grads_jit = jax.jit(_batched_client_grads)


class FederatedSimulation:
    """Simulates one FL deployment: n clients + MEC server, one scheme.

    Clients hold equally sized local minibatches of RFF-transformed data
    (x_stack: (n, l, q), y_stack: (n, l, c)); the delay network follows
    paper §V-A.
    """

    def __init__(self, x_stack, y_stack, fl_cfg: FLConfig,
                 train_cfg: TrainConfig, *, scheme: Optional[str] = None,
                 steps_per_epoch: int = 1, nodes: Optional[list] = None,
                 rng: Optional[np.random.Generator] = None,
                 secure_aggregation: bool = False):
        self.secure_aggregation = secure_aggregation
        self.scheme = scheme or fl_cfg.scheme
        self.fl = fl_cfg
        self.train = train_cfg
        self.x = jnp.asarray(x_stack)
        self.y = jnp.asarray(y_stack)
        self.n, self.l, self.q = self.x.shape
        self.c = self.y.shape[-1]
        self.m = self.n * self.l
        self.steps_per_epoch = steps_per_epoch
        self.rng = rng or np.random.default_rng(fl_cfg.seed + 17)

        # --- delay network (tau scaled to the actual gradient/model packet)
        base_nodes = nodes or mec_network(fl_cfg, d_scalars_per_point=self.q * self.c)
        payload = packet_bits(fl_cfg, self.q * self.c)    # model == gradient size
        self.nodes = [scale_tau(nd, payload) for nd in base_nodes[:self.n]]

        self.t_star = None
        self.loads = np.full(self.n, self.l, dtype=np.float64)
        self.parity = None
        self.setup_time = 0.0
        self.processed_idx = [np.arange(self.l) for _ in range(self.n)]
        if self.scheme == "coded":
            self._setup_coded()

    # ------------------------------------------------------------- coded setup
    def _setup_coded(self):
        fl = self.fl
        u_max = int(round(fl.delta * self.m))
        alloc = load_allocation.two_step_allocate(
            self.nodes, [float(self.l)] * self.n, server=None,
            u_max=float(u_max), m=float(self.m))
        self.t_star = alloc.t_star
        self.u = u_max
        # integer loads (floor, at least 0)
        self.loads = np.minimum(np.floor(alloc.loads).astype(int), self.l)
        # probability of return by t* per client at its optimal load
        self.p_return = np.array([
            nd.cdf(self.t_star, float(ld)) if ld > 0 else 0.0
            for nd, ld in zip(self.nodes, self.loads)])
        # sample the processed subsets + weight matrices, build parity sets
        key = jax.random.PRNGKey(self.fl.seed + 99)
        parities = []
        self.processed_idx = []
        for j in range(self.n):
            idx = self.rng.permutation(self.l)[: self.loads[j]]
            self.processed_idx.append(np.sort(idx))
            w = encoding.weight_vector(self.l, idx, float(self.p_return[j]))
            key, sub = jax.random.split(key)
            parities.append(encoding.encode_local(
                sub, self.x[j], self.y[j], w, self.u))
        if self.secure_aggregation:
            # paper §VI future work: the server only ever sees masked
            # uploads; pairwise masks cancel in the sum (core/secure_agg.py)
            from repro.core import secure_agg
            skey = jax.random.PRNGKey(self.fl.seed + 1234)
            masked = [secure_agg.mask_parity(skey, j, self.n, p)
                      for j, p in enumerate(parities)]
            self.parity = secure_agg.secure_aggregate(masked)
        else:
            self.parity = encoding.aggregate_parity(parities)
        # one-time parity upload overhead: clients upload u*(q+c) scalars in
        # parallel; expected transmissions 1/(1-p) (paper Fig 4a inset).
        bits = packet_bits(fl, self.u * (self.q + self.c))
        self.setup_time = max(
            nd.tau / packet_bits(fl, self.q * self.c) * bits / (1.0 - nd.p)
            for nd in self.nodes)
        # per-round client tensors restricted to processed subsets (ragged ->
        # keep full and mask in gradient: we gather the subset once here)
        self._sub_x = [self.x[j][self.processed_idx[j]] for j in range(self.n)]
        self._sub_y = [self.y[j][self.processed_idx[j]] for j in range(self.n)]

    # ------------------------------------------------------------------ round
    def _sample_round_times(self) -> np.ndarray:
        return np.array([
            nd.sample(self.rng, float(ld), size=1)[0]
            for nd, ld in zip(self.nodes, self.loads)])

    def _lr(self, epoch: int) -> float:
        lr = self.train.learning_rate
        for e in self.train.lr_decay_epochs:
            if epoch >= e:
                lr *= self.train.lr_decay
        return lr

    def run(self, iterations: int,
            eval_fn: Optional[Callable[[jnp.ndarray], tuple[float, float]]] = None,
            eval_every: int = 10) -> FedResult:
        theta = jnp.zeros((self.q, self.c), jnp.float32)
        wall = self.setup_time
        history: list[RoundLog] = []
        n_wait = max(1, int(math.ceil((1.0 - self.fl.psi) * self.n)))

        for it in range(iterations):
            times = self._sample_round_times()
            if self.scheme == "naive":
                returned = np.ones(self.n, dtype=bool)
                t_round = float(np.max(times))
                denom = self.m
            elif self.scheme == "greedy":
                order = np.argsort(times)
                returned = np.zeros(self.n, dtype=bool)
                returned[order[:n_wait]] = True
                t_round = float(times[order[n_wait - 1]])
                denom = int(returned.sum()) * self.l
            elif self.scheme == "coded":
                returned = times <= self.t_star
                t_round = float(self.t_star)
                denom = self.m
            else:
                raise ValueError(self.scheme)

            # gradients
            if self.scheme == "coded":
                grads = []
                for j in range(self.n):
                    if returned[j] and self.loads[j] > 0:
                        grads.append(aggregation.client_gradient(
                            self._sub_x[j], self._sub_y[j], theta))
                coded_g = aggregation.coded_gradient(
                    self.parity.x, self.parity.y, theta, pnr_c=0.0)
                total = coded_g
                for g in grads:
                    total = total + g
                g_m = total / denom + self.train.l2_reg * theta
            else:
                g_all = _batched_client_grads_jit(self.x, self.y, theta)
                mask = jnp.asarray(returned, jnp.float32)[:, None, None]
                g_m = jnp.sum(g_all * mask, axis=0) / denom \
                    + self.train.l2_reg * theta

            epoch = it // self.steps_per_epoch
            theta = theta - self._lr(epoch) * g_m
            wall += t_round

            if eval_fn is not None and (it % eval_every == 0 or it == iterations - 1):
                loss, acc = eval_fn(theta)
            else:
                loss, acc = float("nan"), float("nan")
            history.append(RoundLog(it, wall, int(returned.sum()), loss, acc))

        return FedResult(theta=theta, history=history, t_star=self.t_star,
                         loads=self.loads, setup_time=self.setup_time)
