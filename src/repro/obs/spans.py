"""Zero-overhead-when-disabled span timers with a thread-safe collector.

The runtime's hot paths are annotated with ``with span("solver/two_step")``
blocks; when the module flag is off (the default) ``__enter__`` is a single
flag check — no clock read, no lock, no allocation beyond the span object
itself — so un-instrumented runs pay nothing measurable.  `enable()` turns
every span in the process into a wall-clock measurement recorded in one
in-process collector keyed by span name; `totals()` snapshots it.

Span names in the runtime (all host-side, wrapping whole setup phases or
whole compiled blocks — never per-round work, so overhead stays bounded by
the block count, not the round count):

  ==========================  ==============================================
  ``setup/experiment``        whole scheme setup (`Experiment.__init__`)
  ``solver/two_step``         two-step load-allocation solve
  ``encode/parity``           batched/streamed parity encode
  ``trace/generate``          channel-trace block generation
  ``scan/compile``            first (compiling) call of a cached scan
  ``scan/execute``            warm calls of that scan
  ``checkpoint/save``         `save_state` (atomic npz write)
  ``checkpoint/restore``      `restore_state` (load + digest verify)
  ``hier/shard_setup``        one edge aggregator's deployment setup
  ``hier/round_block``        one hierarchical `run_block`
  ``journal/append``          run-journal block append
  ``service/block``           one `ExperimentService` block advance
  ``service/ckpt_save``       the service's view of one checkpoint save
  ``service/backoff``         retry backoff sleeps
  ==========================  ==============================================

Timing never touches an RNG stream or any value that flows into a
trajectory — runs with spans enabled are bit-identical to runs with spans
disabled (pinned by tests/test_obs.py).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time

__all__ = ["span", "enable", "disable", "enabled", "reset", "record",
           "totals", "write_json", "collecting", "SPANS_NAME"]

#: filename `write_json` conventionally uses inside a run directory
SPANS_NAME = "spans.json"

_enabled = False
_lock = threading.Lock()
#: name -> [count, total_s, min_s, max_s]
_records: "dict[str, list]" = {}


def enabled() -> bool:
    """Whether spans currently measure (module-global, process-wide)."""
    return _enabled


def enable() -> None:
    """Turn every `span` in the process into a recorded measurement."""
    global _enabled
    _enabled = True


def disable() -> None:
    """Return spans to their zero-overhead pass-through behavior."""
    global _enabled
    _enabled = False


def reset() -> None:
    """Drop all collected records (the enable flag is left as is)."""
    with _lock:
        _records.clear()


def record(name: str, seconds: float) -> None:
    """Fold one measured duration into the collector (thread-safe)."""
    with _lock:
        rec = _records.get(name)
        if rec is None:
            _records[name] = [1, seconds, seconds, seconds]
        else:
            rec[0] += 1
            rec[1] += seconds
            if seconds < rec[2]:
                rec[2] = seconds
            if seconds > rec[3]:
                rec[3] = seconds


def totals() -> dict:
    """Snapshot the collector: {name: {count, total_s, min_s, max_s}},
    names sorted so the snapshot serializes deterministically."""
    with _lock:
        return {name: {"count": int(rec[0]), "total_s": float(rec[1]),
                       "min_s": float(rec[2]), "max_s": float(rec[3])}
                for name, rec in sorted(_records.items())}


def write_json(path: str) -> str:
    """Write `totals()` as pretty JSON (a run dir's ``spans.json``)."""
    with open(path, "w") as fh:
        json.dump(totals(), fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


class span:
    """``with span("solver/two_step"): ...`` — wall-clock one region.

    When the module flag is off the context manager is inert (no clock
    read).  ``force=True`` measures regardless of the flag — the duration
    lands in ``self.elapsed_s`` for the caller, but is only folded into
    the global collector when the flag is on (the `ExperimentService`
    uses this for its always-on per-run health timings).
    """
    __slots__ = ("name", "elapsed_s", "_t0", "_force")

    def __init__(self, name: str, *, force: bool = False):
        self.name = name
        self.elapsed_s = None
        self._t0 = None
        self._force = force

    def __enter__(self) -> "span":
        if _enabled or self._force:
            self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._t0 is not None:
            self.elapsed_s = time.perf_counter() - self._t0
            self._t0 = None
            if _enabled:
                record(self.name, self.elapsed_s)
        return False


@contextlib.contextmanager
def collecting(fresh: bool = True):
    """Enable spans for the duration of the block, restoring the previous
    flag afterwards; ``fresh`` clears the collector first.  Yields the
    module so ``with collecting() as spans: ... spans.totals()`` reads
    naturally."""
    global _enabled
    prev = _enabled
    if fresh:
        reset()
    _enabled = True
    try:
        yield __import__(__name__, fromlist=["totals"])
    finally:
        _enabled = prev
