"""Post-hoc straggler attribution from the run's materialized delay tensors.

The engine pre-samples every round's per-client delays into host arrays
before compiling the scan (`fed_runtime._block_single`,
`hier.topology.HierExperiment.run_block`).  With telemetry enabled
(`repro.obs.spans.enable`) those already-materialized blocks are kept —
a numpy reference per block, no RNG touched, no extra draws — and this
module turns them into the paper's delay analysis, per client:

  * **deadline-miss rate** — fraction of rounds a client exceeded the
    round deadline (t* for the coded family, the n_wait-th order
    statistic for the greedy family, the round max for naive);
  * **slowest-k contributions** — how often the client was among the k
    slowest present that round (who *drives* the tail, not just who
    misses);
  * **coded-compensation share** — per round, the fraction of the data
    mass the parity gradient stood in for: ``1 - sum_j l_j r_j / m``
    (a data-mass proxy for the parity share of the update, exact for
    the uniform-weight limit; 0 for schemes with no parity).

Exposed as ``Experiment.attribution()`` and, per shard, as
``HierExperiment.attribution()``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Attribution", "compute_attribution", "round_deadlines"]


@dataclasses.dataclass
class Attribution:
    """Straggler attribution over one run's captured rounds."""
    rounds: int                  # rounds covered
    k: int                       # slowest-k window
    miss_rate: np.ndarray        # (n,) deadline-miss rate per client
    miss_counts: np.ndarray      # (n,) rounds missed
    active_rounds: np.ndarray    # (n,) rounds the client was present
    slowest_k_counts: np.ndarray  # (n,) rounds among the k slowest present
    comp_share: np.ndarray       # (rounds,) coded-compensation data share

    def top_stragglers(self, count: int = 5) -> "list[tuple[int, float]]":
        """[(client, miss_rate)] sorted worst-first, ties by client id."""
        order = np.lexsort((np.arange(len(self.miss_rate)),
                            -self.miss_rate))
        return [(int(j), float(self.miss_rate[j]))
                for j in order[:count]]

    def to_dict(self) -> dict:
        return {
            "rounds": int(self.rounds),
            "k": int(self.k),
            "miss_rate": [float(v) for v in self.miss_rate],
            "miss_counts": [int(v) for v in self.miss_counts],
            "active_rounds": [int(v) for v in self.active_rounds],
            "slowest_k_counts": [int(v) for v in self.slowest_k_counts],
            "comp_share_mean": float(self.comp_share.mean())
            if len(self.comp_share) else 0.0,
            "top_stragglers": [[j, r] for j, r in self.top_stragglers()],
        }


def round_deadlines(step_kind: str, times: np.ndarray, active: np.ndarray,
                    *, t_star=None, t_ideal=None, n_wait=None,
                    t_star_r=None, n_wait_r=None) -> np.ndarray:
    """(T,) per-round deadline implied by the scheme's step kind.

    Mirrors `fed_runtime.build_step`'s host-visible deadline logic:
    coded uses the (possibly re-planned) t*, greedy the n_wait-th order
    statistic among clients present, naive the max over clients present,
    ideal its deterministic round clock.
    """
    T, n = times.shape
    if step_kind == "coded":
        if t_star_r is not None:
            return np.asarray(t_star_r, np.float64)
        return np.full(T, float(t_star), np.float64)
    if step_kind == "adaptive_coded":
        return np.asarray(t_star_r, np.float64)
    if step_kind == "ideal":
        return np.full(T, float(t_ideal), np.float64)
    if step_kind == "naive":
        masked = np.where(active > 0, times, 0.0)
        return masked.max(axis=1)
    if step_kind in ("greedy", "adaptive_greedy"):
        waits = (np.asarray(n_wait_r, np.int64) if n_wait_r is not None
                 else np.full(T, int(n_wait), np.int64))
        srt = np.sort(np.where(active > 0, times, np.inf), axis=1)
        n_act = (active > 0).sum(axis=1)
        k_eff = np.clip(np.minimum(waits, n_act), 1, n)
        dl = srt[np.arange(T), k_eff - 1]
        return np.where(n_act > 0, dl, 0.0)
    raise ValueError(f"unknown step kind {step_kind!r}")


def compute_attribution(times: np.ndarray, active, deadline: np.ndarray,
                        *, loads=None, m=None, coded: bool = False,
                        k: int = 3) -> Attribution:
    """Attribution over (T, n) delay samples against (T,) deadlines.

    `active` is the (T, n) presence mask (churn / sampled cohorts), or
    None for all-present runs.  `loads`/`m` feed the coded-compensation
    data share when `coded`.
    """
    times = np.asarray(times, np.float64)
    T, n = times.shape
    if k < 1:
        raise ValueError(f"k={k} must be >= 1")
    active = (np.ones((T, n), bool) if active is None
              else np.asarray(active) > 0)
    deadline = np.asarray(deadline, np.float64)
    miss = (times > deadline[:, None]) & active
    active_rounds = active.sum(axis=0)
    miss_counts = miss.sum(axis=0)
    miss_rate = miss_counts / np.maximum(active_rounds, 1)
    # slowest-k among clients PRESENT each round: absent clients sort
    # first at -inf, so the tail of the argsort is the live tail — but
    # guard rounds with fewer than k present
    order = np.argsort(np.where(active, times, -np.inf), axis=1,
                       kind="stable")
    tail = order[:, -min(k, n):]
    tail_live = np.take_along_axis(active, tail, axis=1)
    slowest = np.zeros(n, np.int64)
    np.add.at(slowest, tail[tail_live], 1)
    if coded:
        ret = (~miss) & active
        mass = (np.asarray(loads, np.float64)[None, :] * ret).sum(axis=1)
        comp_share = np.clip(1.0 - mass / float(m), 0.0, 1.0)
    else:
        comp_share = np.zeros(T, np.float64)
    return Attribution(rounds=T, k=int(min(k, n)), miss_rate=miss_rate,
                       miss_counts=miss_counts,
                       active_rounds=active_rounds,
                       slowest_k_counts=slowest, comp_share=comp_share)


def attribution_from_blocks(blocks: "list[dict]", step_kind: str, *,
                            t_star=None, t_ideal=None, n_wait=None,
                            loads=None, m=None, k: int = 3) -> Attribution:
    """Concatenate per-block captures (`fed_runtime._block_single`) and
    attribute.  Each block dict: ``times`` (K, n), optional ``active``
    (K, n), optional per-round controls ``t_star_r`` / ``n_wait_r``."""
    if not blocks:
        raise RuntimeError(
            "no telemetry captured for this run: call "
            "repro.obs.spans.enable() before running, then attribution()")
    times = np.concatenate([np.asarray(b["times"], np.float64)
                            for b in blocks])
    active = np.concatenate(
        [np.asarray(b["active"], np.float64) if b.get("active") is not None
         else np.ones_like(np.asarray(b["times"], np.float64))
         for b in blocks])
    has_tsr = any(b.get("t_star_r") is not None for b in blocks)
    has_nwr = any(b.get("n_wait_r") is not None for b in blocks)
    t_star_r = (np.concatenate([np.asarray(b["t_star_r"], np.float64)
                                for b in blocks]) if has_tsr else None)
    n_wait_r = (np.concatenate([np.asarray(b["n_wait_r"], np.int64)
                                for b in blocks]) if has_nwr else None)
    deadline = round_deadlines(step_kind, times, active, t_star=t_star,
                               t_ideal=t_ideal, n_wait=n_wait,
                               t_star_r=t_star_r, n_wait_r=n_wait_r)
    return compute_attribution(
        times, active, deadline, loads=loads, m=m,
        coded=step_kind in ("coded", "adaptive_coded"), k=k)
