"""Run telemetry: span timers, per-round event journal, straggler attribution.

Everything here is observational — enabling telemetry never touches an RNG
stream or changes a trajectory (pinned by tests/test_obs.py).
"""
from repro.obs import spans
from repro.obs.attribution import (
    Attribution,
    attribution_from_blocks,
    compute_attribution,
    round_deadlines,
)
from repro.obs.events import (
    EVENTS_NAME,
    RunJournal,
    histories_equal,
    history_from_journal,
    load_events,
)
from repro.obs.spans import SPANS_NAME, collecting, disable, enable, enabled, span, totals

__all__ = [
    "spans",
    "span",
    "enable",
    "disable",
    "enabled",
    "totals",
    "collecting",
    "SPANS_NAME",
    "RunJournal",
    "EVENTS_NAME",
    "load_events",
    "history_from_journal",
    "histories_equal",
    "Attribution",
    "attribution_from_blocks",
    "compute_attribution",
    "round_deadlines",
]
