"""Append-only JSONL run journal: one event per completed round.

`RunJournal` lives next to a run's checkpoints (``root/<run_id>/
events.jsonl``) and records, per round: the simulated round time and
cumulative wall-clock, the return count, the non-finite-guard mask count,
the divergence-guard skip flag, the effective lr backoff multiplier, and
the evaluated loss/accuracy (null when not evaluated that round).
Hierarchical runs additionally record the per-shard deadlines
``t_star_s``.

Every quantity journaled is *simulated* or derived from the run's state —
no host timestamps, no environment — so the journal is a deterministic
function of (spec, seed): two runs of the same spec and seed produce
byte-identical files (pinned by tests/test_obs.py).  Lines are serialized
with sorted keys and compact separators, and each block's lines are
written with a single ``O_APPEND`` write, so concurrent readers never see
a torn line from a live writer.

The journal is rebuilt from `RunState` accumulators, which carry the full
round history from round 0 — so `sync` after any block (including the
first block after a resume) can fill whatever suffix is missing, and a
journal lost with its directory is fully regrown by the resumed run.
`history_from_journal` reconstructs the exact ``FedResult.history`` list
the runtime would have produced (same floats — JSON round-trips Python
floats exactly).
"""
from __future__ import annotations

import json
import math
import os

import numpy as np

from repro.obs import spans as obs_spans

__all__ = ["RunJournal", "EVENTS_NAME", "load_events",
           "history_from_journal", "histories_equal"]

#: journal filename inside a run directory
EVENTS_NAME = "events.jsonl"


def _resolve(path: str) -> str:
    """A directory means ``<dir>/events.jsonl``; a file path is itself."""
    if path.endswith(".jsonl"):
        return path
    return os.path.join(path, EVENTS_NAME)


def _null_if_nan(value: float):
    value = float(value)
    return None if math.isnan(value) else value


def _nan_if_null(value) -> float:
    return float("nan") if value is None else float(value)


def _encode(event: dict) -> bytes:
    # sorted keys + compact separators + allow_nan=False: the byte
    # serialization is a pure function of the event values (NaN must be
    # mapped to null by the caller, never emitted)
    return (json.dumps(event, sort_keys=True, separators=(",", ":"),
                       allow_nan=False) + "\n").encode()


class RunJournal:
    """One run's ``events.jsonl``: appends per-block, trims on resume.

    ``path`` is the run directory (conventionally the checkpoint dir) or
    the journal file itself.  Opening an existing journal parses it and
    truncates any torn trailing line (a kill mid-append), so appends
    always extend valid content.
    """

    def __init__(self, path: str):
        self.path = _resolve(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._events: "list[dict]" = []
        self._load_existing()

    @property
    def rounds_logged(self) -> int:
        return len(self._events)

    def _load_existing(self) -> None:
        """Parse the file into memory, keeping only the valid contiguous
        round prefix (0, 1, 2, ...); truncate the file past it."""
        try:
            with open(self.path, "rb") as fh:
                raw = fh.read()
        except FileNotFoundError:
            return
        valid_len = 0
        events = []
        for line in raw.splitlines(keepends=True):
            if not line.endswith(b"\n"):
                break                      # torn tail from a mid-append kill
            try:
                event = json.loads(line)
            except json.JSONDecodeError:
                break
            if not isinstance(event, dict) \
                    or event.get("round") != len(events):
                break                      # gap or out-of-order: stop here
            events.append(event)
            valid_len += len(line)
        self._events = events
        if valid_len != len(raw):
            with open(self.path, "r+b") as fh:
                fh.truncate(valid_len)

    # ----------------------------------------------------------- writing
    def reset_to(self, rounds: int) -> None:
        """Keep only events for rounds < `rounds` (atomic rewrite).

        Called before resuming: a journal ahead of the restored state
        (blocks computed, journaled, then lost to a checkpoint rollback)
        is trimmed back so `sync` re-appends the authoritative replay.
        A fresh run calls ``reset_to(0)``.
        """
        rounds = int(rounds)
        if rounds >= len(self._events):
            return
        self._events = self._events[:rounds]
        data = b"".join(_encode(e) for e in self._events)
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as fh:
            fh.write(data)
        os.replace(tmp, self.path)

    def append_events(self, events: "list[dict]") -> None:
        """Append pre-built events (one O_APPEND write for the batch)."""
        if not events:
            return
        for k, event in enumerate(events):
            if event.get("round") != len(self._events) + k:
                raise ValueError(
                    f"journal {self.path!r} holds rounds 0.."
                    f"{len(self._events) - 1}; refusing non-contiguous "
                    f"append of round {event.get('round')!r}")
        data = b"".join(_encode(e) for e in events)
        with obs_spans.span("journal/append"):
            fd = os.open(self.path,
                         os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
            try:
                os.write(fd, data)
            finally:
                os.close(fd)
        self._events.extend(events)

    def sync(self, exp, state) -> int:
        """Append one event per round in [rounds_logged, rounds_done).

        `exp` is the `Experiment` / `HierExperiment` that produced
        `state` (the journal needs its setup_time and, for hier runs,
        the per-shard deadlines).  Returns the number of events
        appended.  Only single-trajectory modes journal — one event per
        round has no meaning for a vmapped realization stack.
        """
        r1 = int(state.rounds_done)
        r0 = self.rounds_logged
        if r1 <= r0:
            return 0
        self.append_events(events_from_state(exp, state, r0, r1))
        return r1 - r0


def events_from_state(exp, state, r0: int, r1: int) -> "list[dict]":
    """Events for global rounds [r0, r1) from a `RunState`'s accumulators
    (which always cover the run from round 0)."""
    if state.mode not in ("single", "hier"):
        raise ValueError(
            f"run journals record single-trajectory runs; mode "
            f"{state.mode!r} has {state.n_realizations} realizations")
    from repro.core.fed_runtime import LR_BACKOFF
    t_rounds = np.asarray(state.t_rounds, np.float64)
    wall = float(exp.setup_time) + np.cumsum(t_rounds)
    n_ret = np.asarray(state.n_ret)
    if state.mode == "hier" or state.n_masked is None:
        n_masked = np.zeros(r1, np.int64)
        skipped = np.zeros(r1, np.int64)
    else:
        n_masked = np.asarray(state.n_masked, np.int64)
        skipped = np.asarray(state.skipped, np.int64)
    # effective lr multiplier AFTER each round: the divergence guard backs
    # off by LR_BACKOFF per skipped round (fed_runtime.build_step)
    lr_scale = LR_BACKOFF ** np.cumsum(skipped, dtype=np.float64)
    t_star_s = None
    if state.mode == "hier":
        t_star_s = [float(p.t_star) for p in exp.plans]
    events = []
    for r in range(r0, r1):
        if state.mode == "single" and state.collect:
            loss = _null_if_nan(state.losses[r])
            acc = _null_if_nan(state.accs[r])
        else:
            loss = acc = None
        event = {
            "round": int(r),
            "t_round_s": float(t_rounds[r]),
            "wall_clock_s": float(wall[r]),
            "returned": int(n_ret[r]),
            "n_masked": int(n_masked[r]),
            "skipped": int(skipped[r]),
            "lr_scale": float(lr_scale[r]),
            "loss": loss,
            "accuracy": acc,
        }
        if t_star_s is not None:
            event["t_star_s"] = t_star_s
        events.append(event)
    return events


# --------------------------------------------------------------- loading
def load_events(path: str) -> "list[dict]":
    """Read a journal -> list of round events (valid contiguous prefix).
    Read-only: a torn tail is skipped here, never truncated on disk."""
    resolved = _resolve(path)
    try:
        with open(resolved, "rb") as fh:
            raw = fh.read()
    except FileNotFoundError:
        raise FileNotFoundError(
            f"no run journal at {resolved!r}") from None
    events = []
    for line in raw.splitlines(keepends=True):
        if not line.endswith(b"\n"):
            break
        try:
            event = json.loads(line)
        except json.JSONDecodeError:
            break
        if not isinstance(event, dict) or event.get("round") != len(events):
            break
        events.append(event)
    return events


def history_from_journal(path: str) -> list:
    """Reconstruct the `FedResult.history` list (of `RoundLog`) a
    completed run produced, exactly — floats round-trip through JSON
    bit-for-bit, nulls come back as the runtime's NaN placeholders."""
    from repro.core.fed_runtime import RoundLog
    return [RoundLog(iteration=int(e["round"]),
                     wall_clock=float(e["wall_clock_s"]),
                     returned=int(e["returned"]),
                     loss=_nan_if_null(e["loss"]),
                     accuracy=_nan_if_null(e["accuracy"]),
                     n_masked=int(e["n_masked"]),
                     skipped=int(e["skipped"]))
            for e in load_events(path)]


def histories_equal(a: list, b: list) -> bool:
    """Field-exact `RoundLog` list comparison (NaN == NaN, unlike the
    dataclass ``==``, which inherits IEEE NaN inequality)."""
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        for field in ("iteration", "returned", "n_masked", "skipped"):
            if getattr(ra, field) != getattr(rb, field):
                return False
        for field in ("wall_clock", "loss", "accuracy"):
            va, vb = getattr(ra, field), getattr(rb, field)
            if math.isnan(va) and math.isnan(vb):
                continue
            if va != vb:
                return False
    return True
