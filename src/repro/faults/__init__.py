"""Fault injection and chaos tooling for the CodedFedL runtime.

CodedFedL's coding layer is built to compensate for *missing* client
work (stragglers, erasures — `repro.net`).  This subsystem injects the
*wrong*-work failure modes a real MEC deployment adds on top — non-finite
client gradient returns, stale-update replay, corrupted parity uploads,
checkpoint truncation/bit-flips, and service block crashes — so the
runtime's graceful-degradation machinery (`fed_runtime.build_step`'s
non-finite guard, `checkpoint.io`'s digest verification,
`launch.service`'s retry/backoff) can be exercised deterministically.

`FaultProfile` declares a fault mix the way `repro.net.channel
.ChannelProfile` declares network dynamics: a frozen, JSON-round-tripping
dataclass addressable by name (`FAULT_PROFILES`) from
``ExperimentSpec.fault_profile``, with per-knob overrides in
``fault_params``.  Per-round/per-client fault draws come from a dedicated
RNG stream (`sample_fault_rows`) that is independent of both the delay
and channel-trace streams, so toggling faults never shifts the network
realization a run faces.
"""
from repro.faults.profile import (FAULT_PROFILES, FaultProfile,  # noqa: F401
                                  get_fault_profile)
from repro.faults.inject import (CODE_CLEAN, CODE_INF, CODE_NAN,  # noqa: F401
                                 CODE_STALE, InjectedCrashError,
                                 bitflip_file, corrupt_checkpoint,
                                 sample_fault_rows, truncate_file)

__all__ = [
    "FaultProfile", "FAULT_PROFILES", "get_fault_profile",
    "InjectedCrashError", "sample_fault_rows", "corrupt_checkpoint",
    "truncate_file", "bitflip_file",
    "CODE_CLEAN", "CODE_NAN", "CODE_INF", "CODE_STALE",
]
