"""Declarative fault profiles, registered like `CHANNEL_PROFILES`.

A `FaultProfile` names the failure modes injected into a run and their
per-round probabilities.  Two layers consume it:

  * **Return faults** (``nan_prob`` / ``stale_prob`` /
    ``parity_corrupt_prob``) are injected into the compiled training step
    by `repro.core.fed_runtime` — a faulty client uploads a non-finite
    gradient, replays its update from a stale model iterate, or (coded
    schemes) the shared parity contribution arrives corrupted.  Corruption
    is modeled as non-finite garbage, which is exactly what the runtime's
    non-finite guard can detect; arbitrary finite Byzantine values are out
    of scope (they need coding-theoretic decoding, not a guard).
  * **Infrastructure faults** (``crash_prob`` / ``ckpt_corrupt_prob``)
    are injected by `repro.launch.service.ExperimentService` — a block
    computation dies mid-flight (SIGKILL-style: no state advance, no
    checkpoint) or a just-written checkpoint is truncated/bit-flipped on
    disk, exercising retry/backoff and the digest-verified restore
    fallback.

All knobs default to 0, so ``FaultProfile()`` (the ``"none"`` profile) is
benign and — because the fault RNG stream is separate from the delay and
channel-trace streams, and a benign profile compiles to the exact
fault-free step — bit-identical to running without a profile at all.
"""
from __future__ import annotations

import dataclasses

_NAN_KINDS = ("nan", "inf", "mix")
_CKPT_KINDS = ("truncate", "bitflip", "mix")


@dataclasses.dataclass(frozen=True)
class FaultProfile:
    """Declarative fault-mix knobs (all OFF by default = benign)."""
    # non-finite client gradient returns: each client's upload is
    # independently corrupted with `nan_prob` per round; `nan_kind`
    # selects NaN, +inf, or an even mix
    nan_prob: float = 0.0
    nan_kind: str = "nan"
    # stale-update replay: the client returns its gradient computed at
    # the PREVIOUS round's model iterate (mutually exclusive with a
    # non-finite fault on the same client-round)
    stale_prob: float = 0.0
    # corrupted parity contribution (coded schemes): the shared parity
    # gradient for the round arrives non-finite and must be masked —
    # the round degrades to the returned clients only
    parity_corrupt_prob: float = 0.0
    # service-level: probability a scheduled block crashes before
    # computing (retried with backoff by the ExperimentService)
    crash_prob: float = 0.0
    # service-level: probability a just-written checkpoint is corrupted
    # on disk, and how ("truncate" | "bitflip" | "mix")
    ckpt_corrupt_prob: float = 0.0
    ckpt_corrupt_kind: str = "truncate"

    def __post_init__(self):
        for name in ("nan_prob", "stale_prob", "parity_corrupt_prob",
                     "crash_prob", "ckpt_corrupt_prob"):
            val = getattr(self, name)
            if not (isinstance(val, (int, float)) and 0.0 <= val <= 1.0):
                raise ValueError(f"{name}={val!r} must lie in [0, 1]")
        if self.nan_kind not in _NAN_KINDS:
            raise ValueError(f"nan_kind={self.nan_kind!r} must be one of "
                             f"{_NAN_KINDS}")
        if self.ckpt_corrupt_kind not in _CKPT_KINDS:
            raise ValueError(f"ckpt_corrupt_kind="
                             f"{self.ckpt_corrupt_kind!r} must be one of "
                             f"{_CKPT_KINDS}")

    # ------------------------------------------------------------ properties
    @property
    def has_return_faults(self) -> bool:
        """True if the compiled step must inject per-round faults."""
        return (self.nan_prob > 0.0 or self.stale_prob > 0.0
                or self.parity_corrupt_prob > 0.0)

    @property
    def has_service_faults(self) -> bool:
        """True if the ExperimentService must inject infra faults."""
        return self.crash_prob > 0.0 or self.ckpt_corrupt_prob > 0.0

    @property
    def is_benign(self) -> bool:
        return not (self.has_return_faults or self.has_service_faults)

    # ------------------------------------------------------------ round trip
    def to_dict(self) -> dict:
        """Plain-JSON dict; `from_dict(to_dict(p)) == p`."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultProfile":
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(
                f"unknown FaultProfile field(s) {sorted(unknown)}")
        return cls(**d)


#: Named profiles addressable from ``ExperimentSpec.fault_profile`` (and
#: `ExperimentService`'s chaos knobs).  "none" is the benign identity;
#: the rest are the fault mixes the resilience bench and chaos tests run.
FAULT_PROFILES: dict[str, FaultProfile] = {
    # benign: compiles to the exact fault-free step
    "none": FaultProfile(),
    # flaky clients: ~8% of uploads per round come back NaN
    "flaky_clients": FaultProfile(nan_prob=0.08),
    # Byzantine-lite mix: occasional NaN/inf plus stale-update replay
    "byzantine_lite": FaultProfile(nan_prob=0.05, nan_kind="mix",
                                   stale_prob=0.10),
    # the shared parity upload is corrupted ~15% of rounds (coded
    # schemes degrade those rounds to the returned clients only)
    "corrupt_parity": FaultProfile(parity_corrupt_prob=0.15),
    # infrastructure only: blocks crash ~30% of the time (service
    # retry/backoff territory), checkpoints survive
    "crash_loop": FaultProfile(crash_prob=0.3),
    # infrastructure only: flaky disk — half the checkpoints written are
    # truncated or bit-flipped (digest-verified fallback territory)
    "bad_disk": FaultProfile(ckpt_corrupt_prob=0.5,
                             ckpt_corrupt_kind="mix"),
    # everything at once: the chaos-test profile
    "chaos": FaultProfile(nan_prob=0.05, nan_kind="mix", stale_prob=0.05,
                          parity_corrupt_prob=0.10, crash_prob=0.2,
                          ckpt_corrupt_prob=0.3, ckpt_corrupt_kind="mix"),
}


def get_fault_profile(name: str) -> FaultProfile:
    try:
        return FAULT_PROFILES[name]
    except KeyError:
        raise ValueError(f"unknown fault profile {name!r} (known: "
                         f"{tuple(FAULT_PROFILES)})") from None
