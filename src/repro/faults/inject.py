"""Deterministic fault draws and checkpoint-corruption helpers.

`sample_fault_rows` turns a `FaultProfile` into per-round fault inputs
for the compiled step with a **fixed draw layout**: every fault family
consumes its RNG block whether or not it is enabled (mirroring the
contract of `repro.net.trace.generate_trace_block`), so toggling one
fault kind never shifts the realization of another.  The generator
passed in is the run's dedicated fault stream
(`fed_runtime.Experiment._fault_rng`, seeded off ``fl.seed + 7717``) —
independent of both the delay-draw RNG and the channel-trace streams, so
enabling faults never changes the network a run faces.

The file-corruption helpers model a flaky disk for the chaos tests:
`truncate_file` is a mid-write kill, `bitflip_file` silent media rot.
Both must be *detected* by `repro.checkpoint.io.restore_state`'s sha256
digest verification, never silently restored.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from repro.faults.profile import FaultProfile

#: per-client fault codes in the compiled step's per-round fault row
CODE_CLEAN = 0
CODE_NAN = 1       # upload is NaN garbage
CODE_INF = 2       # upload is inf garbage
CODE_STALE = 3     # upload replays the previous round's iterate


class InjectedCrashError(RuntimeError):
    """A service block crash injected by a `FaultProfile.crash_prob`."""


def sample_fault_rows(profile: FaultProfile, rng: np.random.Generator,
                      rounds: int, n: int) -> "tuple[np.ndarray, np.ndarray]":
    """(codes, parity_bad) fault inputs for `rounds` rounds of n clients.

    codes: (rounds, n) int32 of CODE_* values; parity_bad: (rounds,)
    float32 0/1 flags marking rounds whose parity contribution is
    corrupted.  Fixed layout: four RNG blocks are always drawn in the
    same order (nan hits, nan kind, stale hits, parity hits) regardless
    of which knobs are enabled.
    """
    rounds, n = int(rounds), int(n)
    u_nan = rng.random((rounds, n))
    u_kind = rng.random((rounds, n))
    u_stale = rng.random((rounds, n))
    u_par = rng.random(rounds)

    codes = np.zeros((rounds, n), np.int32)
    if profile.nan_prob > 0.0:
        if profile.nan_kind == "nan":
            kind = np.full((rounds, n), CODE_NAN, np.int32)
        elif profile.nan_kind == "inf":
            kind = np.full((rounds, n), CODE_INF, np.int32)
        else:
            kind = np.where(u_kind < 0.5, CODE_NAN, CODE_INF).astype(np.int32)
        codes = np.where(u_nan < profile.nan_prob, kind, codes)
    if profile.stale_prob > 0.0:
        codes = np.where((codes == CODE_CLEAN)
                         & (u_stale < profile.stale_prob),
                         CODE_STALE, codes).astype(np.int32)
    parity_bad = (u_par < profile.parity_corrupt_prob).astype(np.float32)
    return codes, parity_bad


# ---------------------------------------------------------------------------
# on-disk corruption (chaos tests / flaky-disk injection)
# ---------------------------------------------------------------------------

def truncate_file(path: str, frac: float = 0.5) -> None:
    """Truncate `path` to `frac` of its size (a mid-write kill)."""
    if not (0.0 <= frac < 1.0):
        raise ValueError(f"frac={frac} must lie in [0, 1)")
    size = os.path.getsize(path)
    with open(path, "r+b") as fh:
        fh.truncate(max(0, int(size * frac)))


def bitflip_file(path: str, n_flips: int = 8,
                 rng: Optional[np.random.Generator] = None) -> None:
    """XOR-flip one bit in each of `n_flips` bytes of `path`.

    Without an rng the flip positions are deterministic (spread through
    the middle of the file, where npz member data lives); an rng draws
    them uniformly.
    """
    size = os.path.getsize(path)
    if size == 0:
        return
    if rng is None:
        positions = [(size // 3 + k * max(1, size // (3 * max(n_flips, 1))))
                     % size for k in range(n_flips)]
    else:
        positions = rng.integers(0, size, size=n_flips).tolist()
    with open(path, "r+b") as fh:
        for pos in positions:
            fh.seek(pos)
            byte = fh.read(1)
            fh.seek(pos)
            fh.write(bytes([byte[0] ^ 0x40]))


def corrupt_checkpoint(path: str, kind: str = "truncate",
                       rng: Optional[np.random.Generator] = None) -> str:
    """Corrupt a checkpoint file in place; returns the mode applied.

    kind: "truncate" | "bitflip" | "mix" (an rng — or a coin derived
    from the file size when none is given — picks between the two).
    """
    if kind == "mix":
        if rng is not None:
            kind = "truncate" if rng.random() < 0.5 else "bitflip"
        else:
            kind = "truncate" if os.path.getsize(path) % 2 else "bitflip"
    if kind == "truncate":
        truncate_file(path)
    elif kind == "bitflip":
        bitflip_file(path, rng=rng)
    else:
        raise ValueError(f"unknown corruption kind {kind!r}")
    return kind
