"""mnist_rff — the paper's own workload: RFF kernel regression on MNIST-like
data, (sigma, q) = (5, 2000), c = 10 classes [paper §V-A]."""
from repro.config import RFFConfig

RFF = RFFConfig(q=2000, sigma=5.0)
D_RAW = 784
N_CLASSES = 10
GLOBAL_MINIBATCH = 12000
N_CLIENTS = 30
