"""command-r-plus-104b — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", arch_type="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000, rope_theta=75000000.0,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
