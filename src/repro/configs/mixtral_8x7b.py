"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, swa_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    source="arXiv:2401.04088",
)
