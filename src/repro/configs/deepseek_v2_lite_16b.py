"""deepseek-v2-lite-16b — MLA (kv_lora 512) + 64-routed/2-shared top-6 MoE
[arXiv:2405.04434]."""
from repro.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", arch_type="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=192,
    d_ff=1408, vocab=102400,
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  d_ff_expert=1408),
    source="arXiv:2405.04434",
)
