"""jamba-1.5-large-398b — Mamba+attention 1:7 hybrid with 16-expert top-2 MoE
[arXiv:2403.19887]."""
from repro.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", arch_type="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, every_n_layers=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, attn_every_n=8),
    source="arXiv:2403.19887",
)
