"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", arch_type="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65536,
    rwkv=RWKVConfig(head_size=64),
    source="arXiv:2404.05892",
)
