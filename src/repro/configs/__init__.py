"""Architecture registry: --arch <id> -> ModelConfig, reduced smoke variants,
and ShapeDtypeStruct input specs for every assigned input shape."""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "yi-6b", "command-r-plus-104b", "internvl2-1b", "mixtral-8x7b",
    "rwkv6-1.6b", "qwen3-4b", "jamba-1.5-large-398b", "deepseek-v2-lite-16b",
    "whisper-base", "qwen3-32b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: 2 layers, d_model<=512, <=4 experts."""
    updates = dict(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512,
    )
    if cfg.rwkv is not None:
        updates["n_heads"] = 4
        updates["n_kv_heads"] = 4
        updates["rwkv"] = dataclasses.replace(cfg.rwkv, head_size=64)
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, d_ff_expert=128,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1))
        updates["d_ff"] = 128
    if cfg.mla is not None:
        updates["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=64, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32)
        updates["head_dim"] = 48
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(cfg.ssm, attn_every_n=2)
        updates["n_layers"] = 2
    if cfg.is_encdec:
        updates["n_encoder_layers"] = 2
        updates["encoder_seq"] = 16
    if cfg.n_prefix_patches:
        updates["n_prefix_patches"] = 4
    if cfg.swa_window:
        updates["swa_window"] = 32
    updates["dtype"] = "float32"        # CPU smoke runs in f32
    return dataclasses.replace(cfg, **updates)


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str,
                batch_override: int | None = None):
    """ShapeDtypeStruct stand-ins for the step function's `batch` argument.

    train/prefill: token batch (+ modality stubs).  decode: one new token.
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B = batch_override or shape.global_batch
    S = shape.seq_len
    f = lambda sh, dt=jnp.int32: jax.ShapeDtypeStruct(sh, dt)
    emb_dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens": f((B, 1))}
    batch = {}
    if cfg.is_encdec:
        batch["frames"] = f((B, cfg.encoder_seq, cfg.d_model), emb_dt)
        batch["tokens"] = f((B, S))
    elif cfg.n_prefix_patches:
        batch["patch_embeds"] = f((B, cfg.n_prefix_patches, cfg.d_model), emb_dt)
        batch["tokens"] = f((B, S - cfg.n_prefix_patches))
    else:
        batch["tokens"] = f((B, S))
    if shape.kind == "train":
        batch["labels"] = f(batch["tokens"].shape)
    return batch


def decode_window(cfg: ModelConfig, shape: ShapeConfig | str) -> int:
    """Window override for long-context decode: sub-quadratic requirement.

    long_500k on archs without native sub-quadratic attention runs the
    sliding-window variant (window 4096) — recorded in DESIGN.md §4.
    Natively windowed archs (mixtral) use their own window everywhere.
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if cfg.swa_window:
        return cfg.swa_window
    if shape.name == "long_500k" and cfg.arch_type not in ("ssm", "hybrid"):
        return 4096
    return 0
