"""whisper-base — encoder-decoder; conv/mel frontend is a stub
[arXiv:2212.04356]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", arch_type="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51865, n_encoder_layers=6, encoder_seq=1500,
    source="arXiv:2212.04356",
)
