"""internvl2-1b — InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821].

VLM carve-out: the vision encoder is a stub; `patch_embeds` are precomputed
(B, 256, d_model) projector outputs consumed as a prefix by the LM.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", arch_type="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151655, n_prefix_patches=256, tie_embeddings=True,
    source="arXiv:2404.16821",
)
