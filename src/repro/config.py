"""Configuration dataclasses for the repro framework.

Everything is a plain frozen dataclass so configs hash/compare cleanly and
can be used as jit static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    num_shared_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0            # expert hidden size (may differ from dense d_ff)
    capacity_factor: float = 1.25
    every_n_layers: int = 1         # apply MoE FFN every n-th layer (1 = all)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 style selective SSM (used by jamba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model/16)
    attn_every_n: int = 8           # hybrid: 1 attention layer per n layers


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64            # rank of data-dependent decay LoRA
    shift_lora: int = 32            # rank of data-dependent token-shift LoRA


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 10000.0
    swa_window: int = 0             # 0 = full attention; >0 sliding window
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # enc-dec (whisper): number of encoder layers; encoder input is a stub
    # of precomputed frame embeddings (audio carve-out).
    n_encoder_layers: int = 0
    encoder_seq: int = 0            # fixed encoder frames (whisper: 1500)
    # vlm: number of prefix patch-embedding positions (stub ViT output)
    n_prefix_patches: int = 0
    # §Perf: pad the embedding/vocab rows up to a multiple of 16 so the
    # logits shard over the `model` axis instead of being all-reduced
    # (MaxText-style).  Padded ids are masked to -inf in the loss.
    pad_vocab: bool = False
    dtype: str = "bfloat16"
    # citation for the config (paper / model card)
    source: str = ""

    @property
    def vocab_padded(self) -> int:
        if not self.pad_vocab:
            return self.vocab
        return -(-self.vocab // 16) * 16

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """True if the arch natively supports O(<seq^2) long-context decode."""
        return self.arch_type in ("ssm", "hybrid") or self.swa_window > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgd"          # sgd | momentum | adam
    learning_rate: float = 6.0      # paper's initial step size for RFF model
    momentum: float = 0.0
    weight_decay: float = 0.0
    l2_reg: float = 9e-6            # paper's lambda
    lr_decay: float = 0.8           # paper: step decay 0.8 at epochs 40, 65
    lr_decay_epochs: Tuple[int, ...] = (40, 65)
    epochs: int = 70
    remat: bool = True
    sharding_policy: str = "fsdp_tp"   # fsdp_tp | tp_only | dp_only


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Federated-learning runtime configuration (paper §V-A defaults)."""
    n_clients: int = 30
    scheme: str = "coded"           # coded | naive | greedy
    psi: float = 0.1                # greedy: wait for (1-psi)*n clients
    delta: float = 0.1              # coded: u_max = delta * m
    # MEC network parameters (paper §V-A)
    max_rate_bps: float = 216e3     # 3 LTE resource blocks
    rate_decay: float = 0.95        # k1
    max_mac_rate: float = 3.072e6   # MAC/s
    mac_decay: float = 0.8          # k2
    alpha: float = 2.0              # compute/memory-access ratio
    p_erasure: float = 0.1          # link erasure probability
    overhead: float = 0.10          # protocol overhead
    bits_per_scalar: int = 32
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RFFConfig:
    """Paper §V-A kernel embedding hyperparameters."""
    q: int = 2000
    sigma: float = 5.0
    seed: int = 1234
