"""Configuration dataclasses for the repro framework.

Everything is a plain frozen dataclass so configs hash/compare cleanly and
can be used as jit static arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    num_shared_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0            # expert hidden size (may differ from dense d_ff)
    capacity_factor: float = 1.25
    every_n_layers: int = 1         # apply MoE FFN every n-th layer (1 = all)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 style selective SSM (used by jamba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 -> ceil(d_model/16)
    attn_every_n: int = 8           # hybrid: 1 attention layer per n layers


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64            # rank of data-dependent decay LoRA
    shift_lora: int = 32            # rank of data-dependent token-shift LoRA


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 10000.0
    swa_window: int = 0             # 0 = full attention; >0 sliding window
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # enc-dec (whisper): number of encoder layers; encoder input is a stub
    # of precomputed frame embeddings (audio carve-out).
    n_encoder_layers: int = 0
    encoder_seq: int = 0            # fixed encoder frames (whisper: 1500)
    # vlm: number of prefix patch-embedding positions (stub ViT output)
    n_prefix_patches: int = 0
    # §Perf: pad the embedding/vocab rows up to a multiple of 16 so the
    # logits shard over the `model` axis instead of being all-reduced
    # (MaxText-style).  Padded ids are masked to -inf in the loss.
    pad_vocab: bool = False
    dtype: str = "bfloat16"
    # citation for the config (paper / model card)
    source: str = ""

    @property
    def vocab_padded(self) -> int:
        if not self.pad_vocab:
            return self.vocab
        return -(-self.vocab // 16) * 16

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """True if the arch natively supports O(<seq^2) long-context decode."""
        return self.arch_type in ("ssm", "hybrid") or self.swa_window > 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgd"          # sgd | momentum | adam
    learning_rate: float = 6.0      # paper's initial step size for RFF model
    momentum: float = 0.0
    weight_decay: float = 0.0
    l2_reg: float = 9e-6            # paper's lambda
    lr_decay: float = 0.8           # paper: step decay 0.8 at epochs 40, 65
    lr_decay_epochs: Tuple[int, ...] = (40, 65)
    epochs: int = 70
    remat: bool = True
    sharding_policy: str = "fsdp_tp"   # fsdp_tp | tp_only | dp_only


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Federated-learning runtime configuration (paper §V-A defaults)."""
    n_clients: int = 30
    scheme: str = "coded"           # coded | naive | greedy
    psi: float = 0.1                # greedy: wait for (1-psi)*n clients
    delta: float = 0.1              # coded: u_max = delta * m
    # MEC network parameters (paper §V-A)
    max_rate_bps: float = 216e3     # 3 LTE resource blocks
    rate_decay: float = 0.95        # k1
    max_mac_rate: float = 3.072e6   # MAC/s
    mac_decay: float = 0.8          # k2
    alpha: float = 2.0              # compute/memory-access ratio
    p_erasure: float = 0.1          # link erasure probability
    overhead: float = 0.10          # protocol overhead
    bits_per_scalar: int = 32
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RFFConfig:
    """Paper §V-A kernel embedding hyperparameters."""
    q: int = 2000
    sigma: float = 5.0
    seed: int = 1234


_ENGINES = ("batched", "legacy")
_KERNEL_BACKENDS = ("xla", "pallas")
_ALLOC_BACKENDS = ("auto", "scalar", "vectorized")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One federated experiment, declaratively.

    The spec composes every knob the paper's experiments vary — scheme,
    coding redundancy, delay profile, mesh, kernel/allocation backends —
    into a single frozen, hashable, JSON-serializable value.  Build the
    runnable deployment with ``repro.api.build_experiment(spec, xs, ys)``;
    the spec itself never holds data arrays, so it round-trips through
    ``to_dict``/``from_dict`` bit-exactly and can be logged next to the
    artifacts it produced.

    ``scheme`` names an entry of the scheme registry
    (``repro.core.schemes``); ``None`` defers to ``fl.scheme``.  Scheme
    names are validated at build time against the live registry (schemes
    may be registered after the spec is created), everything else is
    validated here.  ``scheme_params`` carries scheme-specific knobs (e.g.
    the partial-coding ``u_fraction``) as a sorted tuple of pairs so the
    spec stays hashable; pass a plain dict, it is normalized.
    ``delay_profile`` names a heterogeneity profile
    (``repro.core.delay_model.HETEROGENEITY_PROFILES``) whose k1/k2 knobs
    override the matching ``fl`` fields at build time.  ``mesh`` is a
    device count for a 1-D "clients" mesh (a concrete ``jax.sharding.Mesh``
    is not serializable — pass one to ``build_experiment`` directly).

    ``channel_profile`` names a network-dynamics profile
    (``repro.net.channel.CHANNEL_PROFILES``: Gilbert–Elliott erasure
    bursts, shadowing/MCS rate hopping, compute drift, churn) that the
    engine rolls into a deterministic per-seed trace; ``channel_params``
    overrides individual profile knobs (normalized like
    ``scheme_params``).  The ``"static"`` profile reproduces the
    stationary engine bit-exactly.  ``adapt_every`` is the adaptive
    schemes' re-allocation period in rounds (0 = required only by
    adaptive schemes, which reject it).

    ``checkpoint_every`` makes the run block-structured: the round loop
    executes in blocks of that many rounds, each a resume point where the
    full ``RunState`` can be serialized (0 = one block for the whole
    horizon).  For adaptive schemes it must be a multiple of
    ``adapt_every`` (checked at build time) so re-allocation boundaries
    align with blocks.  ``run_id`` optionally names the run for the
    ``ExperimentService`` checkpoint layout.
    """
    fl: FLConfig = FLConfig()
    train: TrainConfig = TrainConfig()
    rff: Optional[RFFConfig] = None
    scheme: Optional[str] = None
    scheme_params: Tuple[Tuple[str, object], ...] = ()
    delay_profile: Optional[str] = None
    channel_profile: Optional[str] = None
    channel_params: Tuple[Tuple[str, object], ...] = ()
    adapt_every: int = 0
    # fault injection (repro.faults): a named FaultProfile whose
    # return-fault knobs (non-finite uploads, stale replay, parity
    # corruption) are injected into the compiled step, and whose
    # infrastructure knobs (block crashes, checkpoint corruption) the
    # ExperimentService consumes.  `fault_params` overrides individual
    # knobs like `channel_params`.  Fault draws come from a dedicated
    # RNG stream, so toggling faults never shifts the delay/channel
    # realization.
    fault_profile: Optional[str] = None
    fault_params: Tuple[Tuple[str, object], ...] = ()
    # jit-compatible non-finite guard: mask faulty client contributions
    # out of the weighted gradient mask (coded schemes: the parity
    # gradient compensates the masked mass).  On a clean run the guard
    # is an IEEE no-op, so trajectories stay bit-identical; disabling it
    # leaves only the always-on theta-divergence round-skip guard.
    nonfinite_guard: bool = True
    engine: str = "batched"
    kernel_backend: str = "xla"
    alloc_backend: str = "auto"
    mesh: Optional[int] = None
    fused_coded: bool = True
    # fused embed->gradient round path: x_stack passed to build_experiment
    # is RAW (n, l, d) features; phi(X) is computed tile-by-tile inside the
    # gradient kernel each round (kernels.rff_linreg_grad) instead of
    # materializing the (n, l, q) embedded tensor up front.  Requires an
    # `rff` config (it supplies q and the shared Omega/delta seed) and the
    # batched engine.
    fused_embed: bool = False
    secure_aggregation: bool = False
    steps_per_epoch: int = 1
    # resumable runtime: rounds per block between checkpoints (0 = run the
    # whole horizon as one block — the one-shot behaviour), and an optional
    # filesystem-safe identity used by the ExperimentService for per-run
    # checkpoint directories
    checkpoint_every: int = 0
    run_id: Optional[str] = None
    # hierarchical population tier (repro.hier): number of edge-aggregator
    # shards the population is partitioned into, and the per-round
    # Bernoulli client-sampling fraction (its draws come from a dedicated
    # RNG stream, so toggling it never shifts the delay realization; the
    # parity gradient is reweighted to compensate the unsampled mass).
    # The identity values (1, 1.0) keep the flat engine — build_experiment
    # only routes to HierExperiment when either departs from identity.
    hier_shards: int = 1
    sample_fraction: float = 1.0

    def __post_init__(self):
        if self.engine not in _ENGINES:
            raise ValueError(f"unknown engine {self.engine!r} "
                             f"(expected one of {_ENGINES})")
        if self.kernel_backend not in _KERNEL_BACKENDS:
            raise ValueError(f"unknown kernel_backend "
                             f"{self.kernel_backend!r} "
                             f"(expected one of {_KERNEL_BACKENDS})")
        if self.alloc_backend not in _ALLOC_BACKENDS:
            raise ValueError(f"unknown alloc_backend {self.alloc_backend!r} "
                             f"(expected one of {_ALLOC_BACKENDS})")
        if self.mesh is not None and (not isinstance(self.mesh, int)
                                      or self.mesh < 1):
            raise ValueError(f"mesh must be a positive device count or "
                             f"None, got {self.mesh!r}")
        if self.steps_per_epoch < 1:
            raise ValueError(f"steps_per_epoch must be >= 1, "
                             f"got {self.steps_per_epoch}")
        # normalize scheme_params / channel_params (dict / iterable of
        # pairs) to a sorted tuple of pairs so equal specs hash equal
        # regardless of input form
        for field in ("scheme_params", "channel_params", "fault_params"):
            params = getattr(self, field)
            if isinstance(params, dict):
                items = params.items()
            else:
                items = (tuple(p) for p in params)
            norm = tuple(sorted((str(k), v) for k, v in items))
            object.__setattr__(self, field, norm)
        if self.delay_profile is not None:
            from repro.core.delay_model import HETEROGENEITY_PROFILES
            if self.delay_profile not in HETEROGENEITY_PROFILES:
                raise ValueError(
                    f"unknown delay_profile {self.delay_profile!r} "
                    f"(expected one of "
                    f"{tuple(HETEROGENEITY_PROFILES)})")
        if self.adapt_every < 0:
            raise ValueError(
                f"adapt_every must be >= 0, got {self.adapt_every}")
        if (not isinstance(self.checkpoint_every, int)
                or self.checkpoint_every < 0):
            raise ValueError(f"checkpoint_every must be an int >= 0, "
                             f"got {self.checkpoint_every!r}")
        if self.checkpoint_every > 0 and self.engine == "legacy":
            raise ValueError(
                "checkpoint_every requires the batched engine; the legacy "
                "per-client oracle has no block-structured run state")
        if self.fused_embed:
            if self.rff is None:
                raise ValueError(
                    "fused_embed=True requires an RFFConfig (`rff`): the "
                    "fused kernel derives q and the shared Omega/delta "
                    "frequencies from it")
            if self.engine == "legacy":
                raise ValueError(
                    "fused_embed requires the batched engine; the legacy "
                    "per-client oracle consumes pre-embedded features")
            if self.mesh is not None:
                raise ValueError(
                    "fused_embed does not support client-mesh sharding yet")
        if self.run_id is not None:
            import re
            if not (isinstance(self.run_id, str)
                    and re.fullmatch(r"[A-Za-z0-9][A-Za-z0-9._-]{0,127}",
                                     self.run_id)):
                raise ValueError(
                    f"run_id must be a filesystem-safe slug "
                    f"([A-Za-z0-9._-], not starting with '.'), "
                    f"got {self.run_id!r}")
        if self.channel_profile is not None or self.channel_params:
            from repro.net.channel import CHANNEL_PROFILES
            name = self.channel_profile
            if name is not None and name not in CHANNEL_PROFILES:
                raise ValueError(
                    f"unknown channel_profile {name!r} "
                    f"(expected one of {tuple(CHANNEL_PROFILES)})")
            if self.engine == "legacy":
                raise ValueError(
                    "channel dynamics require the batched engine; the "
                    "legacy per-client oracle has no traced-delay path")
            # knob names (and values, via construction) validated eagerly
            # so the error points at the spec
            self.resolved_channel()
        if not isinstance(self.nonfinite_guard, bool):
            raise ValueError(f"nonfinite_guard must be a bool, "
                             f"got {self.nonfinite_guard!r}")
        if self.fault_profile is not None or self.fault_params:
            from repro.faults.profile import FAULT_PROFILES
            name = self.fault_profile
            if name is not None and name not in FAULT_PROFILES:
                raise ValueError(
                    f"unknown fault_profile {name!r} "
                    f"(expected one of {tuple(FAULT_PROFILES)})")
            if self.engine == "legacy":
                raise ValueError(
                    "fault injection requires the batched engine; the "
                    "legacy per-client oracle has no fault path")
            if self.mesh is not None and self.resolved_faults() is not None \
                    and self.resolved_faults().has_return_faults:
                raise ValueError(
                    "return-fault injection does not support client-mesh "
                    "sharding yet (crash/checkpoint faults are fine)")
            # knob names/values validated eagerly, like channel_params
            self.resolved_faults()
        if not isinstance(self.hier_shards, int) \
                or isinstance(self.hier_shards, bool) or self.hier_shards < 1:
            raise ValueError(f"hier_shards must be an int >= 1, "
                             f"got {self.hier_shards!r}")
        if self.hier_shards > self.fl.n_clients:
            raise ValueError(
                f"hier_shards={self.hier_shards} exceeds "
                f"fl.n_clients={self.fl.n_clients} (each edge-aggregator "
                "shard needs at least one client)")
        if not isinstance(self.sample_fraction, (int, float)) \
                or isinstance(self.sample_fraction, bool) \
                or not 0.0 < float(self.sample_fraction) <= 1.0:
            raise ValueError(f"sample_fraction must lie in (0, 1], "
                             f"got {self.sample_fraction!r}")
        if self.hier_active:
            hier = (f"hier_shards={self.hier_shards}, "
                    f"sample_fraction={self.sample_fraction}")
            if self.engine == "legacy":
                raise ValueError(
                    f"the hierarchical tier ({hier}) requires the batched "
                    "engine; the legacy per-client oracle has no sharded "
                    "round")
            if self.channel_profile is not None or self.channel_params:
                raise ValueError(
                    f"the hierarchical tier ({hier}) has no traced-channel "
                    "path yet; drop channel_profile/channel_params "
                    "(population traces: repro.hier.generate_trace_chunked)")
            if self.fault_profile is not None or self.fault_params:
                raise ValueError(
                    f"the hierarchical tier ({hier}) has no fault-injection "
                    "path yet; drop fault_profile/fault_params")
            if self.adapt_every > 0:
                raise ValueError(
                    f"the hierarchical tier ({hier}) runs the static coded "
                    "round per shard; adaptive re-allocation "
                    f"(adapt_every={self.adapt_every}) is not supported")
            if self.fused_embed:
                raise ValueError(
                    f"the hierarchical tier ({hier}) consumes embedded "
                    "client blocks; fused_embed is not supported")
            if self.secure_aggregation:
                raise ValueError(
                    f"the hierarchical tier ({hier}) does not implement "
                    "secure aggregation of shard rows yet")
            if self.mesh is not None:
                raise ValueError(
                    f"the hierarchical tier ({hier}) shards clients over "
                    "edge aggregators, not a device mesh; drop mesh")

    @property
    def hier_active(self) -> bool:
        """True when the spec departs from the flat engine's identity
        configuration and must run on the hierarchical tier."""
        return self.hier_shards > 1 or float(self.sample_fraction) < 1.0

    @property
    def resolved_scheme(self) -> str:
        return self.scheme if self.scheme is not None else self.fl.scheme

    @property
    def scheme_params_dict(self) -> dict:
        return dict(self.scheme_params)

    @property
    def channel_params_dict(self) -> dict:
        return dict(self.channel_params)

    @property
    def fault_params_dict(self) -> dict:
        return dict(self.fault_params)

    def resolved_faults(self):
        """The effective `FaultProfile`, or None when no faults are
        requested.  ``fault_params`` override the named profile's knobs
        (base profile "none" when only overrides are given)."""
        if self.fault_profile is None and not self.fault_params:
            return None
        from repro.faults.profile import FAULT_PROFILES
        base = FAULT_PROFILES[self.fault_profile or "none"]
        if not self.fault_params:
            return base
        try:
            return dataclasses.replace(base, **self.fault_params_dict)
        except TypeError as exc:
            knobs = tuple(f.name for f in dataclasses.fields(base))
            raise ValueError(f"bad fault_params: {exc} "
                             f"(valid knobs: {knobs})") from None

    def resolved_channel(self):
        """The effective `ChannelProfile`, or None when no dynamics are
        requested.  ``channel_params`` override the named profile's knobs
        (base profile "static" when only overrides are given)."""
        if self.channel_profile is None and not self.channel_params:
            return None
        from repro.net.channel import CHANNEL_PROFILES
        base = CHANNEL_PROFILES[self.channel_profile or "static"]
        if not self.channel_params:
            return base
        try:
            return dataclasses.replace(base, **self.channel_params_dict)
        except TypeError as exc:
            knobs = tuple(f.name for f in dataclasses.fields(base))
            raise ValueError(f"bad channel_params: {exc} "
                             f"(valid knobs: {knobs})") from None

    def resolved_fl(self) -> FLConfig:
        """`fl` with the named delay profile's knobs applied."""
        if self.delay_profile is None:
            return self.fl
        from repro.core.delay_model import HETEROGENEITY_PROFILES
        return dataclasses.replace(
            self.fl, **HETEROGENEITY_PROFILES[self.delay_profile])

    # ------------------------------------------------------------- round trip
    def to_dict(self) -> dict:
        """Plain-JSON dict; `from_dict(to_dict(spec)) == spec`."""
        d = dataclasses.asdict(self)
        d["scheme_params"] = dict(self.scheme_params)
        d["channel_params"] = dict(self.channel_params)
        d["fault_params"] = dict(self.fault_params)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        for key, typ in (("fl", FLConfig), ("train", TrainConfig),
                         ("rff", RFFConfig)):
            val = d.get(key)
            if isinstance(val, dict):
                val = dict(val)
                # JSON has no tuples; restore the tuple-typed fields
                for tup_field in ("lr_decay_epochs",):
                    if tup_field in val and val[tup_field] is not None:
                        val[tup_field] = tuple(val[tup_field])
                d[key] = typ(**val)
        valid = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - valid
        if unknown:
            raise ValueError(
                f"unknown ExperimentSpec field(s) {sorted(unknown)} "
                f"(valid fields: {sorted(valid)})")
        return cls(**d)
