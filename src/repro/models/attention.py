"""GQA attention: full / sliding-window, train+prefill+decode, flash-style.

The seq x seq score matrix is never materialized: we lax.scan over KV chunks
with a running (max, denom, acc) online softmax — the TPU-native equivalent
of flash attention, expressed in XLA ops so the multi-pod dry-run lowers
without a custom kernel.  Sliding-window attention uses a rolling cache of
`window` slots for decode (sub-quadratic long-context path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


def init_attn(key, cfg, dtype):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H, hd), dtype),
        "wk": dense_init(ks[1], (D, K, hd), dtype),
        "wv": dense_init(ks[2], (D, K, hd), dtype),
        "wo": dense_init(ks[3], (H, hd, D), dtype),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones((hd,), dtype)
        p["kn"] = jnp.ones((hd,), dtype)
    return p


def _project_q(p, x, positions, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
    return apply_rope(q, positions, cfg.rope_theta)


def _project_kv(p, x, positions, cfg, rope: bool = True):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    if rope:
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def _attend_single(q, k, v, q_pos, k_pos, window: int, causal: bool = True):
    """One-shot attention for q_len == 1 (decode).

    §Perf: the kv-chunk lax.scan forces XLA to materialize (all-gather) a
    seq-sharded KV cache chunk by chunk — 8.7 GB/device/token on the 104B
    decode dry-run.  Written as a single einsum + masked softmax over the
    (sharded) cache length, the partitioner instead replicates the 2 MB
    query, keeps every cache shard local, and all-reduces the small
    softmax partials (EXPERIMENTS.md §Perf iteration 2).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    hd_v = v.shape[-1]
    G = H // K
    scale = 1.0 / np.sqrt(hd)
    qr = q.reshape(B, S, K, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bskgd,btkd->bskgt", qr, k.astype(jnp.float32))
    valid = jnp.broadcast_to(k_pos[None, :] >= 0, (S, k_pos.shape[0]))
    if causal:
        valid = valid & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bskgt,btkd->bskgd", p / jnp.maximum(l, 1e-30),
                     v.astype(jnp.float32))
    return out.reshape(B, S, H, hd_v).astype(q.dtype)


def _flash(q, k, v, q_pos, k_pos, window: int, chunk: int = 512,
           causal: bool = True):
    """Online-softmax attention.

    q: (B, S, H, hd); k, v: (B, T, K, hd); *_pos: (S,), (T,) global positions
    (k_pos may contain -1 for invalid rolling-cache slots).
    Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    if S == 1:                          # decode: one-shot path (see above)
        return _attend_single(q, k, v, q_pos, k_pos, window, causal)
    T, K = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]                  # may differ from hd (MLA)
    G = H // K
    scale = 1.0 / np.sqrt(hd)
    qr = q.reshape(B, S, K, G, hd).astype(jnp.float32) * scale
    chunk = min(chunk, T)
    n_chunks = T // chunk if T % chunk == 0 else -(-T // chunk)
    pad = n_chunks * chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd_v).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)

    def step(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs                     # (B, C, K, hd), (C,)
        s = jnp.einsum("bskgd,bckd->bskgc", qr, k_i.astype(jnp.float32))
        valid = jnp.broadcast_to(p_i[None, :] >= 0, (S, p_i.shape[0]))
        if causal:
            valid = valid & (p_i[None, :] <= q_pos[:, None])
        if window > 0:
            valid = valid & (p_i[None, :] > q_pos[:, None] - window)
        # valid: (S, C) -> broadcast over (B, S, K, G, C)
        s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
        m_i = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_i[..., None])
        corr = jnp.exp(m - m_i)
        l_i = l * corr + jnp.sum(p, axis=-1)
        acc_i = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", p, v_i.astype(jnp.float32))
        return (m_i, l_i, acc_i), None

    m0 = jnp.full((B, S, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, hd_v).astype(q.dtype)


def attn_train(p, x, positions, cfg, window: int = 0):
    """Full-sequence causal attention.  x: (B, S, D), positions: (S,)."""
    q = _project_q(p, x, positions[None, :], cfg)
    k, v = _project_kv(p, x, positions[None, :], cfg)
    win = window if window else cfg.swa_window
    # §Perf: flash-style backward — recompute the kv loop instead of saving
    # the per-chunk online-softmax carries (EXPERIMENTS.md §Perf iter 1b).
    flash = jax.checkpoint(
        lambda q_, k_, v_: _flash(q_, k_, v_, positions, positions, win))
    out = flash(q, k, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_cache(cfg, batch: int, max_seq: int, dtype, window: int = 0):
    """KV cache; rolling when window>0 (sub-quadratic decode)."""
    slots = min(max_seq, window) if window > 0 else max_seq
    K, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, slots, K, hd), dtype),
        "v": jnp.zeros((batch, slots, K, hd), dtype),
        "pos": jnp.full((slots,), -1, jnp.int32),
    }


def attn_prefill(p, x, positions, cfg, cache, window: int = 0):
    """Full forward over the prompt; fills the cache. Returns (out, cache)."""
    q = _project_q(p, x, positions[None, :], cfg)
    k, v = _project_kv(p, x, positions[None, :], cfg)
    win = window if window else cfg.swa_window
    out = _flash(q, k, v, positions, positions, win)
    S = x.shape[1]
    slots = cache["k"].shape[1]
    if slots >= S:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        cp = jax.lax.dynamic_update_slice(
            cache["pos"], positions.astype(jnp.int32), (0,))
    else:                                     # rolling window: keep the tail
        ck = k[:, S - slots:]
        cv = v[:, S - slots:]
        cp = positions[S - slots:].astype(jnp.int32)
    new_cache = {"k": ck, "v": cv, "pos": cp}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def attn_decode(p, x, pos, cfg, cache, window: int = 0):
    """One-token step.  x: (B, 1, D); pos: scalar int32 position."""
    positions = jnp.full((1, 1), pos, jnp.int32)
    q = _project_q(p, x, positions, cfg)
    k, v = _project_kv(p, x, positions, cfg)
    slots = cache["k"].shape[1]
    win = window if window else cfg.swa_window
    slot = jnp.where(win > 0, pos % slots, jnp.minimum(pos, slots - 1))
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    cp = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((1,), pos, jnp.int32), (slot,))
    out = _flash(q, ck, cv, jnp.full((1,), pos, jnp.int32), cp, win)
    new_cache = {"k": ck, "v": cv, "pos": cp}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
