"""Model zoo: dense GQA (+SWA/qk-norm), MLA, MoE, RWKV6, Mamba hybrid,
enc-dec (whisper), VLM prefix-LM.  See model_zoo.build(cfg)."""
from repro.models import model_zoo

__all__ = ["model_zoo"]
