"""Uniform Model facade over the decoder-only stack and the enc-dec stack."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax

from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init_params: Callable
    abstract_params: Callable
    loss_fn: Callable          # (params, batch, window=0, remat=True) -> loss
    prefill: Callable          # (params, batch, window=0) -> (logits, cache)
    decode_step: Callable      # (params, cache, tokens, pos, window=0)
    init_cache: Callable       # (batch, max_seq, window=0) -> cache

    def abstract_cache(self, batch: int, max_seq: int, window: int = 0):
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, max_seq, window))


def build(cfg) -> Model:
    if cfg.is_encdec:
        return Model(
            cfg=cfg,
            init_params=functools.partial(encdec.init_params, cfg),
            abstract_params=functools.partial(encdec.abstract_params, cfg),
            loss_fn=functools.partial(encdec.loss_fn, cfg),
            prefill=functools.partial(encdec.prefill, cfg),
            decode_step=functools.partial(encdec.decode_step, cfg),
            init_cache=functools.partial(encdec.init_cache, cfg),
        )
    return Model(
        cfg=cfg,
        init_params=functools.partial(transformer.init_params, cfg),
        abstract_params=functools.partial(transformer.abstract_params, cfg),
        loss_fn=functools.partial(transformer.loss_fn, cfg),
        prefill=functools.partial(transformer.prefill, cfg),
        decode_step=functools.partial(transformer.decode_step, cfg),
        init_cache=functools.partial(transformer.init_cache, cfg),
    )
