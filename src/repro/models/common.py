"""Shared model building blocks (functional, explicit param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- init utils
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) == 3:            # (D, H, hd) style
        fan_in = shape[0]
    s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- norms
def rms_norm(x, weight, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight + bias).astype(x.dtype)


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S) int."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))              # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    if x.ndim == ang.ndim + 1:                              # head axis present
        ang = ang[..., None, :]                             # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2.0 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ----------------------------------------------------------------- losses
def softmax_cross_entropy(logits, labels, mask=None):
    """logits: (..., V) any float dtype; labels int (...).

    The label log-prob is extracted with an iota==label masked sum instead
    of take_along_axis: a vocab-dim gather on vocab-SHARDED logits makes
    XLA's partitioner replicate the full-batch f32 logits (2x37 GB of
    collectives measured on internvl2 train_4k), while the masked sum stays
    local per shard and psums only the (B, S) partials.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.where(ids == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def swiglu(x, w1, w3, w2):
    """SwiGLU FFN: (.., D) @ (D,F) gates -> (.., D)."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2
