"""Whisper-style encoder-decoder transformer (audio carve-out: the conv/mel
frontend is a stub — `frames` are precomputed frame embeddings (B, T_enc, D)).

Encoder: bidirectional self-attention, GELU FFN, sinusoidal positions.
Decoder: causal self-attention (+ optional sliding window for the long-
context variant) and cross-attention to the encoder output; the decode cache
holds the rolling self-attn KV plus the cross-attn KV computed once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (dense_init, dtype_of, embed_init,
                                 rms_norm, sinusoidal_positions,
                                 softmax_cross_entropy)
from repro.models.attention import _flash


def _init_qkvo(key, cfg, dtype):
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (D, H, hd), dtype),
            "wk": dense_init(ks[1], (D, H, hd), dtype),
            "wv": dense_init(ks[2], (D, H, hd), dtype),
            "wo": dense_init(ks[3], (H, hd, D), dtype)}


def _qkv(p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    return q, k, v


def _init_ffn(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, (cfg.d_model, cfg.d_ff), dtype),
            "w2": dense_init(k2, (cfg.d_ff, cfg.d_model), dtype)}


def _ffn(p, x):
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]


def init_params(cfg, key):
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        ka, kf = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "attn": _init_qkvo(ka, cfg, dtype),
                "ffn": _init_ffn(kf, cfg, dtype)}

    def dec_layer(k):
        ka, kc, kf = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), dtype),
                "ln_x": jnp.ones((cfg.d_model,), dtype),
                "ln2": jnp.ones((cfg.d_model,), dtype),
                "self": _init_qkvo(ka, cfg, dtype),
                "cross": _init_qkvo(kc, cfg, dtype),
                "ffn": _init_ffn(kf, cfg, dtype)}

    return {
        "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model), dtype),
        "enc_in_proj": dense_init(ks[1], (cfg.d_model, cfg.d_model), dtype),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[2], (cfg.d_model, cfg.vocab), dtype),
        "enc": jax.vmap(enc_layer)(
            jax.random.split(ks[3], cfg.n_encoder_layers)),
        "dec": jax.vmap(dec_layer)(jax.random.split(ks[4], cfg.n_layers)),
    }


def abstract_params(cfg):
    import functools
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def encode(cfg, params, frames):
    """frames: (B, T_enc, D) stub embeddings -> (B, T_enc, D)."""
    B, T, D = frames.shape
    x = frames.astype(dtype_of(cfg)) @ params["enc_in_proj"]
    x = x + sinusoidal_positions(T, D).astype(x.dtype)[None]
    pos = jnp.arange(T, dtype=jnp.int32)

    def body(x, p):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(p["attn"], h)
        o = _flash(q, k, v, pos, pos, 0, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + _ffn(p["ffn"], h), None

    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(cfg, p, x, enc_out, pos_q, mode, cache=None, window=0):
    """Decoder layer in train/prefill ('full') or decode mode."""
    new_cache = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p["self"], h)
    if mode in ("train", "prefill"):
        o = _flash(q, k, v, pos_q, pos_q, window)
        if mode == "prefill":
            S = x.shape[1]
            slots = cache["k"].shape[1]
            if slots >= S:
                ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
                cp = jax.lax.dynamic_update_slice(
                    cache["pos"], pos_q.astype(jnp.int32), (0,))
            else:
                ck, cv = k[:, S - slots:], v[:, S - slots:]
                cp = pos_q[S - slots:].astype(jnp.int32)
            new_cache.update({"k": ck, "v": cv, "pos": cp})
    else:                                           # decode: single position
        slots = cache["k"].shape[1]
        p_scalar = pos_q
        slot = jnp.where(window > 0, p_scalar % slots,
                         jnp.minimum(p_scalar, slots - 1))
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        cp = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((1,), p_scalar, jnp.int32), (slot,))
        o = _flash(q, ck, cv, jnp.full((1,), p_scalar, jnp.int32), cp, window)
        new_cache.update({"k": ck, "v": cv, "pos": cp})
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["self"]["wo"])

    # cross attention (encoder output fixed; never causal)
    h = rms_norm(x, p["ln_x"], cfg.norm_eps)
    qx = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["wq"])
    if mode == "decode":
        kx, vx = cache["xk"], cache["xv"]
    else:
        kx = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wk"])
        vx = jnp.einsum("btd,dhk->bthk", enc_out, p["cross"]["wv"])
        if mode == "prefill":
            new_cache.update({"xk": kx, "xv": vx})
    t_pos = jnp.arange(kx.shape[1], dtype=jnp.int32)
    q_pos = (jnp.zeros((qx.shape[1],), jnp.int32) if mode != "decode"
             else jnp.zeros((1,), jnp.int32))
    ox = _flash(qx, kx, vx, q_pos, t_pos, 0, causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", ox, p["cross"]["wo"])
    if mode == "decode":
        new_cache.update({"xk": kx, "xv": vx})

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + _ffn(p["ffn"], h), new_cache


def loss_fn(cfg, params, batch, window: int = 0, remat: bool = True,
            chunked: bool = True):
    """batch: frames (B,T_enc,D), tokens (B,S), labels (B,S)."""
    enc_out = encode(cfg, params, batch["frames"])
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    pos = jnp.arange(S, dtype=jnp.int32)

    def body(x, p):
        out, _ = _dec_layer(cfg, p, x, enc_out, pos, "train", window=window)
        return out, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    x = rms_norm(x, params["dec_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return softmax_cross_entropy(logits, jnp.maximum(labels, 0), mask)


def init_cache(cfg, batch: int, max_seq: int, window: int = 0):
    dtype = dtype_of(cfg)
    slots = min(max_seq, window) if window > 0 else max_seq
    H, hd = cfg.n_heads, cfg.head_dim
    T = cfg.encoder_seq
    one = {"k": jnp.zeros((batch, slots, H, hd), dtype),
           "v": jnp.zeros((batch, slots, H, hd), dtype),
           "pos": jnp.full((slots,), -1, jnp.int32),
           "xk": jnp.zeros((batch, T, H, hd), dtype),
           "xv": jnp.zeros((batch, T, H, hd), dtype)}
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype)
        if a.dtype != jnp.int32
        else jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)


def prefill(cfg, params, batch, window: int = 0, chunked: bool = True):
    enc_out = encode(cfg, params, batch["frames"])
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    B, S = x.shape[0], x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    pos = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, S, window)

    def body(x, xs):
        p, c = xs
        out, nc = _dec_layer(cfg, p, x, enc_out, pos, "prefill", cache=c,
                             window=window)
        return out, nc

    x, cache = jax.lax.scan(body, x, (params["dec"], cache))
    x = rms_norm(x, params["dec_norm"], cfg.norm_eps)
    return x[:, -1, :] @ params["lm_head"], cache


def decode_step(cfg, params, cache, tokens, pos, window: int = 0):
    x = jnp.take(params["embed"], tokens, axis=0)       # (B,1,D)
    x = x + sinusoidal_positions(1, cfg.d_model).astype(x.dtype)[None] * 0 \
        + _pos_embed_at(cfg, pos).astype(x.dtype)

    def body(x, xs):
        p, c = xs
        out, nc = _dec_layer(cfg, p, x, None, pos, "decode", cache=c,
                             window=window)
        return out, nc

    x, cache = jax.lax.scan(body, x, (params["dec"], cache))
    x = rms_norm(x, params["dec_norm"], cfg.norm_eps)
    return x[:, -1, :] @ params["lm_head"], cache


def _pos_embed_at(cfg, pos):
    """Sinusoidal position embedding at a traced position (1, 1, D)."""
    D = cfg.d_model
    i = jnp.arange(D // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2.0 * i / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
